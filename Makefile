# Developer entry points.  The tier-1 invocation is `make test` (the
# default fast lane: pytest.ini deselects tests marked `slow`).
PY := PYTHONPATH=src python

.PHONY: test test-all fuzz cov bench bench-graph bench-check

test:
	$(PY) -m pytest -x -q

test-all:
	$(PY) -m pytest -q -m "slow or not slow"

# Bounded differential fuzz lane (fixed seeds, reproducible): the
# graph/host/hybrid bitwise-parity sweep at CI width.  The default
# `make test` runs the same checker over 10 seeds; this widens it.
fuzz:
	FUZZ_CASES=200 $(PY) -m pytest -q tests/test_fuzz_differential.py

# Fast lane under coverage with the CI floor for the runtime packages
# (requires pytest-cov, see requirements-dev.txt).
cov:
	$(PY) -m pytest -q --cov=repro.sac --cov=repro.jaxsac \
	  --cov-report=term --cov-fail-under=85

bench:
	$(PY) -m benchmarks.run

bench-graph:
	$(PY) -m benchmarks.graph_pipeline

# CI gate: tiny-size update-latency / recompute / speedup check against
# the committed results/bench/BENCH_graph.json baseline (>2x fails),
# plus the headline gate-row assertion — change propagation must beat
# from-scratch wall-clock (paired-median speedup >= 1.0 on the pipeline
# n=2^21 >= 262144, k=1 row).
bench-check:
	$(PY) -m benchmarks.graph_pipeline --check
