# Developer entry points.  The tier-1 invocation is `make test` (the
# default fast lane: pytest.ini deselects tests marked `slow`).
PY := PYTHONPATH=src python

.PHONY: test test-all test-sharded fuzz cov bench bench-graph bench-check \
	bench-serve test-serve test-chaos profile

test:
	$(PY) -m pytest -x -q

test-all:
	$(PY) -m pytest -q -m "slow or not slow"

# Sharded-propagation lane: the mesh parity suite + the fuzz corpus
# under an explicit 8-CPU-device topology (tests/conftest.py defaults
# the flag, but the lane pins it so the device count is not
# environment-dependent).
test-sharded:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	  $(PY) -m pytest -q tests/test_shard.py tests/test_fuzz_differential.py

# Bounded differential fuzz lane (fixed seeds, reproducible): the
# graph/host/hybrid bitwise-parity sweep at CI width.  The default
# `make test` runs the same checker over 10 seeds; this widens it.
fuzz:
	FUZZ_CASES=200 $(PY) -m pytest -q tests/test_fuzz_differential.py

# Fast lane under coverage with the CI floor for the runtime packages
# (requires pytest-cov, see requirements-dev.txt).
cov:
	$(PY) -m pytest -q --cov=repro.sac --cov=repro.jaxsac \
	  --cov-report=term --cov-fail-under=85

bench:
	$(PY) -m benchmarks.run

bench-graph:
	$(PY) -m benchmarks.graph_pipeline

# CI gate: tiny-size update-latency / recompute / speedup check against
# the committed results/bench/BENCH_graph.json baseline (>2x fails),
# plus the headline gate-row assertion — change propagation must beat
# from-scratch wall-clock (paired-median speedup >= 1.0 on the pipeline
# n=2^21 >= 262144, k=1 row) — plus the hybrid-app gate (>= 2x vs pure
# host) and the sharded gate (shards=8 batch update >= 1.0x the
# single-device update on the n=2^21 row, 8 host devices).
bench-check:
	$(PY) -m benchmarks.graph_pipeline --check

# Serving lane: the COW-forest + session-server suites (fork isolation,
# cross-session batching, evict/revive) plus the fork-corpus fuzz case.
test-serve:
	$(PY) -m pytest -q tests/test_forest.py tests/test_serve.py \
	  tests/test_fuzz_differential.py -k fork

# Chaos lane: deterministic fault injection over the serving stack —
# retry/degrade/quarantine ladder, crash-consistent checkpoints,
# supervisor restart budget, device-loss remesh (the `slow` sharded
# integration test included), capped by the multi-session soak that
# asserts surviving sessions bitwise against a fault-free replay.
test-chaos:
	$(PY) -m pytest -q tests/test_chaos.py -m "slow or not slow"

# Serving-layer load benchmark + gates: 8-session batched p99 <= 2x the
# single-session median, fork <= 10% of a full state copy, and the MTTR
# rows — evict-crash-revive p50 <= 50x / quarantine-rollback p50 <= 5x
# the steady-state single-session median.  Rows merge into
# results/bench/BENCH_graph.json (serve-single, serve-multi8,
# serve-fork, serve-mttr).
bench-serve:
	$(PY) -m benchmarks.serve_latency

# Per-level attribution of one deep-traced update (trace="deep"): the
# per-level table on stdout, the structured record at
# results/profile/ATTRIB_pipeline.json, and a Chrome-trace export at
# results/profile/trace_pipeline.json (open in chrome://tracing or
# Perfetto).  See DESIGN.md §Observability.
profile:
	$(PY) -m benchmarks.report --trace results/profile/trace_pipeline.json
