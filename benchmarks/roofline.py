"""Roofline terms per (arch x shape x mesh) from the dry-run artifacts.

Reads ``results/dryrun/<mesh>/<arch>__<shape>[__tag].json`` (produced by
``repro.launch.dryrun``) and derives the three roofline terms on TPU v5e:

    compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_device / HBM_bandwidth       (819 GB/s)
    collective = wire_bytes_per_device / ICI_link_bandwidth (50 GB/s/link)

plus MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill) / 2·N·D_step (decode),
N = active parameter count, and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs that exposes remat / padding / redundancy waste.

Used by ``benchmarks.run`` (the §Roofline table) and the EXPERIMENTS.md
generator.  All terms are *analytic* — this container has no TPU — but
every input comes from the compiled HLO of the production-mesh lowering.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # TPU v5e bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (conservative single-link)

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"

_PARAM_CACHE: Dict[str, Dict[str, float]] = {}


def _params(arch: str) -> Dict[str, float]:
    if arch not in _PARAM_CACHE:
        from repro.configs import get_config
        from repro.models import build_model
        model = build_model(get_config(arch))
        _PARAM_CACHE[arch] = {
            "total": float(model.param_count()),
            "active": float(model.param_count(active_only=True)),
        }
    return _PARAM_CACHE[arch]


def model_flops(arch: str, shape_name: str, kind: str) -> float:
    """Useful model FLOPs per *device* per step (6ND train, 2ND serve)."""
    from repro.models import shape_by_name
    shape = shape_by_name(shape_name)
    n_active = _params(arch)["active"]
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch


def load_cell(arch: str, shape: str, mesh: str = "single",
              tag: str = "") -> Optional[dict]:
    suffix = f"__{tag}" if tag else ""
    f = RESULTS / mesh / f"{arch}__{shape}{suffix}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def terms(rec: dict) -> Optional[dict]:
    """The three roofline terms (seconds/step/device) for one dry-run cell."""
    if rec.get("status") != "ok":
        return None
    hc = rec["hlo_costs"]
    n_dev = rec["devices"]
    t_compute = hc["flops_per_device"] / PEAK_FLOPS
    t_memory = hc["bytes_per_device"] / HBM_BW
    t_collective = hc["collective_wire_bytes_per_device"] / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory),
        ("collective", t_collective), key=lambda kv: kv[1],
    )[0]
    mf_global = model_flops(rec["arch"].replace("-", "_").replace(".", "_"),
                            rec["shape"], rec["kind"])
    mf = mf_global / n_dev
    hlo_flops = hc["flops_per_device"]
    bound = max(t_compute, t_memory, t_collective)
    # Fraction of the achievable roofline this step realizes: useful FLOPs
    # at peak divided by the modeled execution time (the dominant term).
    roofline_frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        tag=rec.get("tag", ""), kind=rec["kind"], devices=n_dev,
        t_compute_s=t_compute, t_memory_s=t_memory,
        t_collective_s=t_collective, dominant=dominant,
        model_flops_per_dev=mf, hlo_flops_per_dev=hlo_flops,
        useful_ratio=(mf / hlo_flops if hlo_flops else 0.0),
        roofline_fraction=roofline_frac,
        hbm_gib_per_dev=(rec["memory"]["argument_bytes"]
                         + rec["memory"]["temp_bytes"]) / 2**30,
    )


def table(mesh: str = "single", tag: str = "") -> List[dict]:
    rows = []
    d = RESULTS / mesh
    if not d.exists():
        return rows
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        if tag and rec.get("tag", "") != tag:
            continue
        if not tag and rec.get("tag", ""):
            continue
        t = terms(rec)
        if t is None:
            rows.append(dict(arch=rec["arch"], shape=rec["shape"],
                             mesh=rec["mesh"], tag=rec.get("tag", ""),
                             status=rec["status"],
                             reason=rec.get("reason", rec.get("error", ""))[:60]))
        else:
            t["status"] = "ok"
            rows.append(t)
    return rows


def format_table(rows: List[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'comp(s)':>9s} {'mem(s)':>9s} "
           f"{'coll(s)':>9s} {'dom':>10s} {'useful':>7s} {'roofl%':>7s} "
           f"{'HBM GiB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:24s} {r['shape']:12s} "
                         f"-- {r['status']}: {r.get('reason','')}")
            continue
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['t_compute_s']:9.4f} "
            f"{r['t_memory_s']:9.4f} {r['t_collective_s']:9.4f} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.3f} "
            f"{100*r['roofline_fraction']:6.1f}% {r['hbm_gib_per_dev']:8.1f}")
    return "\n".join(lines)
