"""Paper Table 10: impact of reader-set size.

W workers each read one of M input modifiables (uniformly assigned) and
write a function of the value to a unique output.  Varying M from 1 to W
sweeps the readers-per-mod ratio from W down to 1: large reader sets
exercise the hashed reader-set representation and the fan-out of the mark
phase, while 1 reader/mod hits the inline single-reader fast path
(Section 5 of the paper).

The update writes every input mod and propagates — all W workers re-run.
"""
from __future__ import annotations

import time
from typing import List

from repro.core import Engine


def run(quick: bool = False) -> List[dict]:
    W = 2_000 if quick else 50_000
    mod_counts = [1, 10, 100, W] if quick else [1, 10, 100, 1000, 10_000, W]
    rows = []
    for M in mod_counts:
        eng = Engine()
        mods = eng.alloc_array(M, "in")
        for i, m in enumerate(mods):
            eng.write(m, i)
        outs = eng.alloc_array(W, "out")

        def worker(i):
            eng.read(mods[i % M], lambda v: eng.write(outs[i], v * 2 + i))

        t0 = time.perf_counter()
        comp = eng.run(lambda: eng.parallel_for(0, W, worker, grain=16))
        t_run = time.perf_counter() - t0

        for i, m in enumerate(mods):
            eng.write(m, i + 1_000_001)
        t1 = time.perf_counter()
        st = comp.propagate()
        t_up = time.perf_counter() - t1
        assert outs[0].peek() == 1_000_001 * 2 + 0

        rows.append(dict(app="readerset_micro", workers=W, mods=M,
                         readers_per_mod=W // M, run_s=round(t_run, 4),
                         update_s=round(t_up, 4),
                         affected=st.affected_readers))
    return rows
