"""Paper Table 9: granularity tradeoff on the string-hash benchmark.

Sweeps the block size g of the Rabin-Karp fingerprint: larger g means a
smaller RSP tree (less memory, lower initial-run overhead) but more
redundant recompute per update.  The paper observes the optimum for k=1
updates at moderate g; this reproduces that curve (wall-clock and tree
size) at CPU-feasible n.
"""
from __future__ import annotations

import time
from typing import List

from repro.core import Engine
from repro.apps import StringHashApp


def run(quick: bool = False) -> List[dict]:
    n = 1 << 14 if quick else 1 << 18
    grains = [16, 32, 64, 128] if quick else [16, 32, 64, 128, 256, 512, 1024, 2048]
    rows = []
    for g in grains:
        app = StringHashApp(n=n, grain=g)
        eng = Engine()
        app.build_input(eng)
        t0 = time.perf_counter()
        comp = app.run(eng)
        t_run = time.perf_counter() - t0
        assert app.output() == app.expected()
        tree = eng.tree_size(comp)

        # k=1 single-character updates, averaged
        reps = 3 if quick else 10
        t1 = time.perf_counter()
        for _ in range(reps):
            app.apply_update(eng, 1)
            comp.propagate()
        t_k1 = (time.perf_counter() - t1) / reps
        assert app.output() == app.expected()

        # k = n/64 characters (batch update)
        kbig = max(n // 64, g)
        t2 = time.perf_counter()
        app.apply_update(eng, kbig)
        comp.propagate()
        t_kbig = time.perf_counter() - t2
        assert app.output() == app.expected()

        rows.append(dict(app="stringhash_granularity", grain=g, n=n,
                         tree_nodes=tree, run_s=round(t_run, 4),
                         update_k1_s=round(t_k1, 6),
                         update_kbig_s=round(t_kbig, 5), kbig=kbig))
    return rows
