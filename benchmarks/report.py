"""Per-level attribution report: where does an update's time go?

Compiles the canonical map -> stencil -> reduce pipeline with
``trace="deep"`` (per-level fenced timings), pushes one k-block edit
through change propagation, and prints a per-level table — nodes,
regime labels, dirty / recomputed / affected blocks, and real per-level
wall-clock — plus the phase breakdown (mark / plan / execute) and the
plan-cache state.  The structured record lands in
``results/profile/ATTRIB_pipeline.json``; ``--trace PATH`` additionally
exports the update as Chrome-trace JSON (load in ``chrome://tracing``
or Perfetto).

Usage:  PYTHONPATH=src python -m benchmarks.report
            [--n 16384] [--block 16] [--k 4] [--backend graph|hybrid]
            [--shards N] [--trace PATH] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from benchmarks.graph_pipeline import _edit, pipeline_program
from repro.obs.chrometrace import chrome_trace, write_chrome_trace

PROFILE_DIR = Path(__file__).resolve().parent.parent / "results" / "profile"


def profile_pipeline(n: int, block: int, k: int, backend: str = "graph",
                     shards=None, seed: int = 0):
    """One deep-traced update of the benchmark pipeline; returns the
    finalized PropagationRecord."""
    prog = pipeline_program(block)
    kw = {} if shards is None else {"shards": shards}
    h = prog.compile(x=n, max_sparse=64, backend=backend,
                     trace="deep", **kw)
    rng = np.random.default_rng(seed)
    data = rng.standard_normal(n).astype(np.float32)
    h.run({"x": jnp.asarray(data)})
    # Edit, revert, re-apply: the first update(new) pays the per-level
    # jit compiles, the revert restores the pre-edit state, and the
    # reported update(new) replays the exact same dirty signature — all
    # per-level executables cached, so per-level ms are steady-state
    # propagation, not compile time.
    old_j, new_j = jnp.asarray(data), jnp.asarray(_edit(rng, data, k, block))
    h.update({"x": new_j})
    h.update({"x": old_j})
    h.update({"x": new_j})
    return h.record


def print_report(rec) -> None:
    d = rec.to_dict()
    print(f"substrate={d['substrate']} mode={d['mode']} "
          f"fenced={d['fenced']} duration={rec.duration_ms:.3f}ms")
    print("phases:")
    for ph in d["phases"]:
        print(f"  {ph['name']:<10s} {ph['dur'] * 1e3:9.3f}ms")
    print(f"{'level':>5s} {'nodes':>5s} {'dirty':>7s} {'recomp':>7s} "
          f"{'affect':>7s} {'ms':>9s}  regimes")
    for lv in d["levels"]:
        if lv["fragment"] is not None:
            continue
        ms = f"{lv['ms']:.3f}" if lv["ms"] is not None else "-"
        regimes = ", ".join(f"{k}x{v}" for k, v in lv["regimes"].items())
        print(f"{lv['level']:>5d} {lv['nodes']:>5d} {lv['dirty']:>7d} "
              f"{lv['recomputed']:>7d} {lv['affected']:>7d} {ms:>9s}  "
              f"{regimes}")
    if d["plan_cache"]:
        print("plan_cache:", d["plan_cache"])
    if d["collectives"]:
        print("collectives:", d["collectives"])
    ctrs = {k: v for k, v in d["counters"].items()
            if not isinstance(v, list)}
    print("counters:", ctrs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 14)
    ap.add_argument("--block", type=int, default=16)
    ap.add_argument("--k", type=int, default=4,
                    help="dirty input blocks per update")
    ap.add_argument("--backend", choices=("graph", "hybrid"),
                    default="graph")
    ap.add_argument("--shards", type=int, default=None)
    ap.add_argument("--trace", type=Path, default=None,
                    help="also export Chrome-trace JSON to this path")
    ap.add_argument("--out", type=Path,
                    default=PROFILE_DIR / "ATTRIB_pipeline.json")
    args = ap.parse_args()

    rec = profile_pipeline(args.n, args.block, args.k,
                           backend=args.backend, shards=args.shards)
    print_report(rec)

    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(rec.to_dict(), indent=2))
    print(f"  -> {args.out}")
    if args.trace is not None:
        write_chrome_trace(chrome_trace([rec]), args.trace)
        print(f"  -> {args.trace}")


if __name__ == "__main__":
    main()
