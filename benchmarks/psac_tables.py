"""Paper Tables 1-8: per-application PSAC benchmarks.

For each of the six applications this measures, in the structure of the
paper's Section 6:

  * the static baseline (same program, ``StaticEngine``: no RSP tree, no
    reader tracking) — wall time + counted work/span,
  * the PSAC initial run — wall time, work/span, and the initial-run
    overhead ratio,
  * dynamic updates over a sweep of batch sizes k — wall time, counted
    work, work savings (WS), and total speedup,
  * RSP tree size / live mods (Table 7) and garbage-collection cost
    (Table 8).

This container exposes one CPU core, so parallel *self-speedup* cannot be
wall-clock-measured.  The engine counts exact work/span under the RSP
structure (span of a P node = max of children), so we report the
simulated p-processor time via Brent's bound W/p + s — the model the
paper's own analysis is stated in (its Section 1.3 cites exactly this
scheduling theorem).  Measured quantities (wall seconds, WS ratios,
crossover points) are real; SU columns are work/span-derived and labeled
``sim``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from repro.core import Engine, StaticEngine
from repro.apps import APPS

P_SIM = 32  # simulated processor count (the paper's machine: 32 cores)


def _wall(f):
    t0 = time.perf_counter()
    out = f()
    return time.perf_counter() - t0, out


# Benchmark sizes: "full" targets ~tens of seconds per app on this
# container's Python engine; "quick" keeps the whole suite under ~1 min
# for CI.  ks are the paper's powers-of-ten batch sizes, capped at n.
SIZES: Dict[str, Dict] = {
    "spellcheck": dict(full=dict(n=2000), quick=dict(n=128),
                       ks=[1, 10, 100, 1000, 2000]),
    "raytracer": dict(full=dict(width=1024, n_circles=12, n_tiles=16),
                      quick=dict(width=96, n_circles=6, n_tiles=4),
                      ks=[1, 2, 6]),
    "stringhash": dict(full=dict(n=1 << 20, grain=64),
                       quick=dict(n=1 << 12, grain=32),
                       ks=[1, 100, 10_000, 100_000, 1 << 20]),
    "sequence": dict(full=dict(n=4096), quick=dict(n=128),
                     ks=[1, 10, 100, 1000, 4096]),
    "trees": dict(full=dict(n=2048), quick=dict(n=128),
                  ks=[1, 10, 100, 1000, 2048]),
    "filter": dict(full=dict(n=8191), quick=dict(n=255),
                   ks=[1, 10, 100, 1000, 8191]),
}


def bench_app(name: str, *, quick: bool = False) -> List[dict]:
    """Run the full Table-1..8 protocol for one app; returns CSV rows."""
    spec = SIZES[name]
    kwargs = spec["quick" if quick else "full"]
    n_elems = list(kwargs.values())[0]
    ks = [k for k in spec["ks"] if k <= n_elems] or [1]
    if quick:
        ks = ks[:3]
    rows: List[dict] = []

    # ---- static baseline -------------------------------------------------
    app = APPS[name](**kwargs)
    s_eng = StaticEngine()
    app.build_input(s_eng)
    t_static, _ = _wall(lambda: app.run(s_eng))
    st = s_eng.stats
    static_sim_su = st.simulated_time(1) / max(st.simulated_time(P_SIM), 1e-12)
    rows.append(dict(app=name, phase="static", k="", wall_s=t_static,
                     work=st.work, span=st.span,
                     sim_su_p32=round(static_sim_su, 2)))

    # ---- PSAC initial run --------------------------------------------------
    app = APPS[name](**kwargs)          # fresh instance: same RNG stream
    eng = Engine()
    app.build_input(eng)
    t_init, comp = _wall(lambda: app.run(eng))
    ist = comp.initial_stats
    assert app.output() == app.expected(), f"{name}: initial run wrong"
    init_sim_su = ist.simulated_time(1) / max(ist.simulated_time(P_SIM), 1e-12)
    rows.append(dict(app=name, phase="psac_initial", k="", wall_s=t_init,
                     work=ist.work, span=ist.span,
                     sim_su_p32=round(init_sim_su, 2),
                     overhead_wall=round(t_init / max(t_static, 1e-9), 2),
                     overhead_work=round(ist.work / max(st.work, 1), 2)))

    # ---- dynamic updates ------------------------------------------------------
    for k in ks:
        app.apply_update(eng, k)
        t_up, pst = _wall(lambda: comp.propagate())
        assert app.output() == app.expected(), f"{name}: k={k} update wrong"
        ws = t_static / max(t_up, 1e-9)
        su = pst.simulated_time(1) / max(pst.simulated_time(P_SIM), 1e-12)
        rows.append(dict(app=name, phase="psac_update", k=k,
                         wall_s=t_up, work=pst.work, span=pst.span,
                         ws=round(ws, 2), sim_su_p32=round(su, 2),
                         total=round(ws * su, 2),
                         affected=pst.affected_readers))

    # ---- Table 7: memory / tree size --------------------------------------
    rows.append(dict(app=name, phase="tree_size", k="",
                     tree_nodes=eng.tree_size(comp),
                     live_mods=eng.live_mods,
                     nodes_per_elem=round(eng.tree_size(comp) / n_elems, 2)))

    # ---- Table 8: garbage collection ---------------------------------------
    t_gc, collected = _wall(lambda: eng.collect())
    rows.append(dict(app=name, phase="gc", k="", wall_s=t_gc,
                     collected=collected,
                     gc_vs_initial=round(t_gc / max(t_init, 1e-9), 4)))
    return rows


def run(quick: bool = False, apps: Optional[Sequence[str]] = None) -> List[dict]:
    rows = []
    for name in (apps or APPS):
        rows.extend(bench_app(name, quick=quick))
    return rows
