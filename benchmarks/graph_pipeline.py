"""Graph-runtime benchmark: recomputed blocks + update latency across k.

Traces three programs through the ``@sac.incremental`` frontend —

  * ``pipeline``   — map -> stencil -> balanced reduce (>= 3 dag levels
    mixing elementwise and tree work), the canonical static block program;
  * ``stringhash`` — the Rabin-Karp host app as a traced program;
  * ``causal``     — a carry-monoid causal op (int32 prefix statistics),
    the block-skip cached-carry path (``kernels.dirty_causal``);

then, for a sweep of edit sizes k (dirty input blocks), measures

  * ``recomputed``      — dag blocks actually recomputed (W_delta),
  * ``total_blocks``    — dag blocks a from-scratch run recomputes,
  * ``update_ms``       — jitted ``update`` wall-clock,
  * ``scratch_ms``      — jitted from-scratch ``run`` wall-clock,
  * ``work_savings``    — total_blocks / recomputed,
  * ``speedup``         — scratch_ms / update_ms,

the graph-runtime analogue of the paper's work-savings / self-speedup
tables.  Results print as rows and merge into
``results/bench/BENCH_graph.json`` (keyed by app/n/block/k).

``--check`` runs the tiny size and compares update latency, recompute
counts, AND speedup against the committed baseline rows instead of
overwriting them; it then runs the gate row (pipeline, n = GATE_N =
2^21 >= 262144, k_blocks = 1) and asserts a paired-median
``speedup >= 1.0`` — the paper's headline claim that change propagation
beats from-scratch in wall-clock, enforced in CI (`make bench-check`) —
plus the hybrid-runtime gate: the ``trees``/``filter`` apps' hybrid
update latency must beat the pure host engine by >= 2x at the benched
sizes (``HYBRID_APPS``; rows ``trees-hybrid`` / ``filter-hybrid``,
where ``scratch_ms`` is the pure-host update being displaced), plus the
sharded gate: on the same pipeline gate row the 8-host-device
``shards=8`` update must be at least as fast as the single-device
update (paired-median >= 1.0; rows ``pipeline-sh{1,2,4,8}`` hold the
scaling curve, ``--sharded`` regenerates it).

Usage:  PYTHONPATH=src python -m benchmarks.graph_pipeline
            [--size tiny|quick|medium|full] [--sharded] [--check]
            [--threshold 2.0]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

# The sharded rows/gate need 8 devices, but forcing the host-platform
# device count perturbs the *single-device* rows (the 8-device CPU
# client adds per-update dispatch overhead that costs the k=1 planned
# update ~25%), so the flag is NOT set here: the single-device gates
# run under the default topology, and the sharded entry points re-exec
# this module in a subprocess with the flag when devices are missing
# (see _sharded_subprocess).
_FLAG = "xla_force_host_platform_device_count"

import jax
import jax.numpy as jnp
import numpy as np

RESULTS = Path(__file__).resolve().parent.parent / "results" / "bench"
BASELINE = RESULTS / "BENCH_graph.json"

SIZES = {                       # name -> (n, block/grain, ks)
    "tiny": (1 << 10, 16, [1, 4, 16]),
    "quick": (1 << 14, 16, [1, 4, 16, 64]),
    "medium": (1 << 18, 64, [1, 4, 16, 64, 256]),
    "full": (1 << 20, 64, [1, 4, 16, 64, 256, 1024]),
    "xl": (1 << 21, 64, [1]),   # the gate row
}
# The CI speedup gate: update must beat from-scratch wall-clock on a
# row with n >= 262144 and a single-block edit.  On CPU backends the
# genuine crossover sits around 2^20 (propagation is dispatch-bound
# while from-scratch grows linearly — see DESIGN.md
# §Propagation-cost-model), so the gate row uses 2^21 where the margin
# is ~1.5-1.8x rather than within timer noise.
GATE_N, GATE_BLOCK = 1 << 21, 64
# Timer-noise floor for --check: latencies below this many ms are
# considered equal (CI machines jitter far more than the runtime does).
NOISE_FLOOR_MS = 1.0


def _time(f, *args, reps: int = 9, **kw):
    """Best-of-reps latency: every rep is individually fenced with
    ``block_until_ready`` and the minimum is reported — the standard
    interference-robust estimator (first-touch allocator/cache warmup
    and noisy-neighbour stalls inflate individual reps up to ~3x on
    shared CI machines, and they only ever inflate)."""
    out = f(*args, **kw)
    jax.block_until_ready(out)          # compile + warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = f(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts)) * 1e3, out


def _provenance(reps: int, paired: bool, estimator: str):
    """Measurement-provenance fields carried on every benchmark row so
    a committed number can be audited later: how it was fenced, how
    many reps, whether the baseline was interleaved in the same rounds,
    which estimator collapsed the reps, and on how many devices."""
    return {
        "fence": "block_until_ready",
        "reps": reps,
        "paired_interleave": paired,
        "devices": len(jax.devices()),
        "estimator": estimator,
    }


def _edit(rng, data: np.ndarray, k_blocks: int, block: int) -> np.ndarray:
    nb = data.shape[0] // block
    out = data.copy()
    for b in rng.choice(nb, size=k_blocks, replace=False):
        pos = b * block + rng.integers(block)
        out[pos] = out[pos] + 1.0 if out.dtype.kind == "f" else (
            (out[pos] + 1) % 120)
    return out


def pipeline_program(block: int):
    from repro import sac

    @sac.incremental(block=block)
    def pipeline(x):
        y = x * 2.0 + 1.0
        s = sac.stencil(lambda w: w[block:2 * block]
                        + 0.5 * (w[:block] + w[2 * block:]), x=y, radius=1)
        return sac.reduce(jnp.add, s, identity=0.0)

    return pipeline


def _sweep(handle, total_blocks, levels, app, n, block, ks, data, seed,
           input_name="x", check=None, reps: int = 5):
    rng = np.random.default_rng(seed)
    scratch_ms, _ = _time(handle.run, {input_name: jnp.asarray(data)})
    rows = []
    for k in ks:
        new = _edit(rng, data, k, block)
        old_j, new_j = jnp.asarray(data), jnp.asarray(new)
        # Stats come from the real k-block diff; latency is then timed
        # over edit/revert pairs so every timed propagate pushes k dirty
        # blocks (the handle is stateful — repeating one input would
        # measure the no-op path).
        jax.block_until_ready(handle.update({input_name: new_j}))
        stats = handle.stats
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(handle.update({input_name: old_j}))
            jax.block_until_ready(handle.update({input_name: new_j}))
            ts.append((time.perf_counter() - t0) / 2)
        upd_ms = float(np.min(ts)) * 1e3      # best-of-reps (see _time)
        data = new
        if check is not None:
            check(app, data)
        rec = int(stats["recomputed"])
        rows.append({
            "app": app, "n": n, "block": block,
            "levels": levels, "k_blocks": k,
            "recomputed": rec, "affected": int(stats["affected"]),
            "total_blocks": total_blocks,
            "work_savings": round(total_blocks / max(rec, 1), 2),
            "update_ms": round(upd_ms, 3), "scratch_ms": round(scratch_ms, 3),
            "speedup": round(scratch_ms / max(upd_ms, 1e-9), 2),
            **_provenance(reps, paired=False, estimator="best_of_reps"),
        })
    return rows


def bench_pipeline(n: int, block: int, ks, seed: int = 0):
    h = pipeline_program(block).compile(x=n, max_sparse=64)
    rng = np.random.default_rng(seed)
    data = rng.standard_normal(n).astype(np.float32)
    return _sweep(h, h.cg.total_blocks, h.cg.num_levels, "pipeline",
                  n, block, ks, data, seed)


def bench_stringhash(n: int, grain: int, ks, seed: int = 0):
    from repro.jaxsac.apps import stringhash_graph, stringhash_oracle

    h = stringhash_graph(n, grain, max_sparse=64)
    rng = np.random.default_rng(seed)
    codes = rng.integers(97, 123, n).astype(np.int32)

    def check(app, data):
        assert int(h.outputs()[0, 0]) == stringhash_oracle(data)

    rows = _sweep(h, h.cg.total_blocks, h.cg.num_levels, "stringhash",
                  n, grain, ks, codes, seed, input_name="text", check=check)
    return rows


def causal_program(block: int):
    """Carry-monoid causal op (int32, exact -> block-skip cached-carry
    path): out block i = block i shifted by the running sum of all
    previous blocks' aggregates."""
    from repro import sac

    @sac.incremental(block=block)
    def causal_app(x):
        return sac.causal(
            None, x,
            lift=lambda b: b.sum(),
            op=jnp.add,
            finalize=lambda s, b: (b + s) % jnp.int32(1 << 20),
            identity=0)

    return causal_app


def bench_causal(n: int, block: int, ks, seed: int = 0):
    h = causal_program(block).compile(x=n, max_sparse=64)
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 120, n).astype(np.int32)
    return _sweep(h, h.cg.total_blocks, h.cg.num_levels, "causal",
                  n, block, ks, codes, seed)


# ---------------------------------------------------------------------------
# Sharded propagation: the n=2^21 scaling curve + the 8-device gate
# ---------------------------------------------------------------------------
# Rows ``pipeline-sh{S}``: the n=2^21 pipeline propagated with its
# block axis sharded over S host devices (S=1 is the plain single-device
# runtime measured under the same discipline).  ``update_ms`` is the
# sharded update, ``scratch_ms`` the single-device update it displaces,
# ``speedup`` the paired-median single/sharded ratio — the same
# displaced-baseline convention as the hybrid rows.
#
# The row is a BATCH edit (SHARD_GATE_K dirty blocks of 32768): batch
# absorption is the regime sharding targets (per-shard dense/sparse
# recomputes run in parallel; cf. "Parallel Batch-dynamic Trees via
# Change Propagation", PAPERS.md).  A single-block edit is
# dispatch-bound — its update is already ~free, there is nothing to
# parallelize, and collectives can only add latency — so the scaling
# gate asserts on the batch row.
SHARD_COUNTS = (1, 2, 4, 8)
SHARD_GATE_DEVICES = 8
SHARD_GATE_K = 4096


def bench_pipeline_sharded(n: int = GATE_N, block: int = GATE_BLOCK,
                           k: int = SHARD_GATE_K, reps: int = 8,
                           shard_counts=SHARD_COUNTS, seed: int = 0):
    """Sharded-vs-single update latency, paired and interleaved: each
    round times one sharded edit/revert pair and one single-device pair
    back to back, and the speedup is the median of per-round ratios
    (shared-machine drift is common-mode, as in check_speedup_gate)."""
    ndev = len(jax.devices())
    counts = [s for s in shard_counts if s <= ndev]
    prog = pipeline_program(block)
    rng = np.random.default_rng(seed)
    data = rng.standard_normal(n).astype(np.float32)
    new = _edit(np.random.default_rng(seed + 1), data, k, block)
    old_j, new_j = jnp.asarray(data), jnp.asarray(new)
    base = prog.compile(x=n, max_sparse=64)
    jax.block_until_ready(base.run({"x": old_j}))
    # warm both edit directions' plans (first updates freeze + compile)
    jax.block_until_ready(base.update({"x": new_j}))
    jax.block_until_ready(base.update({"x": old_j}))
    rows = []
    for s in counts:
        h = (base if s == 1 else
             prog.compile(x=n, max_sparse=64, shards=s))
        if s > 1:
            jax.block_until_ready(h.run({"x": old_j}))
        # Warm both edit directions' plans AND the paired loop itself
        # (first-touch page faults inflate the first rounds) before any
        # timed round.
        for _ in range(2):
            jax.block_until_ready(h.update({"x": new_j}))
            jax.block_until_ready(h.update({"x": old_j}))
            jax.block_until_ready(base.update({"x": new_j}))
            jax.block_until_ready(base.update({"x": old_j}))
        ratios, upd, sgl = [], [], []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(h.update({"x": new_j}))
            jax.block_until_ready(h.update({"x": old_j}))
            t_s = (time.perf_counter() - t0) / 2
            t0 = time.perf_counter()
            jax.block_until_ready(base.update({"x": new_j}))
            jax.block_until_ready(base.update({"x": old_j}))
            t_1 = (time.perf_counter() - t0) / 2
            ratios.append(t_1 / t_s)
            upd.append(t_s)
            sgl.append(t_1)
        stats = h.stats
        rows.append({
            "app": f"pipeline-sh{s}", "n": n, "block": block,
            "levels": h.cg.num_levels, "k_blocks": k,
            "recomputed": int(stats["recomputed"]),
            "affected": int(stats["affected"]),
            "total_blocks": h.cg.total_blocks,
            "work_savings": round(
                h.cg.total_blocks / max(int(stats["recomputed"]), 1), 2),
            "update_ms": round(float(np.median(upd)) * 1e3, 3),
            "scratch_ms": round(float(np.median(sgl)) * 1e3, 3),
            "speedup": round(float(np.median(ratios)), 2),
            **_provenance(reps, paired=True, estimator="paired_median"),
        })
        if h is not base:
            del h            # free the sharded state before the next row
    return rows


# Sentinel marking a process already re-execed with the forced device
# count: if devices are STILL missing there (e.g. a machine whose
# default backend is 1-7 real accelerators, which the host-CPU flag
# cannot add to), the sharded measurements skip instead of recursing.
_SUBPROC_ENV = "REPRO_SHARDED_SUBPROCESS"


def _in_subprocess() -> bool:
    return os.environ.get(_SUBPROC_ENV) == "1"


def _sharded_subprocess(mode: str) -> int:
    """Re-exec this module with an 8-CPU-device topology.  XLA only
    reads the device-count flag at backend init, so once jax is live in
    THIS process on the default topology (keeping the single-device
    gates unperturbed), the sharded measurements need a fresh process.
    Returns the subprocess's exit code."""
    import subprocess

    env = dict(os.environ)
    # Replace (not just append to) any existing device-count flag: an
    # inherited lower value would survive a substring check and leave
    # the child short of devices.
    kept = [f for f in env.get("XLA_FLAGS", "").split() if _FLAG not in f]
    env["XLA_FLAGS"] = " ".join(kept + [f"--{_FLAG}={SHARD_GATE_DEVICES}"])
    env[_SUBPROC_ENV] = "1"
    repo = Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = os.pathsep.join(
        [str(repo / "src"), str(repo), env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.graph_pipeline", mode],
        env=env, cwd=repo)
    return proc.returncode


def check_sharded_gate(reps: int = 10) -> int:
    """The sharded acceptance gate: at the n=2^21 pipeline row, the
    8-host-device sharded update must be at least as fast as the
    single-device update — paired-median speedup >= 1.0 (sharding must
    never cost latency at the gate size).  Runs in a subprocess with
    the forced device count when this process lacks the devices."""
    if len(jax.devices()) < SHARD_GATE_DEVICES:
        if _in_subprocess():
            print(f"  SKIP sharded gate: {len(jax.devices())} devices "
                  f"visible even with --{_FLAG}={SHARD_GATE_DEVICES} "
                  f"(non-CPU default backend?)")
            return 0
        return _sharded_subprocess("--sharded-gate")
    rows = bench_pipeline_sharded(reps=reps,
                                  shard_counts=(SHARD_GATE_DEVICES,))
    r = rows[-1]
    ok = r["speedup"] >= 1.0
    verdict = "ok" if ok else "FAIL"
    print(f"  {verdict} sharded gate: {r['app']} n={r['n']} "
          f"k={r['k_blocks']} sharded {r['update_ms']}ms vs "
          f"single-device {r['scratch_ms']}ms -> paired-median speedup "
          f"{r['speedup']} (need >= 1.0)")
    return 0 if ok else 1


# ---------------------------------------------------------------------------
# Hybrid apps: compiled interior vs pure-host update latency
# ---------------------------------------------------------------------------
# The benched sizes of the hybrid acceptance gate: at these (n, k) the
# hybrid runtime must beat the pure host engine's update latency by
# >= HYBRID_GATE_X.  filter uses modulus=16 (a selective predicate: the
# hybrid win is proportional to the fraction of edits that do NOT flip
# a keep flag, since those re-run zero skeleton readers).
HYBRID_APPS = {
    "trees": dict(n=512, k=64),
    "filter": dict(n=8191, k=512, modulus=16),
}
HYBRID_GATE_X = 2.0


def bench_hybrid_apps(reps: int = 8, seed: int = 0):
    """trees/filterbst rows: hybrid vs pure-host update latency.

    Measurement is paired and interleaved (same discipline as
    ``check_speedup_gate``): both engines get the *same* edit sequence
    (same app seed), each round times one hybrid propagate and one
    pure-host propagate back to back, and the speedup is the median of
    per-round ratios, so shared-machine drift is common-mode.
    """
    from repro.apps import FilterApp, TreeContractionApp
    from repro.core import Engine

    rows = []
    for name, cfg in HYBRID_APPS.items():
        cls = TreeContractionApp if name == "trees" else FilterApp
        kwargs = {k: v for k, v in cfg.items() if k != "k"}
        k = cfg["k"]
        apps, engines, comps = {}, {}, {}
        for mode in (True, False):
            app = cls(seed=seed, hybrid=mode, **kwargs)
            eng = Engine()
            app.build_input(eng)
            comp = app.run(eng)
            app.apply_update(eng, k)        # warm (hybrid: jit compile)
            comp.propagate()
            assert app.output() == app.expected(), (name, mode)
            apps[mode], engines[mode], comps[mode] = app, eng, comp
        ratios, hyb, host = [], [], []
        for _ in range(reps):
            for mode in (True, False):
                apps[mode].apply_update(engines[mode], k)
            t0 = time.perf_counter()
            comps[True].propagate()
            t_h = time.perf_counter() - t0
            t0 = time.perf_counter()
            comps[False].propagate()
            t_p = time.perf_counter() - t0
            ratios.append(t_p / t_h)
            hyb.append(t_h)
            host.append(t_p)
        for mode in (True, False):
            assert apps[mode].output() == apps[mode].expected(), (
                name, mode)
        frag = apps[True].fragment
        st = frag.last_stats
        rows.append({
            "app": f"{name}-hybrid", "n": cfg["n"], "block": 1,
            "levels": frag.cg.num_levels, "k_blocks": k,
            "recomputed": int(st["recomputed"]),
            "affected": int(st["affected"]),
            "total_blocks": frag.cg.total_blocks,
            "work_savings": round(
                frag.cg.total_blocks / max(int(st["recomputed"]), 1), 2),
            # update_ms = hybrid update; scratch_ms = the PURE-HOST
            # update (the baseline this gate displaces), so speedup =
            # paired-median host/hybrid.
            "update_ms": round(float(np.median(hyb)) * 1e3, 3),
            "scratch_ms": round(float(np.median(host)) * 1e3, 3),
            "speedup": round(float(np.median(ratios)), 2),
            **_provenance(reps, paired=True, estimator="paired_median"),
        })
    return rows


def check_hybrid_gate(reps: int = 10) -> int:
    """The hybrid acceptance gate: at the benched sizes, hybrid update
    latency must beat the pure host engine by >= HYBRID_GATE_X."""
    bad = 0
    for r in bench_hybrid_apps(reps=reps):
        ok = r["speedup"] >= HYBRID_GATE_X
        verdict = "ok" if ok else "FAIL"
        print(f"  {verdict} hybrid gate: {r['app']} n={r['n']} "
              f"k={r['k_blocks']} hybrid {r['update_ms']}ms vs host "
              f"{r['scratch_ms']}ms -> paired-median speedup "
              f"{r['speedup']} (need >= {HYBRID_GATE_X})")
        bad += 0 if ok else 1
    return bad


def run(size: str = "quick", seed: int = 0):
    n, block, ks = SIZES[size]
    grain = block * 4 if size in ("tiny", "quick") else 64
    rows = bench_pipeline(n, block, ks, seed)
    rows += bench_stringhash(n, grain, ks, seed)
    rows += bench_causal(n, block, ks, seed)
    if size != "tiny":                  # hybrid app rows (host engine is
        rows += bench_hybrid_apps(seed=seed)   # too slow for the tiny lane)
    return rows


def _key(row):
    return (row["app"], row["n"], row["block"], row["k_blocks"])


def write_json(rows) -> Path:
    """Merge rows into the committed baseline, keyed by app/n/block/k."""
    RESULTS.mkdir(parents=True, exist_ok=True)
    merged = {}
    if BASELINE.exists():
        merged = {_key(r): r for r in json.loads(BASELINE.read_text())}
    for r in rows:
        merged[_key(r)] = r
    BASELINE.write_text(json.dumps(list(merged.values()), indent=2))
    return BASELINE


def check_regression(rows, threshold: float) -> int:
    """Compare fresh rows against the committed baseline; returns the
    number of regressions: update latency beyond threshold, any increase
    in recomputed blocks (the machine-independent signal), or a speedup
    drop beyond threshold on rows where both latencies clear the timer
    noise floor."""
    if not BASELINE.exists():
        print(f"  no baseline at {BASELINE}; run without --check first")
        return 1
    base = {_key(r): r for r in json.loads(BASELINE.read_text())}
    bad = 0
    for r in rows:
        b = base.get(_key(r))
        tag = f"{r['app']} n={r['n']} k={r['k_blocks']}"
        if b is None:
            print(f"  MISSING baseline row: {tag}")
            bad += 1
            continue
        if r["recomputed"] > b["recomputed"]:
            print(f"  REGRESSION {tag}: recomputed {b['recomputed']} -> "
                  f"{r['recomputed']}")
            bad += 1
        ref = max(b["update_ms"], NOISE_FLOOR_MS)
        if r["update_ms"] > threshold * ref:
            print(f"  REGRESSION {tag}: update_ms {b['update_ms']} -> "
                  f"{r['update_ms']} (> {threshold}x)")
            bad += 1
        elif (min(r["update_ms"], r["scratch_ms"]) > NOISE_FLOOR_MS
                and r["speedup"] * threshold < b["speedup"]):
            print(f"  REGRESSION {tag}: speedup {b['speedup']} -> "
                  f"{r['speedup']} (> {threshold}x drop)")
            bad += 1
        else:
            print(f"  ok {tag}: update_ms {b['update_ms']} -> "
                  f"{r['update_ms']}, speedup {b['speedup']} -> "
                  f"{r['speedup']}, recomputed {r['recomputed']}")
    return bad


def check_speedup_gate(reps: int = 12) -> int:
    """The headline gate: on the pipeline gate row (n = GATE_N >=
    262144, single-block edit) change propagation must beat from-scratch
    wall-clock — ``speedup >= 1.0``.

    Measurement is *paired and interleaved*: each round times one fenced
    update pair and one fenced from-scratch run back-to-back, and the
    gate asserts on the median of the per-round ratios.  Shared CI
    machines drift by 2-3x on a scale of seconds; pairing makes that
    common-mode (a stall inflates both sides of the same round) instead
    of randomly flattering whichever side was measured in the quiet
    window."""
    prog = pipeline_program(GATE_BLOCK)
    upd = prog.compile(x=GATE_N, max_sparse=64)
    scr = prog.compile(x=GATE_N, max_sparse=64)
    rng = np.random.default_rng(0)
    data = rng.standard_normal(GATE_N).astype(np.float32)
    new = _edit(rng, data, 1, GATE_BLOCK)
    old_j, new_j = jnp.asarray(data), jnp.asarray(new)
    jax.block_until_ready(upd.run({"x": old_j}))
    jax.block_until_ready(scr.run({"x": old_j}))
    # warm both edit directions' plans
    jax.block_until_ready(upd.update({"x": new_j}))
    jax.block_until_ready(upd.update({"x": old_j}))
    ratios, upds, scrs = [], [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(upd.update({"x": new_j}))
        jax.block_until_ready(upd.update({"x": old_j}))
        t_upd = (time.perf_counter() - t0) / 2
        t0 = time.perf_counter()
        jax.block_until_ready(scr.run({"x": new_j}))
        t_scr = time.perf_counter() - t0
        ratios.append(t_scr / t_upd)
        upds.append(t_upd)
        scrs.append(t_scr)
    speedup = float(np.median(ratios))
    ok = speedup >= 1.0
    verdict = "ok" if ok else "FAIL"
    print(f"  {verdict} speedup gate: pipeline n={GATE_N} k=1 "
          f"update {np.median(upds)*1e3:.2f}ms vs scratch "
          f"{np.median(scrs)*1e3:.2f}ms -> paired-median speedup "
          f"{speedup:.2f} (need >= 1.0)")
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=sorted(SIZES), default="quick")
    ap.add_argument("--full", action="store_true",
                    help="alias for --size full")
    ap.add_argument("--sharded", action="store_true",
                    help="bench the n=2^21 sharded scaling curve "
                         "(pipeline-sh{1,2,4,8} rows) and merge it into "
                         "the committed baseline")
    ap.add_argument("--sharded-gate", action="store_true",
                    help="run only the 8-device sharded gate (the "
                         "--check subprocess entry point)")
    ap.add_argument("--check", action="store_true",
                    help="tiny-size latency check vs the committed baseline "
                         "+ the n=2^21 gate-row speedup assertion "
                         "+ the 8-device sharded-update gate")
    ap.add_argument("--threshold", type=float, default=2.0)
    args = ap.parse_args()
    if args.sharded_gate:
        sys.exit(1 if check_sharded_gate() else 0)
    if args.check:
        rows = run(size="tiny")
        bad = check_regression(rows, args.threshold)
        bad += check_speedup_gate()
        bad += check_hybrid_gate()
        bad += check_sharded_gate()
        sys.exit(1 if bad else 0)
    if args.sharded:
        if (len(jax.devices()) < max(SHARD_COUNTS)
                and not _in_subprocess()):
            sys.exit(_sharded_subprocess("--sharded"))
        # In the forced subprocess (or with enough real devices) bench
        # whatever shard counts fit; bench_pipeline_sharded filters.
        rows = bench_pipeline_sharded()
    else:
        rows = run(size="full" if args.full else args.size)
    for r in rows:
        print("  " + ", ".join(f"{k}={v}" for k, v in r.items()))
    print(f"  -> {write_json(rows)}")


if __name__ == "__main__":
    main()
