"""Graph-runtime benchmark: recomputed blocks + update latency across k.

Builds two traced SP-dags —

  * ``pipeline``   — map -> stencil -> balanced reduce (>= 3 dag levels
    mixing elementwise and tree work), the canonical static block program;
  * ``stringhash`` — the Rabin-Karp host app ported as a graph program;

then, for a sweep of edit sizes k (dirty input blocks), measures

  * ``recomputed``      — dag blocks actually recomputed (W_delta),
  * ``total_blocks``    — dag blocks a from-scratch run recomputes,
  * ``update_ms``       — jitted ``propagate`` wall-clock,
  * ``scratch_ms``      — jitted from-scratch ``init`` wall-clock,
  * ``work_savings``    — total_blocks / recomputed,
  * ``speedup``         — scratch_ms / update_ms,

the graph-runtime analogue of the paper's work-savings / self-speedup
tables.  Results print as rows and are written to
``results/bench/BENCH_graph.json``.

Usage:  PYTHONPATH=src python -m benchmarks.graph_pipeline [--full]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

RESULTS = Path(__file__).resolve().parent.parent / "results" / "bench"


def _time(f, *args, reps: int = 5):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3, out


def _edit(rng, data: np.ndarray, k_blocks: int, block: int) -> np.ndarray:
    nb = data.shape[0] // block
    out = data.copy()
    for b in rng.choice(nb, size=k_blocks, replace=False):
        pos = b * block + rng.integers(block)
        out[pos] = out[pos] + 1.0 if out.dtype.kind == "f" else (
            (out[pos] + 1) % 120)
    return out


def bench_pipeline(n: int, block: int, ks, seed: int = 0):
    from repro.jaxsac import GraphBuilder

    g = GraphBuilder()
    x = g.input("x", n=n, block=block)
    y = g.map(lambda b: b * 2.0 + 1.0, x, name="affine")
    s = g.stencil(lambda w: w[block:2 * block]
                  + 0.5 * (w[:block] + w[2 * block:]), y, radius=1)
    t = g.reduce_tree(jnp.add, s, identity=0.0)
    g.output(t)
    cg = g.compile(max_sparse=64)

    rng = np.random.default_rng(seed)
    data = rng.standard_normal(n).astype(np.float32)
    scratch_ms, state = _time(cg.init, {"x": jnp.asarray(data)})
    rows = []
    for k in ks:
        new = _edit(rng, data, k, block)
        upd_ms, (state, stats) = _time(
            cg.propagate, state, {"x": jnp.asarray(new)})
        data = new
        rec = int(stats["recomputed"])
        rows.append({
            "app": "pipeline", "n": n, "block": block,
            "levels": cg.num_levels, "k_blocks": k,
            "recomputed": rec, "affected": int(stats["affected"]),
            "total_blocks": cg.total_blocks,
            "work_savings": round(cg.total_blocks / max(rec, 1), 2),
            "update_ms": round(upd_ms, 3), "scratch_ms": round(scratch_ms, 3),
            "speedup": round(scratch_ms / max(upd_ms, 1e-9), 2),
        })
    return rows


def bench_stringhash(n: int, grain: int, ks, seed: int = 0):
    from repro.jaxsac.apps import stringhash_graph, stringhash_oracle

    cg, _ = stringhash_graph(n, grain)
    rng = np.random.default_rng(seed)
    codes = rng.integers(97, 123, n).astype(np.int32)
    scratch_ms, state = _time(cg.init, {"text": jnp.asarray(codes)})
    rows = []
    for k in ks:
        codes = _edit(rng, codes, k, grain)
        upd_ms, (state, stats) = _time(
            cg.propagate, state, {"text": jnp.asarray(codes)})
        assert int(cg.result(state)[0, 0]) == stringhash_oracle(codes)
        rec = int(stats["recomputed"])
        rows.append({
            "app": "stringhash", "n": n, "block": grain,
            "levels": cg.num_levels, "k_blocks": k,
            "recomputed": rec, "affected": int(stats["affected"]),
            "total_blocks": cg.total_blocks,
            "work_savings": round(cg.total_blocks / max(rec, 1), 2),
            "update_ms": round(upd_ms, 3), "scratch_ms": round(scratch_ms, 3),
            "speedup": round(scratch_ms / max(upd_ms, 1e-9), 2),
        })
    return rows


def run(quick: bool = True, seed: int = 0):
    if quick:
        ks = [1, 4, 16, 64]
        rows = bench_pipeline(1 << 14, 16, ks, seed)
        rows += bench_stringhash(1 << 14, 64, ks, seed)
    else:
        ks = [1, 4, 16, 64, 256, 1024]
        rows = bench_pipeline(1 << 18, 64, ks, seed)
        rows += bench_stringhash(1 << 18, 64, ks, seed)
    return rows


def write_json(rows) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_graph.json"
    out.write_text(json.dumps(rows, indent=2))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    rows = run(quick=not args.full)
    for r in rows:
        print("  " + ", ".join(f"{k}={v}" for k, v in r.items()))
    print(f"  -> {write_json(rows)}")


if __name__ == "__main__":
    main()
