"""Serving-layer load benchmark: session-server latency + fork cost.

Three row families merge into ``results/bench/BENCH_graph.json`` (same
app/n/block/k key space as the graph rows):

  * ``serve-single``  — one session, unbatched: steady-state median
    update latency through the server path (admission + plan +
    commit).  This is the baseline the multi-session gate is measured
    against.
  * ``serve-multi8``  — 8 concurrent sessions branching one warm base,
    same-shaped sparse edits streaming in waves so cross-session
    batching engages.  ``update_ms`` is the p99 *service* latency
    (plan + propagate spans per request), ``scratch_ms`` the
    single-session median it is gated against; the row also carries
    the end-to-end (queue-wait-included) p50/p99, throughput and
    batch-hit-rate.

  * ``serve-fork``    — COW fork cost vs a full state copy
    (``jnp.copy`` of every leaf, the ``donate=False``-style price a
    session would otherwise pay).  ``update_ms`` is the fork,
    ``scratch_ms`` the copy it displaces.

  * ``serve-mttr``    — mean-time-to-recovery of the two repair paths
    vs the steady-state single-session median: evict-crash-revive
    (checkpoint restore + re-adopt on the next edit) and
    quarantine-rollback (release + re-fork of the last good snapshot
    under injected fatal faults).  ``update_ms`` is the revive MTTR
    p50, ``scratch_ms`` the steady-state median it is gated against.

Both latency phases measure a steady-state window: every session first
absorbs ``WARM_ROUNDS`` warm-up edits (paying its one-time
copy-on-first-scatter burst and the per-signature plan freezes — costs
that are forest/plan-cache design properties, priced by the
``serve-fork`` row and the forest tests, not serving-tail properties),
then ``SessionServer.reset_metrics()`` opens the measured window.

Gates (CI `make bench-serve`):

  * batched multi-session service p99 <= GATE_P99_X (2.0) x
    single-session median — per-request work must stay flat under
    8-way concurrency (batching pays the plan freeze once).  Queue
    wait is reported but not gated: under closed-loop saturation of
    the single executor it is ~sessions x service time by Little's
    law, a property of the offered load, not of the serving layer;
  * fork <= GATE_FORK_FRAC (0.10) x full state copy — branching a warm
    base must be near-free, the premise of the whole serving layer;
  * revive MTTR p50 <= GATE_MTTR_REVIVE_X (50) x steady-state median,
    quarantine-rollback p50 <= GATE_MTTR_QUAR_X (5) x — recovery must
    stay a small constant number of requests' worth of latency, never
    a recompile or a from-scratch rerun.

Usage:  PYTHONPATH=src python -m benchmarks.serve_latency [--no-gate]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.graph_pipeline import (_provenance, pipeline_program,
                                       write_json)

GATE_P99_X = 2.0
GATE_FORK_FRAC = 0.10
GATE_MTTR_REVIVE_X = 50.0
GATE_MTTR_QUAR_X = 5.0

N, BLOCK = 1 << 15, 64
FORK_N = 1 << 18                  # fork row: a state big enough that a
SESSIONS, ROUNDS = 8, 6           # full copy is decisively non-trivial


def _edit_streams(n, n_sessions, rounds, seed=0):
    """Same-shaped sparse load: one edited lane per round, pinned to a
    block interior so every edit quantizes to the same dirty signature
    (a boundary lane dirties the neighbor block too — a different
    signature, i.e. a different service class, not this load)."""
    rng = np.random.default_rng(seed)
    x0 = rng.standard_normal(n).astype(np.float32)
    streams = []
    for i in range(n_sessions):
        x, edits = x0.copy(), []
        for _ in range(rounds):
            x = x.copy()
            lane = int(rng.integers(0, n // BLOCK)) * BLOCK + BLOCK // 2
            x[lane] += 1.0
            edits.append({"x": x.copy()})
        streams.append(edits)
    return x0, streams


WARM_ROUNDS = 2   # covers both dirty-signature classes this load emits


def _measured_run(h, streams):
    """Open one session per stream, absorb each stream's first
    ``WARM_ROUNDS`` edits as warm-up (the per-session
    copy-on-first-scatter burst plus one plan freeze per signature
    class), then measure the rest through a fresh metrics window.  A
    plan freeze inside the window would bury the steady-state p99
    under a one-time compile — asserted against, not filtered out.
    Returns (registry, summary, measured_wall_s)."""
    import asyncio

    async def _main():
        async with h.serve() as server:
            sids = [await server.open() for _ in streams]
            for w in range(WARM_ROUNDS):
                await asyncio.gather(*[server.submit(sid, **streams[i][w])
                                       for i, sid in enumerate(sids)])
            server.reset_metrics()
            reg = server.registry
            misses0 = server.cg.plan_cache_snapshot()["misses"]

            async def drive(i, sid):
                for edit in streams[i][WARM_ROUNDS:]:
                    await server.submit(sid, **edit)

            t0 = time.perf_counter()
            await asyncio.gather(*[drive(i, sid)
                                   for i, sid in enumerate(sids)])
            wall_s = time.perf_counter() - t0
            summary = server.summary()
            assert summary["plan_cache"]["misses"] == misses0, \
                "plan freeze inside the measured window (warm-up too short)"
            await server.shutdown()
            return reg, summary, wall_s

    return asyncio.run(_main())


def bench_single(reps: int = 40, seed: int = 0):
    """Single-session steady-state median request latency through the
    server path (no contention: total latency ~= service)."""
    x0, streams = _edit_streams(N, 1, reps + WARM_ROUNDS, seed)
    h = pipeline_program(BLOCK).compile(x=N, max_sparse=64)
    h.run(x=x0)
    reg, summary, _wall = _measured_run(h, streams)
    h.close()
    assert summary["requests"] == reps
    med = reg.histogram("serve.total_ms").percentile(50)
    return med, {
        "app": "serve-single", "n": N, "block": BLOCK, "k_blocks": 1,
        "update_ms": round(med, 3), "p50_ms": round(med, 3),
        "p99_ms": round(reg.histogram("serve.total_ms").percentile(99), 3),
        "scratch_ms": round(med, 3), "speedup": 1.0,
        "sessions": 1,
        **_provenance(reps, paired=False, estimator="median"),
    }


def bench_multi(single_med_ms: float, seed: int = 0):
    """8 concurrent sessions, cross-session batching, p50/p99 +
    throughput from the server's own metric registry."""
    x0, streams = _edit_streams(N, SESSIONS, ROUNDS + WARM_ROUNDS, seed)
    h = pipeline_program(BLOCK).compile(x=N, max_sparse=64)
    h.run(x=x0)
    reg, summary, wall_s = _measured_run(h, streams)
    h.close()
    n_req = summary["requests"]
    assert n_req == SESSIONS * ROUNDS
    assert summary["batch_joins"] > 0, "load pattern failed to batch"
    # Service time per request: the work the server does for it (plan +
    # propagate), i.e. end-to-end latency minus queue wait.
    service = [e["plan_ms"] + e["propagate_ms"]
               for e in reg.events("serve.request")]
    svc_p99 = float(np.percentile(service, 99))
    row = {
        "app": f"serve-multi{SESSIONS}", "n": N, "block": BLOCK,
        "k_blocks": 1,
        # update_ms carries the gated number: batched p99 service latency.
        "update_ms": round(svc_p99, 3),
        "service_p99_ms": round(svc_p99, 3),
        "p50_ms": round(summary["p50_ms"], 3),
        "p99_ms": round(summary["p99_ms"], 3),
        "scratch_ms": round(single_med_ms, 3),
        "speedup": round(single_med_ms / max(svc_p99, 1e-9), 2),
        "sessions": SESSIONS,
        "requests": n_req,
        "throughput_rps": round(n_req / wall_s, 1),
        "batch_hit_rate": round(summary["batch_hit_rate"], 3),
        **_provenance(ROUNDS, paired=False, estimator="p99"),
    }
    return row


def bench_fork(reps: int = 30, seed: int = 0):
    """COW fork vs full state copy, same warm state."""
    rng = np.random.default_rng(seed)
    h = pipeline_program(BLOCK).compile(x=FORK_N, max_sparse=64)
    h.run(x=rng.standard_normal(FORK_N).astype(np.float32))
    base = h._forest()

    fork_ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        child = base.fork()
        fork_ts.append(time.perf_counter() - t0)
        child.release()

    state = base.state
    copy_ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        copied = jax.tree.map(jnp.copy, state)
        jax.block_until_ready(copied)
        copy_ts.append(time.perf_counter() - t0)
    h.close()

    fork_ms = float(np.median(fork_ts)) * 1e3
    copy_ms = float(np.median(copy_ts)) * 1e3
    row = {
        "app": "serve-fork", "n": FORK_N, "block": BLOCK, "k_blocks": 0,
        "update_ms": round(fork_ms, 4),       # the fork
        "scratch_ms": round(copy_ms, 3),      # the copy it displaces
        "speedup": round(copy_ms / max(fork_ms, 1e-9), 1),
        "fork_frac_of_copy": round(fork_ms / copy_ms, 4),
        **_provenance(reps, paired=False, estimator="median"),
    }
    return fork_ms, copy_ms, row


def bench_mttr(single_med_ms: float, cycles: int = 6, seed: int = 0):
    """MTTR of the two repair paths, from the server's own
    ``serve.recovery_ms`` histogram:

      * evict-crash-revive — evict the session, then submit: the server
        revives it (verified checkpoint restore + forest re-adopt)
        before serving;
      * quarantine-rollback — injected fatal faults on both the planned
        commit and the oracle fail the request, tripping
        ``quarantine_after=1``: rollback to the last good snapshot,
        then ``reinstate()``.

    Both are p50 over ``cycles`` repetitions against the steady-state
    single-session median."""
    import asyncio
    import tempfile

    from repro.runtime.faults import ChaosInjector, FaultSpec

    x0, streams = _edit_streams(N, 1, 2 * cycles + WARM_ROUNDS + 1, seed)
    edits = streams[0]
    h = pipeline_program(BLOCK).compile(x=N, max_sparse=64)
    h.run(x=x0)
    tmp = tempfile.mkdtemp(prefix="serve_mttr_")

    async def _main():
        async with h.serve(ckpt_dir=tmp, quarantine_after=1) as server:
            sid = await server.open()
            k = 0
            for _ in range(WARM_ROUNDS):
                await server.submit(sid, **edits[k])
                k += 1
            server.reset_metrics()
            for _ in range(cycles):
                await server.evict(sid)
                await server.submit(sid, **edits[k])   # auto-revive
                k += 1
            revive_ms = server.registry.histogram(
                "serve.recovery_ms").percentile(50)
            server.reset_metrics()
            for c in range(cycles):
                with ChaosInjector(
                        [FaultSpec("forest.commit", at=(1,), kind="fatal"),
                         FaultSpec("forest.oracle", at=(1,), kind="fatal")],
                        seed=c):
                    try:
                        await server.submit(sid, **edits[k])
                    except Exception:
                        pass
                    k += 1
                await server.reinstate(sid)
            quar_ms = server.registry.histogram(
                "serve.recovery_ms").percentile(50)
            await server.submit(sid, **edits[k])       # post-chaos health
            await server.shutdown()
            return revive_ms, quar_ms

    revive_ms, quar_ms = asyncio.run(_main())
    h.close()
    row = {
        "app": "serve-mttr", "n": N, "block": BLOCK, "k_blocks": 1,
        # update_ms carries the gated number: evict-crash-revive MTTR p50.
        "update_ms": round(revive_ms, 3),
        "revive_p50_ms": round(revive_ms, 3),
        "quarantine_p50_ms": round(quar_ms, 3),
        "scratch_ms": round(single_med_ms, 3),
        "speedup": round(single_med_ms / max(revive_ms, 1e-9), 3),
        "cycles": cycles,
        **_provenance(cycles, paired=False, estimator="median"),
    }
    return revive_ms, quar_ms, row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-gate", action="store_true",
                    help="emit rows without asserting the gates")
    args = ap.parse_args()

    single_med, row_single = bench_single()
    row_multi = bench_multi(single_med)
    fork_ms, copy_ms, row_fork = bench_fork()
    revive_ms, quar_ms, row_mttr = bench_mttr(single_med)
    rows = [row_single, row_multi, row_fork, row_mttr]
    for r in rows:
        print("  " + ", ".join(f"{k}={v}" for k, v in r.items()))
    print(f"  -> {write_json(rows)}")

    if args.no_gate:
        return
    bad = 0
    p99, med = row_multi["service_p99_ms"], row_multi["scratch_ms"]
    ok = p99 <= GATE_P99_X * med
    print(f"  {'ok' if ok else 'FAIL'} serve gate: {SESSIONS}-session "
          f"batched service p99 {p99}ms vs single-session median {med}ms "
          f"(need <= {GATE_P99_X}x)")
    bad += 0 if ok else 1
    ok = fork_ms <= GATE_FORK_FRAC * copy_ms
    print(f"  {'ok' if ok else 'FAIL'} fork gate: fork {fork_ms:.4f}ms vs "
          f"full copy {copy_ms:.3f}ms "
          f"({fork_ms / copy_ms:.1%}, need <= {GATE_FORK_FRAC:.0%})")
    bad += 0 if ok else 1
    ok = revive_ms <= GATE_MTTR_REVIVE_X * single_med
    print(f"  {'ok' if ok else 'FAIL'} mttr gate: evict-crash-revive p50 "
          f"{revive_ms:.3f}ms vs steady median {single_med:.3f}ms "
          f"(need <= {GATE_MTTR_REVIVE_X:.0f}x)")
    bad += 0 if ok else 1
    ok = quar_ms <= GATE_MTTR_QUAR_X * single_med
    print(f"  {'ok' if ok else 'FAIL'} mttr gate: quarantine-rollback p50 "
          f"{quar_ms:.3f}ms vs steady median {single_med:.3f}ms "
          f"(need <= {GATE_MTTR_QUAR_X:.0f}x)")
    bad += 0 if ok else 1
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
