"""Benchmark driver: one benchmark per paper table.

  tables 1-8   per-app PSAC benchmarks (static / initial / updates / memory / GC)
  table 9      string-hash granularity sweep
  table 10     reader-set size microbenchmark
  roofline     three-term roofline per (arch x shape) from the dry-run

Usage:
  PYTHONPATH=src python -m benchmarks.run                 # quick versions
  PYTHONPATH=src python -m benchmarks.run --full          # paper-scale sizes
  PYTHONPATH=src python -m benchmarks.run --only apps --app trees
  PYTHONPATH=src python -m benchmarks.run --only roofline --mesh multi

Results are printed and appended as CSV under results/bench/.
"""
from __future__ import annotations

import argparse
import csv
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results" / "bench"


def _write_csv(name: str, rows) -> None:
    if not rows:
        return
    RESULTS.mkdir(parents=True, exist_ok=True)
    keys: list = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    out = RESULTS / f"{name}.csv"
    with out.open("w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(rows)
    print(f"  -> {out}")


def _print_rows(rows) -> None:
    for r in rows:
        print("  " + ", ".join(f"{k}={v}" for k, v in r.items()))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (several minutes)")
    ap.add_argument("--only", default="all",
                    choices=["all", "apps", "granularity", "readersets",
                             "graph", "roofline"])
    ap.add_argument("--app", default=None, help="restrict --only apps")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--tag", default="", help="roofline variant tag")
    args = ap.parse_args()
    quick = not args.full

    t0 = time.time()
    if args.only in ("all", "apps"):
        from . import psac_tables
        print(f"== Tables 1-8: application benchmarks "
              f"({'quick' if quick else 'full'}) ==")
        rows = psac_tables.run(quick=quick,
                               apps=[args.app] if args.app else None)
        _print_rows(rows)
        _write_csv("psac_tables", rows)

    if args.only in ("all", "granularity"):
        from . import granularity
        print("== Table 9: granularity sweep ==")
        rows = granularity.run(quick=quick)
        _print_rows(rows)
        _write_csv("granularity", rows)

    if args.only in ("all", "readersets"):
        from . import readersets
        print("== Table 10: reader-set size ==")
        rows = readersets.run(quick=quick)
        _print_rows(rows)
        _write_csv("readersets", rows)

    if args.only in ("all", "graph"):
        from . import graph_pipeline
        print("== Graph runtime: recomputed blocks / update latency ==")
        rows = graph_pipeline.run(size="quick" if quick else "full")
        _print_rows(rows)
        print(f"  -> {graph_pipeline.write_json(rows)}")

    if args.only in ("all", "roofline"):
        from . import roofline
        print(f"== Roofline ({args.mesh} mesh) ==")
        rows = roofline.table(mesh=args.mesh, tag=args.tag)
        if rows:
            print(roofline.format_table(rows))
            _write_csv(f"roofline_{args.mesh}" + (f"_{args.tag}" if args.tag else ""),
                       rows)
        else:
            print("  (no dry-run results found — run repro.launch.dryrun first)")

    print(f"benchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
