"""Chaos suite: deterministic fault injection across the serving stack.

The paper's determinism contract — propagation reproduces the
from-scratch run exactly — makes recovery *verifiable*: after any
retry, rollback, revival, or remesh, the served state must be bitwise
identical to a fault-free replay of the accepted edits.  Every test
here asserts that, under a seeded ``ChaosInjector`` schedule
(repro.runtime.faults) whose firing pattern replays exactly.

Per-fault-class regressions (each fails or hangs without its fix):

  * transient commit fault      -> bounded retry (side-effect-free
    commits make the same PendingUpdate re-dispatchable)
  * persistent planned-path
    failure                     -> degrade to the copy oracle, sticky
    per session after ``degrade_after``
  * repeated request failure    -> quarantine: rollback to the last
    good snapshot, other sessions untouched, reinstate() resumes
  * expired deadline            -> resolved before paying plan/commit
  * full admission queue        -> fail-fast retryable backpressure
  * evict/revive faults         -> evict leaves the session live;
    revive retries; checkpoint is never half-released
  * ckpt commit/load faults     -> partial checkpoints invisible,
    corrupt ones skipped for the previous verified step
  * device loss (``shards=N``)  -> supervisor remesh onto fewer
    devices + checkpoint restore, bitwise

The capstone soak drives N concurrent sessions under a schedule that
hits every site (sync points, commit dispatch, the oracle, ckpt
save/commit/load, evict/revive — device loss has its own sharded
test) and asserts every session's final outputs bitwise against a
fault-free dedicated-handle replay of its accepted edits, with the
server still live afterwards.
"""
import asyncio
import json
import time
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest

import repro.sac as sac
from repro import ckpt
from repro.obs.metrics import MetricRegistry
from repro.runtime import (ChaosInjector, DeviceLost, FaultSpec,
                           InjectedFault, Supervisor, is_transient,
                           remesh_shards)
from repro.runtime import faults as faults_mod
from repro.serve import (DeadlineExceeded, ServerClosed, ServerOverloaded,
                         SessionQuarantined, UnknownSession)


@sac.incremental(block=16)
def _prog(x):
    y = x * 2.0 + 1.0
    s = sac.stencil(lambda w: w[16:32] + 0.5 * (w[:16] + w[32:]),
                    y, radius=1)
    return sac.reduce(jnp.add, s, identity=0.0)


def _streams(n_sessions, rounds, n=512, seed=0):
    rng = np.random.default_rng(seed)
    x0 = np.arange(n, dtype=np.float32)
    streams = []
    for i in range(n_sessions):
        x = x0.copy()
        edits = []
        for r in range(rounds):
            x = x.copy()
            x[int(rng.integers(0, n))] += float(i + r + 1)
            edits.append({"x": x.copy()})
        streams.append(edits)
    return x0, streams


def _replay(x0, edits, n=512):
    """Fault-free dedicated-handle replay: the bitwise oracle."""
    ref = _prog.compile(x=n)
    ref.run(x=x0)
    out = np.asarray(ref.outputs())
    for e in edits:
        out = np.asarray(ref.update(**e))
    return out


# ---------------------------------------------------------------------------
# The injector itself: schedules, determinism, installation
# ---------------------------------------------------------------------------
def test_fault_spec_fires_at_visits():
    inj = ChaosInjector([FaultSpec("a.site", at=(2, 4))], seed=0)
    log = []
    for _ in range(6):
        try:
            inj.fire("a.site")
            log.append("ok")
        except InjectedFault:
            log.append("boom")
    assert log == ["ok", "boom", "ok", "boom", "ok", "ok"]
    assert inj.fired == [("a.site", 2, "transient"), ("a.site", 4, "transient")]


def test_fault_spec_patterns_and_kinds():
    inj = ChaosInjector([FaultSpec("sync.*", at=(1,), kind="device_loss")],
                        seed=0)
    inj.fire("forest.commit")            # no match: silent
    with pytest.raises(DeviceLost) as ei:
        inj.fire("sync.mark_counts")
    assert ei.value.device_loss and not is_transient(ei.value)
    assert is_transient(InjectedFault("s", 1))
    assert not is_transient(RuntimeError("plain"))


def test_probabilistic_schedule_replays_exactly():
    """Same (schedule, seed) -> same fired log; draws are keyed per
    (spec, site, visit), so interleaving other sites cannot shift which
    faults fire."""
    sched = [FaultSpec("s.a", p=0.3), FaultSpec("s.b", p=0.5, times=2)]

    def drive(inj, interleave):
        for i in range(40):
            for site in (["s.a", "s.b", "s.noise"] if interleave
                         else ["s.a", "s.b"]):
                try:
                    inj.fire(site)
                except InjectedFault:
                    pass
        return [(s, v, k) for (s, v, k) in inj.fired if s != "s.noise"]

    a = drive(ChaosInjector(sched, seed=7), interleave=False)
    b = drive(ChaosInjector(sched, seed=7), interleave=True)
    c = drive(ChaosInjector(sched, seed=8), interleave=False)
    assert a == b and len(a) > 0
    assert a != c                        # the seed matters
    assert sum(1 for s, _, _ in a if s == "s.b") <= 2   # times= bound


def test_inject_is_noop_without_installed_injector():
    faults_mod.inject("any.site")        # must not raise
    with ChaosInjector([FaultSpec("x", at=(1,))], seed=0) as inj:
        with pytest.raises(InjectedFault):
            faults_mod.inject("x")
    faults_mod.inject("x")               # uninstalled on exit
    assert inj.visits["x"] == 1


# ---------------------------------------------------------------------------
# Serving regressions, one per fault class
# ---------------------------------------------------------------------------
def _serve_one(h, edits, schedule, seed=0, **opts):
    """Run one session's edits under a chaos schedule; returns
    (results-or-exceptions, final outputs, server, injector)."""
    async def main():
        res = []
        async with h.serve(**opts) as server:
            with ChaosInjector(schedule, seed=seed) as inj:
                sid = await server.open()
                for e in edits:
                    try:
                        res.append(await server.submit(sid, **e))
                    except Exception as exc:
                        res.append(exc)
            final = np.asarray(server.outputs(sid))
            summary = server.summary()
            session = server.sessions[sid]
            await server.stop()
        return res, final, summary, session, inj

    return asyncio.run(main())


def test_transient_commit_fault_is_retried():
    """A transient fault at commit dispatch is absorbed by bounded
    retry — safe because the staged-refcount commit is side-effect-free
    on failure.  Without the retry the submit raises InjectedFault."""
    x0, streams = _streams(1, 2)
    h = _prog.compile(x=512)
    h.run(x=x0)
    reg = MetricRegistry()
    res, final, _summary, session, inj = _serve_one(
        h, streams[0], [FaultSpec("forest.commit", at=(1,))], registry=reg)
    assert all(isinstance(r, dict) for r in res), res
    assert inj.fired_sites() == {"forest.commit"}
    assert reg.counters["serve.retries"].value >= 1
    assert not session.degraded
    assert np.array_equal(final, _replay(x0, streams[0]))


def test_fatal_commit_faults_degrade_to_oracle():
    """A non-retryable planned-path failure falls back to the copy
    oracle (request still served, counted serve.degraded); after
    ``degrade_after`` consecutive plan failures the session goes sticky
    degraded and stops paying for planning at all."""
    x0, streams = _streams(1, 3)
    h = _prog.compile(x=512)
    h.run(x=x0)
    reg = MetricRegistry()
    res, final, _summary, session, _inj = _serve_one(
        h, streams[0], [FaultSpec("forest.commit", p=1.0, kind="fatal")],
        registry=reg, degrade_after=2)
    assert all(isinstance(r, dict) for r in res), res
    assert session.degraded              # sticky after 2 plan failures
    assert reg.counters["serve.degraded"].value == 3
    assert np.array_equal(final, _replay(x0, streams[0]))


def test_quarantine_rolls_back_and_reinstates(tmp_path):
    """When even the oracle fails, the request fails; after
    ``quarantine_after`` consecutive failures the session rolls back to
    its last good snapshot and quarantines.  Reads serve the rolled-back
    state, edits fail fast, other sessions are untouched, and
    reinstate() resumes — all bitwise against the accepted-edit
    replay."""
    x0, streams = _streams(2, 2, seed=3)
    h = _prog.compile(x=512)
    h.run(x=x0)
    reg = MetricRegistry()
    schedule = [FaultSpec("forest.commit", at=(1,), kind="fatal"),
                FaultSpec("forest.oracle", at=(1,), kind="fatal")]

    async def main():
        async with h.serve(registry=reg, quarantine_after=1,
                           degrade_after=99) as server:
            with ChaosInjector(schedule, seed=0):
                sa = await server.open()
                sb = await server.open()
                # sa's first edit: commit fatal -> oracle fatal -> fails
                with pytest.raises(InjectedFault):
                    await server.submit(sa, **streams[0][0])
                assert server.sessions[sa].status == "quarantined"
                # fail-fast while quarantined; reads serve rolled-back state
                with pytest.raises(SessionQuarantined):
                    await server.submit(sa, **streams[0][1])
                quarantined_view = np.asarray(server.outputs(sa))
                # the other tenant is untouched (faults exhausted: times=1)
                rb = await server.submit(sb, **streams[1][0])
                await server.reinstate(sa)
                ra = await server.submit(sa, **streams[0][1])
            await server.stop()
            return quarantined_view, np.asarray(ra["outputs"]), \
                np.asarray(rb["outputs"])

    qview, ra, rb = asyncio.run(main())
    assert reg.counters["serve.quarantines"].value == 1
    assert np.array_equal(qview, _replay(x0, []))        # zero accepted edits
    assert np.array_equal(ra, _replay(x0, [streams[0][1]]))
    assert np.array_equal(rb, _replay(x0, [streams[1][0]]))


def test_deadline_expires_before_paying_work():
    x0, streams = _streams(1, 1)
    h = _prog.compile(x=512)
    h.run(x=x0)
    reg = MetricRegistry()

    async def main():
        async with h.serve(registry=reg) as server:
            sid = await server.open()
            with pytest.raises(DeadlineExceeded):
                await server.submit(sid, **streams[0][0], deadline_s=0.0)
            s = server.sessions[sid]
            assert s.updates == 0        # no plan/commit was paid
            # a deadline that fits still serves
            r = await server.submit(sid, **streams[0][0], deadline_s=60.0)
            await server.stop()
            return np.asarray(r["outputs"])

    out = asyncio.run(main())
    assert reg.counters["serve.deadline_exceeded"].value == 1
    assert np.array_equal(out, _replay(x0, streams[0]))


def test_backpressure_rejects_when_queue_full():
    """With max_queue=1, concurrent submits beyond the first are
    rejected synchronously (never enqueued) with a retryable error —
    and a later retry succeeds."""
    x0, streams = _streams(4, 1)
    h = _prog.compile(x=512)
    h.run(x=x0)
    reg = MetricRegistry()

    async def main():
        async with h.serve(registry=reg, max_queue=1) as server:
            sids = [await server.open() for _ in range(4)]
            res = await asyncio.gather(
                *[server.submit(sids[i], **streams[i][0]) for i in range(4)],
                return_exceptions=True)
            served = [r for r in res if isinstance(r, dict)]
            rejected = [r for r in res if isinstance(r, ServerOverloaded)]
            assert len(served) == 1 and len(rejected) == 3
            assert all(r.retryable for r in rejected)
            retry = await server.submit(sids[1], **streams[1][0])
            await server.stop()
            return retry

    retry = asyncio.run(main())
    assert reg.counters["serve.rejected"].value == 3
    assert np.array_equal(np.asarray(retry["outputs"]),
                          _replay(x0, streams[1]))


def test_evict_fault_leaves_session_live(tmp_path):
    """A fault during evict (before or inside save_session) must leave
    the session live with every buffer intact — never a half-released
    tenant."""
    x0, streams = _streams(1, 2)
    h = _prog.compile(x=512)
    h.run(x=x0)

    async def main():
        async with h.serve(ckpt_dir=str(tmp_path)) as server:
            with ChaosInjector([FaultSpec("session.evict", at=(1,))],
                               seed=0):
                sid = await server.open()
                await server.submit(sid, **streams[0][0])
                with pytest.raises(InjectedFault):
                    await server.evict(sid)
                assert server.sessions[sid].status == "live"
                # the session keeps serving, and a later evict works
                r2 = await server.submit(sid, **streams[0][1])
                await server.evict(sid)
                assert server.sessions[sid].status == "evicted"
            await server.stop()
            return np.asarray(r2["outputs"])

    out = asyncio.run(main())
    assert np.array_equal(out, _replay(x0, streams[0]))


def test_revive_fault_is_retried(tmp_path):
    x0, streams = _streams(1, 2)
    h = _prog.compile(x=512)
    h.run(x=x0)
    reg = MetricRegistry()

    async def main():
        async with h.serve(ckpt_dir=str(tmp_path), registry=reg) as server:
            sid = await server.open()
            await server.submit(sid, **streams[0][0])
            await server.evict(sid)
            with ChaosInjector([FaultSpec("session.revive", at=(1,))],
                               seed=0):
                r2 = await server.submit(sid, **streams[0][1])  # auto-revive
            assert server.sessions[sid].status == "live"
            assert server.sessions[sid].revivals == 1
            await server.stop()
            return np.asarray(r2["outputs"])

    out = asyncio.run(main())
    assert reg.counters["serve.retries"].value >= 1
    assert np.array_equal(out, _replay(x0, streams[0]))


def test_sync_site_fault_retried_at_plan(tmp_path):
    """The injector chains onto obs.syncpoints.HOOK: the planned path's
    one host sync (mark_counts) becomes a fault site, and a transient
    fault there retries the plan."""
    x0, streams = _streams(1, 1)
    h = _prog.compile(x=512)
    h.run(x=x0)
    reg = MetricRegistry()
    res, final, _summary, _session, inj = _serve_one(
        h, streams[0], [FaultSpec("sync.mark_counts", at=(1,))],
        registry=reg)
    assert all(isinstance(r, dict) for r in res), res
    assert "sync.mark_counts" in inj.fired_sites()
    assert reg.counters["serve.retries"].value >= 1
    assert np.array_equal(final, _replay(x0, streams[0]))


# ---------------------------------------------------------------------------
# Checkpoint crash consistency
# ---------------------------------------------------------------------------
def _save_two(tmp_path):
    s1 = {"w": jnp.arange(8, dtype=jnp.float32)}
    s2 = {"w": jnp.arange(8, dtype=jnp.float32) * 3.0}
    ckpt.save(tmp_path, s1, 1)
    ckpt.save(tmp_path, s2, 2)
    return s1, s2


def test_ckpt_commit_fault_leaves_invisible_partial(tmp_path):
    state = {"w": jnp.ones(4)}
    with ChaosInjector([FaultSpec("ckpt.commit", at=(1,))], seed=0):
        with pytest.raises(InjectedFault):
            ckpt.save(tmp_path, state, 1)
        assert ckpt.latest_step(tmp_path) is None   # partial is invisible
        ckpt.save(tmp_path, state, 1)               # clean retry commits
    assert ckpt.latest_step(tmp_path) == 1


def test_corrupt_truncated_manifest_falls_back(tmp_path):
    reg = MetricRegistry()
    ckpt.set_registry(reg)
    s1, _s2 = _save_two(tmp_path)
    man = tmp_path / "step_00000002" / "MANIFEST.json"
    man.write_text(man.read_text()[: len(man.read_text()) // 2])  # torn write
    assert ckpt.latest_step(tmp_path) == 2          # committed, but...
    assert ckpt.latest_step(tmp_path, verify=True) == 1
    restored = ckpt.restore(
        tmp_path, {"w": jnp.zeros(8, dtype=jnp.float32)})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(s1["w"]))
    assert reg.counters["ckpt.corrupt_skipped"].value >= 1
    ckpt.set_registry(None)


def test_corrupt_flipped_leaf_byte_falls_back(tmp_path):
    s1, _s2 = _save_two(tmp_path)
    d2 = tmp_path / "step_00000002"
    leaf = sorted(d2.glob("*.npy"))[0]
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0xFF                                  # bit rot in the data
    leaf.write_bytes(bytes(raw))
    assert ckpt.latest_step(tmp_path, verify=True) == 1
    restored = ckpt.restore(
        tmp_path, {"w": jnp.zeros(8, dtype=jnp.float32)})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(s1["w"]))
    # an explicit request for the corrupt step is an error, not a guess
    with pytest.raises(ckpt.CorruptCheckpoint):
        ckpt.restore(tmp_path, {"w": jnp.zeros(8, dtype=jnp.float32)},
                     step=2)


# ---------------------------------------------------------------------------
# Supervisor: window budget, device loss -> remesh
# ---------------------------------------------------------------------------
class _EditSource:
    """Deterministic pipeline stub: batch_at(step) is pure in step."""

    def __init__(self, edits):
        self.edits = edits
        self.step = 0

    def batch_at(self, step):
        return self.edits[step]


def test_supervisor_restart_budget_is_sliding_window(tmp_path):
    """Old restarts outside the window don't count against the budget
    (the lifetime counter hot-looped: a long healthy run accumulated
    license to spin).  Rapid failures inside the window still trip."""
    sup = Supervisor(step_fn=lambda s, b: (s, {}),
                     pipeline=_EditSource([]), ckpt_dir=str(tmp_path),
                     init_state=lambda: {"w": jnp.zeros(2)},
                     max_restarts=2, restart_window_s=10.0,
                     restart_backoff_s=0.0)
    # Ancient history: many restarts, all far outside the window.
    sup._restart_times = [time.monotonic() - 1000.0] * 50
    sup.restarts = 50
    state, step = sup._recover(RuntimeError("blip"))     # must NOT give up
    assert step == 0
    sup._recover(RuntimeError("blip"))
    with pytest.raises(RuntimeError, match="blip"):      # 3rd in-window
        sup._recover(RuntimeError("blip"))


def test_supervisor_metrics_log_dedupes_replayed_steps(tmp_path):
    """Replay after restore must not leave duplicate step entries in
    metrics_log (the pre-fix log double-counted every replayed step)."""
    target = jnp.asarray(np.random.default_rng(0).standard_normal(8),
                         dtype=jnp.float32)

    def init_state():
        return {"w": jnp.zeros(8, dtype=jnp.float32)}

    def step_fn(state, batch):
        w = state["w"] + 0.1 * (target - state["w"])
        return {"w": w}, {"loss": jnp.sum((target - w) ** 2)}

    from repro.data import DataPipeline
    from repro.runtime import FaultInjector
    sup = Supervisor(step_fn=step_fn,
                     pipeline=DataPipeline(512, global_batch=4, seq_len=16,
                                           seed=0),
                     ckpt_dir=str(tmp_path), init_state=init_state,
                     ckpt_every=5, fault_injector=FaultInjector([7, 13]),
                     restart_backoff_s=0.001)
    sup.run(20)
    steps = [m["step"] for m in sup.metrics_log]
    assert steps == list(range(20))      # one entry per step, no dupes
    assert sup.restarts == 2


def test_remesh_shards_picks_largest_divisor():
    assert remesh_shards(4, 32) == 4
    assert remesh_shards(3, 32) == 2     # 3 does not divide 32
    assert remesh_shards(5, 32) == 4
    assert remesh_shards(1, 32) == 1
    assert remesh_shards(7, 30) == 6
    assert remesh_shards(64, 32) == 32   # never more shards than blocks


@pytest.mark.slow
def test_device_loss_remesh_restores_bitwise(tmp_path):
    """Injected device loss on a ``shards=4`` handle: the supervisor
    remeshes onto the surviving devices (shards=2 via remesh_shards),
    restores the sharded propagation state from the last committed
    checkpoint, re-freezes plans on the new topology, and the final
    trajectory is bitwise the fault-free one."""
    n, blocks = 512, 512 // 16
    x0, streams = _streams(1, 5, n=n, seed=9)
    edits = streams[0]

    ctx = {}

    def build(shards):
        h = _prog.compile(x=n, shards=shards)
        h.run(x=x0)
        ctx["h"] = h
        return h

    build(4)

    def init_state():
        # Fresh propagation state laid out on the current topology.
        return ctx["h"].cg.init(x=x0)

    def step_fn(state, edit):
        cg = ctx["h"].cg
        new_state, _stats = cg.propagate(state, edit)
        return new_state, {"out": cg.result(new_state).sum()}

    def restore_fn(ckpt_dir, step):
        cg = ctx["h"].cg
        st = ckpt.restore(ckpt_dir, cg.abstract_state(), step=step)
        # Lay the restored (host-resident) leaves out over the new mesh.
        return cg._sharder.place(st) if cg._sharder is not None else st

    def remesh_fn(exc):
        assert isinstance(exc, DeviceLost)
        surviving = 2                    # half the mesh is gone
        build(remesh_shards(surviving, blocks))

    sup = Supervisor(step_fn=step_fn, pipeline=_EditSource(edits),
                     ckpt_dir=str(tmp_path), init_state=init_state,
                     ckpt_every=1, restore_fn=restore_fn,
                     remesh_fn=remesh_fn, restart_backoff_s=0.001)
    with ChaosInjector(
            [FaultSpec("device.loss", at=(3,), kind="device_loss")],
            seed=0) as inj:
        final = sup.run(len(edits))
    assert inj.fired_sites() == {"device.loss"}
    assert sup.device_losses == 1
    assert ctx["h"].cg.num_shards == 2   # re-meshed onto the survivors

    want = _replay(x0, edits, n=n)
    got = np.asarray(ctx["h"].cg.result(final))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# The capstone soak
# ---------------------------------------------------------------------------
def test_chaos_soak_every_site_bitwise_survivors(tmp_path):
    """N concurrent sessions, R rounds, a seeded schedule that hits
    every injection site reachable on a single-device server.  Outcome:
    every submit resolves (no wedged futures), every session's final
    outputs are bitwise a fault-free dedicated-handle replay of its
    accepted edits, and the drain loop still serves after the chaos
    window closes."""
    N, R = 4, 5
    x0, streams = _streams(N, R, seed=11)
    h = _prog.compile(x=512)
    h.run(x=x0)
    reg = MetricRegistry()
    schedule = [
        # deterministic one-shots so every site provably fires
        FaultSpec("sync.mark_counts", at=(4,)),
        FaultSpec("forest.commit", at=(2,)),
        FaultSpec("forest.commit", at=(6,), kind="fatal"),  # -> oracle
        FaultSpec("forest.oracle", at=(1,)),
        FaultSpec("session.evict", at=(1,)),
        FaultSpec("ckpt.commit", at=(1,)),
        FaultSpec("ckpt.save", at=(2,)),
        FaultSpec("session.revive", at=(1,)),
        FaultSpec("ckpt.load", at=(1,)),
        # plus background probabilistic noise the retry ladder absorbs
        FaultSpec("forest.commit", p=0.08, times=3),
        FaultSpec("sync.*", p=0.02, times=2),
    ]
    accepted = {i: [] for i in range(N)}
    inj = ChaosInjector(schedule, seed=23)

    async def main():
        async with h.serve(ckpt_dir=str(tmp_path), registry=reg,
                           max_retries=3) as server:
            sids = [await server.open() for _ in range(N)]
            with inj:
                for r in range(R):
                    res = await asyncio.gather(
                        *[server.submit(sids[i], **streams[i][r])
                          for i in range(N)],
                        return_exceptions=True)
                    for i, x in enumerate(res):
                        assert not isinstance(x, asyncio.CancelledError)
                        if isinstance(x, dict):
                            accepted[i].append(streams[i][r])
                    if r == 1:
                        # mid-soak eviction sweep: hits the evict +
                        # ckpt save/commit sites; failures leave the
                        # session live by contract
                        for sid in sids:
                            try:
                                await server.evict(sid)
                            except Exception:
                                pass
            # chaos window closed: the server must still be serving
            heal = await server.submit(sids[0], **streams[0][0])
            assert isinstance(heal, dict)
            accepted[0].append(streams[0][0])
            finals = [np.asarray(server.outputs(sids[i])) for i in range(N)]
            statuses = [server.sessions[sids[i]].status for i in range(N)]
            summary = server.summary()
            await server.stop()
            return finals, statuses, summary

    finals, statuses, summary = asyncio.run(main())

    # Every single-device site fired under the pinned (schedule, seed).
    assert {"sync.mark_counts", "forest.commit", "forest.oracle",
            "session.evict", "session.revive", "ckpt.save", "ckpt.commit",
            "ckpt.load"} <= inj.fired_sites(), inj.fired_sites()
    # The fault log is the reproducible artifact: re-running this test
    # replays it exactly (same schedule, same seed, same visit order).
    assert len(inj.fired) >= 8

    # Bitwise: every session == fault-free replay of its accepted edits.
    for i in range(N):
        want = _replay(x0, accepted[i])
        np.testing.assert_array_equal(finals[i], want, err_msg=f"session {i}")
        assert statuses[i] in ("live", "quarantined", "evicted")

    assert summary["requests"] >= 1
    assert reg.counters["serve.retries"].value >= 1
