"""Optimizers and schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (clip_by_global_norm, make_adafactor, make_adamw,
                         make_schedule)


def _quadratic_losses(optimizer, steps=120, lr=0.05):
    """Minimize ||x - t||^2 from a fixed start; returns loss trajectory."""
    t = jnp.asarray(np.linspace(-1, 1, 32), jnp.float32)
    params = {"x": jnp.zeros(32), "y": jnp.full((4, 8), 0.5)}
    state = optimizer.init(params)

    def loss_fn(p):
        return jnp.sum((p["x"] - t) ** 2) + jnp.sum(p["y"] ** 2)

    losses = []
    for step in range(steps):
        g = jax.grad(loss_fn)(params)
        params, state = optimizer.update(
            g, state, params, jnp.asarray(step), jnp.asarray(lr))
        losses.append(float(loss_fn(params)))
    return losses


@pytest.mark.parametrize("make", [lambda: make_adamw(),
                                  lambda: make_adamw(state_dtype=jnp.bfloat16),
                                  lambda: make_adafactor()])
def test_optimizer_converges(make):
    losses = _quadratic_losses(make())
    assert losses[-1] < losses[0] * 0.05, losses[-1]


def test_adamw_weight_decay_shrinks_params():
    opt = make_adamw(weight_decay=0.5)
    # decoupled decay applies to matrices (ndim >= 2) only
    params = {"w": jnp.ones((4, 8)), "b": jnp.ones(8)}
    state = opt.init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    p2, _ = opt.update(zeros, state, params, jnp.asarray(0), jnp.asarray(0.1))
    assert float(jnp.max(p2["w"])) < 1.0
    np.testing.assert_allclose(np.asarray(p2["b"]), 1.0)  # vectors undecayed


def test_clip_by_global_norm():
    g = {"a": jnp.full(4, 3.0), "b": jnp.full(9, 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    from repro.optim.base import global_norm
    assert float(norm) == pytest.approx(np.sqrt(4 * 9 + 9 * 16), rel=1e-5)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)
    # below the threshold: untouched
    small = {"a": jnp.full(4, 1e-3)}
    out, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(out["a"]), 1e-3, rtol=1e-5)


@pytest.mark.parametrize("kind", ["cosine", "wsd", "constant"])
def test_schedules_shape(kind):
    sched = make_schedule(kind, 1e-3, 1000)
    vals = [float(sched(jnp.asarray(s))) for s in
            (0, 5, 100, 500, 900, 950, 999, 1000)]
    assert all(v >= 0 for v in vals)
    assert max(vals) <= 1e-3 * 1.001
    # warmup: starts below peak (but nonzero — step 0 must train)
    assert 0 < vals[0] < 1e-3 / 2


def test_wsd_plateau_and_decay():
    sched = make_schedule("wsd", 1e-3, 1000, warmup_steps=50)
    plateau = [float(sched(jnp.asarray(s))) for s in (200, 400, 600, 800)]
    assert all(v == pytest.approx(1e-3, rel=1e-5) for v in plateau)
    assert float(sched(jnp.asarray(995))) < 1e-3 / 2
