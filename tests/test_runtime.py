"""Distributed runtime: checkpointing, data determinism, fault tolerance,
elastic resharding, gradient compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import ckpt
from repro.data import DataPipeline, TokenSource
from repro.runtime import (FaultInjector, StepTimer, Supervisor,
                           make_compressor, remesh_plan, reshard_state)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------
def _state(step=0):
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4) + step,
                       "b": jnp.ones(4) * step},
            "step": jnp.asarray(step)}


def test_ckpt_roundtrip(tmp_path):
    s = _state(7)
    ckpt.save(tmp_path, s, 7)
    restored = ckpt.restore(tmp_path, jax.eval_shape(lambda: _state()))
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_latest_and_gc(tmp_path):
    for step in (10, 20, 30, 40):
        ckpt.save(tmp_path, _state(step), step)
    assert ckpt.latest_step(tmp_path) == 40
    removed = ckpt.gc_old(tmp_path, keep=2)
    assert removed == [10, 20]
    assert ckpt.list_steps(tmp_path) == [30, 40]


def test_ckpt_uncommitted_ignored(tmp_path):
    ckpt.save(tmp_path, _state(1), 1)
    # simulate a crash mid-save: committed marker missing
    d = ckpt.save(tmp_path, _state(2), 2)
    (d / "COMMITTED").unlink()
    assert ckpt.latest_step(tmp_path) == 1
    restored = ckpt.restore(tmp_path, jax.eval_shape(lambda: _state()))
    assert float(restored["step"]) == 1


def test_ckpt_async(tmp_path):
    ckpt.save_async(tmp_path, _state(5), 5)
    ckpt.wait_for_async_saves()
    assert ckpt.latest_step(tmp_path) == 5


def test_ckpt_structure_mismatch(tmp_path):
    ckpt.save(tmp_path, _state(1), 1)
    bad = {"params": {"w": jax.ShapeDtypeStruct((5, 5), jnp.float32)}}
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, bad)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic():
    p1 = DataPipeline(512, global_batch=8, seq_len=32, seed=3)
    p2 = DataPipeline(512, global_batch=8, seq_len=32, seed=3)
    for _ in range(3):
        a, b = next(p1), next(p2)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are tokens shifted by one
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_data_resume():
    p = DataPipeline(512, global_batch=4, seq_len=16, seed=0)
    next(p), next(p)
    state = p.state_dict()
    b3 = next(p)
    q = DataPipeline(512, global_batch=4, seq_len=16, seed=0)
    q.load_state_dict(state)
    np.testing.assert_array_equal(next(q)["tokens"], b3["tokens"])


def test_data_shards_partition_global_batch():
    full = DataPipeline(512, global_batch=8, seq_len=16, seed=1)
    parts = [DataPipeline(512, global_batch=8, seq_len=16, seed=1,
                          shard_id=i, num_shards=4) for i in range(4)]
    gb = full.batch_at(5)["tokens"]
    got = np.concatenate([p.batch_at(5)["tokens"] for p in parts])
    np.testing.assert_array_equal(gb, got)


def test_data_reshard_preserves_stream():
    p = DataPipeline(512, global_batch=8, seq_len=16, seed=1,
                     shard_id=0, num_shards=2)
    p.step = 7
    q = p.reshard(shard_id=1, num_shards=4)
    assert q.step == 7
    # shard 1 of 4 holds rows 2..3 of the global batch
    gb = DataPipeline(512, 8, 16, seed=1).batch_at(7)["tokens"]
    np.testing.assert_array_equal(q.batch_at(7)["tokens"], gb[2:4])


# ---------------------------------------------------------------------------
# Supervisor: crash -> restore -> identical trajectory
# ---------------------------------------------------------------------------
def _toy_training(tmp_path, fault_at):
    """Tiny linear-regression 'training' under the supervisor."""
    target = jnp.asarray(np.random.default_rng(0).standard_normal(16),
                         jnp.float32)

    def init_state():
        return {"w": jnp.zeros(16), "step": jnp.asarray(0)}

    @jax.jit
    def step_fn(state, batch):
        x = jnp.asarray(batch["tokens"][:, :16], jnp.float32) / 512.0

        def loss(w):
            pred = x @ w
            lbl = jnp.asarray(batch["labels"][:, 0], jnp.float32) / 512.0
            return jnp.mean((pred - lbl) ** 2) + 1e-3 * jnp.sum((w - target) ** 2)

        g = jax.grad(loss)(state["w"])
        w = state["w"] - 0.3 * g
        return ({"w": w, "step": state["step"] + 1},
                {"loss": loss(state["w"])})

    pipeline = DataPipeline(512, global_batch=4, seq_len=32, seed=0)
    inj = FaultInjector(fault_at)
    sup = Supervisor(step_fn=step_fn, pipeline=pipeline,
                     ckpt_dir=str(tmp_path), init_state=init_state,
                     ckpt_every=5, fault_injector=inj)
    final = sup.run(20)
    return final, sup


def test_supervisor_restart_exact_trajectory(tmp_path):
    clean, sup_clean = _toy_training(tmp_path / "clean", fault_at=[])
    faulty, sup_faulty = _toy_training(tmp_path / "faulty",
                                       fault_at=[7, 13])
    assert sup_faulty.restarts == 2
    np.testing.assert_array_equal(np.asarray(clean["w"]),
                                  np.asarray(faulty["w"]))
    # metrics replays cover the re-run steps; final logged losses agree
    last_clean = [m for m in sup_clean.metrics_log if m["step"] == 19][0]
    last_faulty = [m for m in sup_faulty.metrics_log if m["step"] == 19][-1]
    assert last_clean["loss"] == last_faulty["loss"]


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    with pytest.raises(RuntimeError, match="injected fault"):
        _toy_training_always_fail(tmp_path)


def _toy_training_always_fail(tmp_path):
    def init_state():
        return {"step": jnp.asarray(0)}

    def step_fn(state, batch):
        raise RuntimeError("injected fault: permanent")

    sup = Supervisor(step_fn=step_fn,
                     pipeline=DataPipeline(512, 4, 16, seed=0),
                     ckpt_dir=str(tmp_path), init_state=init_state,
                     max_restarts=2)
    sup.run(5)


def test_step_timer_flags_stragglers():
    t = StepTimer(straggler_factor=3.0, warmup=2)
    for s in range(6):
        assert not t.observe(s, 0.1)
    assert t.observe(6, 1.0)          # 10x the mean
    assert t.straggler_steps == [6]
    assert not t.observe(7, 0.11)     # baseline unpolluted


# ---------------------------------------------------------------------------
# Elasticity
# ---------------------------------------------------------------------------
def test_remesh_plan():
    assert remesh_plan(256, 16, 256) == (16, 16)
    assert remesh_plan(240, 16, 256) == (8, 16)   # 15 doesn't divide 256
    assert remesh_plan(255, 16, 240) == (15, 16)
    with pytest.raises(AssertionError):
        remesh_plan(8, 16, 256)


def test_reshard_state_local():
    from repro.launch.mesh import make_local_mesh

    state = {"w": jnp.arange(64.0).reshape(8, 8), "s": jnp.asarray(3)}
    axes = {"w": ("batch", None), "s": None}
    mesh = make_local_mesh()
    out = reshard_state(state, axes, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(state["w"]))
    # The local mesh spans every visible device (conftest.py exposes 8
    # host CPU devices for the sharded-propagation tests).
    assert out["w"].sharding.mesh.shape["data"] == len(jax.devices())


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------
def test_topk_compressor_error_feedback():
    comp = make_compressor("topk", frac=0.25)
    g = {"w": jnp.asarray([4.0, 0.1, 0.2, 0.05])}
    out1 = comp(g)
    # only the largest element sent
    np.testing.assert_allclose(np.asarray(out1["w"]), [4.0, 0, 0, 0])
    # residual accumulates: after enough steps the small coords get through
    sent_total = np.asarray(out1["w"])
    for _ in range(8):
        sent_total = sent_total + np.asarray(comp(g)["w"])
    # error feedback ensures total sent approaches total gradient mass
    want = np.asarray(g["w"]) * 9
    assert abs(sent_total.sum() - want.sum()) / want.sum() < 0.2


def test_int8_compressor_unbiased():
    comp = make_compressor("int8", seed=0)
    g = {"w": jnp.full(4096, 0.333)}
    outs = np.stack([np.asarray(comp(g)["w"]) for _ in range(20)])
    np.testing.assert_allclose(outs.mean(), 0.333, rtol=2e-3)


def test_compression_in_train_step():
    """grad_compression hook plugs into make_train_step."""
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.optim import make_optimizer, make_schedule
    from repro.launch.train import init_train_state, make_train_step

    cfg = get_smoke_config("yi_6b")
    model = build_model(cfg)
    opt = make_optimizer(cfg)
    step = make_train_step(model, opt, make_schedule("cosine", 1e-3, 10),
                           grad_compression=make_compressor("int8"))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                     cfg.vocab_size),
    }
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
