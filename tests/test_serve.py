"""Session server: admission, cross-session batching, eviction, accounting.

The serving contract (repro/serve/server.py):

  * >= 8 concurrent sessions branch one warm base; every session's
    result stream is bitwise what a dedicated single-session handle
    would have computed (sessions are *logically* independent);
  * concurrent compatible edits (same trace, same quantized dirty
    signature) batch: the freeze is paid once, observable both in the
    batcher counters and in the shared plan cache (misses stay flat
    while requests grow), and reported through ``obs`` records;
  * idle sessions evict to committed checkpoints and revive bitwise on
    their next edit;
  * every request carries queue-wait / plan / propagate spans into the
    metric registry (p50/p99 come from the histograms).
"""
import asyncio

import numpy as np
import jax.numpy as jnp
import pytest

import repro.sac as sac
from repro.launch.serve import run_session_workload
from repro.obs.metrics import MetricRegistry
from repro.serve.batcher import Batch, EditBatcher, EditRequest, compatible


@sac.incremental(block=16)
def _prog(x):
    y = x * 2.0 + 1.0
    s = sac.stencil(lambda w: w[16:32] + 0.5 * (w[:16] + w[32:]),
                    y, radius=1)
    return sac.reduce(jnp.add, s, identity=0.0)


def _streams(n_sessions, rounds, n=512, seed=0):
    rng = np.random.default_rng(seed)
    x0 = np.arange(n, dtype=np.float32)
    streams = []
    for i in range(n_sessions):
        x = x0.copy()
        edits = []
        for r in range(rounds):
            x = x.copy()
            x[int(rng.integers(0, n))] += float(i + r + 1)
            edits.append({"x": x.copy()})
        streams.append(edits)
    return x0, streams


# ---------------------------------------------------------------------------
# Batcher unit semantics (pure logic, no server)
# ---------------------------------------------------------------------------
class _FakeSession:
    def __init__(self, cg):
        self.cg = cg


class _FakePending:
    def __init__(self, plan):
        self.plan = plan


def _req(cg, plan):
    return EditRequest(session=_FakeSession(cg), inputs={},
                       pending=_FakePending(plan) if plan else None)


def test_batcher_groups_by_trace_and_signature():
    cg_a, cg_b = object(), object()
    p1, p2 = ("skip", "dense"), ("skip", ("sparse", 4))
    reqs = [_req(cg_a, p1), _req(cg_b, p1), _req(cg_a, p1),
            _req(cg_a, p2), _req(cg_a, None)]
    b = EditBatcher()
    batches = b.group(reqs)
    sizes = sorted(len(x) for x in batches)
    assert sizes == [1, 1, 1, 2]          # (a,p1)x2, (b,p1), (a,p2), fallback
    assert b.requests_batched == 1
    assert compatible(reqs[0], reqs[2])
    assert not compatible(reqs[0], reqs[1])   # other trace
    assert not compatible(reqs[0], reqs[3])   # other signature
    assert not compatible(reqs[4], reqs[4])   # unplannable never batches


def test_batcher_max_batch_splits():
    cg = object()
    reqs = [_req(cg, ("dense",)) for _ in range(5)]
    batches = EditBatcher(max_batch=2).group(reqs)
    assert [len(x) for x in batches] == [2, 2, 1]
    # Stable: arrival order preserved through the split.
    flat = [r for b in batches for r in b.requests]
    assert flat == reqs


# ---------------------------------------------------------------------------
# The smoke test: 8 concurrent sessions over one warm base
# ---------------------------------------------------------------------------
def test_server_smoke_eight_sessions(tmp_path):
    N, R = 8, 3
    x0, streams = _streams(N, R)
    h = _prog.compile(x=512)
    base = np.asarray(h.run(x=x0))
    reg = MetricRegistry()
    results, summary = run_session_workload(
        h, streams, ckpt_dir=str(tmp_path), registry=reg)

    # All requests served; cross-session batching actually happened and
    # is visible through the obs records, not just internal counters.
    assert summary["requests"] == N * R
    assert summary["batch_joins"] > 0
    assert summary["batch_hit_rate"] > 0
    assert reg.events("serve.batch"), "no batch events recorded"
    assert len(reg.events("serve.request")) == N * R
    for e in reg.events("serve.request"):
        for span in ("queue_wait_ms", "plan_ms", "propagate_ms",
                     "total_ms"):
            assert span in e and e[span] >= 0.0
    # Batched signatures share the plan cache: one miss per distinct
    # signature, everything else hits.
    pc = summary["plan_cache"]
    assert pc["misses"] < summary["requests"]
    assert pc["hits"] > 0
    # p50/p99 materialize from the histograms.
    assert summary["p50_ms"] > 0 and summary["p99_ms"] >= summary["p50_ms"]

    # Per-session correctness: each stream bitwise equals a dedicated
    # single-session replay; the warm base is bitwise untouched.
    for i, stream in enumerate(streams):
        ref = _prog.compile(x=512)
        ref.run(x=x0)
        for r, edit in enumerate(stream):
            want = np.asarray(ref.update(**edit))
            got = np.asarray(results[i][r]["outputs"])
            assert np.array_equal(want, got), (i, r)
    assert np.array_equal(np.asarray(h.outputs()), base)


def test_server_same_edit_batches_across_sessions(tmp_path):
    """Identical concurrent edits — the strongest batching case: one
    admission wave, one signature, one plan freeze total."""
    N = 8
    x0, streams = _streams(1, 1)
    edit = streams[0][0]
    h = _prog.compile(x=512)
    h.run(x=x0)
    _results, summary = run_session_workload(h, [[edit]] * N)
    assert summary["requests"] == N
    assert summary["batch_joins"] == N - 1      # all in one batch
    assert summary["plan_cache"]["misses"] == 1


def test_server_concurrent_same_session_submits_serialize():
    """Two concurrent submits to ONE session land in one admission wave;
    the server must serialize them — the second edit planned only after
    the first commit — or the second plan's mark masks are computed
    against pre-commit state and skip nodes that are actually dirty.
    The edits are independent (B leaves A's index at its base value), so
    a stale plan would produce a state that is neither A nor B."""
    n = 512
    x0 = np.arange(n, dtype=np.float32)
    a = x0.copy()
    a[3] += 1.0
    b = x0.copy()
    b[400] += 2.0
    h = _prog.compile(x=n)
    h.run(x=x0)

    async def main():
        async with h.serve() as server:
            sid = await server.open()
            r1, r2 = await asyncio.gather(server.submit(sid, x=a),
                                          server.submit(sid, x=b))
            final = server.outputs(sid)
            await server.shutdown()
            return r1, r2, np.asarray(final)

    r1, r2, final = asyncio.run(main())
    ref = _prog.compile(x=n)
    ref.run(x=x0)
    assert np.array_equal(np.asarray(ref.update(x=a)),
                          np.asarray(r1["outputs"]))
    want = np.asarray(ref.update(x=b))
    assert np.array_equal(want, np.asarray(r2["outputs"]))
    assert np.array_equal(want, final)


def test_server_outputs_copy_survives_next_commit():
    """``outputs()`` hands back owned buffers: the session's next commit
    donates the touched output leaves in place, which must not delete a
    previously read result under the caller."""
    x0, streams = _streams(1, 2)
    e1, e2 = streams[0]
    h = _prog.compile(x=512)
    h.run(x=x0)

    async def main():
        async with h.serve() as server:
            sid = await server.open()
            await server.submit(sid, **e1)
            snap = server.outputs(sid)
            await server.submit(sid, **e2)   # donates the output leaf
            await server.shutdown()
            return np.asarray(snap)

    snap = asyncio.run(main())
    ref = _prog.compile(x=512)
    ref.run(x=x0)
    assert np.array_equal(np.asarray(ref.update(**e1)), snap)


def test_server_drain_loop_survives_internal_errors():
    """An exception escaping the wave (server-side bug) or the idle
    sweep must fail that wave's futures — not kill the drain task and
    hang every later submit forever."""
    x0, streams = _streams(1, 1)
    edit = streams[0][0]
    h = _prog.compile(x=512)
    h.run(x=x0)

    def _boom(*_a, **_k):
        raise RuntimeError("boom")

    async def main():
        async with h.serve() as server:
            sid = await server.open()
            orig_group = server.batcher.group
            server.batcher.group = _boom
            with pytest.raises(RuntimeError, match="boom"):
                await server.submit(sid, **edit)
            server.batcher.group = orig_group
            server.evict_idle = _boom    # sweep errors must not kill it
            res = await server.submit(sid, **edit)
            await server.shutdown()
            return res

    res = asyncio.run(main())
    ref = _prog.compile(x=512)
    ref.run(x=x0)
    assert np.array_equal(np.asarray(ref.update(**edit)),
                          np.asarray(res["outputs"]))


# ---------------------------------------------------------------------------
# Eviction / revival
# ---------------------------------------------------------------------------
def test_server_evict_and_revive_bitwise(tmp_path):
    x0, streams = _streams(1, 2)
    h = _prog.compile(x=512)
    h.run(x=x0)

    async def main():
        async with h.serve(ckpt_dir=str(tmp_path)) as server:
            sid = await server.open()
            r1 = await server.submit(sid, **streams[0][0])
            await server.evict(sid)
            assert server.sessions[sid].status == "evicted"
            r2 = await server.submit(sid, **streams[0][1])  # auto-revive
            assert server.sessions[sid].status == "live"
            assert server.sessions[sid].revivals == 1
            summary = server.summary()
            await server.shutdown()
            return r1, r2, summary

    r1, r2, summary = asyncio.run(main())
    ref = _prog.compile(x=512)
    ref.run(x=x0)
    assert np.array_equal(np.asarray(ref.update(**streams[0][0])),
                          np.asarray(r1["outputs"]))
    assert np.array_equal(np.asarray(ref.update(**streams[0][1])),
                          np.asarray(r2["outputs"]))
    assert summary["requests"] == 2


def test_server_idle_eviction(tmp_path):
    x0, streams = _streams(1, 1)
    h = _prog.compile(x=512)
    h.run(x=x0)

    async def main():
        async with h.serve(ckpt_dir=str(tmp_path),
                           evict_idle_s=0.0) as server:
            sid = await server.open()
            await server.submit(sid, **streams[0][0])
            await asyncio.sleep(0.01)
            # The drain loop sweeps idle sessions after each cycle; the
            # manual sweep covers the no-traffic case.  Either way the
            # session must be checkpointed out by now.
            server.evict_idle()
            assert server.sessions[sid].status == "evicted"
            # Reads revive too.
            out = server.outputs(sid)
            assert server.sessions[sid].status == "live"
            await server.shutdown()
            return np.asarray(out)

    out = asyncio.run(main())
    ref = _prog.compile(x=512)
    ref.run(x=x0)
    assert np.array_equal(np.asarray(ref.update(**streams[0][0])), out)


# ---------------------------------------------------------------------------
# Guardrails
# ---------------------------------------------------------------------------
def test_server_session_limit():
    x0, _ = _streams(1, 1)
    h = _prog.compile(x=512)
    h.run(x=x0)

    async def main():
        async with h.serve(max_sessions=2) as server:
            await server.open()
            await server.open()
            with pytest.raises(RuntimeError, match="session limit"):
                await server.open()
            await server.shutdown()

    asyncio.run(main())


def test_server_rejects_non_graph_backend():
    x0, _ = _streams(1, 1)
    h = _prog.compile("host", x=512)
    h.run(x=x0)
    with pytest.raises(AssertionError, match="graph backend"):
        from repro.serve import SessionServer

        SessionServer(h)


def test_server_bad_input_name_rejected_per_request():
    x0, streams = _streams(1, 1)
    h = _prog.compile(x=512)
    h.run(x=x0)

    async def main():
        async with h.serve() as server:
            sid = await server.open()
            with pytest.raises(AssertionError, match="unknown inputs"):
                await server.submit(sid, bogus=x0)
            # The session (and server) survive a bad request.
            res = await server.submit(sid, **streams[0][0])
            await server.shutdown()
            return res

    res = asyncio.run(main())
    ref = _prog.compile(x=512)
    ref.run(x=x0)
    assert np.array_equal(np.asarray(ref.update(**streams[0][0])),
                          np.asarray(res["outputs"]))


# ---------------------------------------------------------------------------
# Typed session errors
# ---------------------------------------------------------------------------
def test_unknown_session_typed_errors():
    """Unknown or closed sids get a typed UnknownSession on every
    session-addressed call — not a KeyError from the internals."""
    from repro.serve import UnknownSession

    x0, streams = _streams(1, 1)
    h = _prog.compile(x=512)
    h.run(x=x0)

    async def main():
        async with h.serve() as server:
            with pytest.raises(UnknownSession, match="nope"):
                await server.submit("nope", **streams[0][0])
            with pytest.raises(UnknownSession):
                server.outputs("nope")
            with pytest.raises(UnknownSession):
                await server.evict("nope")
            sid = await server.open()
            await server.close_session(sid)
            # a closed sid is gone for edits/reads...
            with pytest.raises(UnknownSession):
                await server.submit(sid, **streams[0][0])
            # ...but close is idempotent (retried teardown is a no-op)
            await server.close_session(sid)
            await server.shutdown()

    asyncio.run(main())


# ---------------------------------------------------------------------------
# Shutdown paths
# ---------------------------------------------------------------------------
def test_server_stop_resolves_parked_futures():
    """stop() with a non-empty queue serves (never abandons) every
    parked future before returning."""
    x0, streams = _streams(4, 1)
    h = _prog.compile(x=512)
    h.run(x=x0)

    async def main():
        async with h.serve() as server:
            sids = [await server.open() for _ in range(4)]
            # Park the submits: suppress the drain wake-up so the queue
            # fills without being served.
            real_set = server._wake.set
            server._wake.set = lambda: None
            tasks = [asyncio.ensure_future(
                server.submit(sids[i], **streams[i][0])) for i in range(4)]
            await asyncio.sleep(0.01)
            assert len(server._queue) == 4     # parked, unserved
            server._wake.set = real_set
            await server.stop()                # must drain, then stop
            res = await asyncio.gather(*tasks)
            assert all("outputs" in r for r in res)
            return [np.asarray(r["outputs"]) for r in res]

    outs = asyncio.run(main())
    for i, out in enumerate(outs):
        ref = _prog.compile(x=512)
        ref.run(x=x0)
        assert np.array_equal(np.asarray(ref.update(**streams[i][0])), out)


def test_server_shutdown_with_inflight_submits():
    """shutdown() while submits are in flight: every future resolves
    (served — they were admitted before the stop), then sessions are
    released."""
    x0, streams = _streams(1, 2)
    h = _prog.compile(x=512)
    h.run(x=x0)

    async def main():
        server = h.serve()
        async with server:
            sid = await server.open()
            t1 = asyncio.ensure_future(server.submit(sid, **streams[0][0]))
            t2 = asyncio.ensure_future(server.submit(sid, **streams[0][1]))
            await asyncio.sleep(0)             # enqueue both
            await server.shutdown()
            r1, r2 = await asyncio.gather(t1, t2)
            assert "outputs" in r1 and "outputs" in r2
            assert server.sessions == {}       # released, not leaked
        return np.asarray(r2["outputs"])

    out = asyncio.run(main())
    ref = _prog.compile(x=512)
    ref.run(x=x0)
    ref.update(**streams[0][0])
    assert np.array_equal(np.asarray(ref.update(**streams[0][1])), out)


def test_server_double_start_rejected():
    x0, _ = _streams(1, 1)
    h = _prog.compile(x=512)
    h.run(x=x0)

    async def main():
        async with h.serve() as server:
            with pytest.raises(AssertionError, match="already started"):
                server.start()
            await server.shutdown()

    asyncio.run(main())


def test_server_submit_after_stop_clean_error():
    from repro.serve import ServerClosed

    x0, streams = _streams(1, 1)
    h = _prog.compile(x=512)
    h.run(x=x0)

    async def main():
        server = h.serve()
        async with server:
            sid = await server.open()
        # exited: stopped but sessions still readable
        out = np.asarray(server.outputs(sid))
        with pytest.raises(ServerClosed):
            await server.submit(sid, **streams[0][0])
        return out

    out = asyncio.run(main())
    ref = _prog.compile(x=512)
    ref.run(x=x0)
    assert np.array_equal(np.asarray(ref.outputs()), out)
