"""Computation-distance tests (Definition 4.2, Theorem 4.2)."""
import math
import random

from repro.core import Engine
from repro.core.distance import computation_distance


def build_sum(eng, n):
    mods = eng.alloc_array(n, "x")
    for i, m in enumerate(mods):
        eng.write(m, i)
    res = eng.mod("res")

    def rec(lo, hi, out):
        if hi - lo == 1:
            eng.read(mods[lo], lambda v: eng.write(out, v))
            return
        mid = (lo + hi) // 2
        l, r = eng.mod(), eng.mod()
        eng.par(lambda: rec(lo, mid, l), lambda: rec(mid, hi, r))
        eng.read((l, r), lambda a, b: eng.write(out, a + b))

    comp = eng.run(lambda: rec(0, n, res))
    return mods, res, comp


def run_fresh(n, values):
    eng = Engine()
    mods = eng.alloc_array(n, "x")
    for m, v in zip(mods, values):
        eng.write(m, v)
    res = eng.mod("res")

    def rec(lo, hi, out):
        if hi - lo == 1:
            eng.read(mods[lo], lambda v: eng.write(out, v))
            return
        mid = (lo + hi) // 2
        l, r = eng.mod(), eng.mod()
        eng.par(lambda: rec(lo, mid, l), lambda: rec(mid, hi, r))
        eng.read((l, r), lambda a, b: eng.write(out, a + b))

    comp = eng.run(lambda: rec(0, n, res))
    return comp


def test_identical_runs_zero_distance():
    n = 64
    a = run_fresh(n, list(range(n)))
    b = run_fresh(n, list(range(n)))
    d = computation_distance(a.root, b.root)
    assert d.work == 0 and d.affected_reads == 0


def test_single_change_log_distance():
    n = 64
    vals = list(range(n))
    a = run_fresh(n, vals)
    vals2 = list(vals)
    vals2[17] = 999
    b = run_fresh(n, vals2)
    d = computation_distance(a.root, b.root)
    # leaf + log2(64) combines, counted in both trees
    assert d.affected_reads == 2 * (1 + int(math.log2(n)))


def test_theorem_4_2_bound():
    """Affected reads of a k-update are O(k log(1 + n/k))."""
    n = 256
    rng = random.Random(0)
    for k in (1, 4, 16, 64, 256):
        eng = Engine()
        mods, res, comp = build_sum(eng, n)
        idx = rng.sample(range(n), k)
        for i in idx:
            eng.write(mods[i], 1000 + i)
        st = comp.propagate()
        bound = 4 * k * (1 + math.log2(1 + n / k))
        assert st.affected_readers <= bound, (k, st.affected_readers, bound)
        assert res.peek() == sum(
            1000 + i if i in set(idx) else i for i in range(n))


def test_propagation_work_matches_distance():
    """Realized propagation re-execution equals the distance frontier."""
    n = 128
    vals = list(range(n))
    eng = Engine()
    mods, res, comp = build_sum(eng, n)
    vals2 = list(vals)
    for i in (3, 77):
        vals2[i] = -5
        eng.write(mods[i], -5)
    st = comp.propagate()
    fresh = run_fresh(n, vals2)
    d = computation_distance(comp.root, fresh.root)
    # distance counts affected pairs over both trees; propagation re-ran
    # one reader per pair.
    assert d.affected_reads == 0  # updated tree == fresh tree (determinism)
    assert res.peek() == sum(vals2)
    assert st.affected_readers >= 2
