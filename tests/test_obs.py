"""Observability layer: records, sync-point rule, Chrome trace, metrics.

The contract under test (DESIGN.md §Observability):

  * every backend — graph, host, hybrid, and the mesh-sharded graph —
    emits one ``PropagationRecord`` per update with phase timings,
    per-level counts + regime labels, and plan-cache state;
  * ``trace="counters"`` adds ZERO host sync points to the planned
    propagate (asserted by counting ``repro.obs.syncpoints`` calls with
    tracing off vs on) and leaves stats bitwise unchanged;
  * ``trace="deep"`` fences per level and records real per-level ms;
  * the Chrome-trace export is valid JSON with per-row monotonic
    timestamps and one complete event per phase and per level;
  * the metric registry / flight ring / JSONL sink and the supervisor's
    straggler + checkpoint/restart events all round-trip.
"""
import io
import json

import jax.numpy as jnp
import numpy as np
import pytest

import repro.sac as sac
from repro.obs import (JsonlSink, MetricRegistry, PropagationRecorder,
                       chrome_trace, syncpoints)
from repro.obs.record import (LevelRecord, PhaseSpan, PropagationRecord,
                              merge_records)

N, BLOCK = 256, 16


@sac.incremental(block=BLOCK)
def pipeline(x):
    y = x * 2.0 + 1.0
    s = sac.stencil(lambda w: w[BLOCK:2 * BLOCK]
                    + 0.5 * (w[:BLOCK] + w[2 * BLOCK:]), y, radius=1)
    return sac.reduce(jnp.add, s, identity=0.0)


def _data(seed=0):
    rng = np.random.default_rng(seed)
    x0 = rng.integers(-5, 6, N).astype(np.float32)
    x1 = x0.copy()
    x1[3] += 1.0
    x1[200] += 2.0
    return x0, x1


BACKENDS = [("graph", {}), ("graph", {"shards": 2}),
            ("host", {}), ("hybrid", {}), ("hybrid", {"shards": 2})]


@pytest.mark.parametrize("backend,kw", BACKENDS,
                         ids=[f"{b}{'-sh' if k else ''}" for b, k in BACKENDS])
def test_record_per_backend(backend, kw):
    """One update on every substrate yields a record with phases,
    per-level counts, and regime labels — and outputs stay bitwise
    identical to the untraced handle."""
    mode = "counters" if backend == "host" else "deep"
    x0, x1 = _data()
    h = pipeline.compile(backend=backend, trace=mode, x=N, **kw)
    h.run(x=x0)
    out = h.update(x=x1)
    plain = pipeline.compile(backend=backend, x=N, **kw)
    plain.run(x=x0)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(plain.update(x=x1)))
    rec = h.record
    assert rec is not None
    d = rec.to_dict()
    assert d["substrate"] == backend
    assert d["mode"] == mode
    assert [p["name"] for p in d["phases"]]
    assert d["counters"]["dirty_inputs"] == 2
    assert d["counters"]["recomputed"] == int(plain.stats["recomputed"])
    lvls = d["levels"]
    assert lvls and all("regimes" in lv for lv in lvls)
    assert sum(lv["recomputed"] or 0 for lv in lvls) \
        == d["counters"]["recomputed"]
    assert any(lv["regimes"] for lv in lvls)
    if mode == "deep" and backend == "graph" and not kw:
        assert d["fenced"]
        assert all(lv["ms"] is not None for lv in lvls)
    if kw.get("shards"):
        assert d["collectives"], d
    # the export is always valid JSON
    json.dumps(chrome_trace([rec]))


def test_counters_mode_adds_zero_host_syncs():
    """The sync-point rule: the planned propagate makes exactly the
    same sequence of host syncs with ``trace='counters'`` as with
    tracing off — and stats are bitwise unchanged."""
    x0, x1 = _data()

    def syncs_of(h):
        h.run(x=x0)
        h.update(x=x1)          # warm: plan freeze + compile
        h.update(x=x0)
        calls = []
        old = syncpoints.HOOK
        syncpoints.HOOK = lambda tag, kind: calls.append((tag, kind))
        try:
            h.update(x=x1)
            st = h.stats
        finally:
            syncpoints.HOOK = old
        return calls, st

    plain_calls, plain_stats = syncs_of(pipeline.compile(x=N))
    traced_calls, traced_stats = syncs_of(
        pipeline.compile(x=N, trace="counters"))
    assert traced_calls == plain_calls
    assert plain_calls == [("mark_counts", "host_read")]
    for key in ("recomputed", "affected", "dirty_inputs"):
        assert plain_stats[key] == traced_stats[key], key


def test_deep_mode_fences_are_tagged():
    """Deep mode pays for per-level wall-clock with per-level fences —
    all routed through syncpoints, tagged with the level."""
    x0, x1 = _data()
    h = pipeline.compile(x=N, trace="deep")
    h.run(x=x0)
    h.update(x=x1)
    h.update(x=x0)
    calls = []
    old = syncpoints.HOOK
    syncpoints.HOOK = lambda tag, kind: calls.append((tag, kind))
    try:
        h.update(x=x1)
    finally:
        syncpoints.HOOK = old
    fences = [t for t, k in calls if k == "fence"]
    assert any(t.startswith("level_") for t in fences), calls
    assert ("mark_counts", "host_read") in calls


def test_chrome_trace_schema():
    """Valid trace-event JSON: thread-name metadata per row, one
    complete event per phase and per level, monotonic ts per row."""
    x0, x1 = _data()
    h = pipeline.compile(x=N, trace="deep")
    h.run(x=x0)
    h.update(x=x1)
    trace = json.loads(json.dumps(chrome_trace([h.record])))
    evs = trace["traceEvents"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert metas and all(e["name"] == "thread_name" for e in metas)
    X = [e for e in evs if e["ph"] == "X"]
    names = {e["name"] for e in X}
    assert {"mark", "plan", "execute"} <= names
    n_levels = len(h.record.levels)
    assert sum(1 for e in X if e["name"].startswith("L")) == n_levels
    for e in X:
        assert e["ts"] >= 0 and e["dur"] >= 0
    by_tid = {}
    for e in X:
        if e["cat"] == "level":
            by_tid.setdefault(e["tid"], []).append(e["ts"])
    for tids in by_tid.values():
        assert tids == sorted(tids)
        assert len(set(tids)) == len(tids), "level ts not strictly increasing"


def test_profile_api(tmp_path):
    """``handle.profile()`` works on a handle compiled WITHOUT trace=
    (temporary deep recorder), writes the trace file, and detaches."""
    x0, x1 = _data()
    h = pipeline.compile(x=N)
    h.run(x=x0)
    out = tmp_path / "trace.json"
    trace = h.profile({"x": x1}, path=str(out))
    assert h.recorder is None                 # temp recorder detached
    assert trace["traceEvents"]
    disk = json.loads(out.read_text())
    assert disk == json.loads(json.dumps(trace))
    # deep mode was forced: levels carry fenced ms
    lvl = [e for e in trace["traceEvents"]
           if e.get("cat") == "level"]
    assert lvl and any(e["dur"] > 0 for e in lvl)


def test_flight_recorder_bounded():
    """The flight ring keeps the last N records; dump() is JSON-able."""
    x0, x1 = _data()
    h = pipeline.compile(x=N, trace="counters", trace_flight=3)
    h.run(x=x0)
    for i in range(5):
        h.update(x=x1 if i % 2 == 0 else x0)
    recs = h.records()
    assert len(recs) == 3
    assert [r.seq for r in recs] == [2, 3, 4]
    dump = h.recorder.dump()
    json.dumps(dump)
    assert len(dump) == 3 and dump[-1]["seq"] == 4


def test_hybrid_merged_plan_cache_shape():
    """Satellite pin: the hybrid backend's ``stats['plan_cache']`` is
    the merged per-fragment summary — scalar hit/miss/eviction sums,
    per-fragment size/cap lists — and is always present."""
    x0, x1 = _data()
    h = pipeline.compile(backend="hybrid", x=N)
    h.run(x=x0)
    h.update(x=x1)
    pc = h.stats["plan_cache"]
    assert set(pc) == {"hits", "misses", "evictions", "size", "cap"}
    for k in ("hits", "misses", "evictions"):
        assert isinstance(pc[k], int), (k, pc)
    assert isinstance(pc["size"], list) and isinstance(pc["cap"], list)
    assert len(pc["size"]) == len(pc["cap"]) >= 1
    assert pc["misses"] >= 1
    h.update(x=x0)
    h.update(x=x1)
    assert h.stats["plan_cache"]["hits"] >= 1


def test_merge_records_sums_and_tags():
    a = PropagationRecord(
        substrate="graph", seq=0, mode="counters", t_start=0.0,
        levels=[LevelRecord(level=0, nodes=1, regimes={"dense": 1},
                            recomputed=3)],
        counters={"recomputed": 3}, collectives={"mark": {"x:psum": 1}})
    b = PropagationRecord(
        substrate="graph", seq=0, mode="counters", t_start=0.0,
        levels=[LevelRecord(level=0, nodes=2, regimes={"skip": 2},
                            recomputed=4)],
        counters={"recomputed": 4}, collectives={"mark": {"x:psum": 2}})
    m = merge_records([a, b], substrate="hybrid", seq=7, mode="counters",
                      t_start=0.0,
                      phases=[PhaseSpan("execute", 0.0, 1.0)])
    assert m.counters["recomputed"] == 7
    assert [lv.fragment for lv in m.levels] == ["f0", "f1"]
    assert m.collectives == {"mark": {"x:psum": 3}}
    assert len(m.fragments) == 2


# ---------------------------------------------------------------------------
# Metric registry + sink + supervisor routing
# ---------------------------------------------------------------------------
def test_metric_registry_and_sink():
    buf = io.StringIO()
    reg = MetricRegistry(sink=JsonlSink(buf))
    reg.counter("c").inc()
    reg.counter("c").inc(2)
    assert reg.counter("c").value == 3
    for v in (1.0, 2.0, 3.0, 4.0, 100.0):
        reg.histogram("h").observe(v)
    assert reg.histogram("h").count == 5
    assert reg.histogram("h").percentile(50) == 3.0
    reg.event("straggler", step=6)
    reg.event("restart", step=7)
    assert [e["event"] for e in reg.events()] == ["straggler", "restart"]
    assert reg.events("restart") == [{"event": "restart", "step": 7}]
    lines = [json.loads(l) for l in buf.getvalue().splitlines()]
    assert lines == [{"event": "straggler", "step": 6},
                     {"event": "restart", "step": 7}]
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 3
    assert snap["histograms"]["h"]["max"] == 100.0


def test_recorder_feeds_registry():
    x0, x1 = _data()
    reg = MetricRegistry()
    h = pipeline.compile(x=N)
    h._attach_recorder(PropagationRecorder(mode="counters", registry=reg))
    h.run(x=x0)
    h.update(x=x1)
    h.update(x=x0)
    assert reg.counter("propagates").value == 2
    assert reg.histogram("propagate_ms.graph").count == 2
    # edit + revert share one dirty signature: one freeze, one hit
    assert reg.counter("plan_cache.misses").value == 1
    assert reg.counter("plan_cache.hits").value == 1
    # the cache's live event bridge fires as they happen too
    assert reg.counter("plan_cache.miss_events").value == 1
    assert reg.counter("plan_cache.hit_events").value == 1


def test_step_timer_registry_routing():
    """Straggler events flow through the registry; the public
    ``straggler_steps`` list is unchanged."""
    from repro.runtime.supervisor import StepTimer

    reg = MetricRegistry()
    t = StepTimer(straggler_factor=3.0, warmup=2, registry=reg)
    for s in range(6):
        assert not t.observe(s, 0.1)
    assert t.observe(6, 1.0)
    assert t.straggler_steps == [6]
    assert reg.counter("stragglers").value == 1
    (ev,) = reg.events("straggler")
    assert ev["step"] == 6
    assert reg.histogram("step_ms").count == 7


def test_supervisor_emits_checkpoint_and_restart_events(tmp_path):
    from repro.data import DataPipeline
    from repro.runtime.supervisor import FaultInjector, Supervisor

    def init_state():
        return {"w": jnp.zeros(4), "step": jnp.asarray(0)}

    def step_fn(state, batch):
        return ({"w": state["w"] + 1.0, "step": state["step"] + 1},
                {"loss": jnp.float32(0.0)})

    reg = MetricRegistry()
    sup = Supervisor(step_fn=step_fn,
                     pipeline=DataPipeline(512, 4, 16, seed=0),
                     ckpt_dir=str(tmp_path), init_state=init_state,
                     ckpt_every=5, fault_injector=FaultInjector([7]),
                     registry=reg)
    sup.run(10)
    assert sup.restarts == 1
    assert reg.counter("restarts").value == 1
    (rs,) = reg.events("restart")
    assert rs["step"] == 5                  # resumed from the step-5 ckpt
    kinds = [e["kind"] for e in reg.events("checkpoint")]
    assert kinds.count("final") == 1
    assert reg.counter("checkpoints").value == len(kinds)


# ---------------------------------------------------------------------------
# Bench provenance
# ---------------------------------------------------------------------------
def test_bench_rows_carry_provenance():
    import benchmarks.graph_pipeline as bench

    rows = bench.bench_pipeline(1 << 10, 16, [1])
    (r,) = rows
    assert r["fence"] == "block_until_ready"
    assert r["estimator"] == "best_of_reps"
    assert r["reps"] == 5 and r["paired_interleave"] is False
    assert r["devices"] >= 1
    committed = json.loads(bench.BASELINE.read_text())
    assert all("fence" in row and "estimator" in row for row in committed)
