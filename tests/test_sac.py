"""repro.sac: the tracing frontend over both execution backends.

The API contract under test: an ordinary Python function decorated with
``@sac.incremental`` traces to one static SP-dag, and the SAME trace
executes on the jitted graph runtime and on the paper-faithful host
engine with bitwise-identical outputs and matching changed-block counts.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

import repro.sac as sac


def _rand(n, seed=0, lo=-5, hi=6):
    return np.random.default_rng(seed).integers(lo, hi, n).astype(np.float32)


@sac.incremental(block=8)
def pipeline(x):
    y = x * 2.0 + 1.0
    s = sac.stencil(lambda w: w[8:16] + 0.5 * (w[:8] + w[16:]), y, radius=1)
    return sac.reduce(jnp.add, s, identity=0.0)


# ---------------------------------------------------------------------------
# The decorator + handle facade
# ---------------------------------------------------------------------------
def test_run_update_stats_facade():
    h = pipeline.compile(x=512, max_sparse=8)
    data = _rand(512)
    out = h.run(x=data)
    assert h.stats["phase"] == "run"
    edited = data.copy()
    edited[100] += 4.0
    out2 = h.update(x=edited)
    scratch = pipeline.compile(x=512, max_sparse=8).run(x=edited)
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(scratch))
    st = h.stats
    assert st["phase"] == "update" and st["dirty_inputs"] == 1
    assert 0 < st["recomputed"] < h.cg.total_blocks
    # stats is a snapshot, not a live view
    snap = h.stats
    h.update(x=edited)
    assert snap["phase"] == "update"


def test_compile_requires_all_input_sizes():
    with pytest.raises(TypeError, match="missing"):
        pipeline.compile(max_sparse=8)


def test_update_before_run_raises():
    h = pipeline.compile(x=64)
    with pytest.raises(RuntimeError):
        h.update(x=np.zeros(64, np.float32))


def test_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        pipeline.compile("tpu-v9", x=64)


def test_input_spec_forms():
    data = _rand(256)
    for spec in (256, (256,), data):
        h = pipeline.compile(x=spec, max_sparse=4)
        np.testing.assert_array_equal(np.asarray(h.run(x=data)),
                                      np.asarray(pipeline.compile(
                                          x=256, max_sparse=4).run(x=data)))


def test_per_input_block_dict():
    @sac.incremental(block={"a": 8, "b": 4})
    def prog(a, b):
        return sac.reduce(jnp.add, a), sac.reduce(jnp.add, b)

    h = prog.compile(a=64, b=32, max_sparse=4)
    assert h.cg.nodes[h.cg.input_names["a"]].block == 8
    assert h.cg.nodes[h.cg.input_names["b"]].block == 4


# ---------------------------------------------------------------------------
# Operator overloading + ufunc interception
# ---------------------------------------------------------------------------
def test_operators_and_ufuncs_lower_to_jnp():
    @sac.incremental(block=4)
    def prog(a, b):
        u = np.tanh(a)                   # unary numpy ufunc -> jnp.tanh
        v = np.maximum(a, b)             # binary ufunc, two tracers
        w = np.add(1.0, v)               # ufunc with a leading constant
        z = (2.0 * u - w / 4.0) ** 2
        z = -z + abs(b)
        return sac.reduce(jnp.add, z)

    h = prog.compile(a=64, b=64, max_sparse=4)
    a, b = _rand(64, 1), _rand(64, 2)
    out = h.run(a=a, b=b)
    want = (-((2 * np.tanh(a) - (1 + np.maximum(a, b)) / 4) ** 2)
            + np.abs(b)).sum()
    np.testing.assert_allclose(float(out[0]), float(want), rtol=1e-5)


def test_jnp_coercion_raises():
    # jnp functions coerce eagerly and cannot see the tracer; whether
    # jax consults __jax_array__ (our pointed message) or rejects the
    # argument itself, the failure must be a TypeError at trace time,
    # never a silently-concretized value.
    @sac.incremental(block=4)
    def prog(x):
        return jnp.tanh(x)

    with pytest.raises(TypeError):
        prog.compile(x=16)


def test_elementwise_lifts_arbitrary_fn():
    @sac.incremental(block=4)
    def prog(x):
        return sac.reduce(jnp.add, sac.elementwise(jnp.tanh)(x))

    h = prog.compile(x=32, max_sparse=4)
    d = _rand(32, 3)
    np.testing.assert_allclose(float(h.run(x=d)[0]),
                               float(np.tanh(d).sum()), rtol=1e-5)


# ---------------------------------------------------------------------------
# seq/par context managers
# ---------------------------------------------------------------------------
def test_seq_context_manager_orders_ops():
    @sac.incremental(block=4)
    def prog(x):
        with sac.seq():
            a = x + 1.0
            b = x * 2.0                  # no data edge, but seq-ordered
        return a, b

    h = prog.compile(x=32)
    a_h, b_h = h.out_handles
    assert h.cg.level_of[b_h.idx] > h.cg.level_of[a_h.idx]


def test_par_inside_seq_shares_level():
    @sac.incremental(block=4)
    def prog(x):
        with sac.seq():
            pre = x + 1.0
            with sac.par():
                a = pre * 2.0
                b = pre * 3.0
            post = sac.zip_blocks(lambda u, v: u + v, a, b)
        return post, a, b

    h = prog.compile(x=32)
    post_h, a_h, b_h = h.out_handles
    assert h.cg.level_of[a_h.idx] == h.cg.level_of[b_h.idx]
    assert h.cg.level_of[post_h.idx] > h.cg.level_of[a_h.idx]


def test_seq_par_outside_trace_raise():
    with pytest.raises(RuntimeError, match="outside"):
        sac.seq()
    with pytest.raises(RuntimeError, match="outside"):
        sac.par()


# ---------------------------------------------------------------------------
# Backend parity (the core contract; broader sweeps in test_sac_property)
# ---------------------------------------------------------------------------
def _both(prog, edits, **inputs):
    hg = prog.compile(max_sparse=4, **inputs)
    hh = prog.compile("host", **inputs)
    arrays = {k: v for k, v in inputs.items()}
    og, oh = hg.run(**arrays), hh.run(**arrays)
    yield hg, hh, og, oh
    for ed in edits:
        og, oh = hg.update(**ed), hh.update(**ed)
        yield hg, hh, og, oh


def _assert_same(og, oh):
    if not isinstance(og, tuple):
        og, oh = (og,), (oh,)
    for a, b in zip(og, oh):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_host_graph_parity_all_op_kinds():
    @sac.incremental(block=4)
    def prog(x, y):
        with sac.par():
            u = x + y                    # zip_map
            v = sac.stencil(lambda w: w[4:8] + w[:4] - w[8:], x,
                            radius=1)    # stencil (clamped)
        f = sac.stencil(lambda w: w[4:8] * 0.5 + w[8:], y, radius=1,
                        fill=1.0)        # stencil (filled)
        s = sac.scan(jnp.add, u)         # agg + escan + local
        t = sac.reduce(jnp.maximum, v, identity=-jnp.inf)
        return s, t, f

    x, y = _rand(48, 5), _rand(48, 6)    # 12 blocks: not a power of two
    x2 = x.copy(); x2[13] = 9.0
    y2 = y.copy(); y2[0] -= 1.0; y2[47] += 2.0
    for hg, hh, og, oh in _both(prog, [dict(x=x2), dict(y=y2)], x=x, y=y):
        _assert_same(og, oh)
        if hg.stats.get("phase") == "update":
            assert hg.stats["affected"] == hh.stats["affected"]
            assert hg.stats["dirty_inputs"] == hh.stats["dirty_inputs"]


def test_host_backend_work_span_accounting():
    """The host backend reports the paper's exact counters and realizes
    O(k)-ish propagation work for a 1-block edit."""
    @sac.incremental(block=4)
    def prog(x):
        return sac.reduce(jnp.add, x * 1.5)

    h = prog.compile("host", x=64)
    d = _rand(64, 7)
    h.run(x=d)
    full_work = h.stats["work"]
    assert full_work > 0 and h.stats["span"] > 0
    d2 = d.copy(); d2[30] += 1.0
    h.update(x=d2)
    st = h.stats
    assert 0 < st["work"] < full_work
    assert st["recomputed"] <= 2 + int(np.ceil(np.log2(16)))


def test_host_value_cutoff_stops_propagation():
    @sac.incremental(block=4)
    def prog(x):
        return sac.reduce(jnp.add, sac.map_blocks(
            lambda b: jnp.clip(b, 0.0, 1.0), x))

    h = prog.compile("host", x=64)
    d = np.full(64, 5.0, np.float32)     # saturates to 1 everywhere
    h.run(x=d)
    d2 = d.copy(); d2[10] = 9.0          # still saturates
    out = h.update(x=d2)
    assert float(out[0]) == 64.0
    assert h.stats["recomputed"] == 1    # the map block only
    assert h.stats["affected"] == 0


def test_causal_via_frontend_both_backends():
    block = 4

    def cmean(x, i):
        pos = jnp.arange(x.shape[0]) // block
        w = (pos <= i).astype(x.dtype)
        return jnp.full((block,), (x * w).sum() / w.sum(), x.dtype)

    @sac.incremental(block=block)
    def prog(x):
        return sac.causal(cmean, x)

    x = _rand(32, 8)
    x2 = x.copy(); x2[20] = 7.0          # block 5 -> suffix [5, 8)
    for hg, hh, og, oh in _both(prog, [dict(x=x2)], x=x):
        _assert_same(og, oh)
    assert hg.stats["recomputed"] == 3   # suffix blocks 5, 6, 7


# ---------------------------------------------------------------------------
# Ports: the named apps go through the frontend (acceptance criteria)
# ---------------------------------------------------------------------------
def test_stringhash_via_both_backends():
    from repro.jaxsac.apps import stringhash_graph, stringhash_oracle

    n, grain = 1024, 64
    rng = np.random.default_rng(0)
    codes = rng.integers(97, 123, n).astype(np.int32)
    hg = stringhash_graph(n, grain, max_sparse=8)
    hh = stringhash_graph(n, grain, backend="host")
    og, oh = hg.run(text=codes), hh.run(text=codes)
    _assert_same(og, oh)
    assert int(og[0, 0]) == stringhash_oracle(codes)
    codes[100] = 98
    og, oh = hg.update(text=codes), hh.update(text=codes)
    _assert_same(og, oh)
    assert int(og[0, 0]) == stringhash_oracle(codes)
    assert hg.stats["affected"] == hh.stats["affected"]


def test_stringhash_non_pow2_blocks_matches_oracle():
    """Regression: the combine's identity is the PAIR (0, 1); a scalar 0
    would annihilate the hash on identity-padded odd reduce levels."""
    from repro.jaxsac.apps import stringhash_graph, stringhash_oracle

    n, grain = 960, 64                   # 15 leaf blocks: odd levels
    rng = np.random.default_rng(1)
    codes = rng.integers(97, 123, n).astype(np.int32)
    hg = stringhash_graph(n, grain, max_sparse=4)
    hh = stringhash_graph(n, grain, backend="host")
    og, oh = hg.run(text=codes), hh.run(text=codes)
    _assert_same(og, oh)
    assert int(og[0, 0]) == stringhash_oracle(codes)
    codes[900] = 97
    og = hg.update(text=codes)
    _assert_same(og, hh.update(text=codes))
    assert int(og[0, 0]) == stringhash_oracle(codes)


def test_graphbuilder_deprecation_shim():
    import repro.jaxsac as jx

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        gb_cls = jx.GraphBuilder
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    from repro.jaxsac.graph import GraphBuilder
    assert gb_cls is GraphBuilder        # the shim IS the IR builder


# ---------------------------------------------------------------------------
# Ladner-Fischer escan reader tree + carry-causal lowering (host backend)
# ---------------------------------------------------------------------------
def test_host_escan_ladner_fischer_span():
    """The carry pass lowers as a reader tree: a late single-element edit
    re-executes O(log n) combines with polylog span, instead of the O(n)
    monolithic carry reader (work *and* span accounting must shrink)."""
    n = 256

    @sac.incremental(block=1)
    def prog(x):
        return sac.scan(jnp.add, x)

    h = prog.compile("host", x=n)
    d = _rand(n, 23)
    h.run(x=d)
    full_work, full_span = h.stats["work"], h.stats["span"]
    d2 = d.copy(); d2[n - 1] += 1.0      # last element: log-depth cover
    h.update(x=d2)
    st = h.stats
    lg = int(np.ceil(np.log2(n)))
    # the whole update (marks + re-executed combines + finalizes):
    assert st["recomputed"] <= 4 * lg, st
    assert st["work"] <= 32 * lg, st
    assert st["span"] <= 4 * lg, st
    assert st["span"] < full_span
    assert st["work"] < full_work // 4


def test_host_escan_tree_bitwise_parity_floats():
    """The reader tree mirrors jax.lax.associative_scan's odd/even
    recursion combine-for-combine, so float scans stay bitwise equal to
    the graph backend (including non-power-of-two block counts)."""
    for n, block in [(48, 4), (64, 4), (104, 8)]:
        @sac.incremental(block=block)
        def prog(x):
            return sac.scan(jnp.add, x)

        hg = prog.compile("graph", x=n, max_sparse=8)
        hh = prog.compile("host", x=n)
        d = _rand(n, n)
        og, oh = hg.run(x=d), hh.run(x=d)
        _assert_same(og, oh)
        d2 = d.copy(); d2[n // 3] += 1.0; d2[n - 1] -= 2.0
        og, oh = hg.update(x=d2), hh.update(x=d2)
        _assert_same(og, oh)
        assert hg.stats["affected"] == hh.stats["affected"]


def test_carry_causal_parity_both_backends():
    """Carry-causal (declared monoid) lowers on both backends with the
    same scan bracketing: bitwise-identical outputs and matching
    affected counts, floats included."""
    block = 4

    @sac.incremental(block=block)
    def prog(x):
        return sac.causal(
            None, x,
            lift=lambda b: jnp.stack([b.sum(), jnp.float32(b.shape[0])]),
            op=jnp.add,
            finalize=lambda s, b: jnp.full((block,), s[0] / s[1],
                                           jnp.float32),
            identity=0.0)

    hg = prog.compile("graph", x=48, max_sparse=4)
    hh = prog.compile("host", x=48)
    d = _rand(48, 31)
    og, oh = hg.run(x=d), hh.run(x=d)
    _assert_same(og, oh)
    d2 = d.copy(); d2[30] += 1.0
    og, oh = hg.update(x=d2), hh.update(x=d2)
    _assert_same(og, oh)
    assert hg.stats["affected"] == hh.stats["affected"]
    assert hg.stats["dirty_inputs"] == hh.stats["dirty_inputs"]
