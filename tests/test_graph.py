"""The SP-dag graph runtime: tracing, scheduling, jitted propagation.

The system invariant under test is the graph-runtime restatement of
Theorem 4.1: for ANY traced dag and ANY update, ``propagate`` must leave
the state exactly (bitwise) where ``init`` on the updated input would,
while recomputing O(k log(n/k))-ish blocks instead of everything.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.jaxsac import IncrementalReduce
from repro.jaxsac.apps import GraphStringHash, stringhash_graph, \
    stringhash_oracle
from repro.jaxsac.graph import GraphBuilder   # IR level (sac is the API)
from repro.jaxsac.reduce import _LegacyIncrementalReduce


def assert_states_equal(cg, state_a, state_b):
    for i, (a, b) in enumerate(zip(state_a["v"], state_b["v"])):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"node {i} ({cg.nodes[i].kind} {cg.nodes[i].name!r})")


# ---------------------------------------------------------------------------
# A ≥3-level pipeline mixing map + stencil + reduce
# ---------------------------------------------------------------------------
def make_pipeline(n=1024, block=8, max_sparse=16, use_pallas=False,
                  **compile_kw):
    g = GraphBuilder()
    x = g.input("x", n=n, block=block)
    y = g.map(lambda b: b * 2.0 + 1.0, x, name="affine")
    s = g.stencil(lambda w: w[block:2 * block]
                  + 0.5 * (w[:block] + w[2 * block:]), y, radius=1)
    t = g.reduce_tree(jnp.add, s, identity=0.0)
    g.output(t)
    cg = g.compile(max_sparse=max_sparse, use_pallas=use_pallas,
                   **compile_kw)
    return cg


def test_pipeline_levels_and_blocks():
    cg = make_pipeline(n=1024, block=8)
    # input -> map -> stencil -> leaf fold -> log2(128) reduce levels
    assert cg.num_levels == 3 + 1 + int(math.log2(128))
    assert cg.total_blocks == 128 + 128 + 128 + 127
    # every schedule level's nodes are distinct and cover the dag once
    flat = [i for lvl in cg.schedule for i in lvl]
    assert sorted(flat) == list(range(len(cg.nodes)))


@pytest.mark.parametrize("k", [1, 3, 17, 128])
def test_pipeline_update_equals_from_scratch(k):
    cg = make_pipeline()
    rng = np.random.default_rng(k)
    x = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    state = cg.init(x=x)
    blocks = rng.choice(128, size=k, replace=False)
    y = np.asarray(x).copy()
    for b in blocks:
        y[b * 8 + rng.integers(8)] = rng.standard_normal()
    y = jnp.asarray(y)
    state, stats = cg.propagate(state, {"x": y})
    assert_states_equal(cg, state, cg.init(x=y))
    # Theorem 4.2 shape: k dirty chains of height log(n/k), plus the
    # stencil dilation (x3) on the two elementwise levels.
    nb = 128
    bound = 5 * k * (1 + math.log2(1 + nb / min(k, nb))) + 16
    assert int(stats["recomputed"]) <= bound, (int(stats["recomputed"]), bound)


def test_pipeline_noop_update_zero_work():
    cg = make_pipeline()
    x = jnp.asarray(np.arange(1024), jnp.float32)
    state = cg.init(x=x)
    state, stats = cg.propagate(state, {"x": x + 0.0})
    assert int(stats["recomputed"]) == 0
    assert int(stats["affected"]) == 0


def test_value_cutoff_stops_midway():
    """An edit masked out by the map's value cutoff propagates nowhere."""
    g = GraphBuilder()
    x = g.input("x", n=256, block=4)
    y = g.map(lambda b: jnp.clip(b, 0.0, 1.0), x)    # saturating
    t = g.reduce_tree(jnp.add, y, identity=0.0)
    g.output(t)
    cg = g.compile(max_sparse=8)
    x0 = jnp.full((256,), 5.0, jnp.float32)           # all saturate to 1
    state = cg.init(x=x0)
    state, stats = cg.propagate(state, {"x": x0.at[100].set(9.0)})
    # the edited block recomputes at the map, but its value is unchanged,
    # so the whole reduce tree stays clean.
    assert int(stats["recomputed"]) == 1
    assert int(stats["affected"]) == 0
    np.testing.assert_allclose(float(cg.result(state)[0]), 256.0)


# ---------------------------------------------------------------------------
# zip_map + scan + seq/par
# ---------------------------------------------------------------------------
def test_zip_map_and_par_schedule():
    g = GraphBuilder()
    x = g.input("x", n=128, block=4)
    (a,), (b,) = g.par(lambda: [g.map(lambda v: v + 1.0, x)],
                       lambda: [g.map(lambda v: v * 2.0, x)])
    z = g.zip_map(lambda u, v: u * v, a, b)
    g.output(z)
    cg = g.compile(max_sparse=4)
    assert cg.level_of[a.idx] == cg.level_of[b.idx]   # P: level-sharable
    rng = np.random.default_rng(0)
    d = jnp.asarray(rng.standard_normal(128), jnp.float32)
    state = cg.init(x=d)
    np.testing.assert_allclose(np.asarray(cg.value(state, z)),
                               np.asarray((d + 1.0) * (d * 2.0)))
    d2 = d.at[13].set(5.0)
    state, stats = cg.propagate(state, {"x": d2})
    assert_states_equal(cg, state, cg.init(x=d2))
    assert int(stats["recomputed"]) == 3              # one block, 3 nodes


def test_seq_orders_independent_branches():
    g = GraphBuilder()
    x = g.input("x", n=64, block=4)
    (a,), (b,) = g.seq(lambda: [g.map(lambda v: v + 1.0, x)],
                       lambda: [g.map(lambda v: v * 2.0, x)])
    cg = g.compile()
    assert cg.level_of[b.idx] > cg.level_of[a.idx]    # S: strict order


def test_seq_empty_branch_keeps_ordering():
    """A seq branch that traces no nodes must not break the S-chain."""
    g = GraphBuilder()
    x = g.input("x", n=64, block=4)
    a, _, b = g.seq(lambda: g.map(lambda v: v + 1.0, x),
                    lambda: None,                    # traces nothing
                    lambda: g.map(lambda v: v * 2.0, x))
    cg = g.compile()
    assert cg.level_of[b.idx] > cg.level_of[a.idx]


def test_numpy_inputs_are_copied():
    """In-place mutation of a numpy input after init/propagate must not
    alias the stored state (CompiledGraph owns numpy inputs)."""
    cg = make_pipeline()
    d = np.zeros(1024, np.float32)
    state = cg.init(x=d)
    d[0] = 5.0
    state, stats = cg.propagate(state, {"x": d})
    assert int(stats["dirty_inputs"]) == 1
    assert_states_equal(cg, state, cg.init(x=d.copy()))


@pytest.mark.parametrize("k", [1, 4, 16])
def test_scan_update_equals_from_scratch(k):
    g = GraphBuilder()
    x = g.input("x", n=512, block=8)
    sc = g.scan(jnp.add, x, identity=0.0)
    g.output(sc)
    cg = g.compile(max_sparse=8)
    rng = np.random.default_rng(k)
    # integers: carries must compare bitwise-equal to cut off cleanly
    d = jnp.asarray(rng.integers(-5, 6, 512), jnp.float32)
    state = cg.init(x=d)
    np.testing.assert_allclose(np.asarray(cg.value(state, sc)),
                               np.cumsum(np.asarray(d)))
    y = np.asarray(d).copy()
    y[rng.choice(512, size=k, replace=False)] += 1.0
    y = jnp.asarray(y)
    state, stats = cg.propagate(state, {"x": y})
    assert_states_equal(cg, state, cg.init(x=y))


def test_scan_suffix_cutoff():
    """A +1/-1 edit pair inside one block leaves every carry unchanged:
    only that block's aggregate and local scan recompute downstream."""
    g = GraphBuilder()
    x = g.input("x", n=256, block=8)
    sc = g.scan(jnp.add, x, identity=0.0)
    g.output(sc)
    cg = g.compile(max_sparse=8)
    d = jnp.asarray(np.arange(256), jnp.float32)
    state = cg.init(x=d)
    y = d.at[80].add(1.0).at[83].add(-1.0)   # same block, net zero
    state, stats = cg.propagate(state, {"x": y})
    assert_states_equal(cg, state, cg.init(x=y))
    # agg recomputes 1 block, carry recomputes 0 (no carry read changed),
    # local recomputes 1 block.
    assert int(stats["recomputed"]) == 2


# ---------------------------------------------------------------------------
# Sparse / dense / Pallas regime parity
# ---------------------------------------------------------------------------
def test_sparse_dense_pallas_agree():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    y = jnp.asarray(rng.standard_normal(1024), jnp.float32)  # all dirty
    states = []
    for ms, pallas in ((4, False), (4096, False), (4, True)):
        cg = make_pipeline(max_sparse=ms, use_pallas=pallas)
        state = cg.init(x=x)
        state, _ = cg.propagate(state, {"x": y})
        states.append((cg, state))
    for cg, state in states[1:]:
        assert_states_equal(cg, states[0][1], state)


def test_pallas_partial_tile_clean_blocks_bitwise_stable():
    """Dense Pallas recompute of a partially-dirty tile must keep the
    tile's clean blocks bitwise equal to the old state (the kernel
    recomputes whole tiles; the runtime masks them back)."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    # max_sparse=2 with a 5-block edit forces the dense path everywhere
    cgp = make_pipeline(max_sparse=2, use_pallas=True)
    cgj = make_pipeline(max_sparse=2, use_pallas=False)
    y = np.asarray(x).copy()
    for b in (8, 9, 40, 41, 100):         # partial tiles of 8 blocks
        y[b * 8] += 1.0
    y = jnp.asarray(y)
    sp, _ = cgp.propagate(cgp.init(x=x), {"x": y})
    sj, _ = cgj.propagate(cgj.init(x=x), {"x": y})
    assert_states_equal(cgp, sp, sj)


# ---------------------------------------------------------------------------
# IncrementalReduce re-based on the graph runtime vs the legacy oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,k", [(0, 1), (1, 7), (2, 40), (3, 512)])
def test_reduce_rebase_bitwise_and_counts(seed, k):
    rng = np.random.default_rng(seed)
    new = IncrementalReduce(n=512, block=4, op=jnp.add, identity=0.0,
                            max_sparse=32)
    old = _LegacyIncrementalReduce(n=512, block=4, op=jnp.add, identity=0.0,
                                   max_sparse=32)
    x = jnp.asarray(rng.standard_normal(512), jnp.float32)
    sn, so = new.init(x), old.init(x)
    np.testing.assert_array_equal(np.asarray(new.result(sn)),
                                  np.asarray(old.result(so)))
    for step in range(3):
        idx = rng.choice(512, size=min(k, 512), replace=False)
        x = x.at[jnp.asarray(idx)].set(
            jnp.asarray(rng.standard_normal(len(idx)), jnp.float32))
        sn, stn = jax.jit(new.update)(sn, x)
        so, sto = jax.jit(old.update)(so, x)
        # bitwise-identical result, equal-or-lower realized work
        np.testing.assert_array_equal(np.asarray(new.result(sn)),
                                      np.asarray(old.result(so)))
        assert int(stn["recomputed"]) <= int(sto["recomputed"])
        assert int(stn["affected"]) <= int(sto["affected"])


def test_reduce_rebase_max_op():
    new = IncrementalReduce(n=256, block=4, op=jnp.maximum, identity=-1e30,
                            max_sparse=8)
    x = jnp.zeros(256).at[100].set(50.0)
    state = new.init(x)
    state, stats = jax.jit(new.update)(state, x.at[7].set(1.0))
    assert float(new.result(state)) == 50.0
    assert int(stats["recomputed"]) <= 8


# ---------------------------------------------------------------------------
# Rabin-Karp host app ported as a graph program
# ---------------------------------------------------------------------------
def test_stringhash_graph_matches_oracle():
    app = GraphStringHash(n=8192, grain=64, seed=0)
    app.run()
    assert app.output() == app.expected()
    for k in (1, 3, 64, 1000):
        stats = app.apply_update(k)
        assert app.output() == app.expected(), k
        assert int(stats["recomputed"]) >= 1


def test_stringhash_graph_complexity():
    """k-block edits touch O(k log(nb/k)) dag blocks (Theorem 4.2)."""
    n, grain = 16384, 64
    nb = n // grain                       # 256 leaf blocks
    h = stringhash_graph(n, grain, use_pallas=False, max_sparse=64)
    rng = np.random.default_rng(0)
    codes = rng.integers(97, 123, n).astype("int32")
    # pass the numpy array itself: CompiledGraph copies numpy inputs, so
    # the in-place edits below cannot alias the stored state
    h.run(text=codes)
    for k in (1, 4, 16):
        idx = rng.choice(nb, size=k, replace=False)
        for b in idx:
            codes[b * grain + rng.integers(grain)] = rng.integers(97, 123)
        out = h.update(text=codes)
        assert int(out[0, 0]) == stringhash_oracle(codes)
        bound = 3 * k * (1 + math.log2(1 + nb / k)) + 8
        assert int(h.stats["recomputed"]) <= bound


# ---------------------------------------------------------------------------
# Builder validation
# ---------------------------------------------------------------------------
def test_builder_rejects_bad_shapes():
    g = GraphBuilder()
    with pytest.raises(AssertionError):
        g.input("x", n=100, block=8)      # not divisible
    with pytest.raises(AssertionError):
        GraphBuilder().compile()


# ---------------------------------------------------------------------------
# Non-power-of-two block counts (odd levels pad with the op identity)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nb,block", [(12, 8), (7, 4), (13, 4), (3, 1)])
def test_reduce_tree_odd_blocks(nb, block):
    g = GraphBuilder()
    x = g.input("x", n=nb * block, block=block)
    t = g.reduce_tree(jnp.add, x, identity=0.0)
    g.output(t)
    cg = g.compile(max_sparse=4)
    rng = np.random.default_rng(nb)
    d = jnp.asarray(rng.integers(-9, 10, nb * block), jnp.float32)
    state = cg.init(x=d)
    assert float(cg.result(state)[0]) == float(d.sum())
    d2 = d.at[rng.integers(nb * block)].add(3.0)
    state, stats = cg.propagate(state, {"x": d2})
    assert_states_equal(cg, state, cg.init(x=d2))
    # one dirty chain up a ceil(log2 nb)-level tree (+ leaf fold)
    assert int(stats["recomputed"]) <= 2 + math.ceil(math.log2(nb))


@pytest.mark.parametrize("nb", [7, 13])
def test_reduce_tree_odd_max_op(nb):
    """Identity padding must be neutral for non-sum ops too."""
    g = GraphBuilder()
    x = g.input("x", n=nb, block=1)
    t = g.reduce_tree(jnp.maximum, x, identity=-jnp.inf)
    g.output(t)
    cg = g.compile(max_sparse=2)
    d = -jnp.arange(float(nb))            # max is element 0
    state = cg.init(x=d)
    assert float(cg.result(state)[0]) == 0.0
    d2 = d.at[nb - 1].set(99.0)           # new max in the padded tail
    state, _ = cg.propagate(state, {"x": d2})
    assert float(cg.result(state)[0]) == 99.0
    assert_states_equal(cg, state, cg.init(x=d2))


@pytest.mark.parametrize("nb,block", [(11, 8), (5, 4)])
def test_scan_odd_blocks(nb, block):
    g = GraphBuilder()
    x = g.input("x", n=nb * block, block=block)
    sc = g.scan(jnp.add, x, identity=0.0)
    g.output(sc)
    cg = g.compile(max_sparse=4)
    rng = np.random.default_rng(nb)
    d = jnp.asarray(rng.integers(-5, 6, nb * block), jnp.float32)
    state = cg.init(x=d)
    np.testing.assert_allclose(np.asarray(cg.result(state)),
                               np.cumsum(np.asarray(d)))
    d2 = d.at[3].add(1.0)
    state, _ = cg.propagate(state, {"x": d2})
    assert_states_equal(cg, state, cg.init(x=d2))


def test_incremental_reduce_odd_blocks():
    r = IncrementalReduce(n=24, block=2, op=jnp.add, identity=0.0,
                          max_sparse=4)          # 12 blocks: not a pow2
    x = jnp.arange(24.0)
    state = r.init(x)
    assert float(r.result(state)) == float(x.sum())
    y = x.at[17].set(-3.0)
    state, _ = jax.jit(r.update)(state, y)
    assert float(r.result(state)) == float(y.sum())


# ---------------------------------------------------------------------------
# Interval DirtySet + the causal edge kind
# ---------------------------------------------------------------------------
def _causal_mean(block):
    def fn(x, i):
        pos = jnp.arange(x.shape[0]) // block
        w = (pos <= i).astype(x.dtype)
        s = (x * w).sum() / w.sum()
        return jnp.full((block,), s, x.dtype)

    return fn


@pytest.mark.parametrize("rep", ["mask", "interval"])
def test_causal_update_equals_from_scratch(rep):
    nb, block = 16, 4
    g = GraphBuilder()
    x = g.input("x", n=nb * block, block=block)
    c = g.causal(_causal_mean(block), x)
    g.output(c)
    cg = g.compile(max_sparse=4, dirty=rep)
    d = jnp.asarray(np.arange(nb * block), jnp.float32)
    state = cg.init(x=d)
    d2 = d.at[40].set(-5.0)               # block 10 -> dirty suffix [10, 16)
    state, stats = cg.propagate(state, {"x": d2})
    assert_states_equal(cg, state, cg.init(x=d2))
    assert int(stats["recomputed"]) == nb - 10   # suffix, both reps exact


# The ad-hoc mask-vs-interval pipeline equivalence check that used to
# live here is superseded by the property-based conformance suite in
# test_dirtyset_laws.py (exactness, abstraction soundness, precision
# bounds, and lattice laws for every transfer of both representations).


def test_autotuned_max_sparse_per_level():
    """max_sparse="auto" calibrates a per-node crossover at the first
    init (when feature widths are known) and stays correct."""
    g = GraphBuilder()
    x = g.input("x", n=1024, block=8)
    t = g.reduce_tree(jnp.add, g.map(lambda b: b * 3.0, x), identity=0.0)
    g.output(t)
    cg = g.compile()                      # default: auto
    assert cg._ks is None                 # resolved lazily at init
    d = jnp.asarray(np.random.default_rng(1).standard_normal(1024),
                    jnp.float32)
    state = cg.init(x=d)
    op_nodes = [nd for nd in cg.nodes if nd.kind != "input"]
    assert all(1 <= cg._ks[nd.idx] <= nd.num_blocks for nd in op_nodes)
    d2 = d.at[100].set(7.0)
    state, _ = cg.propagate(state, {"x": d2})
    assert_states_equal(cg, state, cg.init(x=d2))


def test_propagate_before_init_rejected():
    cg = make_pipeline()
    cg2 = make_pipeline(max_sparse="auto")
    state = cg.init(x=jnp.zeros(1024, jnp.float32))
    with pytest.raises(AssertionError, match="init"):
        cg2.propagate(state, {"x": jnp.zeros(1024, jnp.float32)})


def test_propagate_rejects_unknown_input():
    cg = make_pipeline()
    state = cg.init(x=jnp.zeros(1024, jnp.float32))
    with pytest.raises(AssertionError):
        cg.propagate(state, {"bogus": jnp.zeros(1024, jnp.float32)})


# ---------------------------------------------------------------------------
# Propagation fast path: donation, level skip, packing, block-skip carries
# ---------------------------------------------------------------------------
def test_donation_chained_propagates_bitwise():
    """Donation-aliasing regression: chaining several propagates from one
    init (the steady-state in-place path) must stay bitwise identical to
    the copying runtime (donate=False), with no use-after-donate error
    anywhere along the chain."""
    rng = np.random.default_rng(0)
    x0 = rng.standard_normal(1024).astype(np.float32)
    edits, x = [], x0
    for i in range(4):
        x = x.copy()
        x[(137 * (i + 1)) % 1024] += 1.0 + i
        edits.append(x)
    cgd = make_pipeline(donate=True)
    cgc = make_pipeline(donate=False)
    sd = cgd.init(x=jnp.asarray(x0))
    sc = cgc.init(x=jnp.asarray(x0))
    for e in edits:
        sd, std = cgd.propagate(sd, {"x": jnp.asarray(e)})
        sc, stc = cgc.propagate(sc, {"x": jnp.asarray(e)})
        assert int(std["recomputed"]) == int(stc["recomputed"])
    assert_states_equal(cgd, sd, sc)
    assert_states_equal(cgd, sd, cgd.init(x=jnp.asarray(edits[-1])))


def test_donation_invalidates_superseded_state():
    """The documented aliasing rule: once a state is donated to a later
    propagate, its buffers are dead — reading them raises instead of
    silently returning stale data."""
    cg = make_pipeline(donate=True)
    d = jnp.asarray(np.random.default_rng(3).standard_normal(1024),
                    jnp.float32)
    s0 = cg.init(x=d)
    s1, _ = cg.propagate(s0, {"x": d.at[5].set(9.0)})
    # node 1 (the map) is recomputed in place: its old buffer is donated
    # and dead.  (Leaves the executable never consumes — e.g. the input
    # value, whose diff ran in the mark phase — may survive as pruned
    # arguments, but the contract covers the whole state.)
    with pytest.raises(RuntimeError):
        np.asarray(s0["v"][1])
    # the live state stays readable
    assert np.asarray(s1["v"][1]).shape == (1024,)


def test_level_skip_noop_update_touches_nothing():
    """A propagate whose input diff is empty must report zero recomputed
    blocks and leave every value bitwise intact (the whole-level skip:
    each clean level costs one scalar compare)."""
    for level_skip in (True, False):
        cg = make_pipeline(level_skip=level_skip)
        d = jnp.asarray(np.random.default_rng(5).standard_normal(1024),
                        jnp.float32)
        state = cg.init(x=d)
        ref = cg.init(x=d)
        state, stats = cg.propagate(state, {"x": d + 0.0})
        assert int(stats["recomputed"]) == 0
        assert int(stats["affected"]) == 0
        assert_states_equal(cg, state, ref)


def test_level_packing_batches_same_fn_nodes():
    """Two parallel reduce trees (same op) and two same-fn maps pack into
    per-level groups; the batched gather->fn->scatter stays bitwise equal
    to from-scratch."""
    rng = np.random.default_rng(7)
    f = lambda b: b * 3.0 + 1.0          # shared per-block function

    g = GraphBuilder()
    x = g.input("x", n=512, block=4)
    y = g.input("y", n=512, block=4)
    u, v = g.map(f, x), g.map(f, y)
    g.output(g.reduce_tree(jnp.add, u, identity=0.0))
    g.output(g.reduce_tree(jnp.add, v, identity=0.0))
    cg = g.compile(max_sparse=8)
    packed = [grp for lvl in cg._level_groups for grp in lvl if len(grp) > 1]
    assert packed, "same-fn nodes of a level must form packed groups"

    dx = rng.standard_normal(512).astype(np.float32)
    dy = rng.standard_normal(512).astype(np.float32)
    state = cg.init(x=jnp.asarray(dx), y=jnp.asarray(dy))
    dx2 = dx.copy(); dx2[37] += 1.0
    dy2 = dy.copy(); dy2[411] -= 2.0
    state, stats = cg.propagate(
        state, {"x": jnp.asarray(dx2), "y": jnp.asarray(dy2)})
    assert_states_equal(cg, state,
                        cg.init(x=jnp.asarray(dx2), y=jnp.asarray(dy2)))
    assert int(stats["recomputed"]) < cg.total_blocks // 4


def test_escan_block_skip_matches_scratch_int():
    """Integer scans route through the block-skip carry path (cached
    prefix reseed) under both dirty representations and both backends of
    the dense kernel, staying bitwise equal to from-scratch."""
    rng = np.random.default_rng(11)
    d = rng.integers(0, 1000, 264).astype(np.int32)   # 33 blocks: tail pad

    def build(**kw):
        g = GraphBuilder()
        x = g.input("x", n=264, block=8)
        g.output(g.scan(jnp.add, x, identity=0))
        return g.compile(max_sparse=4, **kw)

    for kw in (dict(dirty="mask"), dict(dirty="interval"),
               dict(dirty="mask", use_pallas=True, interpret=True,
                    pallas_tile=4)):
        cg = build(**kw)
        state = cg.init(x=jnp.asarray(d))
        d2 = d.copy(); d2[100] += 7
        state, stats = cg.propagate(state, {"x": jnp.asarray(d2)})
        assert_states_equal(cg, state, cg.init(x=jnp.asarray(d2)))
        d3 = d2.copy(); d3[260] -= 3                  # tail-block edit
        state, _ = cg.propagate(state, {"x": jnp.asarray(d3)})
        assert_states_equal(cg, state, cg.init(x=jnp.asarray(d3)))


def test_carry_causal_cached_states():
    """Carry-causal nodes cache their per-block carry states in the
    propagation state and keep them in sync with from-scratch."""
    g = GraphBuilder()
    x = g.input("x", n=128, block=8)
    h = g.causal(None, x, lift=lambda b: b.sum(), op=jnp.add,
                 finalize=lambda s, b: b + s, identity=0)
    g.output(h)
    cg = g.compile(max_sparse=4)
    rng = np.random.default_rng(13)
    d = rng.integers(0, 100, 128).astype(np.int32)
    state = cg.init(x=jnp.asarray(d))
    assert str(h.idx) in state["c"]
    d2 = d.copy(); d2[77] += 5
    state, stats = cg.propagate(state, {"x": jnp.asarray(d2)})
    ref = cg.init(x=jnp.asarray(d2))
    assert_states_equal(cg, state, ref)
    np.testing.assert_array_equal(np.asarray(state["c"][str(h.idx)]),
                                  np.asarray(ref["c"][str(h.idx)]))
    # suffix semantics: blocks before the edit stay untouched
    assert int(stats["recomputed"]) == 128 // 8 - 77 // 8


def test_pallas_stencil_and_mixed_dtype_routing():
    """The Pallas dense path now serves stencil windows (halo-aware row
    payloads), pads non-tile-multiple block counts, and upcasts mixed
    parent dtypes — all bitwise equal to the XLA dense path."""
    rng = np.random.default_rng(17)

    def build(use_pallas):
        g = GraphBuilder()
        x = g.input("x", n=88, block=8)              # 11 blocks: tail pad
        y = g.input("y", n=88, block=8)
        xi = g.map(lambda b: (b * 10).astype(jnp.int32), x)
        z = g.zip_map(lambda a, b: a + b, y, xi)     # f32 + i32 -> f32
        s = g.stencil(lambda w: w[8:16] + 0.5 * (w[:8] + w[16:]), z,
                      radius=1)
        g.output(s)
        return g.compile(max_sparse=1, use_pallas=use_pallas,
                         interpret=True, pallas_tile=4)

    dx = rng.standard_normal(88).astype(np.float32)
    dy = rng.standard_normal(88).astype(np.float32)
    cgp, cgx = build(True), build(False)
    sp = cgp.init(x=jnp.asarray(dx), y=jnp.asarray(dy))
    sx = cgx.init(x=jnp.asarray(dx), y=jnp.asarray(dy))
    dx2 = dx.copy(); dx2[3] += 1.0; dx2[70] -= 2.0; dx2[85] += 0.5
    sp, _ = cgp.propagate(sp, {"x": jnp.asarray(dx2)})
    sx, _ = cgx.propagate(sx, {"x": jnp.asarray(dx2)})
    assert_states_equal(cgp, sp, sx)


def test_planned_matches_legacy_cond_propagate():
    """The planned two-phase propagate (mark -> host plan -> branch-free
    executable) must stay bitwise identical to the legacy lax.cond
    runtime across regimes (skip/sparse/dense plans) and report the same
    stats."""
    rng = np.random.default_rng(23)
    d = rng.standard_normal(1024).astype(np.float32)
    cgp = make_pipeline(max_sparse=16, plan=True)
    cgl = make_pipeline(max_sparse=16, plan=False)
    sp = cgp.init(x=jnp.asarray(d))
    sl = cgl.init(x=jnp.asarray(d))
    cur = d
    for k in (1, 5, 400):                # sparse, sparse, dense plans
        new = cur.copy()
        for j in rng.choice(1024, k, replace=False):
            new[j] += 1.0
        sp, stp = cgp.propagate(sp, {"x": jnp.asarray(new)})
        sl, stl = cgl.propagate(sl, {"x": jnp.asarray(new)})
        assert_states_equal(cgp, sp, sl)
        for key in ("recomputed", "affected", "dirty_inputs"):
            assert int(stp[key]) == int(stl[key]), (k, key)
        cur = new
    # no-op edit: the planned executable is just the mark pass
    sp, stp = cgp.propagate(sp, {"x": jnp.asarray(cur)})
    assert int(stp["recomputed"]) == 0
    assert_states_equal(cgp, sp, sl)
