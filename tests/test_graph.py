"""The SP-dag graph runtime: tracing, scheduling, jitted propagation.

The system invariant under test is the graph-runtime restatement of
Theorem 4.1: for ANY traced dag and ANY update, ``propagate`` must leave
the state exactly (bitwise) where ``init`` on the updated input would,
while recomputing O(k log(n/k))-ish blocks instead of everything.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.jaxsac import IncrementalReduce
from repro.jaxsac.apps import GraphStringHash, stringhash_graph, \
    stringhash_oracle
from repro.jaxsac.graph import GraphBuilder   # IR level (sac is the API)
from repro.jaxsac.reduce import _LegacyIncrementalReduce


def assert_states_equal(cg, state_a, state_b):
    for i, (a, b) in enumerate(zip(state_a["v"], state_b["v"])):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"node {i} ({cg.nodes[i].kind} {cg.nodes[i].name!r})")


# ---------------------------------------------------------------------------
# A ≥3-level pipeline mixing map + stencil + reduce
# ---------------------------------------------------------------------------
def make_pipeline(n=1024, block=8, max_sparse=16, use_pallas=False,
                  **compile_kw):
    g = GraphBuilder()
    x = g.input("x", n=n, block=block)
    y = g.map(lambda b: b * 2.0 + 1.0, x, name="affine")
    s = g.stencil(lambda w: w[block:2 * block]
                  + 0.5 * (w[:block] + w[2 * block:]), y, radius=1)
    t = g.reduce_tree(jnp.add, s, identity=0.0)
    g.output(t)
    cg = g.compile(max_sparse=max_sparse, use_pallas=use_pallas,
                   **compile_kw)
    return cg


def test_pipeline_levels_and_blocks():
    cg = make_pipeline(n=1024, block=8)
    # input -> map -> stencil -> leaf fold -> log2(128) reduce levels
    assert cg.num_levels == 3 + 1 + int(math.log2(128))
    assert cg.total_blocks == 128 + 128 + 128 + 127
    # every schedule level's nodes are distinct and cover the dag once
    flat = [i for lvl in cg.schedule for i in lvl]
    assert sorted(flat) == list(range(len(cg.nodes)))


@pytest.mark.parametrize("k", [1, 3, 17, 128])
def test_pipeline_update_equals_from_scratch(k):
    cg = make_pipeline()
    rng = np.random.default_rng(k)
    x = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    state = cg.init(x=x)
    blocks = rng.choice(128, size=k, replace=False)
    y = np.asarray(x).copy()
    for b in blocks:
        y[b * 8 + rng.integers(8)] = rng.standard_normal()
    y = jnp.asarray(y)
    state, stats = cg.propagate(state, {"x": y})
    assert_states_equal(cg, state, cg.init(x=y))
    # Theorem 4.2 shape: k dirty chains of height log(n/k), plus the
    # stencil dilation (x3) on the two elementwise levels.
    nb = 128
    bound = 5 * k * (1 + math.log2(1 + nb / min(k, nb))) + 16
    assert int(stats["recomputed"]) <= bound, (int(stats["recomputed"]), bound)


def test_pipeline_noop_update_zero_work():
    cg = make_pipeline()
    x = jnp.asarray(np.arange(1024), jnp.float32)
    state = cg.init(x=x)
    state, stats = cg.propagate(state, {"x": x + 0.0})
    assert int(stats["recomputed"]) == 0
    assert int(stats["affected"]) == 0


def test_value_cutoff_stops_midway():
    """An edit masked out by the map's value cutoff propagates nowhere."""
    g = GraphBuilder()
    x = g.input("x", n=256, block=4)
    y = g.map(lambda b: jnp.clip(b, 0.0, 1.0), x)    # saturating
    t = g.reduce_tree(jnp.add, y, identity=0.0)
    g.output(t)
    cg = g.compile(max_sparse=8)
    x0 = jnp.full((256,), 5.0, jnp.float32)           # all saturate to 1
    state = cg.init(x=x0)
    state, stats = cg.propagate(state, {"x": x0.at[100].set(9.0)})
    # the edited block recomputes at the map, but its value is unchanged,
    # so the whole reduce tree stays clean.
    assert int(stats["recomputed"]) == 1
    assert int(stats["affected"]) == 0
    np.testing.assert_allclose(float(cg.result(state)[0]), 256.0)


# ---------------------------------------------------------------------------
# zip_map + scan + seq/par
# ---------------------------------------------------------------------------
def test_zip_map_and_par_schedule():
    g = GraphBuilder()
    x = g.input("x", n=128, block=4)
    (a,), (b,) = g.par(lambda: [g.map(lambda v: v + 1.0, x)],
                       lambda: [g.map(lambda v: v * 2.0, x)])
    z = g.zip_map(lambda u, v: u * v, a, b)
    g.output(z)
    cg = g.compile(max_sparse=4)
    assert cg.level_of[a.idx] == cg.level_of[b.idx]   # P: level-sharable
    rng = np.random.default_rng(0)
    d = jnp.asarray(rng.standard_normal(128), jnp.float32)
    state = cg.init(x=d)
    np.testing.assert_allclose(np.asarray(cg.value(state, z)),
                               np.asarray((d + 1.0) * (d * 2.0)))
    d2 = d.at[13].set(5.0)
    state, stats = cg.propagate(state, {"x": d2})
    assert_states_equal(cg, state, cg.init(x=d2))
    assert int(stats["recomputed"]) == 3              # one block, 3 nodes


def test_seq_orders_independent_branches():
    g = GraphBuilder()
    x = g.input("x", n=64, block=4)
    (a,), (b,) = g.seq(lambda: [g.map(lambda v: v + 1.0, x)],
                       lambda: [g.map(lambda v: v * 2.0, x)])
    cg = g.compile()
    assert cg.level_of[b.idx] > cg.level_of[a.idx]    # S: strict order


def test_seq_empty_branch_keeps_ordering():
    """A seq branch that traces no nodes must not break the S-chain."""
    g = GraphBuilder()
    x = g.input("x", n=64, block=4)
    a, _, b = g.seq(lambda: g.map(lambda v: v + 1.0, x),
                    lambda: None,                    # traces nothing
                    lambda: g.map(lambda v: v * 2.0, x))
    cg = g.compile()
    assert cg.level_of[b.idx] > cg.level_of[a.idx]


def test_numpy_inputs_are_copied():
    """In-place mutation of a numpy input after init/propagate must not
    alias the stored state (CompiledGraph owns numpy inputs)."""
    cg = make_pipeline()
    d = np.zeros(1024, np.float32)
    state = cg.init(x=d)
    d[0] = 5.0
    state, stats = cg.propagate(state, {"x": d})
    assert int(stats["dirty_inputs"]) == 1
    assert_states_equal(cg, state, cg.init(x=d.copy()))


@pytest.mark.parametrize("k", [1, 4, 16])
def test_scan_update_equals_from_scratch(k):
    g = GraphBuilder()
    x = g.input("x", n=512, block=8)
    sc = g.scan(jnp.add, x, identity=0.0)
    g.output(sc)
    cg = g.compile(max_sparse=8)
    rng = np.random.default_rng(k)
    # integers: carries must compare bitwise-equal to cut off cleanly
    d = jnp.asarray(rng.integers(-5, 6, 512), jnp.float32)
    state = cg.init(x=d)
    np.testing.assert_allclose(np.asarray(cg.value(state, sc)),
                               np.cumsum(np.asarray(d)))
    y = np.asarray(d).copy()
    y[rng.choice(512, size=k, replace=False)] += 1.0
    y = jnp.asarray(y)
    state, stats = cg.propagate(state, {"x": y})
    assert_states_equal(cg, state, cg.init(x=y))


def test_scan_suffix_cutoff():
    """A +1/-1 edit pair inside one block leaves every carry unchanged:
    only that block's aggregate and local scan recompute downstream."""
    g = GraphBuilder()
    x = g.input("x", n=256, block=8)
    sc = g.scan(jnp.add, x, identity=0.0)
    g.output(sc)
    cg = g.compile(max_sparse=8)
    d = jnp.asarray(np.arange(256), jnp.float32)
    state = cg.init(x=d)
    y = d.at[80].add(1.0).at[83].add(-1.0)   # same block, net zero
    state, stats = cg.propagate(state, {"x": y})
    assert_states_equal(cg, state, cg.init(x=y))
    # agg recomputes 1 block, carry recomputes 0 (no carry read changed),
    # local recomputes 1 block.
    assert int(stats["recomputed"]) == 2


# ---------------------------------------------------------------------------
# Sparse / dense / Pallas regime parity
# ---------------------------------------------------------------------------
def test_sparse_dense_pallas_agree():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    y = jnp.asarray(rng.standard_normal(1024), jnp.float32)  # all dirty
    states = []
    for ms, pallas in ((4, False), (4096, False), (4, True)):
        cg = make_pipeline(max_sparse=ms, use_pallas=pallas)
        state = cg.init(x=x)
        state, _ = cg.propagate(state, {"x": y})
        states.append((cg, state))
    for cg, state in states[1:]:
        assert_states_equal(cg, states[0][1], state)


def test_pallas_partial_tile_clean_blocks_bitwise_stable():
    """Dense Pallas recompute of a partially-dirty tile must keep the
    tile's clean blocks bitwise equal to the old state (the kernel
    recomputes whole tiles; the runtime masks them back)."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    # max_sparse=2 with a 5-block edit forces the dense path everywhere
    cgp = make_pipeline(max_sparse=2, use_pallas=True)
    cgj = make_pipeline(max_sparse=2, use_pallas=False)
    y = np.asarray(x).copy()
    for b in (8, 9, 40, 41, 100):         # partial tiles of 8 blocks
        y[b * 8] += 1.0
    y = jnp.asarray(y)
    sp, _ = cgp.propagate(cgp.init(x=x), {"x": y})
    sj, _ = cgj.propagate(cgj.init(x=x), {"x": y})
    assert_states_equal(cgp, sp, sj)


# ---------------------------------------------------------------------------
# IncrementalReduce re-based on the graph runtime vs the legacy oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,k", [(0, 1), (1, 7), (2, 40), (3, 512)])
def test_reduce_rebase_bitwise_and_counts(seed, k):
    rng = np.random.default_rng(seed)
    new = IncrementalReduce(n=512, block=4, op=jnp.add, identity=0.0,
                            max_sparse=32)
    old = _LegacyIncrementalReduce(n=512, block=4, op=jnp.add, identity=0.0,
                                   max_sparse=32)
    x = jnp.asarray(rng.standard_normal(512), jnp.float32)
    sn, so = new.init(x), old.init(x)
    np.testing.assert_array_equal(np.asarray(new.result(sn)),
                                  np.asarray(old.result(so)))
    for step in range(3):
        idx = rng.choice(512, size=min(k, 512), replace=False)
        x = x.at[jnp.asarray(idx)].set(
            jnp.asarray(rng.standard_normal(len(idx)), jnp.float32))
        sn, stn = jax.jit(new.update)(sn, x)
        so, sto = jax.jit(old.update)(so, x)
        # bitwise-identical result, equal-or-lower realized work
        np.testing.assert_array_equal(np.asarray(new.result(sn)),
                                      np.asarray(old.result(so)))
        assert int(stn["recomputed"]) <= int(sto["recomputed"])
        assert int(stn["affected"]) <= int(sto["affected"])


def test_reduce_rebase_max_op():
    new = IncrementalReduce(n=256, block=4, op=jnp.maximum, identity=-1e30,
                            max_sparse=8)
    x = jnp.zeros(256).at[100].set(50.0)
    state = new.init(x)
    state, stats = jax.jit(new.update)(state, x.at[7].set(1.0))
    assert float(new.result(state)) == 50.0
    assert int(stats["recomputed"]) <= 8


# ---------------------------------------------------------------------------
# Rabin-Karp host app ported as a graph program
# ---------------------------------------------------------------------------
def test_stringhash_graph_matches_oracle():
    app = GraphStringHash(n=8192, grain=64, seed=0)
    app.run()
    assert app.output() == app.expected()
    for k in (1, 3, 64, 1000):
        stats = app.apply_update(k)
        assert app.output() == app.expected(), k
        assert int(stats["recomputed"]) >= 1


def test_stringhash_graph_complexity():
    """k-block edits touch O(k log(nb/k)) dag blocks (Theorem 4.2)."""
    n, grain = 16384, 64
    nb = n // grain                       # 256 leaf blocks
    h = stringhash_graph(n, grain, use_pallas=False, max_sparse=64)
    rng = np.random.default_rng(0)
    codes = rng.integers(97, 123, n).astype("int32")
    # pass the numpy array itself: CompiledGraph copies numpy inputs, so
    # the in-place edits below cannot alias the stored state
    h.run(text=codes)
    for k in (1, 4, 16):
        idx = rng.choice(nb, size=k, replace=False)
        for b in idx:
            codes[b * grain + rng.integers(grain)] = rng.integers(97, 123)
        out = h.update(text=codes)
        assert int(out[0, 0]) == stringhash_oracle(codes)
        bound = 3 * k * (1 + math.log2(1 + nb / k)) + 8
        assert int(h.stats["recomputed"]) <= bound


# ---------------------------------------------------------------------------
# Builder validation
# ---------------------------------------------------------------------------
def test_builder_rejects_bad_shapes():
    g = GraphBuilder()
    with pytest.raises(AssertionError):
        g.input("x", n=100, block=8)      # not divisible
    with pytest.raises(AssertionError):
        GraphBuilder().compile()


# ---------------------------------------------------------------------------
# Non-power-of-two block counts (odd levels pad with the op identity)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nb,block", [(12, 8), (7, 4), (13, 4), (3, 1)])
def test_reduce_tree_odd_blocks(nb, block):
    g = GraphBuilder()
    x = g.input("x", n=nb * block, block=block)
    t = g.reduce_tree(jnp.add, x, identity=0.0)
    g.output(t)
    cg = g.compile(max_sparse=4)
    rng = np.random.default_rng(nb)
    d = jnp.asarray(rng.integers(-9, 10, nb * block), jnp.float32)
    state = cg.init(x=d)
    assert float(cg.result(state)[0]) == float(d.sum())
    d2 = d.at[rng.integers(nb * block)].add(3.0)
    state, stats = cg.propagate(state, {"x": d2})
    assert_states_equal(cg, state, cg.init(x=d2))
    # one dirty chain up a ceil(log2 nb)-level tree (+ leaf fold)
    assert int(stats["recomputed"]) <= 2 + math.ceil(math.log2(nb))


@pytest.mark.parametrize("nb", [7, 13])
def test_reduce_tree_odd_max_op(nb):
    """Identity padding must be neutral for non-sum ops too."""
    g = GraphBuilder()
    x = g.input("x", n=nb, block=1)
    t = g.reduce_tree(jnp.maximum, x, identity=-jnp.inf)
    g.output(t)
    cg = g.compile(max_sparse=2)
    d = -jnp.arange(float(nb))            # max is element 0
    state = cg.init(x=d)
    assert float(cg.result(state)[0]) == 0.0
    d2 = d.at[nb - 1].set(99.0)           # new max in the padded tail
    state, _ = cg.propagate(state, {"x": d2})
    assert float(cg.result(state)[0]) == 99.0
    assert_states_equal(cg, state, cg.init(x=d2))


@pytest.mark.parametrize("nb,block", [(11, 8), (5, 4)])
def test_scan_odd_blocks(nb, block):
    g = GraphBuilder()
    x = g.input("x", n=nb * block, block=block)
    sc = g.scan(jnp.add, x, identity=0.0)
    g.output(sc)
    cg = g.compile(max_sparse=4)
    rng = np.random.default_rng(nb)
    d = jnp.asarray(rng.integers(-5, 6, nb * block), jnp.float32)
    state = cg.init(x=d)
    np.testing.assert_allclose(np.asarray(cg.result(state)),
                               np.cumsum(np.asarray(d)))
    d2 = d.at[3].add(1.0)
    state, _ = cg.propagate(state, {"x": d2})
    assert_states_equal(cg, state, cg.init(x=d2))


def test_incremental_reduce_odd_blocks():
    r = IncrementalReduce(n=24, block=2, op=jnp.add, identity=0.0,
                          max_sparse=4)          # 12 blocks: not a pow2
    x = jnp.arange(24.0)
    state = r.init(x)
    assert float(r.result(state)) == float(x.sum())
    y = x.at[17].set(-3.0)
    state, _ = jax.jit(r.update)(state, y)
    assert float(r.result(state)) == float(y.sum())


# ---------------------------------------------------------------------------
# Interval DirtySet + the causal edge kind
# ---------------------------------------------------------------------------
def _causal_mean(block):
    def fn(x, i):
        pos = jnp.arange(x.shape[0]) // block
        w = (pos <= i).astype(x.dtype)
        s = (x * w).sum() / w.sum()
        return jnp.full((block,), s, x.dtype)

    return fn


@pytest.mark.parametrize("rep", ["mask", "interval"])
def test_causal_update_equals_from_scratch(rep):
    nb, block = 16, 4
    g = GraphBuilder()
    x = g.input("x", n=nb * block, block=block)
    c = g.causal(_causal_mean(block), x)
    g.output(c)
    cg = g.compile(max_sparse=4, dirty=rep)
    d = jnp.asarray(np.arange(nb * block), jnp.float32)
    state = cg.init(x=d)
    d2 = d.at[40].set(-5.0)               # block 10 -> dirty suffix [10, 16)
    state, stats = cg.propagate(state, {"x": d2})
    assert_states_equal(cg, state, cg.init(x=d2))
    assert int(stats["recomputed"]) == nb - 10   # suffix, both reps exact


def test_interval_rep_pipeline_matches_mask():
    """The interval hull over-approximates but must stay bitwise sound."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    cgm = make_pipeline(max_sparse=16)
    cgi = make_pipeline(max_sparse=16, dirty="interval")
    sm = cgm.init(x=x)
    si = cgi.init(x=x)
    y2 = np.asarray(x).copy()
    y2[17] += 1.0
    y2[900] -= 2.0                        # two distant blocks: hull >> mask
    y2 = jnp.asarray(y2)
    sm, stm = cgm.propagate(sm, {"x": y2})
    si, sti = cgi.propagate(si, {"x": y2})
    assert_states_equal(cgm, sm, si)
    assert int(sti["recomputed"]) >= int(stm["recomputed"])
    assert int(sti["affected"]) >= int(stm["affected"])


def test_autotuned_max_sparse_per_level():
    """max_sparse="auto" calibrates a per-node crossover at the first
    init (when feature widths are known) and stays correct."""
    g = GraphBuilder()
    x = g.input("x", n=1024, block=8)
    t = g.reduce_tree(jnp.add, g.map(lambda b: b * 3.0, x), identity=0.0)
    g.output(t)
    cg = g.compile()                      # default: auto
    assert cg._ks is None                 # resolved lazily at init
    d = jnp.asarray(np.random.default_rng(1).standard_normal(1024),
                    jnp.float32)
    state = cg.init(x=d)
    op_nodes = [nd for nd in cg.nodes if nd.kind != "input"]
    assert all(1 <= cg._ks[nd.idx] <= nd.num_blocks for nd in op_nodes)
    d2 = d.at[100].set(7.0)
    state, _ = cg.propagate(state, {"x": d2})
    assert_states_equal(cg, state, cg.init(x=d2))


def test_propagate_before_init_rejected():
    cg = make_pipeline()
    cg2 = make_pipeline(max_sparse="auto")
    state = cg.init(x=jnp.zeros(1024, jnp.float32))
    with pytest.raises(AssertionError, match="init"):
        cg2.propagate(state, {"x": jnp.zeros(1024, jnp.float32)})


def test_propagate_rejects_unknown_input():
    cg = make_pipeline()
    state = cg.init(x=jnp.zeros(1024, jnp.float32))
    with pytest.raises(AssertionError):
        cg.propagate(state, {"bogus": jnp.zeros(1024, jnp.float32)})
