"""End-to-end behaviour: train/serve on a local mesh, dry-run machinery.

These are the integration seams: the same model/step/sharding code the
512-device dry-run lowers, executed for real on the 1-device local mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model, shape_by_name, SHAPES
from repro.optim import make_optimizer, make_schedule
from repro.launch.mesh import make_local_mesh
from repro.launch.train import init_train_state, make_train_step
from repro.launch.serve import make_decode_step, make_prefill_step
from repro.shardlib import rules_for_mode, shard_ctx


def test_shapes_registry():
    names = {s.name for s in SHAPES}
    assert names == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    s = shape_by_name("train_4k")
    assert s.seq_len == 4096 and s.global_batch == 256 and s.kind == "train"
    s = shape_by_name("decode_32k")
    assert s.seq_len == 32768 and s.global_batch == 128 and s.kind == "decode"
    s = shape_by_name("long_500k")
    assert s.seq_len == 524288 and s.global_batch == 1


@pytest.mark.slow
def test_train_under_mesh():
    """train_step jits and runs under an explicit mesh + sharding rules."""
    cfg = get_smoke_config("yi_6b")
    model = build_model(cfg)
    optimizer = make_optimizer(cfg)
    step = make_train_step(model, optimizer, make_schedule("cosine", 1e-3, 100))
    mesh = make_local_mesh()
    with shard_ctx(mesh, rules_for_mode("train")):
        state = init_train_state(model, optimizer, jax.random.PRNGKey(0))
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                         cfg.vocab_size),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                         cfg.vocab_size),
        }
        with mesh:
            state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.slow
def test_serve_roundtrip_under_mesh():
    cfg = get_smoke_config("yi_6b")
    model = build_model(cfg)
    prefill = make_prefill_step(model, impl="naive")
    decode = make_decode_step(model, decode_impl="naive")
    mesh = make_local_mesh()
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    with shard_ctx(mesh, rules_for_mode("decode")), mesh:
        logits, cache = jax.jit(prefill)(params, {"tokens": tokens})
        # grow cache and decode 3 tokens greedily
        cache = jax.tree.map(
            lambda c: jnp.pad(c, [(0, 0), (0, 8)] + [(0, 0)] * (c.ndim - 2))
            if c.ndim >= 3 and c.shape[1] == S else c, cache)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        for i in range(3):
            pos = jnp.full((B,), S + i, jnp.int32)
            next_tok, logits2, cache = jax.jit(decode)(params, cache, tok, pos)
            tok = next_tok[:, None]
    assert tok.shape == (B, 1)


@pytest.mark.slow
def test_decode_cache_layout_roundtrip():
    """Prefill cache layout == decode cache layout for every family."""
    for arch in ("yi_6b", "mamba2_370m", "recurrentgemma_9b",
                 "deepseek_v3_671b", "seamless_m4t_large_v2"):
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        B, S = 1, 16
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S),
                                              0, cfg.vocab_size)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((B, S, cfg.d_model), jnp.bfloat16)
        _, cache = model.prefill(params, batch, impl="naive")
        # decoder cache length == decoder token length (= S here)
        want = model.cache_shapes(B, S)
        got_shapes = jax.tree.map(lambda a: a.shape, cache)
        want_shapes = jax.tree.map(lambda s: s.shape, want)
        assert got_shapes == want_shapes, (arch, got_shapes, want_shapes)


@pytest.mark.slow
def test_local_dryrun_lower_compile():
    """The dry-run contract (lower + compile + analyses) on the local mesh."""
    from repro.launch.hlo_analysis import analyze_hlo

    cfg = get_smoke_config("minicpm_2b")
    model = build_model(cfg)
    optimizer = make_optimizer(cfg)
    step = make_train_step(model, optimizer, make_schedule("cosine", 1e-3, 100))
    mesh = make_local_mesh()
    with shard_ctx(mesh, rules_for_mode("train")), mesh:
        state_abs = jax.eval_shape(
            lambda: init_train_state(model, optimizer, jax.random.PRNGKey(0)))
        batch = {
            "tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
            "labels": jax.ShapeDtypeStruct((2, 32), jnp.int32),
        }
        lowered = jax.jit(step).lower(state_abs, batch)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    assert ma is not None
    costs = analyze_hlo(compiled.as_text(), 1)
    assert costs.flops > 0
    assert costs.bytes > 0


def test_benchmark_runner_quick(capsys):
    """The benchmark driver's quick paths execute end to end."""
    from benchmarks import psac_tables, readersets

    rows = psac_tables.bench_app("stringhash", quick=True)
    phases = {r["phase"] for r in rows}
    assert {"static", "psac_initial", "psac_update", "tree_size",
            "gc"} <= phases
    rows = readersets.run(quick=True)
    assert len(rows) >= 3
