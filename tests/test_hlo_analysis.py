"""The roofline's HLO cost model vs known-FLOP programs.

cost_analysis() on XLA:CPU counts while bodies once; analyze_hlo
re-multiplies by trip counts.  These tests pin the model to analytically
known cases so the §Roofline numbers are trustworthy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def costs_of(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return analyze_hlo(compiled.as_text(), 1)


def test_single_matmul_flops():
    A = jax.ShapeDtypeStruct((1024, 512), jnp.float32)
    B = jax.ShapeDtypeStruct((512, 256), jnp.float32)
    c = costs_of(lambda a, b: a @ b, A, B)
    assert c.flops == pytest.approx(2 * 1024 * 512 * 256, rel=0.01)
    # operands + result, each touched once
    want_bytes = 4 * (1024 * 512 + 512 * 256 + 1024 * 256)
    assert c.bytes == pytest.approx(want_bytes, rel=0.1)


def test_scan_multiplies_by_trip_count():
    def scanned(a, bs):
        def body(x, b):
            return x @ b, ()
        out, _ = jax.lax.scan(body, a, bs)
        return out

    A = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    Bs = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    c = costs_of(scanned, A, Bs)
    assert c.flops == pytest.approx(7 * 2 * 256 * 128 * 128, rel=0.01)
    assert c.unparsed_whiles == 0


def test_nested_scan():
    def nested(a, bs):
        def outer(x, grp):
            def inner(y, b):
                return y @ b, ()
            y, _ = jax.lax.scan(inner, x, grp)
            return y, ()
        out, _ = jax.lax.scan(outer, a, bs)
        return out

    A = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    Bs = jax.ShapeDtypeStruct((5, 3, 128, 128), jnp.float32)
    c = costs_of(nested, A, Bs)
    assert c.flops == pytest.approx(15 * 2 * 256 * 128 * 128, rel=0.01)


def test_scan_slices_charged_not_full_stack():
    """In-place slice semantics: a scan over stacked weights must charge
    per-iteration slice traffic, not the whole stack every iteration
    (the 40x memory-term overcount fixed in §Perf hillclimb A, iter 2)."""
    def scanned(a, bs):
        def body(x, b):
            return jnp.tanh(x @ b), ()
        out, _ = jax.lax.scan(body, a, bs)
        return out

    A = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    Bs = jax.ShapeDtypeStruct((40, 128, 128), jnp.float32)
    c = costs_of(scanned, A, Bs)
    act, w = 256 * 128 * 4, 128 * 128 * 4
    assert c.bytes < 40 * (2 * w + 6 * act)          # slice-granular
    assert c.bytes > 40 * (w + 2 * act) * 0.5        # but not free
    stack_per_iter_model = 40 * (40 * w)             # the old overcount
    assert c.bytes < stack_per_iter_model / 3


def test_grad_counts_forward_and_backward():
    def loss(a, b):
        return jnp.sum(jnp.tanh(a @ b))

    A = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    B = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = costs_of(lambda a, b: jax.grad(loss)(a, b), A, B)
    fwd = 2 * 256 * 128 * 128
    # fwd matmul + da = g @ b.T  (db dropped: grad wrt a only)
    assert c.flops >= 1.9 * fwd


def test_collective_accounting():
    import os
    from jax.sharding import NamedSharding, PartitionSpec as P

    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (dry-run covers this via 512)")


def test_collective_parsing_from_text():
    # Hand-written post-SPMD HLO exercising the collective parser.
    hlo = """
HloModule test

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p0), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
  %ag = f32[1024]{0} all-gather(%ar), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %out = f32[1024]{0} add(%ar, %ag)
}
"""
    c = analyze_hlo(hlo, 8)
    assert c.collectives["all-reduce"].count == 1
    assert c.collectives["all-reduce"].bytes == 4096
    # ring all-reduce: 2*(g-1)/g * bytes, g=4
    assert c.collectives["all-reduce"].wire_bytes == pytest.approx(
        2 * 3 / 4 * 4096)
    assert c.collectives["all-gather"].count == 1
    # all-gather wire volume scales with output size
    assert c.collectives["all-gather"].wire_bytes == pytest.approx(
        3 / 4 * 4096)


def test_fusion_intermediates_free():
    def chain(a):
        return jnp.sum(jnp.tanh(a) * 2.0 + jnp.exp(a))

    A = jax.ShapeDtypeStruct((4096, 256), jnp.float32)
    c = costs_of(chain, A)
    # bytes should be a small multiple of the input, NOT ~8x (tanh/exp/mul/
    # add/sum all separately counted) — fusion collapses intermediates.
    # XLA:CPU fuses less aggressively than TPU, so allow one extra pass.
    assert c.bytes < 4 * 4096 * 256 * 4
