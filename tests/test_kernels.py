"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def randn(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


FLASH_CASES = [
    # B, Sq, Skv, KV, G, hd, hv, causal, window, offset, dtype
    (1, 256, 256, 2, 2, 64, 64, True, 0, 0, jnp.float32),
    (2, 128, 128, 1, 4, 128, 128, True, 0, 0, jnp.float32),
    (1, 256, 256, 2, 1, 128, 128, False, 0, 0, jnp.float32),
    (1, 256, 256, 1, 2, 64, 64, True, 128, 0, jnp.float32),
    (1, 128, 128, 1, 1, 96, 96, True, 0, 0, jnp.float32),      # phi3 head_dim
    (1, 128, 128, 1, 2, 256, 256, True, 0, 0, jnp.float32),    # gemma head_dim
    (2, 128, 384, 1, 4, 64, 64, True, 0, 256, jnp.float32),    # suffix continuation
    (1, 128, 384, 2, 1, 64, 64, True, 128, 256, jnp.float32),  # window + offset
    (1, 256, 256, 2, 2, 64, 64, True, 0, 0, jnp.bfloat16),
    (1, 128, 128, 1, 2, 128, 128, False, 0, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    B, Sq, Skv, KV, G, hd, hv, causal, window, offset, dt = case
    q = randn((B, Sq, KV, G, hd), dt)
    k = randn((B, Skv, KV, hd), dt)
    v = randn((B, Skv, KV, hv), dt)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              offset=offset, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   offset=offset)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_flash():
    """The Pallas kernel and the pure-JAX custom-VJP flash agree."""
    from repro.models.flash import flash_attention_grouped

    q = randn((1, 512, 2, 2, 64), jnp.float32)
    k = randn((1, 512, 2, 64), jnp.float32)
    v = randn((1, 512, 2, 64), jnp.float32)
    a = ops.flash_attention(q, k, v, causal=True, interpret=True)
    b = flash_attention_grouped(q, k, v, causal=True)
    # model flash returns [B,S,KV,G,hv]
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-5, atol=2e-5)


def test_flash_blocks_divisibility_guard():
    q = randn((1, 100, 1, 1, 64), jnp.float32)
    k = randn((1, 100, 1, 64), jnp.float32)
    v = randn((1, 100, 1, 64), jnp.float32)
    with pytest.raises(AssertionError):
        ops.flash_attention(q, k, v, causal=True, interpret=True)


# ---------------------------------------------------------------------------
@given(st.integers(1, 5), st.integers(0, 2**31 - 1), st.booleans())
@settings(max_examples=12, deadline=None)
def test_dirty_reduce_property(tiles, seed, all_dirty):
    rng = np.random.default_rng(seed)
    P, W, block = tiles * 8, 128, 8
    kids = jnp.asarray(rng.standard_normal((P, 2, W)), jnp.float32)
    old = jnp.asarray(rng.standard_normal((P, W)), jnp.float32)
    dirty = jnp.asarray(np.ones(P, bool) if all_dirty
                        else rng.random(P) < 0.3)
    out = ops.dirty_reduce_level(kids, old, dirty, block=block, interpret=True)
    tile_dirty = np.repeat(
        np.asarray(dirty).reshape(-1, block).any(1), block)
    want = ref.dirty_reduce_level_ref(kids, old, jnp.asarray(tile_dirty))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


def test_dirty_reduce_clean_is_identity():
    P, W = 32, 128
    kids = randn((P, 2, W), jnp.float32)
    old = randn((P, W), jnp.float32)
    out = ops.dirty_reduce_level(kids, old, jnp.zeros(P, bool), interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(old))


# ---------------------------------------------------------------------------
# dirty_map: the generalized dirty-tile kernel (arbitrary combining fn)
# ---------------------------------------------------------------------------
def _tile_dilate(dirty, block):
    return np.repeat(np.asarray(dirty).reshape(-1, block).any(1), block)


@pytest.mark.parametrize("op", [jnp.add, jnp.maximum, jnp.multiply])
def test_dirty_map_reduce_level_any_op(op):
    """dirty_map reproduces a reduce level for any combining op."""
    P, W, block = 32, 128, 8
    rng = np.random.default_rng(0)
    kids = jnp.asarray(rng.standard_normal((P, 2, W)), jnp.float32)
    old = jnp.asarray(rng.standard_normal((P, W)), jnp.float32)
    dirty = jnp.asarray(rng.random(P) < 0.3)

    def fn(rows):                       # rows: [tile, 2*W]
        pair = rows.reshape(rows.shape[0], 2, W)
        return op(pair[:, 0], pair[:, 1])

    out = ops.dirty_map(fn, [kids.reshape(P, 2 * W)], old, dirty,
                        block=block, interpret=True)
    want = ref.dirty_map_ref(fn, [kids.reshape(P, 2 * W)], old,
                             jnp.asarray(_tile_dilate(dirty, block)))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_dirty_map_two_inputs():
    P, W, block = 24, 64, 8
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((P, W)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((P, W)), jnp.float32)
    old = jnp.asarray(rng.standard_normal((P, W)), jnp.float32)
    dirty = jnp.asarray(rng.random(P) < 0.5)
    fn = lambda x, y: x * y + 1.0
    out = ops.dirty_map(fn, [a, b], old, dirty, block=block, interpret=True)
    want = ref.dirty_map_ref(fn, [a, b], old,
                             jnp.asarray(_tile_dilate(dirty, block)))
    # mul+add may fuse to an FMA outside the kernel: allow 1-ulp slack
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_dirty_map_clean_is_identity():
    P, W = 16, 128
    x = randn((P, W), jnp.float32)
    old = randn((P, W), jnp.float32)
    out = ops.dirty_map(lambda v: v * 3.0, [x], old, jnp.zeros(P, bool),
                        block=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(old))


# ---------------------------------------------------------------------------
GM_CASES = [
    (200, 64, 256, 5, [50, 0, 90, 37, 23], jnp.float32),
    (64, 32, 128, 2, [64, 0], jnp.float32),
    (128, 128, 128, 4, [1, 2, 3, 122], jnp.float32),
    (96, 64, 128, 3, [32, 32, 32], jnp.bfloat16),
]


@pytest.mark.parametrize("case", GM_CASES)
def test_grouped_matmul_matches_ref(case):
    M, D, F, E, sizes, dt = case
    x = randn((M, D), dt)
    w = randn((E, D, F), dt)
    gs = jnp.asarray(sizes, jnp.int32)
    out = ops.grouped_matmul(x, w, gs, mb=16, fb=64, interpret=True)
    want = ref.grouped_matmul_ref(x, w, gs)
    tol = 5e-2 if dt == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_grouped_matmul_matches_ragged_dot():
    M, D, F, E = 120, 32, 128, 4
    x = randn((M, D), jnp.float32)
    w = randn((E, D, F), jnp.float32)
    gs = jnp.asarray([30, 42, 0, 48], jnp.int32)
    out = ops.grouped_matmul(x, w, gs, mb=8, fb=64, interpret=True)
    want = jax.lax.ragged_dot(x, w, gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# dirty_causal: block-skip carry scan vs the dense associative_scan oracle
# ---------------------------------------------------------------------------
def _dense_scan_oracle(op, contrib):
    return jax.lax.associative_scan(op, contrib, axis=0)


def _check_block_skip(contrib, start, op, identity, block, state_shape=()):
    """Edit-suffix protocol: old states memoize the pre-edit scan; the
    kernel must rebuild the post-edit scan bitwise from the cached
    prefix, and keep every pre-suffix row bitwise stable."""
    old_states = _dense_scan_oracle(op, contrib)
    edited = contrib.at[start:].add(jnp.asarray(3, contrib.dtype)) \
        if start < contrib.shape[0] else contrib
    want = _dense_scan_oracle(op, edited)
    got = ops.dirty_causal_scan(edited, old_states, jnp.int32(start), op,
                                identity=identity, block=block,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # clean-block stability under the changed-mask cutoff: rows before
    # the dirty suffix are the cached rows, bit for bit
    np.testing.assert_array_equal(np.asarray(got)[:start],
                                  np.asarray(old_states)[:start])


def test_dirty_causal_basic_suffixes():
    for P, block in [(16, 4), (10, 4), (33, 8), (7, 8)]:
        contrib = jnp.asarray(RNG.integers(0, 1000, (P, 3)), jnp.int32)
        for start in (0, 1, P // 2, P - 1, P):
            _check_block_skip(contrib, start, jnp.add, 0, block)


def test_dirty_causal_scalar_state_and_float_exact():
    # scalar per-block states
    contrib = jnp.asarray(RNG.integers(0, 100, (24,)), jnp.int32)
    _check_block_skip(contrib, 9, jnp.add, 0, 8)
    # float32 holding small integers: addition is exact, so any
    # re-bracketing is bitwise stable — the float case the block-skip
    # contract covers
    contrib = jnp.asarray(RNG.integers(0, 64, (24, 2)), jnp.float32)
    _check_block_skip(contrib, 13, jnp.add, 0.0, 4)


def test_dirty_causal_modular_op():
    # Rabin-Karp-style modular combine (non-commutative pair state).
    # NB: Python-int modulus — ops traced into a Pallas kernel body must
    # not capture array constants (same contract as dirty_map's fn) —
    # and M < sqrt(2^31) so products stay in int32 (overflow wraparound
    # is deterministic but not associative across re-bracketings).
    M = 46_337

    def combine(a, b):
        return jnp.stack([(a[..., 0] * b[..., 1] + b[..., 0]) % M,
                          (a[..., 1] * b[..., 1]) % M], axis=-1)

    contrib = jnp.stack(
        [jnp.asarray(RNG.integers(0, 1000, (20,)), jnp.int32),
         jnp.full((20,), 31, jnp.int32)], axis=-1)
    old = _dense_scan_oracle(combine, contrib)
    edited = contrib.at[11, 0].set(999)
    want = _dense_scan_oracle(combine, edited)
    got = ops.dirty_causal_scan(edited, old, jnp.int32(11), combine,
                                identity=jnp.asarray([0, 1], jnp.int32),
                                block=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(2, 48), st.integers(0, 2**31 - 1), st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_dirty_causal_block_skip_property(P, seed, blk_pow):
    """Property: for ANY length, tile size, and random edit suffix, the
    block-skip kernel rebuilds the dense oracle's scan bitwise from the
    cached prefix states."""
    block = 2 ** blk_pow
    r = np.random.default_rng(seed)
    contrib = jnp.asarray(r.integers(-1000, 1000, (P, 2)), jnp.int32)
    old_states = _dense_scan_oracle(jnp.add, contrib)
    start = int(r.integers(0, P + 1))
    edited = contrib.at[start:].add(jnp.int32(r.integers(1, 100))) \
        if start < P else contrib
    want = _dense_scan_oracle(jnp.add, edited)
    got = ops.dirty_causal_scan(edited, old_states, jnp.int32(start),
                                jnp.add, identity=0, block=block,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got)[:start],
                                  np.asarray(old_states)[:start])
