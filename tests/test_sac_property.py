"""Property tests: random traced programs, host vs graph backend.

The system invariant (Theorem 4.1, frontend restatement): for ANY
program expressible in the ``repro.sac`` frontend and ANY sequence of
batch edits, the jit-compiled graph backend and the paper-faithful host
engine must produce bitwise-identical outputs, and their post-cutoff
changed-block counts ("affected") must agree — the two backends are one
semantics on two substrates.

Programs are generated from a seed (ops drawn from the full frontend
vocabulary, value-bounded so float non-associativity cannot manufacture
spurious diffs), so the sweep runs without hypothesis; when hypothesis
is installed (requirements-dev.txt) it drives the same generator through
many more seeds.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import HAVE_HYPOTHESIS, given, settings, st

import repro.sac as sac

# Value-bounded op vocabulary: every op keeps small-integer-valued f32
# inputs in a small range, so bitwise equality across backends is a real
# test of the lowering, not of float edge cases.
UNARY = [
    ("affine", lambda x: x * 2.0 + 1.0),
    ("clip", lambda x: sac.elementwise(jnp.clip)(x, -3.0, 3.0)),
    ("abs", lambda x: abs(x)),
    ("neg", lambda x: -x),
    ("halve", lambda x: x / 2.0),
]
BINARY = [
    ("add", lambda a, b: a + b),
    ("sub", lambda a, b: a - b),
    ("min", lambda a, b: np.minimum(a, b)),
    ("max", lambda a, b: np.maximum(a, b)),
]


def make_program(seed: int):
    """Random program over two inputs; returns (program, n, block)."""
    rng = np.random.default_rng(seed)
    block = int(rng.choice([2, 4]))
    nb = int(rng.choice([5, 8, 12, 16]))     # non-pow2 counts included
    n = nb * block
    n_ops = int(rng.integers(2, 6))
    picks = [(rng.random(), int(rng.integers(10**6)))
             for _ in range(n_ops)]
    use_scan = bool(rng.integers(2))

    @sac.incremental(block=block)
    def prog(x0, x1):
        pool = [x0, x1]
        for r, sub in picks:
            srng = np.random.default_rng(sub)
            if r < 0.45:
                name, f = UNARY[srng.integers(len(UNARY))]
                src = pool[srng.integers(len(pool))]
                pool.append(f(src))
            elif r < 0.8:
                name, f = BINARY[srng.integers(len(BINARY))]
                a = pool[srng.integers(len(pool))]
                b = pool[srng.integers(len(pool))]
                pool.append(f(a, b))
            else:
                src = pool[srng.integers(len(pool))]
                pool.append(sac.stencil(
                    lambda w: w[block:2 * block]
                    + 0.5 * (w[:block] + w[2 * block:]),
                    src, radius=1))
        last = pool[-1]
        outs = [sac.reduce(jnp.add, last, identity=0.0),
                sac.reduce(jnp.maximum, last, identity=-jnp.inf)]
        if use_scan:
            outs.append(sac.scan(jnp.add, pool[2 if len(pool) > 2 else 0]))
        return tuple(outs)

    return prog, n, block


def _edit_batches(rng, n, rounds=3):
    for _ in range(rounds):
        which = int(rng.integers(3))         # x0 / x1 / both
        k = int(rng.integers(1, max(2, n // 4)))
        yield which, rng.choice(n, size=k, replace=False), \
            rng.integers(-5, 6, k).astype(np.float32)


def check_seed(seed: int):
    prog, n, block = make_program(seed)
    rng = np.random.default_rng(seed + 1)
    x0 = rng.integers(-5, 6, n).astype(np.float32)
    x1 = rng.integers(-5, 6, n).astype(np.float32)
    hg = prog.compile(x0=n, x1=n, max_sparse=4)
    hh = prog.compile("host", x0=n, x1=n)
    og = hg.run(x0=x0, x1=x1)
    oh = hh.run(x0=x0, x1=x1)
    for a, b in zip(og, oh):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"seed {seed} initial run")
    for which, idx, vals in _edit_batches(rng, n):
        if which in (0, 2):
            x0 = x0.copy()
            x0[idx] = vals
        if which in (1, 2):
            x1 = x1.copy()
            x1[idx[::-1]] = vals
        og = hg.update(x0=x0, x1=x1)
        oh = hh.update(x0=x0, x1=x1)
        for a, b in zip(og, oh):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"seed {seed} edit which={which}")
        assert hg.stats["affected"] == hh.stats["affected"], (
            seed, which, hg.stats, hh.stats)
        assert hg.stats["dirty_inputs"] == hh.stats["dirty_inputs"], (
            seed, which, hg.stats, hh.stats)


# Always-on sweep (seeded): the invariant must hold without dev deps.
@pytest.mark.parametrize("seed", range(8))
def test_backend_parity_seeded(seed):
    check_seed(seed)


@given(st.integers(100, 10**6))
@settings(max_examples=15, deadline=None)
def test_backend_parity_hypothesis(seed):
    check_seed(seed)


if HAVE_HYPOTHESIS:  # keep the shim import "used" for linters
    pass
