"""Public API surface: every exported name imports and old paths hold.

Guards the ``repro.sac`` introduction: the new frontend is re-exported
from ``repro.jaxsac``, while the pre-redesign entry points
(``IncrementalReduce``, ``incremental_prefill``, ``GraphBuilder``)
remain importable at their old paths (the last via a deprecation shim).
"""
import importlib
import warnings

import pytest


@pytest.mark.parametrize("module", ["repro.sac", "repro.jaxsac"])
def test_all_public_names_importable(module):
    mod = importlib.import_module(module)
    assert mod.__all__, module
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for name in mod.__all__:
            assert getattr(mod, name) is not None, f"{module}.{name}"


def test_old_paths_still_importable():
    from repro.jaxsac import (BlockTensor, CompiledGraph,  # noqa: F401
                              IncrementalReduce, dirty_from_diff,
                              incremental_prefill, prefill_distance)
    from repro.jaxsac.reduce import IncrementalReduce as IR2
    from repro.jaxsac.prefill import incremental_prefill as IP2
    assert IncrementalReduce is IR2
    assert incremental_prefill is IP2


def test_sac_reexported_from_jaxsac():
    import repro.jaxsac as jx
    import repro.sac as sac
    assert jx.sac is sac
    assert sac.incremental is jx.sac.incremental


def test_graphbuilder_old_path_warns_but_works():
    import repro.jaxsac as jx
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        gb = jx.GraphBuilder
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    from repro.jaxsac.graph import GraphBuilder
    assert gb is GraphBuilder


def test_dirtyset_surface():
    from repro.jaxsac import MaskDirty, IntervalDirty
    from repro.jaxsac.dirtyset import DIRTY_REPS, DirtySet
    assert DIRTY_REPS == {"mask": MaskDirty, "interval": IntervalDirty}
    assert isinstance(MaskDirty.none(4), DirtySet)
    assert isinstance(IntervalDirty.none(4), DirtySet)
