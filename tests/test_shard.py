"""Sharded propagation + plan cache + packed gather.

The sharded runtime's contract is *bitwise invisibility*: for any
traced program, ``compile(shards=N)`` must produce the same outputs,
the same post-cutoff ``affected`` counts, and the same realized
``recomputed`` distance as the single-device runtime, for every edit —
the shards only change where the work runs.  These tests pin that
contract on every edge kind (including the distributed carry exchange,
the stencil halo ppermute, and the reduce tree's
all-gather-then-local-combine tail), plus the dirty-signature plan
cache's zero-refreeze steady state and the packed gather's
recompute-count preservation.

Multi-device CPU comes from conftest.py
(``--xla_force_host_platform_device_count=8``); tests skip when fewer
devices are visible (e.g. an externally pinned XLA_FLAGS).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.sac as sac
from repro.jaxsac.graph_ops import mask_indices
from repro.shardlib import block_mesh

BLOCK = 4


def _devices_or_skip(n):
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} devices, have {len(jax.devices())}")


def _pipeline():
    @sac.incremental(block=BLOCK)
    def prog(x):
        y = x * 2.0 + 1.0
        s = sac.stencil(lambda w: w[BLOCK:2 * BLOCK]
                        + 0.5 * (w[:BLOCK] + w[2 * BLOCK:]), y, radius=1)
        return sac.reduce(jnp.add, s, identity=0.0)

    return prog


def _carry():
    @sac.incremental(block=BLOCK)
    def prog(x):
        return sac.causal(None, x, lift=lambda b: b.sum(), op=jnp.add,
                          finalize=lambda s, b: b + s, identity=0)

    return prog


def _scan(identity):
    @sac.incremental(block=BLOCK)
    def prog(x):
        return sac.scan(jnp.add, x, identity=identity)

    return prog


def _edit(rng, data, k=1):
    new = data.copy()
    for lane in rng.choice(data.shape[0], size=k, replace=False):
        new[lane] = new[lane] + 1
    return new


def _parity(prog, n, shards, dtype=np.float32, reps=4, edits=None,
            seed=0, **kw):
    """Run prog single-device and sharded through ``reps`` edits and
    assert bitwise outputs + identical stats."""
    h1 = prog.compile(x=n, max_sparse=4, **kw)
    h2 = prog.compile(x=n, max_sparse=4, shards=shards, **kw)
    rng = np.random.default_rng(seed)
    data = rng.integers(-5, 6, n).astype(dtype)
    a, b = h1.run(x=data), h2.run(x=data)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for r in range(reps):
        new = (_edit(rng, data, 1 + r % 3) if edits is None
               else edits(rng, data, r))
        a, b = h1.update(x=new), h2.update(x=new)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"edit {r}")
        s1, s2 = h1.stats, h2.stats
        for key in ("recomputed", "affected", "dirty_inputs"):
            assert s1[key] == s2[key], (key, r, s1, s2)
        data = new
    return h1, h2


# ---------------------------------------------------------------------------
# Bitwise parity per edge kind
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shards", [2, 4, 8])
def test_pipeline_parity(shards):
    _devices_or_skip(shards)
    _parity(_pipeline(), 64 * BLOCK, shards)


@pytest.mark.parametrize("nb", [12, 13, 67])
def test_pipeline_parity_awkward_counts(nb):
    # 13 is prime (every level replicated), 12 mixes sharded levels with
    # an odd identity-padded one, 67 forces the sparse regime live.
    _devices_or_skip(3)
    _parity(_pipeline(), nb * BLOCK, 3)


def test_carry_causal_distributed_exact():
    # int32 carry monoid: the cross-shard Ladner-Fischer exchange runs
    # (exact dtype) and must stay bitwise equal to the single-device
    # block-skip refold.
    _devices_or_skip(4)
    h1, h2 = _parity(_carry(), 16 * BLOCK, 4, dtype=np.int32)
    assert h2.cg._sharder.sharded[1], "carry node should be sharded"


def test_scan_int_distributed_float_replicated():
    _devices_or_skip(4)
    _parity(_scan(0), 16 * BLOCK, 4, dtype=np.int32)
    h1, h2 = _parity(_scan(0.0), 16 * BLOCK, 4, dtype=np.float32)
    escan = [nd.idx for nd in h2.cg.nodes if nd.kind == "escan"]
    # float escan re-bracketing is unsound for the bitwise cutoff: the
    # node must have fallen back to replicated compute.
    assert not h2.cg._sharder.sharded[escan[0]]


def test_stencil_fill_and_wide_radius():
    _devices_or_skip(8)

    @sac.incremental(block=BLOCK)
    def prog(x):
        s = sac.stencil(lambda w: w[2 * BLOCK:3 * BLOCK]
                        + w[:BLOCK] + w[4 * BLOCK:], x, radius=2,
                        fill=1.5)
        return sac.reduce(jnp.add, s, identity=0.0)

    # nb=16 over 8 shards -> 2 local blocks = radius: ppermute halo path;
    # the same program over 8 shards with nb=8 -> 1 local block < radius:
    # full-gather fallback.  Both must be bitwise.
    _parity(prog, 16 * BLOCK, 8)
    _parity(prog, 8 * BLOCK, 8)


def test_boundary_straddling_edits():
    # Edits that straddle shard boundaries (the halo / carry exchange
    # paths) rather than landing inside one chunk.
    _devices_or_skip(4)
    n = 32 * BLOCK

    def edits(rng, data, r):
        new = data.copy()
        cut = (r % 3 + 1) * (n // 4)           # a shard boundary
        for lane in range(max(cut - 3, 0), min(cut + 3, n)):
            new[lane] = new[lane] + 1
        return new

    _parity(_pipeline(), n, 4, edits=edits)
    _parity(_carry(), n, 4, dtype=np.int32, edits=edits)


def test_interval_rep_and_legacy_plan_and_nodonate():
    _devices_or_skip(2)
    _parity(_scan(0), 16 * BLOCK, 2, dtype=np.int32, dirty="interval")
    _parity(_pipeline(), 16 * BLOCK, 2, plan=False)
    _parity(_pipeline(), 16 * BLOCK, 2, donate=False)


def test_multi_input_zip():
    _devices_or_skip(2)

    @sac.incremental(block=BLOCK)
    def prog(x, y):
        z = x + y * 2.0
        return sac.reduce(jnp.maximum, z, identity=-jnp.inf)

    n = 24 * BLOCK
    h1 = prog.compile(x=n, y=n, max_sparse=4)
    h2 = prog.compile(x=n, y=n, max_sparse=4, shards=2)
    rng = np.random.default_rng(0)
    x = rng.integers(-5, 6, n).astype(np.float32)
    y = rng.integers(-5, 6, n).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(h1.run(x=x, y=y)),
                                  np.asarray(h2.run(x=x, y=y)))
    for r in range(3):
        tgt = [x, y][r % 2].copy()
        tgt[rng.integers(n)] += 1.0
        kw = {"x": tgt} if r % 2 == 0 else {"y": tgt}
        np.testing.assert_array_equal(np.asarray(h1.update(**kw)),
                                      np.asarray(h2.update(**kw)))
        assert h1.stats["affected"] == h2.stats["affected"]
        if r % 2 == 0:
            x = tgt
        else:
            y = tgt


def test_per_shard_recompute_counts():
    _devices_or_skip(4)
    prog = _pipeline()
    h = prog.compile(x=64 * BLOCK, max_sparse=4, shards=4)
    rng = np.random.default_rng(0)
    data = rng.integers(-5, 6, 64 * BLOCK).astype(np.float32)
    h.run(x=data)
    new = data.copy()
    new[0] += 1.0                        # one block in shard 0
    h.update(x=new)
    per = h.stats["recomputed_per_shard"]
    assert len(per) == 4
    # Shard 0 owns the edited chunk: it must do at least as much local
    # masked work as any other shard, and some work must have happened.
    assert per[0] == max(per) and sum(per) > 0


def test_mesh_arg_and_errors():
    _devices_or_skip(2)
    prog = _pipeline()
    h = prog.compile(x=16 * BLOCK, max_sparse=4,
                     mesh=block_mesh(2))    # explicit mesh object
    data = np.arange(16 * BLOCK, dtype=np.float32)
    h.run(x=data)
    with pytest.raises(ValueError):
        block_mesh(10 ** 6)
    with pytest.raises(AssertionError):
        prog.compile("host", x=16 * BLOCK, shards=2)


def test_hybrid_fragments_accept_mesh():
    _devices_or_skip(2)

    @sac.incremental(block=BLOCK)
    def prog(x):
        with sac.static_region("a"):
            y = x * 2.0
        with sac.static_region("b"):
            return sac.reduce(jnp.add, y, identity=0.0)

    n = 16 * BLOCK
    h1 = prog.compile("hybrid", x=n, max_sparse=4)
    h2 = prog.compile("hybrid", x=n, max_sparse=4, shards=2)
    rng = np.random.default_rng(0)
    data = rng.integers(-5, 6, n).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(h1.run(x=data)),
                                  np.asarray(h2.run(x=data)))
    new = data.copy()
    new[7] += 1.0
    np.testing.assert_array_equal(np.asarray(h1.update(x=new)),
                                  np.asarray(h2.update(x=new)))
    assert h1.stats["recomputed"] == h2.stats["recomputed"]


# ---------------------------------------------------------------------------
# Plan cache
# ---------------------------------------------------------------------------
def test_plan_cache_zero_refreeze_on_repeated_pattern():
    """The serving steady state: a repeated edit pattern must stop
    freezing plans after its first update — only hits afterwards."""
    prog = _pipeline()
    h = prog.compile(x=64 * BLOCK, max_sparse=8)
    rng = np.random.default_rng(0)
    data = rng.integers(-5, 6, 64 * BLOCK).astype(np.float32)
    h.run(x=data)
    new = data.copy()
    new[130] += 1.0                      # interior single-block edit
    h.update(x=new)
    h.update(x=data)                     # revert: same dirty signature
    frozen = h.stats["plan_cache"]["misses"]
    for _ in range(6):                   # steady state: hits only
        h.update(x=new)
        h.update(x=data)
    pc = h.stats["plan_cache"]
    assert pc["misses"] == frozen, pc
    assert pc["hits"] >= 12, pc
    assert pc["evictions"] == 0, pc


def test_plan_cache_sharded_zero_refreeze():
    _devices_or_skip(2)
    prog = _pipeline()
    h = prog.compile(x=64 * BLOCK, max_sparse=8, shards=2)
    rng = np.random.default_rng(0)
    data = rng.integers(-5, 6, 64 * BLOCK).astype(np.float32)
    h.run(x=data)
    new = data.copy()
    new[200] += 1.0
    h.update(x=new)
    h.update(x=data)
    frozen = h.stats["plan_cache"]["misses"]
    for _ in range(4):
        h.update(x=new)
        h.update(x=data)
    assert h.stats["plan_cache"]["misses"] == frozen


def test_plan_cache_lru_eviction():
    # nb must exceed TINY_NB so the sparse buckets differentiate the
    # signatures (tiny nodes are always planned dense).
    prog = _pipeline()
    h = prog.compile(x=256 * BLOCK, max_sparse=8, plan_cache=2)
    rng = np.random.default_rng(0)
    data = rng.integers(-5, 6, 256 * BLOCK).astype(np.float32)
    h.run(x=data)
    # Three clearly distinct signatures: 1, 2 and 33 dirty blocks (33 >
    # max_sparse -> dense) cycled through a cap-2 cache must evict.
    variants = []
    for k in (1, 2, 33):
        new = data.copy()
        for b in range(k):
            new[8 + b * BLOCK] += 1.0
        variants.append(new)
    for _ in range(3):
        for v in variants:
            h.update(x=v)
            h.update(x=data)
    pc = h.stats["plan_cache"]
    assert pc["size"] <= 2 and pc["evictions"] > 0, pc
    # Evicted plans must still produce correct results when refrozen.
    ref = prog.compile(x=256 * BLOCK, max_sparse=8)
    ref.run(x=data)
    for v in variants:
        np.testing.assert_array_equal(np.asarray(h.update(x=v)),
                                      np.asarray(ref.update(x=v)))
        np.testing.assert_array_equal(np.asarray(h.update(x=data)),
                                      np.asarray(ref.update(x=data)))


def test_quantized_budget_still_covers_all_dirty_lanes():
    # Edit sizes within one power-of-two bucket share a signature; the
    # bucket's gather budget must still cover every dirty lane (nb >
    # TINY_NB so the sparse regime is actually planned).
    prog = _pipeline()
    h = prog.compile(x=256 * BLOCK, max_sparse=16)
    ref = prog.compile(x=256 * BLOCK, max_sparse=16)
    rng = np.random.default_rng(0)
    data = rng.integers(-5, 6, 256 * BLOCK).astype(np.float32)
    h.run(x=data)
    ref.run(x=data)
    misses = []
    for k in (5, 6, 7):
        # Contiguous k-block edits: every node's count lands in the same
        # power-of-two bucket for k in 5..7 (input/map 8, stencil 8
        # after dilation, each reduce level its own shared bucket), so
        # only the first edit may freeze.
        new = data.copy()
        for b in range(k):
            new[b * BLOCK] += 1.0
        np.testing.assert_array_equal(np.asarray(h.update(x=new)),
                                      np.asarray(ref.update(x=new)))
        np.testing.assert_array_equal(np.asarray(h.update(x=data)),
                                      np.asarray(ref.update(x=data)))
        misses.append(h.stats["plan_cache"]["misses"])
    assert misses[-1] == misses[0], misses


# ---------------------------------------------------------------------------
# Device-side index extraction
# ---------------------------------------------------------------------------
def test_mask_indices_matches_flatnonzero():
    rng = np.random.default_rng(0)
    for nb in (1, 5, 64, 257):
        for _ in range(20):
            mask = rng.random(nb) < 0.3
            k = int(rng.integers(1, nb + 1))
            got = np.asarray(mask_indices(jnp.asarray(mask), k))
            want = np.full((k,), nb, np.int32)
            ix = np.flatnonzero(mask)[:k]
            want[:len(ix)] = ix
            np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Packed gather
# ---------------------------------------------------------------------------
def _packed_progs():
    def idx_fn(xb):
        return jnp.abs(xb.sum(axis=1, keepdims=True)).astype(jnp.int32) % 7

    def packed(own, nbrs):
        return own + 0.5 * nbrs[0]

    def full_fn(xf, i, _b=BLOCK):
        nb = xf.shape[0] // _b
        xb = xf.reshape(nb, _b)
        j = jnp.clip(jnp.abs(xb[i].sum()).astype(jnp.int32) % 7,
                     0, nb - 1)
        return xb[i] + 0.5 * xb[j]

    @sac.incremental(block=BLOCK)
    def packed_prog(x):
        g = sac.gather(None, idx_fn, x, arity=1, packed=packed)
        return sac.reduce(jnp.add, g, identity=0.0)

    @sac.incremental(block=BLOCK)
    def full_prog(x):
        g = sac.gather(full_fn, idx_fn, x, arity=1)
        return sac.reduce(jnp.add, g, identity=0.0)

    return packed_prog, full_prog


def test_packed_gather_parity_and_counts():
    """Packed form: identical outputs across graph/host/hybrid AND
    identical recomputed-block counts to the full-parent form."""
    packed_prog, full_prog = _packed_progs()
    n = 14 * BLOCK
    handles = {
        "graph": packed_prog.compile(x=n, max_sparse=4),
        "host": packed_prog.compile("host", x=n),
        "hybrid": packed_prog.compile("hybrid", x=n, max_sparse=4),
        "full": full_prog.compile(x=n, max_sparse=4),
    }
    rng = np.random.default_rng(3)
    data = rng.integers(-5, 6, n).astype(np.float32)
    outs = {k: h.run(x=data) for k, h in handles.items()}
    for k, o in outs.items():
        np.testing.assert_array_equal(np.asarray(outs["graph"]),
                                      np.asarray(o), err_msg=k)
    for r in range(5):
        new = _edit(rng, data, 1 + r % 2)
        outs = {k: h.update(x=new) for k, h in handles.items()}
        for k, o in outs.items():
            np.testing.assert_array_equal(np.asarray(outs["graph"]),
                                          np.asarray(o),
                                          err_msg=f"{k} edit {r}")
        sg = handles["graph"].stats
        assert sg["recomputed"] == handles["full"].stats["recomputed"]
        assert sg["affected"] == handles["host"].stats["affected"]
        data = new


def test_packed_gather_sharded():
    _devices_or_skip(2)
    packed_prog, _ = _packed_progs()
    _parity(packed_prog, 14 * BLOCK, 2, seed=3)
