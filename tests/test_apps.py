"""All six paper applications: correctness vs oracle across batch updates."""
import pytest

from repro.core import Engine, StaticEngine
from repro.apps import APPS

SMALL = {
    "spellcheck": dict(n=48),
    "raytracer": dict(width=64, n_circles=5, n_tiles=4),
    "stringhash": dict(n=1024, grain=32),
    "sequence": dict(n=96),
    "trees": dict(n=96),
    "filter": dict(n=127),
}


@pytest.mark.parametrize("name", list(APPS))
def test_initial_run_correct(name):
    app = APPS[name](**SMALL[name])
    eng = Engine()
    app.build_input(eng)
    app.run(eng)
    assert app.output() == app.expected()


@pytest.mark.parametrize("name", list(APPS))
@pytest.mark.parametrize("k", [1, 3, 10])
def test_updates_correct(name, k):
    app = APPS[name](**SMALL[name])
    eng = Engine()
    app.build_input(eng)
    comp = app.run(eng)
    for _ in range(3):
        app.apply_update(eng, k)
        comp.propagate()
        assert app.output() == app.expected(), (name, k)


@pytest.mark.parametrize("name", list(APPS))
def test_update_saves_work(name):
    # raytracer needs a proportionate scene: one circle of few in a tiny
    # scene dirties most tiles (the paper's "many readers per mod" case),
    # so give it enough pixels for locality to pay off.
    kwargs = dict(width=512, n_circles=12, n_tiles=16) \
        if name == "raytracer" else SMALL[name]
    app = APPS[name](**kwargs)
    eng = Engine()
    app.build_input(eng)
    comp = app.run(eng)
    app.apply_update(eng, 1)
    st = comp.propagate()
    assert app.output() == app.expected()
    # raytracer: the conservative tile index re-traces ~half the rays per
    # moved circle at CI scene sizes (the paper's 26x WS needs 4M-pixel
    # frames where per-ray work dwarfs index overhead) — hold it to 1.7x.
    factor = 1.7 if name == "raytracer" else 2.0
    assert st.work < comp.initial_stats.work / factor, (
        name, st.work, comp.initial_stats.work)


@pytest.mark.parametrize("name", list(APPS))
def test_static_engine_agrees(name):
    app = APPS[name](**SMALL[name])
    seng = StaticEngine()
    app.build_input(seng)
    app.run(seng)
    assert app.output() == app.expected()


def test_trees_structural_updates():
    from repro.apps import TreeContractionApp

    app = TreeContractionApp(n=96, seed=3)
    eng = Engine()
    app.build_input(eng)
    comp = app.run(eng)
    for _ in range(4):
        moved = app.apply_structure_update(eng, 2)
        assert moved > 0
        comp.propagate()
        assert app.output() == app.expected()


def test_trees_mixed_value_and_structure():
    from repro.apps import TreeContractionApp

    app = TreeContractionApp(n=64, seed=9)
    eng = Engine()
    app.build_input(eng)
    comp = app.run(eng)
    app.apply_update(eng, 5)
    app.apply_structure_update(eng, 1)
    comp.propagate()
    assert app.output() == app.expected()


@pytest.mark.parametrize("grain", [16, 64, 256])
def test_stringhash_granularities(grain):
    from repro.apps import StringHashApp

    app = StringHashApp(n=1024, grain=grain)
    eng = Engine()
    app.build_input(eng)
    comp = app.run(eng)
    assert app.output() == app.expected()
    app.apply_update(eng, grain)
    comp.propagate()
    assert app.output() == app.expected()


def test_sequence_full_contraction_invariant():
    """Sum over live accumulators is round-invariant, so the result is
    right even for adversarial coin sequences (short round budget)."""
    from repro.apps import ListContractionApp

    for seed in range(5):
        app = ListContractionApp(n=33, seed=seed)
        eng = Engine()
        app.build_input(eng)
        app.run(eng)
        assert app.output() == app.expected()
