"""Expert-parallel MoE dispatch vs oracles on a forced multi-device mesh.

These run in a subprocess so the 8 fake host devices never leak into the
rest of the suite (jax locks device count at first init).
"""
import json
import os
import subprocess
import sys

import pytest

# Subprocess jit of full MoE fwd+bwd on 8 fake devices: minutes-scale on a
# loaded CI box.  Run with `make test-all`.
pytestmark = pytest.mark.slow

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models import moe as moe_mod
from repro.models.params import init_tree
from repro.shardlib import shard_ctx, rules_for_mode, make_mesh

cfg = get_smoke_config("%(arch)s")
# EP enforces per-shard capacity quotas; give enough headroom that nothing
# drops, so the dropless oracle is an exact reference.
cfg = cfg.replace(moe_capacity_factor=16.0)
mesh = make_mesh((2, 4), ("data", "model"))
p = init_tree(moe_mod.moe_specs(cfg, 0), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)

with shard_ctx(mesh, rules_for_mode("train")), mesh:
    out_ep, aux_ep = jax.jit(lambda p, x: moe_mod.moe_fwd_ep(cfg, p, x))(p, x)
out_ref, aux_ref = moe_mod.moe_fwd_ref(cfg, p, x)
err = float(jnp.max(jnp.abs(out_ep - out_ref)))

g_ref = jax.grad(lambda p: jnp.sum(moe_mod.moe_fwd_ref(cfg, p, x)[0] ** 2))(p)
with shard_ctx(mesh, rules_for_mode("train")), mesh:
    g_ep = jax.jit(jax.grad(
        lambda p: jnp.sum(moe_mod.moe_fwd_ep(cfg, p, x)[0] ** 2)))(p)
gerr = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a - b))), g_ref, g_ep)))
print(json.dumps({"err": err, "gerr": gerr,
                  "aux": float(aux_ep), "aux_ref": float(aux_ref)}))
"""


@pytest.mark.parametrize("arch", ["deepseek_v3_671b", "arctic_480b"])
def test_ep_matches_reference(arch):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT % {"arch": arch}],
        capture_output=True, text=True, env=env, timeout=420,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # at smoke capacity nothing drops, so EP == dropless reference
    assert res["err"] < 1e-4, res
    assert res["gerr"] < 1e-3, res
    assert abs(res["aux"] - res["aux_ref"]) < 0.05
