"""Optional-hypothesis shim for property-based tests.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt).  When it
is installed, this module re-exports the real ``given``/``settings``/``st``.
When it is missing, property-based tests must *skip* — but the rest of the
module (plain pytest tests) must stay collectable and runnable, so a plain
``pytest.importorskip("hypothesis")`` at module scope is too blunt.  Instead
we export decoration-compatible stand-ins:

  * ``given(...)`` returns a decorator that replaces the test with a skip.
  * ``settings(...)`` is a no-op decorator.
  * ``st`` is an opaque stub whose attributes/calls absorb any strategy
    expression (including ``@st.composite`` and strategy construction at
    module scope) without executing anything.

Usage in a test module::

    from _hypothesis_shim import HAVE_HYPOTHESIS, given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis absent
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Absorbs any attribute access / call made while *declaring*
        strategies, so module-level strategy expressions never fail."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _StrategyStub()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(fn)

        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco
