"""COW state forest semantics: isolation, aliasing, undo, durability.

The forest's contract (repro/serve/forest.py):

  * ``fork()`` never moves device data; buffers alias until first write;
  * edits to one forest node are invisible to every other — bitwise —
    in both directions, across graph (shards 1 and 2), hybrid, and the
    host reference backend;
  * a chain of ``snapshot()``/``undo()`` replays exactly what a
    ``donate=False`` linear handle computes — the COW split executable
    is the same math, only the buffer ownership differs;
  * copy-on-first-scatter is *observable*: after a fork, leaves the
    plan skipped stay physically shared, touched ones diverge;
  * ``save_session``/``restore_session`` round-trip a session bitwise —
    the restored session's next propagate matches the never-evicted
    one's, and its warmed plan signatures hit the shared plan cache.
"""
import numpy as np
import jax.numpy as jnp
import pytest

import repro.sac as sac
from repro.serve.forest import ForestState, restore_session, save_session

from test_fuzz_differential import (SHARD_COUNTS, _apply_edit, _inputs,
                                    build_program, random_spec)


@sac.incremental(block=16)
def _prog(x):
    y = x * 2.0 + 1.0
    s = sac.stencil(lambda w: w[16:32] + 0.5 * (w[:16] + w[32:]),
                    y, radius=1)
    return sac.reduce(jnp.add, s, identity=0.0)


def _edits(n, rounds=3, seed=0):
    rng = np.random.default_rng(seed)
    x = np.arange(n, dtype=np.float32)
    out = [x.copy()]
    for r in range(rounds):
        x = x.copy()
        x[int(rng.integers(0, n))] += float(r + 1)
        out.append(x.copy())
    return out


# ---------------------------------------------------------------------------
# Bidirectional bitwise isolation, all backends
# ---------------------------------------------------------------------------
def _check_isolation(parent, child, update_kw, frozen_outputs):
    got = np.asarray(child.update(**update_kw))
    assert np.array_equal(np.asarray(parent.outputs()), frozen_outputs), \
        "child edit perturbed parent"
    return got


@pytest.mark.parametrize("backend", ["graph", "hybrid", "host"])
def test_fork_isolation_backends(backend):
    xs = _edits(256)
    h = _prog.compile(backend, x=256)
    base = np.asarray(h.run(x=xs[0]))
    child = h.fork()
    # Child edits: parent bitwise frozen; child matches a fresh replay.
    ref = _prog.compile(backend, x=256)
    ref.run(x=xs[0])
    for x in xs[1:]:
        got = _check_isolation(h, child, {"x": x}, base)
        want = np.asarray(ref.update(x=x))
        assert np.array_equal(want, got), backend
    # Parent edits: child bitwise frozen (isolation is bidirectional).
    child_now = np.asarray(child.outputs())
    h.update(x=xs[1])
    assert np.array_equal(np.asarray(child.outputs()), child_now)


@pytest.mark.skipif(2 not in SHARD_COUNTS, reason="needs 2 devices")
def test_fork_isolation_shards2():
    xs = _edits(256)
    h = _prog.compile(x=256, shards=2)
    base = np.asarray(h.run(x=xs[0]))
    child = h.fork()
    ref = _prog.compile(x=256)
    ref.run(x=xs[0])
    for x in xs[1:]:
        got = _check_isolation(h, child, {"x": x}, base)
        assert np.array_equal(np.asarray(ref.update(x=x)), got)


def test_fork_isolation_random_specs():
    """Random fuzz specs: fork the graph handle mid-stream, edit both
    sides, and check bidirectional bitwise isolation."""
    for seed in range(3):
        spec = random_spec(np.random.default_rng(seed + 3000))
        prog, n, _block = build_program(spec)
        hg = prog.compile(x0=n, x1=n, max_sparse=4)
        x0, x1 = _inputs(spec)
        hg.run(x0=x0, x1=x1)
        # Warm one edit, then branch.
        x0, x1 = _apply_edit(x0, x1, spec["edits"][0], n)
        hg.update(x0=x0, x1=x1)
        parent_out = [np.asarray(v) for v in hg.outputs()]
        child = hg.fork()
        for edit in spec["edits"][1:]:
            x0, x1 = _apply_edit(x0, x1, edit, n)
            child.update(x0=x0, x1=x1)
            for a, b in zip(parent_out, hg.outputs()):
                np.testing.assert_array_equal(a, np.asarray(b),
                                              err_msg=f"spec={spec}")
        child_out = [np.asarray(v) for v in child.outputs()]
        hg.update(x0=x0 + 1.0, x1=x1)
        for a, b in zip(child_out, child.outputs()):
            np.testing.assert_array_equal(a, np.asarray(b),
                                          err_msg=f"spec={spec}")


# ---------------------------------------------------------------------------
# snapshot/undo chain == donate=False linear replay
# ---------------------------------------------------------------------------
def test_snapshot_undo_chain_matches_copies():
    xs = _edits(256, rounds=3)
    h = _prog.compile(x=256)
    ref = _prog.compile(x=256, donate=False)
    h.run(x=xs[0])
    ref.run(x=xs[0])
    checkpoints = [np.asarray(h.outputs())]
    for x in xs[1:]:
        h.snapshot()
        got = np.asarray(h.update(x=x))
        want = np.asarray(ref.update(x=x))
        assert np.array_equal(want, got)
        checkpoints.append(got)
    for want in reversed(checkpoints[:-1]):
        h.undo()
        assert np.array_equal(np.asarray(h.outputs()), want)
    with pytest.raises(RuntimeError):
        h.undo()


def test_snapshot_commit_drops_restore_point():
    xs = _edits(256, rounds=2)
    h = _prog.compile(x=256)
    h.run(x=xs[0])
    h.snapshot()
    after = np.asarray(h.update(x=xs[1]))
    h.commit()
    assert np.array_equal(np.asarray(h.outputs()), after)
    with pytest.raises(RuntimeError):
        h.undo()


# ---------------------------------------------------------------------------
# COW mechanics are observable: aliasing + refcounts
# ---------------------------------------------------------------------------
def test_fork_aliases_until_write_and_copies_only_touched():
    xs = _edits(512)
    h = _prog.compile(x=512)
    h.run(x=xs[0])
    base = h._forest()
    child_state = base.fork()
    # Fork is pure aliasing: every leaf shared, zero device copies.
    assert len(child_state.aliased_keys(base)) == child_state.num_leaves
    assert child_state.cow_copies == 0
    # One sparse edit: only plan-touched leaves diverge.
    pending = child_state.plan({"x": xs[1]})
    assert pending is not None
    donated, touched = base.cg.cow_touched_keys(pending.plan)
    child_state.commit(pending)
    still = set(child_state.aliased_keys(base))
    assert set(touched).isdisjoint(still), "touched leaf still aliased"
    untouched = set(child_state._leaves) - set(touched)
    assert untouched <= still, "untouched leaf was copied"
    assert 0 < child_state.cow_copies <= len(donated)
    # Release drops the child's claims: the base is exclusive again.
    child_state.release()
    assert base.shared_keys() == []


def test_commit_failure_preserves_sharing():
    """A commit whose executable raises must leave the node's aliasing
    metadata untouched: if the copy-on-first-scatter refcount changes
    landed before the failure, the node would believe it owns a still-
    shared buffer exclusively, and the *retried* commit would donate the
    base's buffer — corrupting the parent."""
    xs = _edits(256)
    h = _prog.compile(x=256)
    base_out = np.asarray(h.run(x=xs[0]))
    base = h._forest()
    child = base.fork()
    pending = child.plan({"x": xs[1]})
    assert pending is not None

    class _FailingEntry:
        def fn(self, *_a, **_k):
            raise RuntimeError("dispatch boom")

    orig = child.cg.cow_entry
    child.cg.cow_entry = lambda plan: (_FailingEntry(), False)
    try:
        with pytest.raises(RuntimeError, match="dispatch boom"):
            child.commit(pending)
    finally:
        child.cg.cow_entry = orig
    # Nothing moved: every leaf still aliases the base, refcounts say so.
    assert len(child.aliased_keys(base)) == child.num_leaves
    assert set(child.shared_keys()) == set(child._leaves)
    assert child.cow_copies == 0 and child.updates == 0
    # The retried commit copies-on-first-scatter properly: the child
    # matches a clean replay and the base is bitwise unperturbed (the
    # old bug donated the base's buffer here).
    child.commit(pending)
    ref = _prog.compile(x=256)
    ref.run(x=xs[0])
    want = np.asarray(ref.update(x=xs[1]))
    got = np.asarray(child.cg.value(child, h.out_handles[0]))
    assert np.array_equal(want, got)
    assert np.array_equal(np.asarray(h.outputs()), base_out)


def test_forest_state_duck_types_raw_state():
    xs = _edits(128)
    h = _prog.compile(x=128)
    h.run(x=xs[0])
    fs = h._forest()
    raw = fs.state
    assert isinstance(raw["v"], tuple)
    np.testing.assert_array_equal(np.asarray(fs["v"][0]),
                                  np.asarray(raw["v"][0]))


# ---------------------------------------------------------------------------
# Durability: ckpt round-trip is bitwise; signatures re-warm the cache
# ---------------------------------------------------------------------------
def test_session_ckpt_roundtrip_bitwise(tmp_path):
    xs = _edits(256, rounds=4)
    h = _prog.compile(x=256)
    h.run(x=xs[0])
    fs = h._forest()
    fs.propagate({"x": xs[1]})

    # Branch the timeline: `live` continues unevicted; `restored` goes
    # through disk.  Their *next* propagate must be bitwise identical.
    live = fs.fork()
    save_session(tmp_path, fs, step=fs.updates)
    restored, meta = restore_session(h.cg, tmp_path)
    assert meta["kind"] == "forest_session"
    assert meta["updates"] == fs.updates

    # Restored state is bitwise the saved one, leaf by leaf.
    for key, arr in restored._leaves.items():
        np.testing.assert_array_equal(np.asarray(arr),
                                      np.asarray(live._leaves[key]),
                                      err_msg=key)

    s_live = live.propagate({"x": xs[2]})
    s_rest = restored.propagate({"x": xs[2]})
    for key, arr in restored._leaves.items():
        np.testing.assert_array_equal(np.asarray(arr),
                                      np.asarray(live._leaves[key]),
                                      err_msg=f"post-propagate {key}")
    for key in ("recomputed", "affected", "dirty_inputs"):
        assert int(np.asarray(s_live[key])) == int(np.asarray(s_rest[key]))


def test_restore_rewarms_plan_signatures(tmp_path):
    xs = _edits(256, rounds=3)
    h = _prog.compile(x=256)
    h.run(x=xs[0])
    fs = h._forest()
    fs.propagate({"x": xs[1]})          # warms one ("cow", plan) entry
    assert fs.plan_history
    save_session(tmp_path, fs, step=1)

    # Fresh graph (fresh empty plan cache) = the restart scenario.
    h2 = _prog.compile(x=256)
    h2.run(x=xs[0])
    before = h2.cg.plan_cache_snapshot()
    restored, _ = restore_session(h2.cg, tmp_path)
    after = h2.cg.plan_cache_snapshot()
    assert after["size"] == before["size"] + len(fs.plan_history)
    # Same-shaped edit on the restored session: signature HIT, not a
    # re-freeze — the serving steady state survives eviction.
    restored.propagate({"x": xs[2]})
    final = h2.cg.plan_cache_snapshot()
    assert final["hits"] == after["hits"] + 1
    assert final["misses"] == after["misses"]


def test_restore_rejects_mismatched_dirty_rep(tmp_path):
    xs = _edits(128)
    h = _prog.compile(x=128, dirty="mask")
    h.run(x=xs[0])
    save_session(tmp_path, h._forest(), step=0)
    h2 = _prog.compile(x=128, dirty="interval")
    h2.run(x=xs[0])
    with pytest.raises(AssertionError, match="dirty rep"):
        restore_session(h2.cg, tmp_path)


# ---------------------------------------------------------------------------
# Supervisor reuse: the pluggable restore path
# ---------------------------------------------------------------------------
def test_supervisor_pluggable_restore(tmp_path):
    from repro.runtime.supervisor import Supervisor

    xs = _edits(128)
    h = _prog.compile(x=128)
    h.run(x=xs[0])
    fs = h._forest()
    fs.propagate({"x": xs[1]})
    save_session(tmp_path, fs, step=fs.updates)

    sup = Supervisor(
        step_fn=None, pipeline=None, ckpt_dir=str(tmp_path),
        init_state=lambda: (_ for _ in ()).throw(
            AssertionError("restore_fn must bypass init_state")),
        restore_fn=lambda d, step: restore_session(h.cg, d, step=step)[0])
    state, step = sup._restore_or_init()
    assert step == fs.updates
    assert isinstance(state, ForestState)
    for key, arr in state._leaves.items():
        np.testing.assert_array_equal(np.asarray(arr),
                                      np.asarray(fs._leaves[key]))
