"""Hybrid runtime: regions, boundaries, the gather edge, app parity.

Unit coverage for the skeleton/interior machinery that the differential
fuzzer exercises statistically: region partition layering, fragment
skip, boundary write cutoff in ``EngineFragment``, three-backend parity
of the data-dependent ``gather`` edge (not part of the fuzz vocabulary
— its reader sets are data), and the acceptance invariant that the
hybrid apps are identical to their pure-host originals across updates.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.sac as sac
from repro.core import Engine, StaticEngine
from repro.jaxsac.graph import GraphBuilder
from repro.sac.hybrid import partition_regions


# ---------------------------------------------------------------------------
# Region partition
# ---------------------------------------------------------------------------
def test_partition_untagged_is_one_region():
    g = GraphBuilder()
    x = g.input("x", n=8, block=2)
    y = g.map(lambda b: b + 1.0, x)
    g.reduce_tree(jnp.add, y)
    regions = partition_regions(g.nodes)
    assert len(regions) == 1
    assert regions[0].key == (None, 0)


def test_partition_reopened_tag_is_new_fragment():
    """a -> b -> a: the second 'a' run depends on 'b', so it must be a
    separate fragment in a later layer (the region dag stays acyclic)."""
    g = GraphBuilder()
    x = g.input("x", n=8, block=2)
    with g.static_region("a"):
        y = g.map(lambda b: b + 1.0, x)
    with g.static_region("b"):
        z = g.map(lambda b: b * 2.0, y)
    with g.static_region("a"):
        g.zip_map(jnp.add, y, z)
    regions = partition_regions(g.nodes)
    assert [r.key for r in regions] == [("a", 0), ("b", 1), ("a", 2)]


def test_partition_parallel_tags_share_layer():
    g = GraphBuilder()
    x = g.input("x", n=8, block=2)
    with g.static_region("a"):
        y = g.map(lambda b: b + 1.0, x)
    with g.static_region("b"):
        z = g.map(lambda b: b * 2.0, x)    # independent of region a
    regions = partition_regions(g.nodes)
    assert {r.key for r in regions} == {("a", 0), ("b", 0)}
    del y, z


# ---------------------------------------------------------------------------
# Hybrid backend: boundary transfer + fragment skip
# ---------------------------------------------------------------------------
def _two_region_prog(block):
    @sac.incremental(block=block)
    def prog(x):
        with sac.static_region("a"):
            y = x * 2.0 + 1.0
            s = sac.stencil(lambda w: w[block:2 * block]
                            + 0.5 * (w[:block] + w[2 * block:]),
                            y, radius=1)
        with sac.static_region("b"):
            r = sac.reduce(jnp.add, s, identity=0.0)
        return r, s

    return prog


def test_hybrid_matches_graph_and_skips_clean_fragments():
    n, block = 64, 4
    prog = _two_region_prog(block)
    hg = prog.compile(x=n, max_sparse=4)
    hy = prog.compile("hybrid", x=n, max_sparse=4)
    assert hy.num_fragments == 2
    rng = np.random.default_rng(0)
    data = rng.integers(-5, 6, n).astype(np.float32)
    for a, b in zip(hg.run(x=data), hy.run(x=data)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for t in range(3):
        data = data.copy()
        data[(t * 13) % n] += 1.0
        for a, b in zip(hg.update(x=data), hy.update(x=data)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert int(hg.stats["affected"]) == hy.stats["affected"]
        assert int(hg.stats["recomputed"]) == hy.stats["recomputed"]
        assert int(hg.stats["dirty_inputs"]) == hy.stats["dirty_inputs"]
    # Same input again: region a runs (its named input was passed, the
    # diff is empty), region b is SKIPPED — no boundary mask changed.
    hy.update(x=data)
    assert hy.stats["fragments_run"] == 1
    assert hy.stats["recomputed"] == 0


# ---------------------------------------------------------------------------
# The gather edge: three-backend parity (not in the fuzz vocabulary)
# ---------------------------------------------------------------------------
def _ring_prog(n):
    def idx_fn(xb):
        i = jnp.arange(xb.shape[0])
        nb = xb.shape[0]
        return jnp.stack([(i - 1) % nb, (i + 1) % nb], axis=1)

    def fn(x, i):
        nb = x.shape[0]
        return x[i] + 2 * x[(i - 1) % nb] + 3 * x[(i + 1) % nb]

    @sac.incremental(block=1)
    def ring(x):
        g1 = sac.gather(fn, idx_fn, x, arity=2)
        g2 = sac.gather(fn, idx_fn, g1, arity=2)     # chained gathers
        return sac.reduce(jnp.add, g2, identity=0), g2

    return ring


@pytest.mark.parametrize("n", [12, 96])   # tiny-dense and sparse regimes
def test_gather_three_backend_parity(n):
    prog = _ring_prog(n)
    hg = prog.compile(x=n, max_sparse=8)
    hh = prog.compile("host", x=n)
    hy = prog.compile("hybrid", x=n, max_sparse=8)
    rng = np.random.default_rng(1)
    d = rng.integers(0, 100, n).astype(np.int32)
    outs = [h.run(x=d) for h in (hg, hh, hy)]
    for o in outs[1:]:
        for a, b in zip(outs[0], o):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for t in range(3):
        d = d.copy()
        d[int(rng.integers(n))] += 1
        outs = [h.update(x=d) for h in (hg, hh, hy)]
        for o in outs[1:]:
            for a, b in zip(outs[0], o):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b))
        assert int(hg.stats["affected"]) == int(hh.stats["affected"]) \
            == int(hy.stats["affected"])
        assert int(hg.stats["recomputed"]) == int(hy.stats["recomputed"])


def test_gather_dirty_stays_local():
    """A 1-lane edit through a gather dirties only the lane + its
    readers (the data-dependent reader map, not a dense transfer)."""
    n = 96
    prog = _ring_prog(n)
    hg = prog.compile(x=n, max_sparse=8)
    d = np.zeros(n, np.int32)
    hg.run(x=d)
    d2 = d.copy()
    d2[50] = 7
    hg.update(x=d2)
    # g1 dirties {49,50,51}, g2 dirties {48..52}: 8 gather blocks plus
    # the reduce tree's O(log n) path — far below a dense n-per-level.
    assert int(hg.stats["recomputed"]) < 30, hg.stats


# ---------------------------------------------------------------------------
# EngineFragment: boundary write cutoff into the host engine
# ---------------------------------------------------------------------------
def test_engine_fragment_boundary_cutoff():
    """Downstream host readers re-run ONLY for output blocks whose
    value actually changed (fragment -> host dirty transfer)."""
    from repro.sac.host import EngineFragment

    n = 8

    @sac.incremental(block=1)
    def clipped(x):
        return sac.map_blocks(
            lambda b: jnp.clip(b[0], 0, 3).astype(jnp.int32), x,
            name="clip")

    eng = Engine()
    mods = eng.alloc_array(n, "x")
    for i, m in enumerate(mods):
        eng.write(m, i)
    runs = [0] * n

    def build():
        frag = EngineFragment(clipped, {"x": mods},
                              dtypes={"x": np.int32}, max_sparse=4)
        (out,) = frag.install(eng)

        def watch(i):
            eng.read(out[i], lambda v, _i=i: runs.__setitem__(
                _i, runs[_i] + 1))

        eng.parallel_for(0, n, watch)

    comp = eng.run(build)
    assert runs == [1] * n
    eng.write(mods[1], 2)      # clip(2) = 2 != clip(1) = 1: changes
    eng.write(mods[6], 9)      # clip(9) = 3 == clip(6) = 3: cutoff
    comp.propagate()
    assert runs[1] == 2 and runs[6] == 1, runs
    assert sum(runs) == n + 1


# ---------------------------------------------------------------------------
# Acceptance: hybrid apps bitwise identical to the pure host engine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 3])
def test_trees_hybrid_identical_to_host(seed):
    from repro.apps import TreeContractionApp

    ah = TreeContractionApp(n=96, seed=seed, hybrid=True)
    ap = TreeContractionApp(n=96, seed=seed, hybrid=False)
    eh, ep = Engine(), Engine()
    ah.build_input(eh)
    ap.build_input(ep)
    ch, cp = ah.run(eh), ap.run(ep)
    assert ah.output() == ap.output() == ah.expected()
    for _ in range(2):
        ah.apply_update(eh, 3)
        ap.apply_update(ep, 3)
        ch.propagate()
        cp.propagate()
        assert ah.output() == ap.output() == ah.expected()
    ah.apply_structure_update(eh, 2)
    ap.apply_structure_update(ep, 2)
    ch.propagate()
    cp.propagate()
    assert ah.output() == ap.output() == ah.expected()


def test_filter_hybrid_identical_to_host():
    from repro.apps import FilterApp

    ah = FilterApp(n=127, seed=1, hybrid=True)
    ap = FilterApp(n=127, seed=1, hybrid=False)
    eh, ep = Engine(), Engine()
    ah.build_input(eh)
    ap.build_input(ep)
    ch, cp = ah.run(eh), ap.run(ep)
    assert ah.output() == ap.output() == ah.expected()
    for _ in range(3):
        ah.apply_update(eh, 7)
        ap.apply_update(ep, 7)
        ch.propagate()
        cp.propagate()
        assert ah.output() == ap.output() == ah.expected()


def test_hybrid_apps_on_static_engine():
    from repro.apps import FilterApp, TreeContractionApp

    a = TreeContractionApp(n=64, seed=1, hybrid=True)
    se = StaticEngine()
    a.build_input(se)
    a.run(se)
    assert a.output() == a.expected()
    f = FilterApp(n=63, seed=1, hybrid=True)
    se = StaticEngine()
    f.build_input(se)
    f.run(se)
    assert f.output() == f.expected()
