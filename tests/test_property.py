"""Property-based tests (hypothesis): the system's core invariant.

For ANY deterministic program in the framework and ANY sequence of batch
updates, change propagation must yield exactly the state a from-scratch
run on the updated input would produce (Theorem 4.1).  We generate random
nested-parallel dataflow programs and random update sequences and check
the invariant, plus stability properties of the apps.
"""
import random

from _hypothesis_shim import given, settings, st

from repro.core import Engine
from repro.core.distance import computation_distance


# ---------------------------------------------------------------------------
# Random program generator: a layered dataflow of combine readers.  Layer 0
# reads inputs; each later node reads 1-3 mods from earlier layers with a
# random associative-ish integer function, possibly through a data-dependent
# branch (exercising dynamic RSP restructuring).
# ---------------------------------------------------------------------------
def make_program(eng, inputs, layout, fns):
    """layout: list of layers; each node = (src_indices, fn_id).
    Returns list of all mods (inputs + internal) in creation order."""
    all_mods = list(inputs)

    def run():
        created = []
        for layer in layout:
            layer_mods = [eng.mod() for _ in layer]

            def do_layer(layer=layer, layer_mods=layer_mods):
                def node(j):
                    srcs, fn_id = layer[j]
                    mods = [all_mods[s] for s in srcs]
                    fn = fns[fn_id]
                    eng.read(tuple(mods),
                             lambda *vs: eng.write(layer_mods[j], fn(*vs)))
                eng.parallel_for(0, len(layer), node)

            do_layer()
            all_mods.extend(layer_mods)
            created.extend(layer_mods)

    return run


FNS = [
    lambda *vs: sum(vs),
    lambda *vs: min(vs),
    lambda *vs: max(vs) - min(vs),
    lambda *vs: sum(v * v for v in vs) % 1009,
    lambda *vs: vs[0] - sum(vs[1:]),
    lambda *vs: (vs[0] + 7) if vs[0] % 2 == 0 else sum(vs),  # branchy
]


@st.composite
def programs(draw):
    n_inputs = draw(st.integers(2, 8))
    n_layers = draw(st.integers(1, 4))
    layout = []
    avail = n_inputs
    for _ in range(n_layers):
        width = draw(st.integers(1, 5))
        layer = []
        for _ in range(width):
            arity = draw(st.integers(1, min(3, avail)))
            srcs = draw(st.lists(st.integers(0, avail - 1),
                                 min_size=arity, max_size=arity))
            fn_id = draw(st.integers(0, len(FNS) - 1))
            layer.append((tuple(srcs), fn_id))
        layout.append(layer)
        avail += width
    values = draw(st.lists(st.integers(-50, 50),
                           min_size=n_inputs, max_size=n_inputs))
    n_updates = draw(st.integers(1, 3))
    updates = []
    for _ in range(n_updates):
        k = draw(st.integers(1, n_inputs))
        idx = draw(st.lists(st.integers(0, n_inputs - 1),
                            min_size=k, max_size=k, unique=True))
        vals = draw(st.lists(st.integers(-50, 50), min_size=k, max_size=k))
        updates.append(list(zip(idx, vals)))
    return layout, values, updates


def run_program(layout, values):
    eng = Engine()
    inputs = eng.alloc_array(len(values), "in")
    for m, v in zip(inputs, values):
        eng.write(m, v)
    prog = make_program(eng, inputs, layout, FNS)
    comp = eng.run(prog)
    return eng, inputs, comp


@given(programs())
@settings(max_examples=60, deadline=None)
def test_propagate_equals_from_scratch(prog):
    layout, values, updates = prog
    eng, inputs, comp = run_program(layout, values)
    cur = list(values)
    for batch in updates:
        for i, v in batch:
            cur[i] = v
            eng.write(inputs[i], v)
        comp.propagate()
        # from-scratch oracle
        eng2, inputs2, comp2 = run_program(layout, cur)
        d = computation_distance(comp.root, comp2.root)
        assert d.work == 0 and d.affected_reads == 0, (
            "propagated tree diverges from from-scratch tree")


@given(st.integers(2, 64), st.data())
@settings(max_examples=30, deadline=None)
def test_sum_app_any_updates(n, data):
    """Algorithm-1 sum stays correct under arbitrary update sequences."""
    eng = Engine()
    mods = eng.alloc_array(n, "x")
    vals = data.draw(st.lists(st.integers(-100, 100), min_size=n, max_size=n))
    for m, v in zip(mods, vals):
        eng.write(m, v)
    res = eng.mod()

    def rec(lo, hi, out):
        if hi - lo == 1:
            eng.read(mods[lo], lambda v: eng.write(out, v))
            return
        mid = (lo + hi) // 2
        l, r = eng.mod(), eng.mod()
        eng.par(lambda: rec(lo, mid, l), lambda: rec(mid, hi, r))
        eng.read((l, r), lambda a, b: eng.write(out, a + b))

    comp = eng.run(lambda: rec(0, n, res))
    for _ in range(3):
        k = data.draw(st.integers(1, n))
        idx = data.draw(st.lists(st.integers(0, n - 1), min_size=k,
                                 max_size=k, unique=True))
        for i in idx:
            vals[i] = data.draw(st.integers(-100, 100))
            eng.write(mods[i], vals[i])
        comp.propagate()
        assert res.peek() == sum(vals)


@given(st.integers(4, 48), st.integers(0, 1000), st.data())
@settings(max_examples=20, deadline=None)
def test_list_contraction_random(n, seed, data):
    from repro.apps import ListContractionApp

    app = ListContractionApp(n=n, seed=seed)
    eng = Engine()
    app.build_input(eng)
    comp = app.run(eng)
    assert app.output() == app.expected()
    for _ in range(2):
        k = data.draw(st.integers(1, n))
        app.apply_update(eng, k)
        comp.propagate()
        assert app.output() == app.expected()


@given(st.integers(4, 40), st.integers(0, 1000), st.data())
@settings(max_examples=15, deadline=None)
def test_tree_contraction_random(n, seed, data):
    from repro.apps import TreeContractionApp

    app = TreeContractionApp(n=n, seed=seed)
    eng = Engine()
    app.build_input(eng)
    comp = app.run(eng)
    assert app.output() == app.expected()
    k = data.draw(st.integers(1, n))
    app.apply_update(eng, k)
    comp.propagate()
    assert app.output() == app.expected()
    if n >= 8:
        app.apply_structure_update(eng, data.draw(st.integers(1, 3)))
        comp.propagate()
        assert app.output() == app.expected()
