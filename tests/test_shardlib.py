"""Logical-axis sharding resolution (the glue the dry-run depends on)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.shardlib import ShardCtx, rules_for_mode, shard_ctx, current_ctx
from repro.launch.mesh import make_local_mesh


@pytest.fixture
def ctx():
    mesh = make_local_mesh(model=1)  # single CPU device
    return ShardCtx(mesh, rules_for_mode("train"))


def test_missing_mesh_axis_dropped(ctx):
    # 'pod' does not exist on the local mesh: ('pod','data') -> ('data',)
    spec = ctx.resolve(("batch", "seq"))
    assert spec == P("data", None)


def test_divisibility_fallback(ctx):
    # an axis whose size does not divide falls back to replication
    spec = ctx.resolve(("q_heads",), shape=(36,))
    # local mesh 'model' has size 1 -> divides; simulate via a fake size
    ctx.axis_sizes["model"] = 16
    spec = ctx.resolve(("q_heads",), shape=(36,))
    assert spec == P(None)
    spec = ctx.resolve(("q_heads",), shape=(32,))
    assert spec == P("model")


def test_axis_used_once(ctx):
    ctx.axis_sizes["model"] = 4
    spec = ctx.resolve(("q_heads", "mlp"), shape=(8, 8))
    # 'model' consumed by q_heads; mlp falls back to replication
    assert spec == P("model", None)


def test_unknown_logical_axis_replicates(ctx):
    assert ctx.resolve(("nonexistent",)) == P(None)


def test_context_stack():
    mesh = make_local_mesh()
    assert current_ctx() is None
    with shard_ctx(mesh, rules_for_mode("train")) as c1:
        assert current_ctx() is c1
        with shard_ctx(mesh, rules_for_mode("decode")) as c2:
            assert current_ctx() is c2
        assert current_ctx() is c1
    assert current_ctx() is None


def test_decode_rules_shard_cache_seq():
    mesh = make_local_mesh()
    ctx = ShardCtx(mesh, rules_for_mode("decode"))
    ctx.axis_sizes["model"] = 16
    spec = ctx.resolve(("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                       shape=(32, 128, 32768, 4, 128))
    assert spec == P(None, "data", "model", None, None)
