"""Test-session environment: expose multiple CPU devices.

The sharded-propagation tests (test_shard.py, the mesh lanes of
test_fuzz_differential.py) need more than one device.  XLA only reads
``--xla_force_host_platform_device_count`` at backend initialization,
so it must be in the environment BEFORE jax is first imported — pytest
imports conftest.py ahead of every test module, which makes this the
one reliable place to set it.

An operator who already set their own device-count flag (the CI sharded
lane does, explicitly) is left alone; tests that need N devices skip
when fewer are visible, so the suite stays runnable everywhere.
"""
import os

_FLAG = "xla_force_host_platform_device_count"
_flags = os.environ.get("XLA_FLAGS", "")
if _FLAG not in _flags:
    os.environ["XLA_FLAGS"] = (_flags + f" --{_FLAG}=8").strip()
