"""Unit tests for the PSAC engine primitives (paper Algorithms 2-5)."""
import pytest

from repro.core import Engine, StaticEngine
from repro.core.engine import Computation


def sum_program(eng, mods, res):
    """The paper's Algorithm 1 divide-and-conquer sum."""
    def rec(lo, hi, out):
        if hi - lo == 1:
            eng.read(mods[lo], lambda v: eng.write(out, v))
            return
        mid = (lo + hi) // 2
        l, r = eng.mod(), eng.mod()
        eng.par(lambda: rec(lo, mid, l), lambda: rec(mid, hi, r))
        eng.read((l, r), lambda a, b: eng.write(out, a + b))

    rec(0, len(mods), res)


@pytest.fixture
def summed():
    eng = Engine()
    mods = eng.alloc_array(16, "x")
    for i, m in enumerate(mods):
        eng.write(m, i)
    res = eng.mod("res")
    comp = eng.run(lambda: sum_program(eng, mods, res))
    return eng, mods, res, comp


def test_initial_run(summed):
    eng, mods, res, comp = summed
    assert res.peek() == sum(range(16))
    assert comp.initial_stats.reads == 31      # 16 leaves + 15 combines
    assert comp.initial_stats.span < comp.initial_stats.work


def test_propagate_single_update(summed):
    eng, mods, res, comp = summed
    eng.write(mods[3], 100)
    st = comp.propagate()
    assert res.peek() == sum(range(16)) - 3 + 100
    # one leaf + log2(16) combines re-execute
    assert st.affected_readers == 5
    assert st.work < comp.initial_stats.work


def test_propagate_batch_update(summed):
    eng, mods, res, comp = summed
    for i in (0, 5, 9, 15):
        eng.write(mods[i], 0)
    comp.propagate()
    assert res.peek() == sum(range(16)) - (0 + 5 + 9 + 15)


def test_equal_value_write_no_marks(summed):
    eng, mods, res, comp = summed
    eng.write(mods[3], 3)          # same value: Algorithm 2 cutoff
    st = comp.propagate()
    assert st.affected_readers == 0
    assert st.traversed == 0


def test_value_cutoff_stops_midway():
    # min-reduction: changing a non-minimal leaf to another non-minimal
    # value re-runs the leaf reader but the combine chain stops as soon
    # as a recomputed min is unchanged.
    eng = Engine()
    mods = eng.alloc_array(8, "x")
    vals = [50, 60, 70, 80, 10, 90, 95, 99]
    for m, v in zip(mods, vals):
        eng.write(m, v)
    res = eng.mod()

    def rec(lo, hi, out):
        if hi - lo == 1:
            eng.read(mods[lo], lambda v: eng.write(out, v))
            return
        mid = (lo + hi) // 2
        l, r = eng.mod(), eng.mod()
        eng.par(lambda: rec(lo, mid, l), lambda: rec(mid, hi, r))
        eng.read((l, r), lambda a, b: eng.write(out, min(a, b)))

    comp = eng.run(lambda: rec(0, 8, res))
    assert res.peek() == 10
    eng.write(mods[1], 55)         # still loses to 50 at the first combine
    st = comp.propagate()
    assert res.peek() == 10
    assert st.affected_readers == 2  # leaf + one combine; then values equal


def test_write_once_violation():
    eng = Engine()
    m = eng.mod()
    eng.write(m, 1)
    a, b = eng.mod(), eng.mod()
    eng.write(a, 1)
    eng.write(b, 2)

    def prog():
        eng.read(a, lambda v: eng.write(m, v + 10))
        eng.read(b, lambda v: eng.write(m, v + 20))

    with pytest.raises(RuntimeError, match="write-once"):
        eng.run(prog)


def test_read_before_write():
    eng = Engine()
    m = eng.mod()
    with pytest.raises(RuntimeError, match="before .*written|read before"):
        eng.run(lambda: eng.read(m, lambda v: None))


def test_dynamic_structure_change():
    """Propagation may build an entirely different subtree (Section 3)."""
    eng = Engine()
    sel = eng.mod("sel")
    xs = eng.alloc_array(4, "x")
    for i, m in enumerate(xs):
        eng.write(m, 10 * (i + 1))
    eng.write(sel, 0)
    res = eng.mod()

    def prog():
        def body(s):
            if s == 0:
                eng.read(xs[0], lambda v: eng.write(res, v))
            else:
                # different shape: a nested combine of three reads
                t = eng.mod()
                eng.read((xs[1], xs[2]), lambda a, b: eng.write(t, a + b))
                eng.read((t, xs[3]), lambda u, c: eng.write(res, u + c))
        eng.read(sel, body)

    comp = eng.run(prog)
    assert res.peek() == 10
    eng.write(sel, 1)
    comp.propagate()
    assert res.peek() == 20 + 30 + 40
    # old subtree is garbage; updates to xs[0] no longer propagate
    eng.collect()
    eng.write(xs[0], 999)
    st = comp.propagate()
    assert res.peek() == 90
    assert st.affected_readers == 0
    # but updates to the new reads do
    eng.write(xs[2], 1)
    comp.propagate()
    assert res.peek() == 20 + 1 + 40


def test_cascading_propagation_order():
    """A chain a -> b -> c re-runs in control order during propagation."""
    eng = Engine()
    a = eng.mod("a")
    eng.write(a, 1)
    b, c = eng.mod("b"), eng.mod("c")
    order = []

    def prog():
        eng.read(a, lambda v: (order.append("rb"), eng.write(b, v * 2))[-1])
        eng.read(b, lambda v: (order.append("rc"), eng.write(c, v + 1))[-1])

    comp = eng.run(prog)
    assert c.peek() == 3
    order.clear()
    eng.write(a, 5)
    comp.propagate()
    assert c.peek() == 11
    assert order == ["rb", "rc"]


def test_gc_collects_detached_subtrees():
    eng = Engine()
    sel = eng.mod()
    eng.write(sel, 0)
    xs = eng.alloc_array(8, "x")
    for m in xs:
        eng.write(m, 1)
    res = eng.mod()

    def prog():
        def body(s):
            out = eng.mod()          # dynamically allocated: scope-owned
            def rec(lo, hi, o):
                if hi - lo == 1:
                    eng.read(xs[lo], lambda v: eng.write(o, v + s))
                    return
                mid = (lo + hi) // 2
                l, r = eng.mod(), eng.mod()
                eng.par(lambda: rec(lo, mid, l), lambda: rec(mid, hi, r))
                eng.read((l, r), lambda p, q: eng.write(o, p + q))
            rec(0, 8, out)
            eng.read(out, lambda v: eng.write(res, v))
        eng.read(sel, body)

    comp = eng.run(prog)
    live_before = eng.live_nodes
    eng.write(sel, 1)
    comp.propagate()
    collected = eng.collect()
    assert collected > 0
    assert eng.live_nodes <= live_before + 4


def test_gc_live_bookkeeping_and_reader_unregistration():
    """collect() after propagation: live_nodes/live_mods return to their
    pre-update level and dead readers vanish from surviving reader sets."""
    eng = Engine()
    x, y = eng.mod("x"), eng.mod("y")
    eng.write(x, 1)
    eng.write(y, 10)
    out = eng.mod("out")

    def prog():
        def outer(v):
            tmp = eng.mod("tmp")          # owned by the reader's scope
            eng.write(tmp, v * 2)
            # inner reader also reads the *persistent* y, so y's reader set
            # must shed the dead inner reader after GC.
            eng.read((tmp, y), lambda t, w: eng.write(out, t + w))
        eng.read(x, outer)

    comp = eng.run(prog)
    assert out.peek() == 12
    nodes0, mods0 = eng.live_nodes, eng.live_mods
    assert len(y.readers) == 1

    eng.write(x, 5)                        # outer re-executes
    comp.propagate()
    assert out.peek() == 20
    # old inner subtree is garbage but still counted until collect();
    # y temporarily sees both the dead and the replacement reader.
    assert eng.live_nodes > nodes0
    assert len(y.readers) == 2
    collected = eng.collect()
    assert collected >= 1
    assert eng.live_nodes == nodes0        # replacement exactly offsets dead
    assert eng.live_mods == mods0          # old owned tmp freed, new one live
    assert len(y.readers) == 1
    # the surviving reader is live: updates through y still propagate
    eng.write(y, 100)
    comp.propagate()
    assert out.peek() == 110


def test_gc_dead_reader_lazily_dropped_from_reader_set():
    """A dead reader still sitting in a reader set is discarded lazily by
    write()'s mark loop (Section 5 lazy deletion)."""
    eng = Engine()
    sel, a = eng.mod("sel"), eng.mod("a")
    eng.write(sel, 0)
    eng.write(a, 7)
    out = eng.mod()

    def prog():
        def body(s):
            if s == 0:
                eng.read(a, lambda v: eng.write(out, v))
            else:
                eng.write(out, -1)
        eng.read(sel, body)

    comp = eng.run(prog)
    eng.write(sel, 1)                      # drops the reader of `a`
    comp.propagate()
    eng.collect()                          # marks it dead, unregisters
    assert len(a.readers) == 0
    # a write to `a` now marks nothing and re-runs nothing
    eng.write(a, 8)
    st = comp.propagate()
    assert st.affected_readers == 0 and out.peek() == -1


def test_collect_idempotent_when_no_garbage():
    eng = Engine()
    mods = eng.alloc_array(4, "x")
    for i, m in enumerate(mods):
        eng.write(m, i)
    res = eng.mod()
    comp = eng.run(lambda: sum_program(eng, mods, res))
    assert eng.collect() == 0              # nothing detached yet
    before = (eng.live_nodes, eng.live_mods)
    assert eng.collect() == 0              # idempotent
    assert (eng.live_nodes, eng.live_mods) == before


def test_write_once_violation_during_propagation():
    """The write-once check fires on the propagation epoch too: two
    readers racing to write the same mod is caught mid-propagate."""
    eng = Engine()
    a = eng.mod("a")
    eng.write(a, 1)
    shared = eng.mod("shared")

    def prog():
        # Two sibling readers of `a` both write `shared` with different
        # values.  The initial run already trips the restriction.
        eng.read(a, lambda v: eng.write(shared, v))
        eng.read(a, lambda v: eng.write(shared, v + 1))

    with pytest.raises(RuntimeError, match="write-once"):
        eng.run(prog)


def test_write_once_equal_value_is_permitted():
    """Algorithm 2's cutoff applies before the write-once check: a second
    writer writing the *same* value marks nothing and does not trip the
    restriction (it re-records the writer instead)."""
    eng = Engine()
    a = eng.mod("a")
    eng.write(a, 3)
    shared = eng.mod("shared")

    def prog():
        eng.read(a, lambda v: eng.write(shared, v * 2))
        eng.read(a, lambda v: eng.write(shared, v * 2))   # equal value

    comp = eng.run(prog)
    assert shared.peek() == 6
    # and propagation keeps the invariant
    eng.write(a, 4)
    comp.propagate()
    assert shared.peek() == 8


def test_static_engine_matches():
    """The static baseline computes the same result with no RSP tree."""
    seng = StaticEngine()
    mods = seng.alloc_array(16, "x")
    for i, m in enumerate(mods):
        seng.write(m, i * i)
    res = seng.mod()
    seng.run(lambda: sum_program(seng, mods, res))
    assert res.peek() == sum(i * i for i in range(16))


def test_parallel_for_span_is_logarithmic():
    eng = Engine()
    xs = eng.alloc_array(256, "x")
    for m in xs:
        eng.write(m, 1)
    outs = eng.alloc_array(256, "o")

    def prog():
        eng.parallel_for(0, 256, lambda i: eng.read(
            xs[i], lambda v: eng.write(outs[i], v)))

    comp = eng.run(prog)
    st = comp.initial_stats
    assert st.work >= 512
    assert st.span <= 80           # ~2*log2(256) levels of par + leaf work
