"""jaxsac: the TPU-native adaptation of parallel self-adjusting computation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.jaxsac import (BlockTensor, IncrementalReduce, dirty_from_diff,
                          incremental_prefill, prefill_distance)
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.moe import dropless_moe


# ---------------------------------------------------------------------------
# BlockTensor
# ---------------------------------------------------------------------------
def test_blocktensor_write_marks_changed_blocks():
    bt = BlockTensor.clean(jnp.zeros(64), block=8)
    new = jnp.zeros(64).at[17].set(1.0).at[50].set(2.0)
    bt2 = bt.write(new)
    want = np.zeros(8, bool)
    want[17 // 8] = want[50 // 8] = True
    np.testing.assert_array_equal(np.asarray(bt2.dirty), want)
    lo, hi = bt2.dirty_interval()
    assert (int(lo), int(hi)) == (2, 7)


def test_blocktensor_equal_write_is_clean():
    x = jnp.arange(32.0)
    bt = BlockTensor.clean(x, block=4)
    bt2 = bt.write(x + 0.0)
    assert not bool(jnp.any(bt2.dirty))
    lo, hi = bt2.dirty_interval()
    assert (int(lo), int(hi)) == (0, 0)


# ---------------------------------------------------------------------------
# IncrementalReduce (Algorithm 1 / Theorem 4.2 on TPU)
# ---------------------------------------------------------------------------
@given(st.integers(0, 2**31 - 1), st.integers(1, 40))
@settings(max_examples=25, deadline=None)
def test_reduce_update_matches_oracle(seed, k):
    rng = np.random.default_rng(seed)
    r = IncrementalReduce(n=512, block=4, op=jnp.add, identity=0.0,
                          max_sparse=32)
    x = jnp.asarray(rng.integers(0, 100, 512), jnp.int32)
    state = r.init(x)
    upd = jax.jit(r.update)
    idx = rng.choice(512, size=k, replace=False)
    y = x.at[jnp.asarray(idx)].set(jnp.asarray(rng.integers(0, 100, k), jnp.int32))
    state, stats = upd(state, y)
    assert int(r.result(state)) == int(y.sum())
    # Theorem 4.2: recompute is O(k log(1 + n/k)) tree nodes
    import math
    bound = 6 * k * (1 + math.log2(1 + 128 / min(k, 128))) + 16
    assert int(stats["recomputed"]) <= bound


def test_reduce_noop_update_zero_work():
    r = IncrementalReduce(n=128, block=2)
    x = jnp.arange(128.0)
    state = r.init(x)
    state, stats = jax.jit(r.update)(state, x + 0.0)
    assert int(stats["recomputed"]) == 0


def test_reduce_value_cutoff_max():
    r = IncrementalReduce(n=256, block=4, op=jnp.maximum, identity=-1e30,
                          max_sparse=8)
    x = jnp.zeros(256).at[100].set(50.0)
    state = r.init(x)
    y = x.at[7].set(1.0)   # below the global max
    state, stats = jax.jit(r.update)(state, y)
    assert float(r.result(state)) == 50.0
    assert int(stats["recomputed"]) <= 8    # propagation dies early


def test_reduce_sparse_dense_agree():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(256), jnp.float32)
    y = jnp.asarray(rng.standard_normal(256), jnp.float32)  # all dirty
    for ms in (4, 1024):
        r = IncrementalReduce(n=256, block=2, max_sparse=ms)
        state = r.init(x)
        state, _ = r.update(state, y)
        np.testing.assert_allclose(float(r.result(state)), float(y.sum()),
                                   rtol=1e-5)


# ---------------------------------------------------------------------------
# Incremental prefill (serving-path change propagation)
# ---------------------------------------------------------------------------
SUPPORTED_ARCHS = ["minicpm_2b", "yi_6b", "phi3_mini_3_8b", "gemma_7b",
                   pytest.param("deepseek_v3_671b", marks=pytest.mark.slow),
                   pytest.param("arctic_480b", marks=pytest.mark.slow),
                   "internvl2_2b"]


def _setup(arch, B=2, S=64, seed=0):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    tok = jax.random.randint(jax.random.PRNGKey(seed + 1), (B, S), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok}
    extra = {}
    if cfg.family == "vlm":
        patches = jax.random.normal(jax.random.PRNGKey(seed + 2),
                                    (B, cfg.num_patches, 1024), jnp.bfloat16)
        batch["patches"] = patches
        extra["patches"] = patches
    return cfg, model, params, tok, batch, extra


def _full_prefill(cfg, model, params, batch):
    if cfg.family == "moe":
        with dropless_moe():
            return model.prefill(params, batch, impl="naive")
    return model.prefill(params, batch, impl="naive")


@pytest.mark.parametrize("arch", SUPPORTED_ARCHS)
def test_incremental_prefill_matches_full(arch):
    cfg, model, params, tok, batch, extra = _setup(arch)
    _, cache0 = _full_prefill(cfg, model, params, batch)
    new_tok = tok.at[:, 40].set((tok[:, 40] + 1) % cfg.vocab_size)
    nb = dict(batch)
    nb["tokens"] = new_tok
    logits_full, cache_full = _full_prefill(cfg, model, params, nb)
    logits_inc, cache_inc, info = incremental_prefill(
        model, params, tok, new_tok, cache0, batch_extra=extra,
        block=16, impl="naive")
    assert info["savings"] > 1.0
    np.testing.assert_allclose(
        np.asarray(logits_full, np.float32), np.asarray(logits_inc, np.float32),
        rtol=3e-2, atol=3e-2)
    for a, b in zip(jax.tree.leaves(cache_full), jax.tree.leaves(cache_inc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=3e-2)


def test_incremental_prefill_noop():
    cfg, model, params, tok, batch, extra = _setup("yi_6b")
    _, cache0 = _full_prefill(cfg, model, params, batch)
    logits, cache, info = incremental_prefill(
        model, params, tok, tok, cache0, block=16, impl="naive")
    assert info["changed_tokens"] == 0 and logits is None
    for a, b in zip(jax.tree.leaves(cache0), jax.tree.leaves(cache)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_incremental_prefill_multiple_rounds():
    """Chained edits: propagate on top of propagated caches."""
    cfg, model, params, tok, batch, extra = _setup("yi_6b")
    _, cache = _full_prefill(cfg, model, params, batch)
    cur = tok
    for pos in (60, 45, 33):
        new = cur.at[:, pos].set(5)
        _, cache, info = incremental_prefill(
            model, params, cur, new, cache, block=16, impl="naive")
        cur = new
    logits_full, cache_full = _full_prefill(cfg, model, params,
                                            {"tokens": cur})
    for a, b in zip(jax.tree.leaves(cache_full), jax.tree.leaves(cache)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=3e-2)


def test_incremental_prefill_unsupported_families():
    for arch in ("mamba2_370m", "recurrentgemma_9b", "seamless_m4t_large_v2"):
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        from repro.jaxsac.prefill import continue_prefill
        with pytest.raises(NotImplementedError):
            continue_prefill(cfg, None, {"tokens": jnp.zeros((1, 8), jnp.int32)},
                             None, 0)


def test_prefill_distance():
    old = np.zeros((1, 64), np.int32)
    new = old.copy()
    new[0, 40] = 1
    new[0, 50] = 2
    info = prefill_distance(old, new, block=16)
    assert info["p0"] == 40
    assert info["p0_bucket"] == 32
    assert info["recompute"] == 32
    assert info["changed_tokens"] == 2
    assert info["savings"] == 2.0


def test_prefill_distance_equivalence_with_legacy():
    """The DirtySet-routed mark phase must reproduce the pre-redesign
    hand-rolled implementation exactly — same buckets, same reported
    work savings — across random edit patterns."""
    from repro.jaxsac.prefill import _prefill_distance_legacy

    rng = np.random.default_rng(0)
    for _ in range(40):
        B = int(rng.integers(1, 3))
        S = int(rng.integers(8, 200))
        old = rng.integers(0, 50, (B, S)).astype(np.int32)
        new = old.copy()
        for _ in range(int(rng.integers(0, 5))):
            new[rng.integers(B), rng.integers(S)] = rng.integers(0, 50)
        block = int(rng.choice([1, 8, 16, 64]))
        prefix = int(rng.choice([0, 16]))
        got = prefill_distance(old, new, block=block, prefix_offset=prefix)
        want = _prefill_distance_legacy(old, new, block=block,
                                        prefix_offset=prefix)
        assert got == want, (got, want)
    # 1-D prompts take the other diff path
    old = np.arange(32, dtype=np.int32)
    new = old.copy()
    new[20] = -1
    assert (prefill_distance(old, new, block=8)
            == _prefill_distance_legacy(old, new, block=8))


@given(st.integers(0, 63), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_prefill_distance_properties(first, extra):
    old = np.zeros((1, 64), np.int32)
    new = old.copy()
    new[0, first] = 1
    for j in range(extra):
        new[0, min(first + j, 63)] = j + 1
    info = prefill_distance(old, new, block=8)
    assert info["p0"] == first
    assert info["p0_bucket"] <= first
    assert info["p0_bucket"] % 8 == 0
    assert info["recompute"] + info["p0_bucket"] == 64


def test_incremental_prefill_flash_impl():
    """impl="flash" routes the continuation's causal attention through
    the Pallas flash kernel with the query offset at p0 — the kernel's
    causal block skip never touches kv tiles beyond each query tile's
    frontier (the serving-path form of the cached-carry block skip)."""
    cfg, model, params, tok, batch, extra = _setup("yi_6b")
    _, cache0 = _full_prefill(cfg, model, params, batch)
    new_tok = tok.at[:, 40].set((tok[:, 40] + 1) % cfg.vocab_size)
    logits_naive, _, _ = incremental_prefill(
        model, params, tok, new_tok, cache0, batch_extra=extra,
        block=16, impl="naive")
    _, cache0b = _full_prefill(cfg, model, params, batch)
    logits_flash, cache_flash, info = incremental_prefill(
        model, params, tok, new_tok, cache0b, batch_extra=extra,
        block=16, impl="flash")
    assert info["savings"] > 1.0
    np.testing.assert_allclose(
        np.asarray(logits_naive, np.float32),
        np.asarray(logits_flash, np.float32), rtol=3e-2, atol=3e-2)
