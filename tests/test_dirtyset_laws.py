"""DirtySet conformance suite: algebraic laws for every representation.

Replaces the ad-hoc mask-vs-interval equivalence checks (the old
``test_interval_rep_pipeline_matches_mask``) with property-based laws
against independent numpy references.  For every edge transfer T of the
SP-dag vocabulary (zip ``union``, reduce ``pair_or``, stencil
``dilate``, escan ``prefix_shift``, causal ``suffix``, data-dependent
``gather``) and random masks m:

  * **exactness** (MaskDirty):  T_mask(m) == T_ref(m) bitwise;
  * **abstraction soundness** (IntervalDirty):  the transfer of the
    hull concretizes to a superset of the reference on the hull —
    an interval propagate may recompute more, never less;
  * **exact-on-suffix**: causal/escan transfers of suffix-shaped sets
    are exact for the interval rep (the O(1)-space serving-path claim);
  * **meet** (the Algorithm-2 value cutoff): ``meet_diff`` equals
    dirty ∩ diff for masks, and the hull thereof for intervals;
  * **lattice laws**: union is commutative/associative/idempotent with
    ``none`` as identity, and every transfer is monotone.

Seeded sweeps keep the laws checked without dev deps; hypothesis (when
installed) widens the case space with shrinking.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import HAVE_HYPOTHESIS, given, settings, st

from repro.jaxsac.dirtyset import DIRTY_REPS, IntervalDirty, MaskDirty

NBS = [1, 2, 3, 5, 8, 13]


# ---------------------------------------------------------------------------
# Independent numpy references for every transfer
# ---------------------------------------------------------------------------
def ref_union(a, b):
    return a | b


def ref_pair_or(m, out_blocks):
    c = m
    if len(c) % 2:
        c = np.concatenate([c, [False]])
    out = c[0::2] | c[1::2]
    assert len(out) == out_blocks
    return out


def ref_dilate(m, r):
    out = m.copy()
    for off in range(1, r + 1):
        out[:-off] |= m[off:]
        out[off:] |= m[:-off]
    return out


def ref_prefix_shift(m):
    out = np.zeros_like(m)
    out[1:] = np.cumsum(m[:-1]) > 0
    return out


def ref_suffix(m):
    return np.cumsum(m) > 0


def ref_gather(m, idx):
    return m | m[np.clip(idx, 0, len(m) - 1)].any(axis=1)


def _rand_mask(rng, nb):
    density = rng.choice([0.0, 0.1, 0.5, 1.0])
    return rng.random(nb) < density


def _rand_idx(rng, nb, arity):
    return rng.integers(0, nb, (nb, arity)).astype(np.int32)


def _mask_of(d):
    return np.asarray(d.to_mask())


def _mk(rep, m):
    return DIRTY_REPS[rep].from_mask(jnp.asarray(m))


def _hull(m):
    """Minimal interval hull of a mask, as a mask."""
    if not m.any():
        return np.zeros_like(m)
    lo, hi = np.flatnonzero(m)[0], np.flatnonzero(m)[-1] + 1
    out = np.zeros_like(m)
    out[lo:hi] = True
    return out


# ---------------------------------------------------------------------------
# The conformance checker (shared by seeded sweep and hypothesis)
# ---------------------------------------------------------------------------
def check_laws(seed: int):
    rng = np.random.default_rng(seed)
    nb = int(NBS[rng.integers(len(NBS))])
    m = _rand_mask(rng, nb)
    m2 = _rand_mask(rng, nb)
    idx = _rand_idx(rng, nb, int(rng.integers(1, 4)))
    r = int(rng.integers(1, 3))
    def _rep_of(d):
        return "mask" if isinstance(d, MaskDirty) else "interval"

    transfers = {
        "union": (lambda d: d.union(_mk(_rep_of(d), m2)),
                  lambda mm: ref_union(mm, m2)),
        "pair_or": (lambda d: d.pair_or((nb + 1) // 2),
                    lambda mm: ref_pair_or(mm, (nb + 1) // 2)),
        "dilate": (lambda d: d.dilate(r), lambda mm: ref_dilate(mm, r)),
        "prefix_shift": (lambda d: d.prefix_shift(), ref_prefix_shift),
        "suffix": (lambda d: d.suffix(), ref_suffix),
        "gather": (lambda d: d.gather(jnp.asarray(idx)),
                   lambda mm: ref_gather(mm, idx)),
    }

    dm, di = _mk("mask", m), _mk("interval", m)
    # roundtrip / scalar views
    np.testing.assert_array_equal(_mask_of(dm), m)
    np.testing.assert_array_equal(_mask_of(di), _hull(m))
    for d in (dm, di):
        mk = _mask_of(d)
        assert int(d.count()) == int(mk.sum())
        assert bool(d.any()) == bool(mk.any())
        start = int(d.start())
        assert start == (int(np.flatnonzero(mk)[0]) if mk.any() else nb)

    for name, (tf, ref) in transfers.items():
        exact = ref(m)
        got_m = _mask_of(tf(dm))
        np.testing.assert_array_equal(got_m, exact,
                                      err_msg=f"mask {name} seed {seed}")
        got_i = _mask_of(tf(di))
        # abstraction soundness: interval-of-hull covers the reference
        assert (got_i | exact == got_i).all(), (name, seed, m, got_i,
                                                exact)
        # precision bound: never exceeds the hull of the reference
        # applied to the hull (the best an interval rep can do)
        over = _hull(ref(_hull(m)))
        assert (got_i | over == over).all(), (name, seed, m, got_i, over)

    # exact-on-suffix: causal/escan transfers of suffix sets
    sm = ref_suffix(m)                  # a suffix-shaped mask
    dsm = _mk("interval", sm)
    np.testing.assert_array_equal(_mask_of(dsm.suffix()), ref_suffix(sm))
    np.testing.assert_array_equal(_mask_of(dsm.prefix_shift()),
                                  ref_prefix_shift(sm))

    # meet_diff == dirty ∩ diff (mask) / hull thereof (interval)
    block = int(rng.integers(1, 3))
    old = rng.integers(-3, 4, nb * block).astype(np.float32)
    new = old.copy()
    flip = rng.random(nb * block) < 0.3
    new[flip] += 1.0
    diff = (old.reshape(nb, block) != new.reshape(nb, block)).any(axis=1)
    got = _mask_of(dm.meet_diff(jnp.asarray(old), jnp.asarray(new), block))
    np.testing.assert_array_equal(got, m & diff)
    got_i = _mask_of(di.meet_diff(jnp.asarray(old), jnp.asarray(new),
                                  block))
    np.testing.assert_array_equal(got_i, _hull(_hull(m) & diff))

    # lattice laws: union commutative/associative/idempotent, none = id
    for rep in ("mask", "interval"):
        a, b = _mk(rep, m), _mk(rep, m2)
        none = DIRTY_REPS[rep].none(nb)
        np.testing.assert_array_equal(_mask_of(a.union(b)),
                                      _mask_of(b.union(a)))
        np.testing.assert_array_equal(_mask_of(a.union(a)), _mask_of(a))
        np.testing.assert_array_equal(_mask_of(a.union(none)),
                                      _mask_of(a))
        c = _mk(rep, _rand_mask(rng, nb))
        np.testing.assert_array_equal(
            _mask_of(a.union(b).union(c)), _mask_of(a.union(b.union(c))))

    # monotonicity: m ⊆ m|m2 must survive every transfer
    big_m = m | m2
    for name, (tf, _refn) in transfers.items():
        small = _mask_of(tf(dm))
        large = _mask_of(tf(_mk("mask", big_m)))
        assert (small | large == large).all(), (name, seed)

    # from_changed_lanes == scatter reference.  Lane indices are unique
    # (+ sentinel padding): the runtime derives them from nonzero(dirty),
    # so that is the representation contract.
    k = int(rng.integers(1, nb + 1))
    lanes = np.concatenate([rng.permutation(nb)[:k],
                            np.full(2, nb)]).astype(np.int32)
    lc = rng.random(k + 2) < 0.5
    refm = np.zeros(nb, bool)
    for i, c in zip(lanes, lc):
        if i < nb and c:
            refm[i] = True
    gm = MaskDirty.from_changed_lanes(jnp.asarray(lanes), jnp.asarray(lc),
                                      nb)
    np.testing.assert_array_equal(_mask_of(gm), refm)
    gi = IntervalDirty.from_changed_lanes(jnp.asarray(lanes),
                                          jnp.asarray(lc), nb)
    np.testing.assert_array_equal(_mask_of(gi), _hull(refm))


@pytest.mark.parametrize("seed", range(25))
def test_dirtyset_laws_seeded(seed):
    check_laws(seed)


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=50, deadline=None)
def test_dirtyset_laws_hypothesis(seed):
    check_laws(seed)


if HAVE_HYPOTHESIS:  # keep the shim import "used" for linters
    pass
