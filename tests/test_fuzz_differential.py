"""Differential SP-dag fuzzer: graph vs host vs hybrid, one semantics.

Random traced programs — the full frontend combinator vocabulary
(map/zip_map/reduce/stencil/scan/causal, plain + carry form) under
random ``sac.seq``/``sac.par`` nesting and random ``sac.static_region``
tags, over random block counts *including primes* — are run through all
three backends with random edit batches.  The invariants:

  * outputs are **bitwise identical** across graph, host, and hybrid,
    after every edit;
  * post-cutoff changed-block counts (``affected``) and input diff
    counts (``dirty_inputs``) agree across all three;
  * realized computation distance (``recomputed``) agrees between the
    monolithic graph backend and the hybrid fragments — the boundary
    re-diff must recover exactly the in-graph changed sets;
  * the **mesh-sharded** graph runtime (2 and 3 host devices, see
    conftest.py) is bitwise identical to single-device on outputs AND
    on affected / dirty_inputs / recomputed — sharding must be
    observationally invisible.  The spec generator emits
    shard-boundary-straddling edits (contiguous lane runs centred on
    n/2 and n/3 cut points) so the halo / carry-exchange collectives
    are exercised, not just chunk-interior scatters.

Programs are generated from a JSON-able *spec* (a plain dict), so
failures are reproducible artifacts: shrunk specs are checked into
``tests/corpus/`` and replayed on every run.  A seeded sweep keeps the
invariant exercised without dev dependencies (``FUZZ_CASES`` widens it
— the CI fuzz lane runs ~200 cases); when hypothesis is installed, a
composite strategy drives the same checker with real shrinking.

The same corpus also gates the graph runtime's internal parities:
``plan=True`` vs ``plan=False`` and ``donate=True`` vs ``donate=False``
must be bitwise identical (previously covered only by hand-written
cases in test_graph.py).
"""
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import HAVE_HYPOTHESIS, given, settings, st

import repro.sac as sac

CORPUS = Path(__file__).parent / "corpus"

# Mesh-sharded lanes run at these shard counts when the devices exist
# (conftest.py forces 8 host CPU devices; an externally pinned
# XLA_FLAGS may expose fewer, in which case the lanes drop out).
SHARD_COUNTS = [s for s in (2, 3) if s <= len(jax.devices())]

# Value-bounded vocabulary: small-integer-valued f32 stays exactly
# representable through every op, so bitwise equality across backends
# tests the lowering, not float edge cases (same rationale as
# test_sac_property.py).
UNARY = ["affine", "halve", "neg", "abs", "clip"]
BINARY = ["add", "sub", "min", "max"]
SHAPED = ["stencil", "scan", "causal_mean", "carry_causal"]
OP_KINDS = UNARY + BINARY + SHAPED


def _apply_op(pool, op, block):
    kind = op["kind"]
    src = pool[op["src"] % len(pool)]
    if kind == "affine":
        return src * 2.0 + 1.0
    if kind == "halve":
        return src / 2.0
    if kind == "neg":
        return -src
    if kind == "abs":
        return abs(src)
    if kind == "clip":
        return sac.elementwise(jnp.clip)(src, -3.0, 3.0)
    if kind in BINARY:
        other = pool[op.get("src2", 0) % len(pool)]
        f = {"add": lambda a, b: a + b, "sub": lambda a, b: a - b,
             "min": lambda a, b: np.minimum(a, b),
             "max": lambda a, b: np.maximum(a, b)}[kind]
        return f(src, other)
    if kind == "stencil":
        return sac.stencil(
            lambda w: w[block:2 * block]
            + 0.5 * (w[:block] + w[2 * block:]), src, radius=1)
    if kind == "scan":
        return sac.scan(jnp.add, src, identity=0.0)
    if kind == "causal_mean":
        def fn(x, i, _b=block):
            pos = jnp.arange(x.shape[0]) // _b
            w = (pos <= i).astype(x.dtype)
            return jnp.full((_b,), (x * w).sum() / w.sum(), x.dtype)

        return sac.causal(fn, src)
    if kind == "carry_causal":
        return sac.causal(
            None, src, lift=lambda b: b.sum(), op=jnp.add,
            finalize=lambda s, b: b + s, identity=0.0)
    raise ValueError(kind)


def build_program(spec):
    """Spec dict -> (@sac.incremental program over x0/x1, n, block)."""
    block = spec["block"]
    n = spec["nb"] * block

    @sac.incremental(block=block)
    def prog(x0, x1):
        pool = [x0, x1]

        def run_segment(seg):
            ctx = seg.get("comp")
            region = seg.get("region")

            def body():
                for op in seg["ops"]:
                    pool.append(_apply_op(pool, op, block))

            def regioned():
                if region is not None:
                    with sac.static_region(region):
                        body()
                else:
                    body()

            if ctx == "seq":
                with sac.seq():
                    regioned()
            elif ctx == "par":
                with sac.par():
                    regioned()
            else:
                regioned()

        for seg in spec["segments"]:
            run_segment(seg)
        last = pool[-1]
        outs = [sac.reduce(jnp.add, last, identity=0.0),
                sac.reduce(jnp.maximum, pool[2 % len(pool)],
                           identity=-jnp.inf)]
        return tuple(outs)

    return prog, n, block


def _inputs(spec):
    rng = np.random.default_rng(spec.get("data_seed", 0))
    n = spec["nb"] * spec["block"]
    return (rng.integers(-5, 6, n).astype(np.float32),
            rng.integers(-5, 6, n).astype(np.float32))


def _apply_edit(x0, x1, edit, n):
    x0, x1 = x0.copy(), x1.copy()
    target = x0 if edit["input"] == 0 else x1
    for lane, val in zip(edit["lanes"], edit["vals"]):
        target[lane % n] = np.float32(val)
    return x0, x1


def check_spec(spec, shards=None):
    """The differential invariant for one spec.  ``shards`` adds
    mesh-sharded graph lanes (default: every count in SHARD_COUNTS)."""
    prog, n, block = build_program(spec)
    shards = SHARD_COUNTS if shards is None else shards
    hg = prog.compile(x0=n, x1=n, max_sparse=4)
    hh = prog.compile("host", x0=n, x1=n)
    hy = prog.compile("hybrid", x0=n, x1=n, max_sparse=4)
    hss = [(f"shards={s}", prog.compile(x0=n, x1=n, max_sparse=4,
                                        shards=s)) for s in shards]
    named = [("host", hh), ("hybrid", hy)] + hss
    x0, x1 = _inputs(spec)
    outs = {name: h.run(x0=x0, x1=x1) for name, h in named}
    ref = hg.run(x0=x0, x1=x1)
    for name, o in outs.items():
        for a, b in zip(ref, o):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{name} initial run, spec={spec}")
    if any(seg.get("region") for seg in spec["segments"]):
        assert hy.num_fragments >= 2, (hy.num_fragments, spec)
    for r, edit in enumerate(spec["edits"]):
        x0, x1 = _apply_edit(x0, x1, edit, n)
        ref = hg.update(x0=x0, x1=x1)
        outs = {name: h.update(x0=x0, x1=x1) for name, h in named}
        for name, o in outs.items():
            for a, b in zip(ref, o):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"{name} edit {r}, spec={spec}")
        sg, sh, sy = hg.stats, hh.stats, hy.stats
        assert int(sg["affected"]) == int(sh["affected"]) \
            == int(sy["affected"]), (r, sg, sh, sy, spec)
        assert int(sg["dirty_inputs"]) == int(sh["dirty_inputs"]) \
            == int(sy["dirty_inputs"]), (r, sg, sh, sy, spec)
        assert int(sg["recomputed"]) == int(sy["recomputed"]), (
            r, sg, sy, spec)
        for name, h in hss:
            ss = h.stats
            assert int(sg["affected"]) == int(ss["affected"]), (
                name, r, sg, ss, spec)
            assert int(sg["recomputed"]) == int(ss["recomputed"]), (
                name, r, sg, ss, spec)
            assert int(sg["dirty_inputs"]) == int(ss["dirty_inputs"]), (
                name, r, sg, ss, spec)


# ---------------------------------------------------------------------------
# Spec generation (seeded — runs everywhere; hypothesis drives the same
# checker with real shrinking when installed)
# ---------------------------------------------------------------------------
BLOCKS = [1, 2, 3, 4]
# Prime and >TINY_NB block counts included: primes hit every odd-level
# padding path, 67 forces the sparse/dense regime machinery live.
NBS = [4, 5, 7, 8, 11, 13, 16, 67]


def random_spec(rng) -> dict:
    block = int(rng.choice(BLOCKS))
    nb = int(NBS[rng.integers(len(NBS))])
    n = nb * block
    pool = 2
    segments = []
    for _ in range(int(rng.integers(1, 4))):
        ops = []
        for _ in range(int(rng.integers(1, 4))):
            kind = OP_KINDS[rng.integers(len(OP_KINDS))]
            ops.append({"kind": kind, "src": int(rng.integers(pool)),
                        "src2": int(rng.integers(pool))})
            pool += 1
        segments.append({
            "comp": [None, "seq", "par"][rng.integers(3)],
            "region": [None, "a", "b"][rng.integers(3)],
            "ops": ops,
        })
    edits = []
    for _ in range(int(rng.integers(2, 4))):
        k = int(rng.integers(1, max(2, n // 2)))
        edits.append({
            "input": int(rng.integers(2)),
            "lanes": [int(l) for l in rng.integers(0, n, k)],
            "vals": [int(v) for v in rng.integers(-5, 6, k)],
        })
    # One shard-boundary-straddling edit: a contiguous lane run centred
    # on an n/2 or n/3 cut point, so the sharded lanes exercise halo
    # exchange and carry hand-off rather than chunk-interior scatters.
    cut = n // int(rng.choice([2, 3]))
    width = int(rng.integers(1, 4))
    lanes = [l % n for l in range(max(cut - width, 0), cut + width)]
    edits.append({
        "input": int(rng.integers(2)),
        "lanes": lanes,
        "vals": [int(v) for v in rng.integers(-5, 6, len(lanes))],
    })
    return {"block": block, "nb": nb, "data_seed": int(rng.integers(10**6)),
            "segments": segments, "edits": edits}


# Bounded sweep: default size keeps the fast lane fast; the CI fuzz lane
# sets FUZZ_CASES=200 (fixed seeds, so failures are reproducible).
FUZZ_CASES = int(os.environ.get("FUZZ_CASES", "10"))


@pytest.mark.parametrize("seed", range(FUZZ_CASES))
def test_fuzz_differential_seeded(seed):
    check_spec(random_spec(np.random.default_rng(seed)))


# ---------------------------------------------------------------------------
# Corpus replay: shrunk specs from past fuzz findings + structural
# minima that pin each boundary mechanism.
# ---------------------------------------------------------------------------
def _corpus_files():
    return sorted(CORPUS.glob("*.json"))


@pytest.mark.parametrize("path", _corpus_files(),
                         ids=lambda p: p.stem)
def test_fuzz_corpus(path):
    case = json.loads(path.read_text())
    check_spec(case["spec"])


# ---------------------------------------------------------------------------
# Plan/legacy and donate parity under the same corpus (satellite of the
# hybrid PR: these were only covered by hand-written cases before)
# ---------------------------------------------------------------------------
VARIANTS = [
    {"plan": True, "donate": True},      # the default fast path
    {"plan": True, "donate": False},
    {"plan": False, "donate": True},     # legacy cond executable
    {"plan": False, "donate": False},
]


def check_variants(spec):
    prog, n, _block = build_program(spec)
    handles = [prog.compile(x0=n, x1=n, max_sparse=4, **kw)
               for kw in VARIANTS]
    x0, x1 = _inputs(spec)
    outs = [h.run(x0=x0, x1=x1) for h in handles]
    for kw, o in zip(VARIANTS[1:], outs[1:]):
        for a, b in zip(outs[0], o):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{kw} initial run")
    for r, edit in enumerate(spec["edits"]):
        x0, x1 = _apply_edit(x0, x1, edit, n)
        outs = [h.update(x0=x0, x1=x1) for h in handles]
        ref = handles[0].stats
        for kw, h, o in zip(VARIANTS[1:], handles[1:], outs[1:]):
            for a, b in zip(outs[0], o):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"{kw} edit {r}, spec={spec}")
            assert int(h.stats["affected"]) == int(ref["affected"]), (
                kw, r, spec)
            assert int(h.stats["recomputed"]) == int(ref["recomputed"]), (
                kw, r, spec)


@pytest.mark.parametrize("path", _corpus_files(),
                         ids=lambda p: p.stem)
def test_plan_donate_parity_corpus(path):
    case = json.loads(path.read_text())
    check_variants(case["spec"])


@pytest.mark.parametrize("seed", range(min(FUZZ_CASES, 6)))
def test_plan_donate_parity_seeded(seed):
    check_variants(random_spec(np.random.default_rng(seed + 1000)))


# ---------------------------------------------------------------------------
# COW fork lane under the same corpus (serving-layer satellite): a fork
# replaying the edit stream must match a donate=False linear handle
# bitwise, the forked-from parent must stay bitwise frozen throughout,
# and stats must agree — the COW split executable is the same math.
# ---------------------------------------------------------------------------
def check_spec_fork(spec):
    prog, n, _block = build_program(spec)
    hg = prog.compile(x0=n, x1=n, max_sparse=4)
    ref = prog.compile(x0=n, x1=n, max_sparse=4, donate=False)
    x0, x1 = _inputs(spec)
    base = [np.asarray(v) for v in hg.run(x0=x0, x1=x1)]
    ref.run(x0=x0, x1=x1)
    child = hg.fork()
    for r, edit in enumerate(spec["edits"]):
        x0, x1 = _apply_edit(x0, x1, edit, n)
        want = ref.update(x0=x0, x1=x1)
        got = child.update(x0=x0, x1=x1)
        for a, b in zip(want, got):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"fork edit {r}, spec={spec}")
        for a, b in zip(base, hg.outputs()):
            np.testing.assert_array_equal(
                a, np.asarray(b),
                err_msg=f"parent perturbed at edit {r}, spec={spec}")
        for key in ("recomputed", "affected", "dirty_inputs"):
            assert int(child.stats[key]) == int(ref.stats[key]), (
                key, r, child.stats, ref.stats, spec)


@pytest.mark.parametrize("path", _corpus_files(),
                         ids=lambda p: p.stem)
def test_fuzz_fork_corpus(path):
    case = json.loads(path.read_text())
    check_spec_fork(case["spec"])


@pytest.mark.parametrize("seed", range(min(FUZZ_CASES, 6)))
def test_fuzz_fork_seeded(seed):
    check_spec_fork(random_spec(np.random.default_rng(seed + 2000)))


# ---------------------------------------------------------------------------
# Hypothesis strategy (drives the same checker with real shrinking)
# ---------------------------------------------------------------------------
@st.composite
def spec_strategy(draw):
    block = draw(st.sampled_from(BLOCKS))
    nb = draw(st.sampled_from(NBS))
    n = nb * block
    pool = 2
    segments = []
    for _ in range(draw(st.integers(1, 3))):
        ops = []
        for _ in range(draw(st.integers(1, 3))):
            ops.append({"kind": draw(st.sampled_from(OP_KINDS)),
                        "src": draw(st.integers(0, pool - 1)),
                        "src2": draw(st.integers(0, pool - 1))})
            pool += 1
        segments.append({"comp": draw(st.sampled_from(
                             [None, "seq", "par"])),
                         "region": draw(st.sampled_from(
                             [None, "a", "b"])),
                         "ops": ops})
    edits = [{"input": draw(st.integers(0, 1)),
              "lanes": draw(st.lists(st.integers(0, n - 1), min_size=1,
                                     max_size=max(1, n // 2))),
              "vals": draw(st.lists(st.integers(-5, 5), min_size=n,
                                    max_size=n))}
             for _ in range(draw(st.integers(1, 3)))]
    return {"block": block, "nb": nb,
            "data_seed": draw(st.integers(0, 10**6)),
            "segments": segments, "edits": edits}


@given(spec_strategy())
@settings(max_examples=15, deadline=None)
def test_fuzz_differential_hypothesis(spec):
    check_spec(spec)


# ---------------------------------------------------------------------------
# Deep-trace lane: the fenced per-level executables must be bitwise
# identical to the single planned executable they replace.
# ---------------------------------------------------------------------------
def test_fuzz_corpus_deep_trace():
    """One corpus case under ``trace='deep'``: outputs and stats must be
    bitwise identical to the untraced run (the per-level jits cross the
    level boundary as dirty masks — lossless for both dirty reps), and
    every level must carry a real fenced wall-clock."""
    files = _corpus_files()
    assert files, "no fuzz corpus checked in"
    spec = json.loads(files[0].read_text())["spec"]
    prog, n, _block = build_program(spec)
    plain = prog.compile(x0=n, x1=n, max_sparse=4)
    deep = prog.compile(x0=n, x1=n, max_sparse=4, trace="deep")
    x0, x1 = _inputs(spec)
    ref = plain.run(x0=x0, x1=x1)
    out = deep.run(x0=x0, x1=x1)
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for r, edit in enumerate(spec["edits"]):
        x0, x1 = _apply_edit(x0, x1, edit, n)
        ref = plain.update(x0=x0, x1=x1)
        out = deep.update(x0=x0, x1=x1)
        for a, b in zip(ref, out):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"deep-trace edit {r}, spec={spec}")
        sp, sd = plain.stats, deep.stats
        for key in ("recomputed", "affected", "dirty_inputs"):
            assert int(sp[key]) == int(sd[key]), (key, r, sp, sd)
        rec = deep.record
        assert rec is not None and rec.fenced
        d = rec.to_dict()
        assert all(lv["ms"] is not None for lv in d["levels"]), d["levels"]


if HAVE_HYPOTHESIS:  # keep the shim import "used" for linters
    pass
