"""Per-architecture smoke tests: reduced configs, real code paths.

Every assigned architecture instantiates its SMOKE config (same family,
small dims) and runs one forward/train step and, where defined, a
prefill + decode step on CPU, asserting shapes and finiteness.  The FULL
configs are exercised only by the dry-run (ShapeDtypeStruct, no alloc).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import build_model
from repro.optim import make_optimizer, make_schedule
from repro.launch.train import init_train_state, make_train_step

DECODE_FAMILIES = ("dense", "vlm", "moe", "ssm", "hybrid", "encdec")


def make_batch(cfg, B=2, S=32, train=True, key=0):
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if train:
        batch["labels"] = jax.random.randint(
            jax.random.PRNGKey(key + 1), (B, S), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(key + 2), (B, cfg.num_patches, 1024),
            jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(key + 3), (B, S, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, metrics = model.loss(params, batch, impl="naive")
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    optimizer = make_optimizer(cfg)
    schedule = make_schedule(cfg.lr_schedule, 1e-3, 100)
    step = make_train_step(model, optimizer, schedule)
    state = init_train_state(model, optimizer, jax.random.PRNGKey(0))
    B = 2 * max(cfg.grad_accum, 1)
    batch = make_batch(cfg, B=B)
    state2, metrics = jax.jit(step)(state, batch)
    assert int(state2["step"]) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state["params"], state2["params"])
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_decreases(arch):
    """A few steps on a fixed batch must reduce the loss (end-to-end
    learning sanity for every family)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    optimizer = make_optimizer(cfg)
    schedule = make_schedule("constant", 3e-3, 100, warmup_steps=1)
    step = jax.jit(make_train_step(model, optimizer, schedule))
    state = init_train_state(model, optimizer, jax.random.PRNGKey(0))
    batch = make_batch(cfg, B=2 * max(cfg.grad_accum, 1), S=16)
    losses = []
    for _ in range(5):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_and_decode(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = make_batch(cfg, B=B, S=S, train=False)
    logits, cache = model.prefill(params, batch, impl="naive")
    # vocab may be padded for sharding; padded tail is masked to -inf
    assert logits.shape[0] == B and logits.shape[-1] >= cfg.vocab_size
    real = np.asarray(logits, np.float32)[..., :cfg.vocab_size]
    assert np.isfinite(real).all()

    tok = jnp.full((B, 1), 3, jnp.int32)
    if cfg.family == "encdec":
        pos = jnp.full((B,), S // 2, jnp.int32)
    else:
        pos = jnp.full((B,), S, jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, tok, pos)
    assert logits2.shape[:2] == (B, 1) and logits2.shape[-1] >= cfg.vocab_size
    assert np.isfinite(
        np.asarray(logits2, np.float32)[..., :cfg.vocab_size]).all()


@pytest.mark.parametrize("arch", ["yi_6b", "gemma_7b", "recurrentgemma_9b"])
def test_decode_matches_prefill(arch):
    """Prefill(S+1)'s last logits == prefill(S) + one decode step.

    (mamba2's SSD scan requires chunk-aligned sequence lengths, so S and
    S+1 can't both prefill; its decode path is covered by
    test_prefill_and_decode.)"""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 1, 33
    full = make_batch(cfg, B=B, S=S, train=False, key=7)
    logits_full, _ = model.prefill(params, full, impl="naive")

    pre = {k: (v[:, :S - 1] if k == "tokens" else v) for k, v in full.items()}
    _, cache = model.prefill(params, pre, impl="naive")
    # pad cache to S positions for the decode write
    def pad(c):
        if c.ndim >= 3 and c.shape[2] == S - 1:
            pad_width = [(0, 0)] * c.ndim
            pad_width[2] = (0, 1)
            return jnp.pad(c, pad_width)
        return c
    if cfg.family in ("dense", "vlm", "moe"):
        cache = jax.tree.map(pad, cache)
    logits_dec, _ = model.decode_step(
        params, cache, full["tokens"][:, -1:],
        jnp.full((B,), S - 1, jnp.int32))
    a = np.asarray(logits_full[:, -1], np.float32)
    b = np.asarray(logits_dec[:, -1], np.float32)
    assert np.allclose(a, b, rtol=3e-2, atol=3e-2), (
        arch, float(np.max(np.abs(a - b))))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL config must carry the exact assigned hyperparameters."""
    spec = {
        "minicpm_2b": (40, 2304, 36, 36, 5760, 122753),
        "yi_6b": (32, 4096, 32, 4, 11008, 64000),
        "phi3_mini_3_8b": (32, 3072, 32, 32, 8192, 32064),
        "gemma_7b": (28, 3072, 16, 16, 24576, 256000),
        "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256000),
        "seamless_m4t_large_v2": (24, 1024, 16, 16, 8192, 256206),
        "mamba2_370m": (48, 1024, 4, 0, 0, 50280),
        "deepseek_v3_671b": (61, 7168, 128, 128, 2048, 129280),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "internvl2_2b": (24, 2048, 16, 8, 8192, 92553),
    }[arch]
    cfg = get_config(arch)
    L, d, H, KV, ff, V = spec
    assert cfg.num_layers == L
    assert cfg.d_model == d
    if cfg.family != "ssm":
        assert cfg.num_heads == H
        assert cfg.num_kv_heads == KV
    assert (cfg.d_ff or 0) == ff
    assert cfg.vocab_size == V


def test_moe_param_counts():
    cfg = get_config("deepseek_v3_671b")
    model = build_model(cfg)
    total = model.param_count()
    active = model.param_count(active_only=True)
    assert 6.0e11 < total < 7.5e11, total      # ~671B
    assert 3.0e10 < active < 4.5e10, active    # ~37B active
