"""Quickstart: parallel self-adjusting computation in 60 lines.

Runs the paper's Algorithm-1 divide-and-conquer sum twice:

  1. on the paper-faithful host engine (``repro.core``) — dynamic RSP
     tree, reader sets, change propagation with work/span accounting;
  2. on the TPU-native jaxsac path (``repro.jaxsac``) — static RSP
     structure, block-granular dirty masks, jit-compiled propagation.

Both show the same O(k log(n/k)) behaviour (Theorem 4.2).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import Engine
from repro.jaxsac import IncrementalReduce

N = 4096


def sum_program(eng, mods, res):
    def rec(lo, hi, out):
        if hi - lo == 1:
            eng.read(mods[lo], lambda v: eng.write(out, v))
            return
        mid = (lo + hi) // 2
        left, right = eng.mod(), eng.mod()
        eng.par(lambda: rec(lo, mid, left), lambda: rec(mid, hi, right))
        eng.read((left, right), lambda a, b: eng.write(out, a + b))

    rec(0, len(mods), res)


def host_engine_demo():
    print(f"== host engine: self-adjusting sum of {N} values ==")
    eng = Engine()
    mods = eng.alloc_array(N, "x")
    for i, m in enumerate(mods):
        eng.write(m, i)
    res = eng.mod("total")
    comp = eng.run(lambda: sum_program(eng, mods, res))
    print(f" initial run : total={res.peek()}  work={comp.initial_stats.work} "
          f"span={comp.initial_stats.span}")
    for k in (1, 16, 256):
        for i in range(k):
            eng.write(mods[i * (N // k)], 7)
        st = comp.propagate()
        ws = comp.initial_stats.work / max(st.work, 1)
        print(f" update k={k:4d}: total={res.peek()}  affected readers="
              f"{st.affected_readers:5d}  work savings={ws:7.1f}x")


def jaxsac_demo():
    print(f"\n== jaxsac (TPU path): incremental block reduction ==")
    r = IncrementalReduce(n=N, block=8, op=jnp.add, identity=0.0,
                          max_sparse=64)
    x = jnp.arange(N, dtype=jnp.int32)
    state = r.init(x)
    update = jax.jit(r.update)
    print(f" initial run : total={int(r.result(state))}")
    y = x
    for k in (1, 16, 256):
        idx = jnp.arange(k) * (N // k)
        y = y.at[idx].set(7)
        state, stats = update(state, y)
        print(f" update k={k:4d}: total={int(r.result(state))}  recomputed "
              f"tree nodes={int(stats['recomputed']):5d} of {2 * N // 8 - 1}")


if __name__ == "__main__":
    host_engine_demo()
    jaxsac_demo()
