"""Quickstart: parallel self-adjusting computation in 80 lines.

1. ``@sac.incremental`` — THE public API: write the ordinary program
   once, compile it onto the jitted graph runtime (``backend="graph"``)
   or the paper-faithful host engine (``backend="host"``), then
   ``run`` / ``update`` / ``stats``.
2. The same Algorithm-1 divide-and-conquer sum hand-written against the
   host engine primitives (``repro.core``) — what the frontend derives
   for you.
3. ``IncrementalReduce`` — the pre-traced reduction wrapper.

All show the same O(k log(n/k)) behaviour (Theorem 4.2).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

import repro.sac as sac
from repro.core import Engine
from repro.jaxsac import IncrementalReduce


@sac.incremental(block=8)
def pipeline(x):
    """An ordinary array program: affine map -> 3-block stencil -> sum."""
    y = x * 2.0 + 1.0
    s = sac.stencil(lambda w: w[8:16] + 0.5 * (w[:8] + w[16:]), y, radius=1)
    return sac.reduce(jnp.add, s, identity=0.0)


N = 4096


def sac_demo():
    print("== @sac.incremental: one trace, two backends ==")
    data = jnp.arange(N, dtype=jnp.float32)
    graph = pipeline.compile(x=N)                  # jitted TPU runtime
    host = pipeline.compile("host", x=N)           # paper-faithful engine
    out = graph.run(x=data)
    assert float(host.run(x=data)[0]) == float(out[0])   # bitwise equal
    print(f" initial run : total={float(out[0]):.1f}  "
          f"(host engine agrees bitwise)")
    for k in (1, 16, 256):
        data = data.at[jnp.arange(k) * (N // k)].add(1.0)
        out = graph.update(x=data)
        host.update(x=data)
        g, h = graph.stats, host.stats
        print(f" update k={k:4d}: total={float(out[0]):9.1f}  recomputed "
              f"blocks={g['recomputed']:4d}/{graph.cg.total_blocks}  "
              f"host work={h['work']:6d} span={h['span']:3d}")


def sum_program(eng, mods, res):
    def rec(lo, hi, out):
        if hi - lo == 1:
            eng.read(mods[lo], lambda v: eng.write(out, v))
            return
        mid = (lo + hi) // 2
        left, right = eng.mod(), eng.mod()
        eng.par(lambda: rec(lo, mid, left), lambda: rec(mid, hi, right))
        eng.read((left, right), lambda a, b: eng.write(out, a + b))

    rec(0, len(mods), res)


def host_engine_demo():
    print(f"\n== host engine primitives: self-adjusting sum of {N} values ==")
    eng = Engine()
    mods = eng.alloc_array(N, "x")
    for i, m in enumerate(mods):
        eng.write(m, i)
    res = eng.mod("total")
    comp = eng.run(lambda: sum_program(eng, mods, res))
    print(f" initial run : total={res.peek()}  work={comp.initial_stats.work} "
          f"span={comp.initial_stats.span}")
    for k in (1, 16, 256):
        for i in range(k):
            eng.write(mods[i * (N // k)], 7)
        st = comp.propagate()
        ws = comp.initial_stats.work / max(st.work, 1)
        print(f" update k={k:4d}: total={res.peek()}  affected readers="
              f"{st.affected_readers:5d}  work savings={ws:7.1f}x")


def jaxsac_demo():
    print(f"\n== jaxsac (TPU path): incremental block reduction ==")
    r = IncrementalReduce(n=N, block=8, op=jnp.add, identity=0.0,
                          max_sparse=64)
    x = jnp.arange(N, dtype=jnp.int32)
    state = r.init(x)
    update = jax.jit(r.update)
    print(f" initial run : total={int(r.result(state))}")
    y = x
    for k in (1, 16, 256):
        idx = jnp.arange(k) * (N // k)
        y = y.at[idx].set(7)
        state, stats = update(state, y)
        print(f" update k={k:4d}: total={int(r.result(state))}  recomputed "
              f"tree nodes={int(stats['recomputed']):5d} of {2 * N // 8 - 1}")


if __name__ == "__main__":
    sac_demo()
    host_engine_demo()
    jaxsac_demo()
