"""End-to-end training driver: ~100M-parameter LM under the full runtime.

Exercises every substrate layer at once: deterministic data pipeline,
train step (grad accumulation, clipping, schedule), sharding rules on the
local mesh, async checkpointing, and the fault-tolerant supervisor —
including an (optional) injected crash to demonstrate restart with an
identical loss trajectory.

  PYTHONPATH=src python examples/train_100m.py --preset tiny --steps 60
  PYTHONPATH=src python examples/train_100m.py --preset 100m --steps 300 \
      --inject-fault 120
"""
import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.data import DataPipeline
from repro.launch.mesh import make_local_mesh
from repro.launch.train import init_train_state, make_train_step
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.optim import make_optimizer, make_schedule
from repro.runtime import FaultInjector, Supervisor, make_compressor
from repro.shardlib import rules_for_mode, shard_ctx

PRESETS = {
    # ~110M params: minicpm-style dense decoder (WSD schedule).
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
                 d_ff=2048, vocab_size=32_000, seq=256, batch=4),
    # seconds-per-step scale for smoke runs
    "tiny": dict(num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
                 d_ff=384, vocab_size=2_048, seq=64, batch=4),
}


def build_cfg(preset: dict) -> ModelConfig:
    return ModelConfig(
        name=f"lm-{preset['d_model']}", family="dense",
        num_layers=preset["num_layers"], d_model=preset["d_model"],
        num_heads=preset["num_heads"], num_kv_heads=preset["num_kv_heads"],
        d_ff=preset["d_ff"], vocab_size=preset["vocab_size"],
        tie_embeddings=True, emb_scale=12.0, lr_schedule="wsd", remat="none",
        param_dtype="float32", compute_dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--inject-fault", type=int, default=0,
                    help="crash at this step once; supervisor restarts")
    ap.add_argument("--compress", default="none",
                    choices=["none", "topk", "int8"])
    ap.add_argument("--seq", type=int, default=0, help="override preset seq")
    ap.add_argument("--batch", type=int, default=0)
    args = ap.parse_args()

    preset = dict(PRESETS[args.preset])
    if args.seq:
        preset["seq"] = args.seq
    if args.batch:
        preset["batch"] = args.batch
    cfg = build_cfg(preset)
    model = build_model(cfg)
    n_params = model.param_count()
    print(f"model: {cfg.name}  {n_params/1e6:.1f}M params  "
          f"seq={preset['seq']} batch={preset['batch']}")

    optimizer = make_optimizer(cfg)
    schedule = make_schedule(cfg.lr_schedule, args.lr, args.steps,
                             warmup_steps=max(args.steps // 8, 2))
    step_fn = make_train_step(
        model, optimizer, schedule, max_grad_norm=0.5,
        grad_compression=make_compressor(args.compress))

    pipeline = DataPipeline(cfg.vocab_size, global_batch=preset["batch"],
                            seq_len=preset["seq"], seed=0)
    mesh = make_local_mesh()

    with shard_ctx(mesh, rules_for_mode("train")), mesh:
        jit_step = jax.jit(step_fn)

        def init_state():
            return init_train_state(model, optimizer, jax.random.PRNGKey(0))

        t_last = [time.perf_counter()]

        def step_with_log(state, batch):
            batch = jax.tree.map(jnp.asarray, batch)
            state, metrics = jit_step(state, batch)
            step = int(state["step"]) - 1
            if step % 10 == 0 or step < 3:
                dt = time.perf_counter() - t_last[0]
                print(f" step {step:5d}  loss={float(metrics['loss']):7.4f}  "
                      f"lr={float(metrics['lr']):.2e}  "
                      f"gnorm={float(metrics['grad_norm']):6.2f}  "
                      f"({dt:5.1f}s since last log)", flush=True)
                t_last[0] = time.perf_counter()
            return state, metrics

        sup = Supervisor(
            step_fn=step_with_log, pipeline=pipeline,
            ckpt_dir=args.ckpt_dir, init_state=init_state,
            ckpt_every=args.ckpt_every,
            fault_injector=FaultInjector(
                [args.inject_fault] if args.inject_fault else []),
            on_straggler=lambda s: print(f"  !! straggler step {s}"))
        t0 = time.perf_counter()
        state = sup.run(args.steps)
        dt = time.perf_counter() - t0

    losses = [m["loss"] for m in sup.metrics_log]
    print(f"done: {args.steps} steps in {dt:.1f}s  "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}  "
          f"restarts={sup.restarts}  "
          f"ckpts={Path(args.ckpt_dir).name}")
    if args.steps >= 30:
        assert losses[-1] < losses[0], "loss did not decrease"


if __name__ == "__main__":
    main()
