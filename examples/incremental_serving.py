"""Incremental serving: change propagation through the prefill path.

Scenario: a long prompt is prefilled once; the user then edits a few
late tokens (revised instruction, updated retrieval chunk).  Instead of
re-running prefill from scratch, ``incremental_prefill`` re-executes only
the positions the edit can affect and patches the KV cache in place —
the serving-side realization of the paper's change propagation.

  PYTHONPATH=src python examples/incremental_serving.py [--arch yi_6b]
      [--seq 4096] [--edits 3]

``--server`` switches to the multi-tenant mode: one warm base state,
N concurrent editors each working in their own copy-on-write session
(``handle.serve()``), compatible edits batched across sessions:

  PYTHONPATH=src python examples/incremental_serving.py --server
      [--editors 8] [--edits 4] [--n 32768]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.jaxsac import incremental_prefill


def serve_main(args):
    """N concurrent editors over one warm base, through the session
    server: each editor forks the base (no device copies), streams
    sparse edits, and gets back exactly what a dedicated handle would
    compute; compatible concurrent edits share one plan freeze."""
    import repro.sac as sac
    from repro.launch.serve import run_session_workload

    n, block = args.n, 64

    @sac.incremental(block=block)
    def doc_score(x):
        y = x * 1.5 + 0.25
        s = sac.stencil(
            lambda w: w[block:2 * block]
            + 0.5 * (w[:block] + w[2 * block:]), y, radius=1)
        return sac.reduce(jnp.add, s, identity=0.0)

    rng = np.random.default_rng(0)
    x0 = rng.standard_normal(n).astype(np.float32)
    h = doc_score.compile(x=n, max_sparse=64)
    h.run(x=x0)
    print(f"serving {args.editors} concurrent editors, "
          f"{args.edits} edits each, doc n={n}")

    streams = []
    for e in range(args.editors):
        x, edits = x0.copy(), []
        for _ in range(args.edits):
            x = x.copy()
            x[int(rng.integers(0, n // block)) * block + block // 2] += 1.0
            edits.append({"x": x.copy()})
        streams.append(edits)

    t0 = time.perf_counter()
    results, summary = run_session_workload(h, streams)
    wall = time.perf_counter() - t0

    for i, stream in enumerate(streams):
        ref = doc_score.compile(x=n, max_sparse=64)
        ref.run(x=x0)
        for r, edit in enumerate(stream):
            want = np.asarray(ref.update(**edit))
            got = np.asarray(results[i][r]["outputs"])
            assert np.array_equal(want, got), (i, r)
    print(f" {summary['requests']} requests in {wall:5.2f}s "
          f"({summary['requests'] / wall:6.1f} req/s)")
    print(f" batching: {summary['batches']} batches, "
          f"{summary['batch_joins']} joins "
          f"(hit rate {summary['batch_hit_rate']:.2f})")
    print(f" latency: p50 {summary['p50_ms']:6.2f}ms  "
          f"p99 {summary['p99_ms']:6.2f}ms")
    pc = summary["plan_cache"]
    print(f" shared plan cache: {pc['hits']} hits / {pc['misses']} misses")
    print(" every editor's stream bitwise == a dedicated replay: ok")
    h.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--edits", type=int, default=3)
    ap.add_argument("--server", action="store_true",
                    help="multi-tenant session-server mode")
    ap.add_argument("--editors", type=int, default=8,
                    help="concurrent editors (server mode)")
    ap.add_argument("--n", type=int, default=1 << 15,
                    help="document size (server mode)")
    args = ap.parse_args()
    if args.server:
        serve_main(args)
        return

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 1, args.seq
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, impl="blocked"))
    print(f"arch={cfg.name} (smoke config)  prompt={S} tokens")
    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": tokens})
    jax.block_until_ready(cache)
    print(f" full prefill (compile+run): {time.perf_counter()-t0:6.2f}s")
    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": tokens})
    jax.block_until_ready(cache)
    t_full = time.perf_counter() - t0
    print(f" full prefill (warm)       : {t_full:6.2f}s")

    rng = np.random.default_rng(0)
    cur = tokens
    for edit in range(args.edits):
        # edit a token in the last eighth of the prompt (the common case)
        pos = int(rng.integers(S - S // 8, S))
        new = cur.at[:, pos].set(int(rng.integers(cfg.vocab_size)))
        t0 = time.perf_counter()
        logits_inc, cache, info = incremental_prefill(
            model, params, cur, new, cache, block=512, impl="blocked")
        jax.block_until_ready(cache)
        dt = time.perf_counter() - t0
        cur = new
        print(f" edit @{pos:5d}: recompute {info['recompute']:5d}/{S} "
              f"positions ({info['savings']:5.1f}x fewer)  "
              f"propagate: {dt:5.2f}s  vs full {t_full:5.2f}s  "
              f"({t_full/dt:4.1f}x wall)")

    # verify against from-scratch prefill on the final prompt
    logits_full, cache_full = prefill(params, {"tokens": cur})
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        cache_full, cache)))
    print(f" cache max|diff| vs from-scratch: {err:.2e}  "
          f"({'exact' if err == 0 else 'cache-dtype rounding'})")


if __name__ == "__main__":
    main()
