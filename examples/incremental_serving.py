"""Incremental serving: change propagation through the prefill path.

Scenario: a long prompt is prefilled once; the user then edits a few
late tokens (revised instruction, updated retrieval chunk).  Instead of
re-running prefill from scratch, ``incremental_prefill`` re-executes only
the positions the edit can affect and patches the KV cache in place —
the serving-side realization of the paper's change propagation.

  PYTHONPATH=src python examples/incremental_serving.py [--arch yi_6b]
      [--seq 4096] [--edits 3]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.jaxsac import incremental_prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--edits", type=int, default=3)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 1, args.seq
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, impl="blocked"))
    print(f"arch={cfg.name} (smoke config)  prompt={S} tokens")
    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": tokens})
    jax.block_until_ready(cache)
    print(f" full prefill (compile+run): {time.perf_counter()-t0:6.2f}s")
    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": tokens})
    jax.block_until_ready(cache)
    t_full = time.perf_counter() - t0
    print(f" full prefill (warm)       : {t_full:6.2f}s")

    rng = np.random.default_rng(0)
    cur = tokens
    for edit in range(args.edits):
        # edit a token in the last eighth of the prompt (the common case)
        pos = int(rng.integers(S - S // 8, S))
        new = cur.at[:, pos].set(int(rng.integers(cfg.vocab_size)))
        t0 = time.perf_counter()
        logits_inc, cache, info = incremental_prefill(
            model, params, cur, new, cache, block=512, impl="blocked")
        jax.block_until_ready(cache)
        dt = time.perf_counter() - t0
        cur = new
        print(f" edit @{pos:5d}: recompute {info['recompute']:5d}/{S} "
              f"positions ({info['savings']:5.1f}x fewer)  "
              f"propagate: {dt:5.2f}s  vs full {t_full:5.2f}s  "
              f"({t_full/dt:4.1f}x wall)")

    # verify against from-scratch prefill on the final prompt
    logits_full, cache_full = prefill(params, {"tokens": cur})
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        cache_full, cache)))
    print(f" cache max|diff| vs from-scratch: {err:.2e}  "
          f"({'exact' if err == 0 else 'cache-dtype rounding'})")


if __name__ == "__main__":
    main()
