"""Logical-axis sharding: the glue between model code and meshes.

Model code annotates parameters and activations with *logical* axis names
('batch', 'embed', 'q_heads', 'experts', ...).  A rule table maps logical
axes to mesh axes per execution mode (train / prefill / decode / long
context).  This keeps every model definition mesh-agnostic: the same
forward function runs on 1 CPU device in smoke tests, a 16x16 pod, or the
2x16x16 multi-pod mesh, differing only in the active ``ShardCtx``.

Rules are *lists* so a logical axis may map to a tuple of mesh axes
(e.g. ``('batch', ('pod', 'data'))`` for cross-pod data parallelism).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "make_mesh",
    "block_mesh",
    "shard_map",
    "ShardCtx",
    "shard_ctx",
    "current_ctx",
    "constrain",
    "logical_to_pspec",
    "sharding_for",
    "tree_shardings",
    "RULES_TRAIN",
    "RULES_PREFILL",
    "RULES_DECODE",
    "rules_for_mode",
]

MeshAxes = Union[None, str, Tuple[str, ...]]
Rules = List[Tuple[str, MeshAxes]]

# ---------------------------------------------------------------------------
# Mesh construction across JAX versions.  Newer JAX exposes
# jax.sharding.AxisType and jax.make_mesh(..., axis_types=...); older
# releases have neither the enum nor the kwarg.  All our meshes want plain
# Auto axes (the default everywhere), so detect once and degrade to the
# vanilla call.
# ---------------------------------------------------------------------------
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Build a Mesh of Auto axes, portable across JAX versions."""
    if _AXIS_TYPE is not None:
        try:
            return jax.make_mesh(
                tuple(shape), tuple(axes),
                axis_types=(_AXIS_TYPE.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(tuple(shape), tuple(axes))
    devices = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return Mesh(devices, tuple(axes))


def block_mesh(shards: int, axis: str = "blocks", devices=None) -> Mesh:
    """One-axis mesh over the first ``shards`` devices — the layout the
    graph runtime shards a traced program's block axis over
    (``CompiledGraph(mesh=...)`` / ``sac ... .compile(shards=N)``).

    On a CPU-only host, expose multiple devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before
    jax import); on real accelerators the default devices are used.
    """
    devices = list(jax.devices() if devices is None else devices)
    if shards > len(devices):
        raise ValueError(
            f"block_mesh(shards={shards}) needs {shards} devices but only "
            f"{len(devices)} are visible; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={shards} before "
            f"importing jax")
    return Mesh(np.asarray(devices[:shards]), (axis,))


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Version-portable shard_map.

    Newer JAX: ``jax.shard_map(..., check_vma=)``; older releases only have
    ``jax.experimental.shard_map.shard_map(..., check_rep=)``.  The check
    flag means the same thing in both (replication/varying-manual-axes
    validation); all our call sites disable it.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check)
        except TypeError:  # top-level shard_map predates the kwarg rename
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)
    from jax.experimental.shard_map import shard_map as _esm

    return _esm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check)

# ---------------------------------------------------------------------------
# Rule tables.  'pod' only exists on the multi-pod mesh; axes not present in
# the active mesh are dropped at resolution time, so one table serves both.
# ---------------------------------------------------------------------------

# Training / prefill: data parallelism over ('pod','data'); tensor
# parallelism over 'model' for heads / mlp / vocab / experts; parameters
# additionally ZeRO-sharded over 'data' on their longest replicated axis
# (handled by the optimizer partitioner, not these rules).
RULES_TRAIN: Rules = [
    ("batch", ("pod", "data")),
    ("seq", None),
    ("embed", None),
    ("q_heads", "model"),
    ("kv_heads", None),        # replicated: kv head counts < 16 for most archs
    ("head_dim", None),
    ("mlp", "model"),
    ("vocab", "model"),
    ("experts", "model"),
    ("expert_mlp", None),
    ("layers", None),
    ("qlora", None),
    ("kvlora", None),
    ("rnn", "model"),
    ("state", None),
    ("conv", None),
    ("frames", None),
    ("patches", None),
    ("zero", ("pod", "data")),  # ZeRO/FSDP shard axis (param/opt storage)
]

# Prefill shares training rules but hands the produced KV cache off in the
# decode layout (sequence-sharded over 'model').
RULES_PREFILL: Rules = RULES_TRAIN + [("cache_seq", "model")]

# Decode: KV caches are sharded along *sequence* over 'model'
# (flash-decoding with log-sum-exp combining), batch over ('pod','data').
RULES_DECODE: Rules = [
    ("batch", ("pod", "data")),
    ("seq", None),
    ("cache_seq", "model"),
    ("embed", None),
    ("q_heads", "model"),
    ("kv_heads", None),
    ("head_dim", None),
    ("mlp", "model"),
    ("vocab", "model"),
    ("experts", "model"),
    ("expert_mlp", None),
    ("layers", None),
    ("qlora", None),
    ("kvlora", None),
    ("rnn", "model"),
    ("state", None),
    ("conv", None),
    ("frames", None),
    ("patches", None),
    ("zero", ("pod", "data")),
]


def rules_for_mode(mode: str) -> Rules:
    return {
        "train": RULES_TRAIN,
        "prefill": RULES_PREFILL,
        "decode": RULES_DECODE,
    }[mode]


# ---------------------------------------------------------------------------
# Context
# ---------------------------------------------------------------------------
class ShardCtx:
    def __init__(self, mesh: Mesh, rules: Rules):
        self.mesh = mesh
        self.rules = dict(rules)
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def resolve(
        self,
        logical: Sequence[Optional[str]],
        shape: Optional[Sequence[int]] = None,
    ) -> P:
        """Map logical axis names to a PartitionSpec under this mesh.

        When ``shape`` is given, mesh axes that do not evenly divide the
        corresponding dimension are dropped (longest dividing prefix of the
        target tuple wins) — explicit jit shardings must divide evenly, and
        this is where awkward head counts (36, 56) fall back to replication
        (recorded as a roofline finding, see EXPERIMENTS.md §Perf)."""
        spec = []
        used: set = set()
        for i, ax in enumerate(logical):
            if ax is None:
                spec.append(None)
                continue
            target = self.rules.get(ax, None)
            if target is None:
                spec.append(None)
                continue
            if isinstance(target, str):
                target = (target,)
            # Drop mesh axes that don't exist on this mesh (e.g. 'pod' on the
            # single-pod mesh) or were already consumed by an earlier dim.
            kept = tuple(
                t for t in target if t in self.axis_sizes and t not in used
            )
            if shape is not None and kept:
                dim = shape[i]
                while kept:
                    size = 1
                    for t in kept:
                        size *= self.axis_sizes[t]
                    if dim % size == 0:
                        break
                    kept = kept[:-1]  # try shorter prefix
            used.update(kept)
            if not kept:
                spec.append(None)
            elif len(kept) == 1:
                spec.append(kept[0])
            else:
                spec.append(kept)
        return P(*spec)


_tls = threading.local()


@contextlib.contextmanager
def shard_ctx(mesh: Mesh, rules: Rules):
    prev = getattr(_tls, "ctx", None)
    _tls.ctx = ShardCtx(mesh, rules)
    try:
        yield _tls.ctx
    finally:
        _tls.ctx = prev


def current_ctx() -> Optional[ShardCtx]:
    return getattr(_tls, "ctx", None)


# ---------------------------------------------------------------------------
# Annotation helpers
# ---------------------------------------------------------------------------
def constrain(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Apply a sharding constraint given logical axes; no-op w/o context."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = ctx.resolve(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def logical_to_pspec(logical: Sequence[Optional[str]], ctx: Optional[ShardCtx] = None) -> P:
    ctx = ctx or current_ctx()
    if ctx is None:
        return P()
    return ctx.resolve(logical)


def sharding_for(logical: Sequence[Optional[str]], ctx: Optional[ShardCtx] = None) -> Optional[NamedSharding]:
    ctx = ctx or current_ctx()
    if ctx is None:
        return None
    return NamedSharding(ctx.mesh, ctx.resolve(logical))


def tree_shardings(axes_tree: Any, ctx: Optional[ShardCtx] = None) -> Any:
    """Map a pytree of logical-axes tuples to a pytree of NamedShardings."""
    ctx = ctx or current_ctx()
    if ctx is None:
        raise RuntimeError("tree_shardings requires an active ShardCtx")
    return jax.tree.map(
        lambda axes: NamedSharding(ctx.mesh, ctx.resolve(axes)),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(a, (str, type(None))) for a in x),
    )


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m
