"""Phi-3-mini 3.8B [arXiv:2404.14219].

Dense decoder: 32L, d_model 3072, 32 heads (MHA: kv=32), d_ff 8192,
vocab 32064, RoPE + SwiGLU.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32_064,
    activation="silu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    grad_accum=4,
)

SMOKE = CONFIG.replace(
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=384,
    vocab_size=512,
    param_dtype="float32",
    compute_dtype="float32",
    cache_dtype="float32",
    remat="none",
    grad_accum=1,
)
