"""InternVL2-2B [arXiv:2404.16821; hf OpenGVLab/InternVL2-2B].

VLM: InternViT vision frontend (STUBBED — input_specs() provides
precomputed patch embeddings [B, 256, 1024]) + InternLM2-1.8B language
backbone: 24L, d_model 2048, 16 heads (kv=8), d_ff 8192, vocab 92553.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    attention="gqa",
    norm="rmsnorm",
    num_patches=256,
    rope_theta=1_000_000.0,
    grad_accum=2,
)

SMOKE = CONFIG.replace(
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=384,
    vocab_size=512,
    num_patches=16,
    param_dtype="float32",
    compute_dtype="float32",
    cache_dtype="float32",
    remat="none",
    grad_accum=1,
)
