"""Mamba2-370M [arXiv:2405.21060].

Attention-free SSM (SSD / state-space duality): 48 layers, d_model 1024,
ssm_state 128, head_dim 64, expand 2 (d_inner 2048 => 32 heads),
vocab 50280.  Sub-quadratic: runs the ``long_500k`` shape with an
O(1)-per-token state.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=32,       # d_inner / ssm_head_dim
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    attention="none",
    norm="rmsnorm",
    tie_embeddings=True,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    ssm_conv_width=4,
    grad_accum=4,   # SSD intra-chunk (Q x Q) fp32 temps at 65k tok/dev don't fit
)

SMOKE = CONFIG.replace(
    num_layers=4,
    d_model=128,
    num_heads=4,        # (128*2)/64
    vocab_size=512,
    ssm_state=32,
    ssm_head_dim=64,
    ssm_chunk=32,
    param_dtype="float32",
    compute_dtype="float32",
    cache_dtype="float32",
    remat="none",
)
