"""DeepSeek-V3 671B [arXiv:2412.19437; hf deepseek-ai/DeepSeek-V3].

MoE decoder with MLA: 61 layers (first 3 dense with d_ff 18432), MoE
layers use 256 routed experts (top-8, sigmoid router) + 1 shared expert,
expert d_ff 2048, d_model 7168, 128 heads (MLA: q_lora 1536, kv_lora 512,
qk_nope 128, qk_rope 64, v 128), vocab 129280, MTP depth 1.

Training at this scale needs ZeRO-sharded optimizer state + activation
remat + gradient accumulation; see EXPERIMENTS.md §Dry-run for the
per-device memory budget.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=2048,            # routed-expert FFN width
    d_ff_dense=18432,     # dense-layer FFN width
    vocab_size=129_280,
    attention="mla",
    norm="rmsnorm",
    moe_experts=256,
    moe_top_k=8,
    moe_shared_experts=1,
    moe_dense_layers=3,
    moe_router="sigmoid",
    moe_capacity_factor=1.25,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mtp_depth=1,
    rope_theta=10_000.0,
    optimizer="adafactor",    # 671B: even bf16 AdamW moments consume the
                              # entire v5e HBM on one pod (6 B/param = 15.7
                              # GiB/chip); factored second moment is the
                              # only single-pod-trainable configuration.
    grad_accum=16,
)

SMOKE = CONFIG.replace(
    num_layers=5,          # 2 dense + 3 MoE
    moe_dense_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=96,
    d_ff_dense=384,
    vocab_size=512,
    moe_experts=8,
    moe_top_k=2,
    q_lora_rank=64,
    kv_lora_rank=32,
    qk_nope_dim=32,
    qk_rope_dim=16,
    v_head_dim=32,
    mtp_depth=1,
    param_dtype="float32",
    compute_dtype="float32",
    cache_dtype="float32",
    remat="none",
    grad_accum=1,
)
