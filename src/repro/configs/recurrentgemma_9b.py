"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427].

Hybrid: RG-LRU recurrent blocks and local (sliding-window 2048) MQA
attention in a 2:1 pattern — block pattern (rec, rec, attn).  38 layers,
d_model 4096, 16 heads with kv=1 (MQA), head_dim 256, d_ff 12288,
vocab 256000.  38 = 12 * (rec,rec,attn) + 2 tail rec layers.

Sub-quadratic: runs the ``long_500k`` shape (recurrent state + bounded
attention window; memory does not grow with context).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    activation="gelu",
    norm="rmsnorm",
    tie_embeddings=True,
    emb_scale_sqrt_dim=True,
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    conv_width=4,
    rope_theta=10_000.0,
    grad_accum=4,
)

SMOKE = CONFIG.replace(
    num_layers=8,  # 2 groups + 2 tail rec
    d_model=128,
    num_heads=4,
    num_kv_heads=1,
    head_dim=32,
    d_ff=384,
    vocab_size=512,
    local_window=64,
    param_dtype="float32",
    compute_dtype="float32",
    cache_dtype="float32",
    remat="none",
    grad_accum=1,
)
