"""SeamlessM4T-large-v2 backbone [arXiv:2308.11596; hf facebook/seamless-m4t-v2-large].

Encoder-decoder transformer backbone: 24 encoder + 24 decoder layers,
d_model 1024, 16 heads (kv=16), d_ff 8192, vocab 256206, LayerNorm.
The speech/audio frontend is a STUB: input_specs() provides precomputed
frame embeddings [B, S_enc, d_model] (assignment spec: modality frontends
are out of scope).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,
    enc_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    activation="gelu",
    norm="layernorm",
    rope_theta=10_000.0,
    grad_accum=2,
)

SMOKE = CONFIG.replace(
    num_layers=3,
    enc_layers=3,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=384,
    vocab_size=512,
    param_dtype="float32",
    compute_dtype="float32",
    cache_dtype="float32",
    remat="none",
    grad_accum=1,
)
