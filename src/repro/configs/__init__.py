"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full (paper-exact) config;
``get_smoke_config(name)`` returns the reduced same-family config used by
CPU smoke tests (small widths/depths/experts, real code paths).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig

ARCHS: List[str] = [
    "minicpm_2b",
    "yi_6b",
    "phi3_mini_3_8b",
    "gemma_7b",
    "recurrentgemma_9b",
    "seamless_m4t_large_v2",
    "mamba2_370m",
    "deepseek_v3_671b",
    "arctic_480b",
    "internvl2_2b",
]

_ALIASES = {
    "minicpm-2b": "minicpm_2b",
    "yi-6b": "yi_6b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "gemma-7b": "gemma_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mamba2-370m": "mamba2_370m",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "arctic-480b": "arctic_480b",
    "internvl2-2b": "internvl2_2b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name)


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(name)}", __package__)
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(name)}", __package__)
    return mod.SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
