"""Yi-6B [arXiv:2403.04652; hf 01-ai/Yi-6B].

Llama-architecture GQA decoder: 32L, d_model 4096, 32 heads, 4 kv heads,
d_ff 11008, vocab 64000, RoPE theta 5e6 (Yi uses long-base RoPE).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64_000,
    activation="silu",
    norm="rmsnorm",
    rope_theta=5_000_000.0,
    grad_accum=4,
)

SMOKE = CONFIG.replace(
    num_layers=4,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=344,
    vocab_size=512,
    param_dtype="float32",
    compute_dtype="float32",
    cache_dtype="float32",
    remat="none",
    grad_accum=1,
)
