"""Snowflake Arctic 480B [hf Snowflake/snowflake-arctic-base].

Dense-MoE hybrid: every layer combines a GQA attention block with a dense
residual FFN *in parallel* with a 128-expert top-2 MoE FFN.  35 layers,
d_model 7168, 56 heads (kv=8), expert d_ff 4864, vocab 32000.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    attention="gqa",
    norm="rmsnorm",
    moe_experts=128,
    moe_top_k=2,
    moe_dense_residual=True,
    moe_router="softmax",
    moe_capacity_factor=1.25,
    rope_theta=10_000.0,
    optimizer="adafactor",    # 480B: see deepseek note — factored moments
    grad_accum=8,
)

SMOKE = CONFIG.replace(
    num_layers=3,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=96,
    vocab_size=512,
    moe_experts=8,
    moe_top_k=2,
    param_dtype="float32",
    compute_dtype="float32",
    cache_dtype="float32",
    remat="none",
    grad_accum=1,
)
