"""MiniCPM-2B [arXiv:2404.06395; hf openbmb/MiniCPM-2B].

Dense llama-like decoder: 40L, d_model 2304, 36 heads (MHA: kv=36),
d_ff 5760, vocab 122753.  MiniCPM specifics: mu-parameterized scaling
(scale_emb=12, scale_depth=1.4 => residual scale 1.4/sqrt(40)), tied
embeddings with logits divided by d_model/256, and the WSD learning-rate
schedule (warmup-stable-decay) for training.
"""
import math

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    activation="silu",
    norm="rmsnorm",
    tie_embeddings=True,
    emb_scale=12.0,
    residual_scale=1.4 / math.sqrt(40),
    rope_theta=10_000.0,
    lr_schedule="wsd",
    grad_accum=4,
)

SMOKE = CONFIG.replace(
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=352,
    vocab_size=512,
    residual_scale=1.4 / math.sqrt(4),
    param_dtype="float32",
    compute_dtype="float32",
    cache_dtype="float32",
    remat="none",
    grad_accum=1,
)
