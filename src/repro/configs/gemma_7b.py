"""Gemma-7B [arXiv:2403.08295; hf google/gemma-7b].

Dense decoder: 28L, d_model 3072, 16 heads with head_dim 256 (attention
width 4096 != d_model), kv=16, GeGLU d_ff 24576, vocab 256000.  Gemma
scales embeddings by sqrt(d_model) and uses (1+scale) RMSNorm; embeddings
are tied.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256_000,
    activation="gelu",
    norm="rmsnorm",
    tie_embeddings=True,
    emb_scale_sqrt_dim=True,
    rope_theta=10_000.0,
    grad_accum=4,
)

SMOKE = CONFIG.replace(
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    param_dtype="float32",
    compute_dtype="float32",
    cache_dtype="float32",
    remat="none",
    grad_accum=1,
)
