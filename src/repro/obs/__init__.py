"""Propagation telemetry: records, capture modes, exporters.

One update on any substrate — jitted graph, host engine, hybrid
fragments, mesh-sharded — yields one ``PropagationRecord``: phase
timings (mark, plan freeze, execute), per-level dirty/recomputed
counts with the regime each node ran under, plan-cache hit/miss, and
(under a mesh) the per-edge-kind collective tally.  Capture is opt-in
via ``compile(trace=...)``:

  * ``trace="counters"`` — near-zero overhead: host timestamps only at
    sync points the planned propagate already has (the one mark-counts
    read), device counters harvested lazily.  The sync-point rule —
    counters mode adds ZERO host syncs to the planned path — is
    enforced by test through ``syncpoints.py``'s monkeypatchable hook.
  * ``trace="deep"`` — per-level executables fenced between levels
    (real per-level wall-clock) wrapped in ``jax.profiler``
    TraceAnnotations, so an XLA profile lines up with SP-dag structure.

Consumers: ``chrometrace.chrome_trace`` (Perfetto-loadable JSON, also
``handle.profile()``), ``metrics.MetricRegistry`` (counters /
histograms / bounded event log with a JSONL sink — also the supervisor
path), the recorder's bounded flight ring, and the per-level
attribution report (``python -m benchmarks.report``).
"""
from .chrometrace import chrome_trace, write_chrome_trace
from .metrics import (Counter, EventLog, Histogram, JsonlSink,
                      MetricRegistry)
from .record import LevelRecord, PhaseSpan, PropagationRecord, merge_records
from .recorder import PropagationRecorder, TraceMethods

__all__ = [
    "PropagationRecord", "LevelRecord", "PhaseSpan", "merge_records",
    "PropagationRecorder", "TraceMethods",
    "chrome_trace", "write_chrome_trace",
    "MetricRegistry", "Counter", "Histogram", "EventLog", "JsonlSink",
]
