"""Counter/histogram registry + bounded event log + JSONL sink.

The unified metrics layer: propagation recorders, the training
supervisor (straggler / checkpoint / restart events), and the future
serving layer's p50/p99 hooks all write through one ``MetricRegistry``
so a process has a single place to scrape.  Everything is plain host
Python — observing a metric never touches the device.

``JsonlSink`` streams events (and final snapshots) as one JSON object
per line; attach it to a registry to get a durable event log without
holding records in memory.
"""
from __future__ import annotations

import json
import math
from collections import deque
from typing import Any, Dict, IO, List, Optional, Union

__all__ = ["Counter", "Histogram", "EventLog", "MetricRegistry",
           "JsonlSink"]


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Histogram:
    """Sampled distribution: count/sum/min/max exact, percentiles from
    a bounded sample window (last ``window`` observations)."""

    __slots__ = ("name", "count", "total", "min", "max", "_samples")

    def __init__(self, name: str, window: int = 4096):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._samples: deque = deque(maxlen=window)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self._samples.append(v)

    def percentile(self, p: float) -> float:
        """p in [0, 100], over the sample window; NaN when empty."""
        if not self._samples:
            return math.nan
        s = sorted(self._samples)
        i = min(len(s) - 1, max(0, round(p / 100 * (len(s) - 1))))
        return s[i]

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def snapshot(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.total,
                "min": self.min if self.count else math.nan,
                "max": self.max if self.count else math.nan,
                "p50": self.percentile(50), "p99": self.percentile(99)}


class EventLog:
    """Bounded structured event log (newest-kept ring)."""

    def __init__(self, cap: int = 1024):
        self._events: deque = deque(maxlen=cap)

    def emit(self, event: str, **fields) -> Dict[str, Any]:
        e = {"event": event, **fields}
        self._events.append(e)
        return e

    def events(self, event: Optional[str] = None) -> List[Dict[str, Any]]:
        if event is None:
            return list(self._events)
        return [e for e in self._events if e["event"] == event]

    def __len__(self) -> int:
        return len(self._events)


class JsonlSink:
    """One JSON object per line, flushed per write."""

    def __init__(self, target: Union[str, IO]):
        if hasattr(target, "write"):
            self._fh, self._own = target, False
        else:
            self._fh, self._own = open(target, "a"), True

    def write(self, obj: Dict[str, Any]) -> None:
        self._fh.write(json.dumps(obj) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._own:
            self._fh.close()


class MetricRegistry:
    """Named counters + histograms + one event log, with an optional
    JSONL sink that sees every event as it is emitted."""

    def __init__(self, event_cap: int = 1024, sink: Optional[JsonlSink] = None):
        self.counters: Dict[str, Counter] = {}
        self.histograms: Dict[str, Histogram] = {}
        self.log = EventLog(cap=event_cap)
        self.sink = sink

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name)
        return h

    def event(self, event: str, **fields) -> None:
        e = self.log.emit(event, **fields)
        if self.sink is not None:
            self.sink.write(e)

    def events(self, event: Optional[str] = None) -> List[Dict[str, Any]]:
        return self.log.events(event)

    def snapshot(self) -> Dict[str, Any]:
        return {
            "counters": {k: c.value for k, c in self.counters.items()},
            "histograms": {k: h.snapshot()
                           for k, h in self.histograms.items()},
            "events": len(self.log),
        }
