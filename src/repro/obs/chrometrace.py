"""Chrome-trace / Perfetto export of propagation records.

``chrome_trace(records)`` renders records as the Chrome trace event
format (the ``traceEvents`` JSON that chrome://tracing and Perfetto
load): one complete ("ph": "X") event per phase and per level, rows
(tids) per record — a hybrid record's fragments get their own rows
under the parent.  Timestamps are microseconds relative to the
earliest record; level events without fenced timings (counters mode)
render as zero-duration markers laid out in level order inside the
execute phase, so the structure stays readable even when only deep
mode pays for real per-level wall-clock.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

from .record import PropagationRecord

__all__ = ["chrome_trace", "write_chrome_trace"]


def _rows(records: List[PropagationRecord]):
    """Flatten records into display rows: each record, then its
    fragment children."""
    rows = []
    for r in records:
        rows.append((f"{r.substrate}#{r.seq}", r))
        for fi, fr in enumerate(r.fragments):
            rows.append((f"{r.substrate}#{r.seq}/f{fi}", fr))
    return rows


def chrome_trace(records: List[PropagationRecord]) -> Dict[str, Any]:
    records = [r.finalize() for r in records]
    rows = _rows(records)
    base = min((r.t_start for _, r in rows), default=0.0)

    def us(t: float) -> float:
        return round((t - base) * 1e6, 3)

    meta: List[Dict[str, Any]] = []
    events: List[Dict[str, Any]] = []
    for tid, (label, rec) in enumerate(rows, start=1):
        meta.append({"ph": "M", "name": "thread_name", "pid": 1,
                     "tid": tid, "args": {"name": label}})
        exec_t0 = rec.t_start
        for ph in rec.phases:
            events.append({
                "name": ph.name, "cat": rec.substrate, "ph": "X",
                "ts": us(ph.t0), "dur": round(ph.dur * 1e6, 3),
                "pid": 1, "tid": tid,
                "args": {"mode": rec.mode, "fenced": rec.fenced}})
            if ph.name == "execute":
                exec_t0 = ph.t0
        t = exec_t0
        for lv in rec.levels:
            if lv.fragment is not None:
                continue                 # rendered on the fragment row
            dur = (lv.ms or 0.0) * 1e-3
            events.append({
                "name": f"L{lv.level}", "cat": "level", "ph": "X",
                "ts": us(t), "dur": round(dur * 1e6, 3),
                "pid": 1, "tid": tid,
                "args": {"nodes": lv.nodes, "regimes": lv.regimes,
                         "dirty": lv.dirty, "recomputed": lv.recomputed,
                         "affected": lv.affected}})
            # Unfenced levels have no measured extent: lay them out as
            # 1us markers so ts stays strictly increasing per row.
            t += dur if dur > 0 else 1e-6
    events.sort(key=lambda e: e["ts"])
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def write_chrome_trace(trace: Dict[str, Any], path: str) -> str:
    with open(path, "w") as fh:
        json.dump(trace, fh, indent=1)
    return path
