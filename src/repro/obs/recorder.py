"""PropagationRecorder: flight ring + metrics bridge + handle mixin.

A recorder is attached to a compiled handle (``compile(trace=...)``)
and collects one ``PropagationRecord`` per update into a bounded ring
(the flight recorder: the last N updates are always dumpable, e.g.
from a failure handler).  Emission also feeds the recorder's
``MetricRegistry`` — propagate count, plan-cache hit/miss counters,
and a wall-clock histogram — using only host-known values, so emitting
never syncs with the device.

``TraceMethods`` is the facade mixin every backend handle inherits:
``.record`` (last update, finalized), ``.records()``, and
``.profile(edits) -> chrome trace`` which forces one deep-mode update
regardless of how the handle was compiled.
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Any, Dict, List, Optional

from .metrics import MetricRegistry
from .record import PropagationRecord

__all__ = ["PropagationRecorder", "TraceMethods", "regime_label"]

MODES = ("counters", "deep")


def regime_label(p) -> str:
    """Human label of one node's frozen plan entry."""
    if isinstance(p, tuple):
        return f"sparse({p[1]})"
    return str(p)


class PropagationRecorder:
    """Collects per-propagate records; see module docstring."""

    def __init__(self, mode: str = "counters", flight: int = 64,
                 registry: Optional[MetricRegistry] = None):
        assert mode in MODES, f"trace mode {mode!r} (expected {MODES})"
        self.mode = mode
        self.registry = registry if registry is not None else MetricRegistry()
        self._ring: deque = deque(maxlen=flight if flight else None)
        self._seq = 0

    # host wall clock; records and phase spans all use this one
    clock = staticmethod(time.perf_counter)

    def next_seq(self) -> int:
        s = self._seq
        self._seq += 1
        return s

    def emit(self, record: PropagationRecord) -> PropagationRecord:
        self._ring.append(record)
        reg = self.registry
        reg.counter("propagates").inc()
        reg.histogram(f"propagate_ms.{record.substrate}").observe(
            record.duration_ms)
        pc = record.plan_cache
        if pc is not None and "hits" in pc:
            # snapshot counters are cumulative; keep registry gauges in
            # step by overwriting instead of accumulating deltas
            reg.counter("plan_cache.hits").value = int(pc["hits"])
            reg.counter("plan_cache.misses").value = int(pc["misses"])
        return record

    # ------------------------------------------------------------------
    @property
    def last(self) -> Optional[PropagationRecord]:
        return self._ring[-1] if self._ring else None

    def records(self) -> List[PropagationRecord]:
        return list(self._ring)

    def drain(self) -> List[PropagationRecord]:
        out = list(self._ring)
        self._ring.clear()
        return out

    def clear(self) -> None:
        self._ring.clear()

    def dump(self, path: Optional[str] = None) -> List[Dict[str, Any]]:
        """Flight-recorder dump: the ring as plain dicts (finalized);
        written as JSON when ``path`` is given."""
        out = [r.to_dict() for r in self._ring]
        if path is not None:
            with open(path, "w") as fh:
                json.dump(out, fh, indent=2)
        return out


class TraceMethods:
    """Record/profile facade shared by GraphHandle / HostHandle /
    HybridHandle.  Handles implement ``_attach_recorder``."""

    _recorder: Optional[PropagationRecorder] = None

    def _attach_recorder(self, rec: Optional[PropagationRecorder]) -> None:
        self._recorder = rec

    @property
    def recorder(self) -> Optional[PropagationRecorder]:
        return self._recorder

    @property
    def record(self) -> Optional[PropagationRecord]:
        """The last update's record (finalized), or None."""
        r = self._recorder
        if r is None or r.last is None:
            return None
        return r.last.finalize()

    def records(self) -> List[PropagationRecord]:
        r = self._recorder
        return [x.finalize() for x in r.records()] if r is not None else []

    def profile(self, inputs: Optional[Dict[str, Any]] = None,
                path: Optional[str] = None, **changed) -> Dict[str, Any]:
        """Run ONE update in deep mode (fenced per-level timings) and
        return its Chrome-trace dict — Perfetto/chrome://tracing
        loadable — writing it to ``path`` when given.  Works on any
        handle; a handle compiled without ``trace=`` gets a temporary
        recorder for the call."""
        from .chrometrace import chrome_trace, write_chrome_trace

        rec, temp = self._recorder, False
        if rec is None:
            rec = PropagationRecorder(mode="deep", flight=4)
            self._attach_recorder(rec)
            temp = True
        old_mode, rec.mode = rec.mode, "deep"
        try:
            self.update(inputs or {}, **changed)
        finally:
            rec.mode = old_mode
            if temp:
                self._attach_recorder(None)
        assert rec.last is not None, "profile(): update emitted no record"
        trace = chrome_trace([rec.last])
        if path is not None:
            write_chrome_trace(trace, path)
        return trace
