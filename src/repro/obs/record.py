"""The per-propagate record schema, shared by every substrate.

One ``PropagationRecord`` per update: wall-clock phases, per-level
counts + regime labels, substrate counters, plan-cache state, and —
under a mesh — the static per-edge-kind collective tally.  The graph
backend fills levels from the frozen plan and the mark counts; the
host backend from its reader re-execution counts; the hybrid backend
merges one record per executed fragment into a single parent record
(``merge_records``), so a consumer sees one record per update
regardless of backend.

Counters may arrive as device scalars (counters mode must not sync);
``finalize()`` materializes them — and distributes the per-level
``rec_per_level`` / ``aff_per_level`` vectors into the level records —
the first time a consumer actually reads the record.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

__all__ = ["PhaseSpan", "LevelRecord", "PropagationRecord",
           "merge_records"]


@dataclasses.dataclass
class PhaseSpan:
    """One timed phase; ``t0`` is seconds on the recorder clock."""

    name: str
    t0: float
    dur: float


@dataclasses.dataclass
class LevelRecord:
    """One dag level of one propagate."""

    level: int
    nodes: int                          # op nodes scheduled in the level
    regimes: Dict[str, int]             # regime label -> node count
    dirty: Optional[int] = None         # mark-pass dirty upper bound
    recomputed: Optional[int] = None    # realized recomputed blocks
    affected: Optional[int] = None      # post-cutoff changed blocks
    ms: Optional[float] = None          # fenced wall-clock (deep mode)
    fragment: Optional[str] = None      # hybrid: owning fragment


def _conv(v):
    if hasattr(v, "dtype") or isinstance(v, np.ndarray):
        a = np.asarray(v)
        return a.item() if a.ndim == 0 else a.tolist()
    if isinstance(v, dict):
        return {k: _conv(x) for k, x in v.items()}
    return v


@dataclasses.dataclass
class PropagationRecord:
    """One update's telemetry (see module docstring)."""

    substrate: str                      # "graph" | "host" | "hybrid"
    seq: int                            # recorder-local sequence number
    mode: str                           # "counters" | "deep"
    t_start: float
    phases: List[PhaseSpan] = dataclasses.field(default_factory=list)
    levels: List[LevelRecord] = dataclasses.field(default_factory=list)
    counters: Dict[str, Any] = dataclasses.field(default_factory=dict)
    plan_cache: Optional[Dict[str, Any]] = None
    collectives: Optional[Dict[str, Dict[str, int]]] = None
    shards: int = 1
    fenced: bool = False                # were phase/level timings fenced?
    fragments: List["PropagationRecord"] = dataclasses.field(
        default_factory=list)
    _final: bool = dataclasses.field(default=False, repr=False)

    # ------------------------------------------------------------------
    @property
    def duration_ms(self) -> float:
        if not self.phases:
            return 0.0
        end = max(p.t0 + p.dur for p in self.phases)
        return (end - self.t_start) * 1e3

    def finalize(self) -> "PropagationRecord":
        """Materialize device-resident counters (this is where a
        counters-mode record finally syncs — on read, not on update)."""
        if self._final:
            return self
        self.counters = {k: _conv(v) for k, v in self.counters.items()}
        rpl = self.counters.get("rec_per_level")
        apl = self.counters.get("aff_per_level")
        for lv in self.levels:
            if lv.fragment is None:     # merged levels were finalized
                if rpl is not None and lv.level < len(rpl):
                    lv.recomputed = int(rpl[lv.level])
                if apl is not None and lv.level < len(apl):
                    lv.affected = int(apl[lv.level])
        for fr in self.fragments:
            fr.finalize()
        self._final = True
        return self

    def to_dict(self) -> Dict[str, Any]:
        self.finalize()
        d = dataclasses.asdict(self)
        d.pop("_final", None)
        for fr in d["fragments"]:
            fr.pop("_final", None)
        return d


def merge_records(children: List[PropagationRecord], *, substrate: str,
                  seq: int, mode: str, t_start: float,
                  phases: Optional[List[PhaseSpan]] = None,
                  plan_cache: Optional[Dict[str, Any]] = None,
                  ) -> PropagationRecord:
    """Fold per-fragment records into one parent record: counters
    summed, levels concatenated with their fragment tag, children kept
    under ``fragments`` for drill-down."""
    counters: Dict[str, Any] = {}
    levels: List[LevelRecord] = []
    coll: Dict[str, Dict[str, int]] = {}
    for fi, ch in enumerate(children):
        ch.finalize()
        tag = f"f{fi}"
        for k, v in ch.counters.items():
            if isinstance(v, (int, float)):
                counters[k] = counters.get(k, 0) + v
        for lv in ch.levels:
            levels.append(dataclasses.replace(lv, fragment=tag))
        for ph, ops in (ch.collectives or {}).items():
            dst = coll.setdefault(ph, {})
            for op, n in ops.items():
                dst[op] = dst.get(op, 0) + n
    return PropagationRecord(
        substrate=substrate, seq=seq, mode=mode, t_start=t_start,
        phases=list(phases or []), levels=levels, counters=counters,
        plan_cache=plan_cache, collectives=coll or None,
        shards=max([c.shards for c in children], default=1),
        fenced=all(c.fenced for c in children) if children else False,
        fragments=list(children))
