"""Host sync points, centralized and countable.

The planned propagate makes exactly ONE host read per update — the
mark-counts transfer that freezes the plan.  That invariant is the
latency model's foundation (DESIGN.md §Propagation-cost-model), so
every host sync the runtime performs is routed through this module:
``host_read`` for device->host transfers, ``fence`` for
``block_until_ready`` barriers.  Tests install ``HOOK`` and assert the
call count is identical with tracing off and with ``trace="counters"``
— the sync-point rule ("counters mode adds no new host syncs") held by
construction AND by measurement.

``trace="deep"`` fences on purpose (per-level wall-clock needs a
barrier per level); those fences go through here too, tagged, so a
profile shows exactly where the mode paid for its timings.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import numpy as np

__all__ = ["host_read", "fence", "HOOK"]

# Test/diagnostic hook: called as HOOK(tag, kind) before every sync,
# kind in {"host_read", "fence"}.  None (the default) costs one global
# load per sync — nothing on the no-sync path.
HOOK: Optional[Callable[[str, str], None]] = None


def host_read(x, tag: str) -> np.ndarray:
    """Device->host transfer (blocks on ``x``)."""
    if HOOK is not None:
        HOOK(tag, "host_read")
    return np.asarray(x)


def fence(x, tag: str):
    """Barrier: block until every leaf of ``x`` is computed."""
    if HOOK is not None:
        HOOK(tag, "fence")
    return jax.block_until_ready(x)
