"""Optimizer interface + gradient utilities."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "clip_by_global_norm", "make_optimizer", "global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """init(params) -> state;  update(grads, state, params, step, lr) ->
    (new_params, new_state).  All pure; states are pytrees mirroring params
    so sharding rules apply leaf-wise."""

    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array, jax.Array], Tuple[Any, Any]]


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def make_optimizer(cfg) -> Optimizer:
    """Build the optimizer named by a ModelConfig."""
    from .adamw import make_adamw
    from .adafactor import make_adafactor

    name = cfg.optimizer
    if name == "adamw":
        return make_adamw(state_dtype=jnp.float32)
    if name == "adamw_bf16":
        # bf16 moments: halves optimizer memory; the update math stays fp32.
        return make_adamw(state_dtype=jnp.bfloat16)
    if name == "adafactor":
        return make_adafactor()
    raise ValueError(f"unknown optimizer {name!r}")
