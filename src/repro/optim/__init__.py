"""Optimizers and LR schedules (pure JAX, shard-friendly pytree states)."""
from .adamw import AdamWState, make_adamw
from .adafactor import AdafactorState, make_adafactor
from .schedules import make_schedule
from .base import Optimizer, clip_by_global_norm, make_optimizer

__all__ = [
    "Optimizer",
    "make_optimizer",
    "make_adamw",
    "make_adafactor",
    "make_schedule",
    "clip_by_global_norm",
    "AdamWState",
    "AdafactorState",
]
