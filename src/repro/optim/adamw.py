"""AdamW with decoupled weight decay and configurable moment dtype."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .base import Optimizer

__all__ = ["AdamWState", "make_adamw"]


class AdamWState(NamedTuple):
    m: Any
    v: Any


def make_adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    state_dtype=jnp.float32,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return AdamWState(m=jax.tree.map(zeros, params), v=jax.tree.map(zeros, params))

    def update(grads, state, params, step, lr):
        step_f = (step + 1).astype(jnp.float32)
        c1 = 1.0 - b1 ** step_f
        c2 = 1.0 - b2 ** step_f

        def leaf(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
            v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
            upd = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
            # Decoupled weight decay on matrices only (ndim >= 2).
            if p.ndim >= 2 and weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            return new_p, m32.astype(state_dtype), v32.astype(state_dtype)

        out = jax.tree.map(leaf, grads, state.m, state.v, params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, AdamWState(m=new_m, v=new_v)

    return Optimizer(init=init, update=update)
