"""Learning-rate schedules: cosine and WSD (warmup-stable-decay).

WSD (MiniCPM, arXiv:2404.06395): linear warmup, long stable plateau, then
a short sharp decay — the schedule the minicpm-2b assignment calls for.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["make_schedule"]


def make_schedule(
    kind: str,
    peak_lr: float,
    total_steps: int,
    warmup_steps: int = 0,
    final_frac: float = 0.1,
    decay_frac: float = 0.1,
):
    warmup_steps = warmup_steps or max(total_steps // 100, 10)

    if kind == "cosine":
        def sched(step):
            step = jnp.minimum(step, total_steps).astype(jnp.float32)
            warm = peak_lr * (step + 1.0) / warmup_steps
            t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
            cos = final_frac * peak_lr + (1 - final_frac) * peak_lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
            return jnp.where(step < warmup_steps, warm, cos)

        return sched

    if kind == "wsd":
        decay_steps = max(int(total_steps * decay_frac), 1)
        stable_end = total_steps - decay_steps

        def sched(step):
            step = jnp.minimum(step, total_steps).astype(jnp.float32)
            warm = peak_lr * (step + 1.0) / warmup_steps
            t = jnp.clip((step - stable_end) / decay_steps, 0, 1)
            # Exponential-style decay to final_frac over the decay window.
            dec = peak_lr * (final_frac ** t)
            out = jnp.where(step < warmup_steps, warm,
                            jnp.where(step < stable_end, peak_lr, dec))
            return out

        return sched

    if kind == "constant":
        def sched(step):
            step = jnp.asarray(step).astype(jnp.float32)
            warm = peak_lr * (step + 1.0) / warmup_steps
            return jnp.where(step < warmup_steps, warm, peak_lr)

        return sched

    raise ValueError(f"unknown schedule {kind!r}")
