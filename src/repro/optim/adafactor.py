"""Adafactor-style optimizer: factored second moment, optional first moment.

For the largest assigned models (deepseek-v3-671b, arctic-480b) full fp32
AdamW moments do not fit a single v5e pod; the factored second moment
reduces optimizer state from 2x fp32 to ~(row+col) fp32 + bf16 momentum.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .base import Optimizer

__all__ = ["AdafactorState", "make_adafactor"]


class AdafactorState(NamedTuple):
    m: Any        # bf16 momentum (or None-like zeros when disabled)
    v_row: Any    # factored second moment (rows)  — fp32
    v_col: Any    # factored second moment (cols)  — fp32
    v_full: Any   # unfactored fallback for ndim<2 leaves


def make_adafactor(
    b1: float = 0.9,
    decay: float = 0.99,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def rows(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
                    else jnp.zeros((1,), jnp.float32))

        def cols(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p) else jnp.zeros((1,), jnp.float32))

        def full(p):
            return (jnp.zeros((1,), jnp.float32) if _factored(p)
                    else jnp.zeros(p.shape, jnp.float32))

        return AdafactorState(
            m=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params),
            v_row=jax.tree.map(rows, params),
            v_col=jax.tree.map(cols, params),
            v_full=jax.tree.map(full, params),
        )

    def update(grads, state, params, step, lr):
        def leaf(g, m, vr, vc, vf, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if _factored(p):
                vr2 = decay * vr + (1 - decay) * g2.mean(axis=-1)
                vc2 = decay * vc + (1 - decay) * g2.mean(axis=-2)
                r = vr2 / jnp.maximum(vr2.mean(axis=-1, keepdims=True), eps)
                upd = g32 / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc2)[..., None, :] + 1e-8)
                vf2 = vf
            else:
                vf2 = decay * vf + (1 - decay) * g2
                upd = g32 / (jnp.sqrt(vf2) + 1e-8)
                vr2, vc2 = vr, vc
            # Update clipping (RMS <= clip_threshold).
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-12)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            m2 = (b1 * m.astype(jnp.float32) + (1 - b1) * upd)
            if p.ndim >= 2 and weight_decay:
                m2 = m2 + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * m2).astype(p.dtype)
            return new_p, m2.astype(jnp.bfloat16), vr2, vc2, vf2

        out = jax.tree.map(leaf, grads, state.m, state.v_row, state.v_col,
                           state.v_full, params)
        pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), AdafactorState(m=pick(1), v_row=pick(2),
                                       v_col=pick(3), v_full=pick(4))

    return Optimizer(init=init, update=update)
