"""Dynamic trees benchmark: randomized tree contraction (paper Table 5).

Computes the sum of node values over a rooted binary tree by Miller-Reif
style contraction: each round every leaf *rakes* into its parent, and an
independent set of unary nodes *compress* (parent adopts the grandchild,
absorbing the spliced node's accumulator).  Randomness (per-round coins)
is pregenerated so re-execution is deterministic (paper, Section 2).

Each round runs two phases, every phase a single-hop read pattern so the
RSP tree stays shallow:

  decision phase:  node i reads states[r][i] (and, when evaluating a
      compress, its parent's and child's states) and writes
      decisions[r][i] in {dead, survive, rake, compress} with payloads.
  state phase:     node i reads decisions[r][i] and its neighbors'
      decisions, and writes states[r+1][i].

Compress uses symmetric neighbor exclusion — a unary head node compresses
only if neither its parent nor its unique child is itself a compress
candidate — so the payload graph stays consistent without multi-hop reads.

A batch update (changing values or moving subtrees) re-runs O(h) readers
per changed node, h = O(log n) rounds, matching the contraction analyses
of [2] translated into this framework (Section 4).

**Hybrid mode (default)**: the per-round phases are statically shaped —
a fixed n-lane sweep whose *values* are data-dependent, with contracted
nodes encoded as dead masked lanes — so they lower onto the jitted
graph runtime as ``gather`` nodes (state rows ``[par, cl, cr, acc,
live]``, decision rows ``[kind, par, a, b, acc]``; a lane reads itself
plus its parent/child lanes, exactly the single-hop pattern above).
The whole contraction pipeline embeds in the host engine as ONE
``EngineFragment``; the data-dependent skeleton — input mods, the
full-contraction check, the result consumer — stays host readers, and
dirty sets cross the boundary in both directions (mod writes mark the
fragment reader; only value-changed output blocks are written back).
``hybrid=False`` keeps the pure host-reader program; the two produce
identical results round for round (same coins, same decisions).
"""
from __future__ import annotations

import math
import random
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["TreeContractionApp"]

# states[r][i]: None (contracted away) or (parent, left, right, acc);
# -1 encodes "no parent" / "no child".
DEAD = ("dead", None)


class TreeContractionApp:
    name = "trees"

    def __init__(self, n: int = 512, seed: int = 0, hybrid: bool = True):
        self.n = n
        self.seed = seed
        self.hybrid = hybrid
        self.rng = random.Random(seed)
        # Random rooted binary tree: node 0 is the root; each later node
        # attaches under a uniformly random node with a free child slot.
        self.parent = [-1] * n
        self.children: List[List[int]] = [[] for _ in range(n)]
        open_slots = [0]
        for i in range(1, n):
            j = self.rng.randrange(len(open_slots))
            p = open_slots[j]
            self.parent[i] = p
            self.children[p].append(i)
            if len(self.children[p]) == 2:
                open_slots[j] = open_slots[-1]
                open_slots.pop()
            open_slots.append(i)
        self.rounds = self._calibrate_rounds()
        self.coins = [
            [self.rng.random() < 0.5 for _ in range(n)]
            for _ in range(self.rounds)
        ]

    # ------------------------------------------------------------------
    def _struct(self, i: int) -> Tuple[int, int, int]:
        ch = self.children[i]
        cl = ch[0] if len(ch) > 0 else -1
        cr = ch[1] if len(ch) > 1 else -1
        return (self.parent[i], cl, cr)

    def _calibrate_rounds(self) -> int:
        """Simulate contraction on the host to size the round count; the
        static round structure must cover dynamic updates too, so pad by
        half again plus slack (tests assert full contraction)."""
        sim_rng = random.Random(0xC0175)
        par = list(self.parent)
        chs = [list(c) for c in self.children]
        live = set(range(self.n))
        rounds = 0
        while len(live) > 1 and rounds < 12 * max(4, int(math.log2(max(self.n, 2)))):
            coins = [sim_rng.random() < 0.5 for _ in range(self.n)]
            self._sim_round(par, chs, live, coins)
            rounds += 1
        return rounds + max(8, rounds // 2)

    @staticmethod
    def _sim_round(par, chs, live, coins):
        def unary(i):
            return len(chs[i]) == 1

        rakes = [i for i in live if not chs[i] and par[i] != -1]
        compresses = []
        for i in live:
            if not unary(i) or par[i] == -1 or not coins[i]:
                continue
            c = chs[i][0]
            if not chs[c]:          # child is a leaf (it rakes) — skip
                continue
            p = par[i]
            p_cand = unary(p) and par[p] != -1 and coins[p]
            c_cand = unary(c) and coins[c]
            if not p_cand and not c_cand:
                compresses.append(i)
        for i in rakes:
            chs[par[i]].remove(i)
            live.discard(i)
        for i in compresses:
            p, c = par[i], chs[i][0]
            chs[p][chs[p].index(i)] = c
            par[c] = p
            live.discard(i)

    # ------------------------------------------------------------------
    def build_input(self, eng):
        self.values = [self.rng.randrange(100) for _ in range(self.n)]
        self.val_mods = eng.alloc_array(self.n, "val")
        self.struct_mods = eng.alloc_array(self.n, "st")
        for i in range(self.n):
            eng.write(self.val_mods[i], self.values[i])
            eng.write(self.struct_mods[i], self._struct(i))
        self.result = eng.mod("total")
        return self.val_mods

    def run(self, eng):
        return eng.run(lambda: self.program(eng))

    # ------------------------------------------------------------------
    def program(self, eng):
        if self.hybrid:
            return self._program_hybrid(eng)
        return self._program_host(eng)

    # ------------------------------------------------------------------
    # Hybrid: contraction rounds as one compiled fragment
    # ------------------------------------------------------------------
    def _traced_contraction(self):
        """The statically-shaped interior: ``rounds`` decision/state
        phase pairs as ``gather`` nodes over [n, 5] int32 lanes."""
        import jax.numpy as jnp

        import repro.sac as sac

        n = self.n
        coins = [jnp.asarray(np.asarray(c, bool)) for c in self.coins]

        def init_fn(s, v):
            # s [1,3] struct, v [1] value -> [par, cl, cr, acc, live]
            return jnp.concatenate(
                [s[0], jnp.stack([v[0], jnp.int32(1)])]).astype(jnp.int32)

        def decide_idx(xb):
            s = xb[:, 0]
            i = jnp.arange(s.shape[0])
            par, cl, cr, live = s[:, 0], s[:, 1], s[:, 2], s[:, 4]
            c = jnp.where(cl != -1, cl, cr)
            pi = jnp.where((live > 0) & (par != -1), par, i)
            ci = jnp.where((live > 0) & (c != -1), c, i)
            return jnp.stack([pi, ci], axis=1)

        def decide_fn(cj):
            def fn(x, i):
                row = x[i]
                par, cl, cr, acc, live = (row[0], row[1], row[2],
                                          row[3], row[4])
                live_b = live > 0
                nk = ((cl != -1).astype(jnp.int32)
                      + (cr != -1).astype(jnp.int32))
                is_rake = live_b & (nk == 0) & (par != -1)
                c = jnp.where(cl != -1, cl, cr)
                cand = live_b & (nk == 1) & (par != -1) & cj[i]
                pi = jnp.clip(par, 0, x.shape[0] - 1)
                ci = jnp.clip(c, 0, x.shape[0] - 1)
                prow, crow = x[pi], x[ci]
                # Neighbour rows are only *used* under ``cand`` — the
                # same predicate the idx_fn uses to include them in the
                # reader set (the gather soundness contract).
                c_is_leaf = (crow[1] == -1) & (crow[2] == -1)
                p_unary = (prow[1] == -1) ^ (prow[2] == -1)
                p_cand = p_unary & (prow[0] != -1) & cj[pi]
                c_unary = (crow[1] == -1) ^ (crow[2] == -1)
                c_cand = c_unary & cj[ci]
                is_comp = cand & ~c_is_leaf & ~p_cand & ~c_cand
                kind = jnp.where(
                    ~live_b, 0,
                    jnp.where(is_rake, 2, jnp.where(is_comp, 3, 1)))
                a = jnp.where(kind == 1, cl, jnp.where(kind == 3, c, -1))
                b = jnp.where(kind == 1, cr, -1)
                return jnp.stack(
                    [kind, jnp.where(kind == 0, -1, par), a, b,
                     jnp.where(kind == 0, 0, acc)]).astype(jnp.int32)

            return fn

        def advance_idx(xb):
            d = xb[:, 0]
            i = jnp.arange(d.shape[0])
            kind, par, a, b = d[:, 0], d[:, 1], d[:, 2], d[:, 3]
            surv = kind == 1
            return jnp.stack(
                [jnp.where(surv & (par != -1), par, i),
                 jnp.where(surv & (a != -1), a, i),
                 jnp.where(surv & (b != -1), b, i)], axis=1)

        def advance_fn(x, i):
            row = x[i]
            kind, par, cl, cr, acc = (row[0], row[1], row[2], row[3],
                                      row[4])
            hi = x.shape[0] - 1
            prow = x[jnp.clip(par, 0, hi)]
            new_par = jnp.where(
                par == -1, -1, jnp.where(prow[0] == 3, prow[1], par))

            def child(c):
                crow = x[jnp.clip(c, 0, hi)]
                exists = c != -1
                raked = exists & (crow[0] == 2)
                compressed = exists & (crow[0] == 3)
                newc = jnp.where(~exists | raked, -1,
                                 jnp.where(compressed, crow[2], c))
                dacc = jnp.where(raked | compressed, crow[4], 0)
                return newc, dacc

            la, da = child(cl)
            lb, db = child(cr)
            live_row = jnp.stack(
                [new_par, jnp.where(la != -1, la, lb),
                 jnp.where(la != -1, lb, -1), acc + da + db,
                 jnp.int32(1)])
            dead_row = jnp.asarray([-1, -1, -1, 0, 0], jnp.int32)
            return jnp.where(kind == 1, live_row,
                             dead_row).astype(jnp.int32)

        @sac.incremental(block=1)
        def contract(st, val):
            s = sac.zip_blocks(init_fn, st, val, name="init")
            for r in range(self.rounds):
                d = sac.gather(decide_fn(coins[r]), decide_idx, s,
                               arity=2, name=f"decide{r}")
                s = sac.gather(advance_fn, advance_idx, d,
                               arity=3, name=f"advance{r}")
            return s

        return contract

    def _program_hybrid(self, eng):
        from repro.sac.host import EngineFragment

        # plan=False: the contraction's dirty pattern differs per edit,
        # so the planned mode would compile one executable per distinct
        # plan; the single cond-based executable compiles once and is
        # shared across instances of the same (n, seed) trace.
        self.fragment = EngineFragment(
            self._traced_contraction(),
            {"st": self.struct_mods, "val": self.val_mods},
            dtypes={"st": np.int32, "val": np.int32},
            cache_key=("trees", self.n, self.seed, self.rounds),
            max_sparse=32, plan=False)
        (final,) = self.fragment.install(eng)

        def finish(blk):
            st = blk.a[0]                  # [par, cl, cr, acc, live]
            if int(st[1]) != -1 or int(st[2]) != -1 or int(st[4]) != 1:
                raise RuntimeError(
                    "tree did not fully contract — increase rounds")
            eng.write(self.result, int(st[3]))

        eng.read(final[0], finish)

    # ------------------------------------------------------------------
    # Pure host: per-round readers (the paper's program, kept verbatim)
    # ------------------------------------------------------------------
    def _program_host(self, eng):
        n = self.n
        states: List[List] = [eng.alloc_array(n, f"s{r}")
                              for r in range(self.rounds + 1)]
        decisions: List[List] = [eng.alloc_array(n, f"d{r}")
                                 for r in range(self.rounds)]

        def init_node(i):
            eng.read(
                (self.struct_mods[i], self.val_mods[i]),
                lambda st, v: eng.write(states[0][i], st + (v,)),
            )

        eng.parallel_for(0, n, init_node)

        for r in range(self.rounds):
            eng.parallel_for(0, n, lambda i, r=r: self._decide(eng, states,
                                                               decisions, r, i))
            eng.parallel_for(0, n, lambda i, r=r: self._advance(eng, states,
                                                                decisions, r, i))

        def finish(st):
            if st is None or st[1] != -1 or st[2] != -1:
                raise RuntimeError(
                    "tree did not fully contract — increase rounds")
            eng.write(self.result, st[3])

        eng.read(states[self.rounds][0], finish)

    # ---- decision phase ------------------------------------------------
    def _decide(self, eng, states, decisions, r, i):
        coins = self.coins[r]

        def body(st):
            if st is None:
                eng.write(decisions[r][i], DEAD)
                return
            par, cl, cr, acc = st
            eng.charge(1)
            kids = [c for c in (cl, cr) if c != -1]
            if not kids and par != -1:
                eng.write(decisions[r][i], ("rake", (par, acc)))
                return
            if len(kids) == 1 and par != -1 and coins[i]:
                c = kids[0]

                def check(pst, cst):
                    # pst/cst are live: a node's parent/child can only die
                    # in *this* round's contraction, decided simultaneously.
                    _, pcl, pcr, _ = pst
                    ccl, ccr, = cst[1], cst[2]
                    c_is_leaf = ccl == -1 and ccr == -1
                    p_unary = (pcl == -1) != (pcr == -1)
                    p_cand = p_unary and pst[0] != -1 and coins[par]
                    c_unary = (ccl == -1) != (ccr == -1)
                    c_cand = c_unary and coins[c]
                    if not c_is_leaf and not p_cand and not c_cand:
                        eng.write(decisions[r][i], ("compress", (par, c, acc)))
                    else:
                        eng.write(decisions[r][i], ("survive", st))

                eng.read((states[r][par], states[r][c]), check)
                return
            eng.write(decisions[r][i], ("survive", st))

        eng.read(states[r][i], body)

    # ---- state phase -----------------------------------------------------
    def _advance(self, eng, states, decisions, r, i):
        def body(dec):
            kind, payload = dec
            if kind in ("dead", "rake", "compress"):
                eng.write(states[r + 1][i], None)
                return
            par, cl, cr, acc = payload
            eng.charge(1)
            neigh = [c for c in (cl, cr) if c != -1]
            has_par = par != -1
            mods = ([decisions[r][par]] if has_par else []) + \
                   [decisions[r][c] for c in neigh]
            if not mods:
                eng.write(states[r + 1][i], (par, cl, cr, acc))
                return

            def combine(*ndecs):
                new_par = par
                idx = 0
                if has_par:
                    pkind, ppay = ndecs[0]
                    idx = 1
                    if pkind == "compress":
                        new_par = ppay[0]     # grandparent adopts me
                new_kids = []
                new_acc = acc
                for c, (ckind, cpay) in zip(neigh, ndecs[idx:]):
                    if ckind == "rake":
                        new_acc += cpay[1]
                    elif ckind == "compress":
                        new_kids.append(cpay[1])  # adopt grandchild
                        new_acc += cpay[2]
                    else:
                        new_kids.append(c)
                ncl = new_kids[0] if len(new_kids) > 0 else -1
                ncr = new_kids[1] if len(new_kids) > 1 else -1
                eng.write(states[r + 1][i], (new_par, ncl, ncr, new_acc))

            eng.read(tuple(mods), combine)

        eng.read(decisions[r][i], body)

    # ---- dynamic updates -------------------------------------------------
    def apply_update(self, eng, k: int):
        """Batch value update: change k node values."""
        idx = self.rng.sample(range(self.n), min(k, self.n))
        for i in idx:
            self.values[i] = self.rng.randrange(100)
            eng.write(self.val_mods[i], self.values[i])

    def apply_structure_update(self, eng, k: int = 1):
        """Batch link/cut: move k random leaves to new parents (keeps the
        structure a tree; re-runs contraction along both root paths)."""
        moved = 0
        attempts = 0
        while moved < k and attempts < 50 * k:
            attempts += 1
            l = self.rng.randrange(1, self.n)
            if self.children[l]:
                continue
            p = self.parent[l]
            cands = [q for q in range(self.n)
                     if q not in (l, p) and len(self.children[q]) < 2]
            if not cands:
                continue
            q = self.rng.choice(cands)
            self.children[p].remove(l)
            self.children[q].append(l)
            self.parent[l] = q
            for node in (l, p, q):
                eng.write(self.struct_mods[node], self._struct(node))
            moved += 1
        return moved

    # ---- oracle ----------------------------------------------------------
    def expected(self) -> int:
        return sum(self.values)

    def output(self):
        return self.result.peek()
