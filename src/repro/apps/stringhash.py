"""Rabin-Karp string fingerprint (paper Table 3, granularity Table 9).

The string is stored as n/g modifiables of g characters each; the hash is
combined with a divide-and-conquer reduction using the homomorphism
    h(a ++ b) = h(a) * B^len(b) + h(b)   (mod p)
so updating one block re-runs O(log(n/g)) combine readers.  The
granularity g is the paper's Table-9 tuning knob.
"""
from __future__ import annotations

import random
import string as _string

__all__ = ["StringHashApp"]

MOD = (1 << 61) - 1
BASE = 257


def block_hash(s: str, charge=None):
    if charge:
        charge(len(s))
    h = 0
    for ch in s:
        h = (h * BASE + ord(ch)) % MOD
    return h, pow(BASE, len(s), MOD)


def combine(l, r):
    hl, pl = l
    hr, pr = r
    return (hl * pr + hr) % MOD, (pl * pr) % MOD


class StringHashApp:
    name = "stringhash"

    def __init__(self, n: int = 65536, grain: int = 64, seed: int = 0):
        assert n % grain == 0
        self.n = n
        self.grain = grain
        self.blocks = n // grain
        self.rng = random.Random(seed)

    def _rand_block(self) -> str:
        return "".join(
            self.rng.choice(_string.ascii_lowercase) for _ in range(self.grain)
        )

    def build_input(self, eng):
        self.data = [self._rand_block() for _ in range(self.blocks)]
        self.mods = eng.alloc_array(self.blocks, "blk")
        for m, s in zip(self.mods, self.data):
            eng.write(m, s)
        self.result = eng.mod("hash")
        return self.mods

    def program(self, eng):
        def hash_rec(lo, hi, res):
            if hi - lo == 1:
                eng.read(
                    self.mods[lo],
                    lambda s: eng.write(res, block_hash(s, eng.charge)),
                )
                return
            mid = (lo + hi) // 2
            left, right = eng.mod(), eng.mod()
            eng.par(
                lambda: hash_rec(lo, mid, left),
                lambda: hash_rec(mid, hi, right),
            )
            eng.read((left, right), lambda x, y: eng.write(res, combine(x, y)))

        hash_rec(0, self.blocks, self.result)

    def run(self, eng):
        return eng.run(lambda: self.program(eng))

    def apply_update(self, eng, k: int):
        """Change k characters (paper counts k single-char updates)."""
        blocks = min(max(k // self.grain, 1), self.blocks)
        idx = self.rng.sample(range(self.blocks), blocks)
        for i in idx:
            pos = self.rng.randrange(self.grain)
            s = self.data[i]
            ch = self.rng.choice(_string.ascii_lowercase)
            self.data[i] = s[:pos] + ch + s[pos + 1:]
            eng.write(self.mods[i], self.data[i])

    def expected(self):
        full = "".join(self.data)
        return block_hash(full)[0]

    def output(self):
        return self.result.peek()[0]
