"""The paper's six benchmark applications, built on the PSAC engine.

Each app implements the paper's benchmark with the same structure it
describes (Section 6): a static program (runs under ``StaticEngine``), the
self-adjusting program (runs under ``Engine``), batch dynamic updates, and
a pure-python oracle for correctness checks.

  * spellcheck — min edit distance of n strings to a target (Table 1)
  * raytracer  — reflective-circle raycaster over a pixel grid (Table 2)
  * stringhash — Rabin-Karp fingerprint of a long string (Table 3)
  * sequence   — randomized list contraction (Table 4)
  * trees      — tree contraction via rake/compress (Table 5)
  * filter     — BST filter by predicate (Table 6)

``trees`` and ``filter`` run HYBRID by default: their statically-shaped
per-round phases execute on the jitted graph runtime (embedded via
``repro.sac.host.EngineFragment``) while the data-dependent skeleton
stays host readers; ``hybrid=False`` restores the all-host originals
(bitwise-identical outputs, tested in tests/test_hybrid.py).
"""
from .spellcheck import SpellcheckApp
from .raytracer import RaytracerApp
from .stringhash import StringHashApp
from .sequence import ListContractionApp
from .trees import TreeContractionApp
from .filterbst import FilterApp

APPS = {
    "spellcheck": SpellcheckApp,
    "raytracer": RaytracerApp,
    "stringhash": StringHashApp,
    "sequence": ListContractionApp,
    "trees": TreeContractionApp,
    "filter": FilterApp,
}

__all__ = ["APPS"] + [c.__name__ for c in APPS.values()]
