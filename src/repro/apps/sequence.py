"""Dynamic sequence benchmark: randomized list contraction (paper Table 4).

Computes an associative aggregate (sum) over a linked list by randomized
mate contraction: in each round every live node flips a pregenerated coin;
a Tail node (coin=0) whose successor is a Head (coin=1) absorbs it.
O(log n) rounds w.h.p.  Randomness is pregenerated so re-execution is
deterministic (paper, Section 2).

Each round runs two phases with strictly single-hop reads so that change
propagation under P nodes is race-free (no reader ever touches a mod
written by a *sibling* strand of the same parallel phase):

  decision phase: node i reads states[r][i] and decides
      {dead, die (absorbed by pred), absorb (eat successor), survive},
      publishing its (pred, next, acc) as the payload;
  state phase:    node i reads its own and its neighbors' decisions and
      writes states[r+1][i].

The protocol maintains the doubly-linked invariant pred(next(i)) == i, and
the sum of live accumulators is invariant across rounds, so the final
divide-and-conquer reduction over live nodes is correct even in the
(never observed; rounds are calibrated) event of incomplete contraction.

Each round's mods are read by the next round, so a k-element batch update
re-runs O(k log n) readers — this is the list-contraction stability bound
of [2] carried into the RSP framework.
"""
from __future__ import annotations

import math
import random
from typing import List

__all__ = ["ListContractionApp"]

DEAD = ("dead", None)


class ListContractionApp:
    name = "sequence"

    def __init__(self, n: int = 1024, seed: int = 0):
        self.n = n
        self.rng = random.Random(seed)
        # Contraction removes ~1/4 of live nodes per round in expectation;
        # the live-acc-sum invariant keeps the result correct even if a few
        # stragglers remain, so a fixed O(log n) round count suffices.
        self.rounds = max(int(2.5 * math.log2(max(n, 2))) + 8, 9)
        # Pregenerated randomness (paper: randomness must be fixed up front
        # so re-execution is deterministic).
        self.coins = [
            [self.rng.random() < 0.5 for _ in range(n)]
            for _ in range(self.rounds)
        ]

    # ------------------------------------------------------------------
    def build_input(self, eng):
        self.values = [self.rng.randrange(100) for _ in range(self.n)]
        self.val_mods = eng.alloc_array(self.n, "val")
        for m, v in zip(self.val_mods, self.values):
            eng.write(m, v)
        self.result = eng.mod("total")
        return self.val_mods

    def run(self, eng):
        return eng.run(lambda: self.program(eng))

    # ------------------------------------------------------------------
    def program(self, eng):
        n = self.n
        states: List[List] = [eng.alloc_array(n, f"s{r}")
                              for r in range(self.rounds + 1)]
        decisions: List[List] = [eng.alloc_array(n, f"d{r}")
                                 for r in range(self.rounds)]

        def init_node(i):
            eng.read(
                self.val_mods[i],
                lambda v: eng.write(
                    states[0][i],
                    (i - 1, i + 1 if i + 1 < n else -1, v),
                ),
            )

        eng.parallel_for(0, n, init_node)

        for r in range(self.rounds):
            eng.parallel_for(0, n, lambda i, r=r: self._decide(eng, states,
                                                               decisions, r, i))
            eng.parallel_for(0, n, lambda i, r=r: self._advance(eng, states,
                                                                decisions, r, i))

        # Reduce the accumulators of live nodes (sum over live accs is
        # invariant round to round, so this equals the total).
        def finish(i, res):
            eng.read(
                states[self.rounds][i],
                lambda st: eng.write(res, 0 if st is None else st[2]),
            )

        def sum_rec(lo, hi, res):
            if hi - lo == 1:
                finish(lo, res)
                return
            mid = (lo + hi) // 2
            l, r_ = eng.mod(), eng.mod()
            eng.par(lambda: sum_rec(lo, mid, l), lambda: sum_rec(mid, hi, r_))
            eng.read((l, r_), lambda a, b: eng.write(res, a + b))

        sum_rec(0, n, self.result)

    # ---- decision phase ---------------------------------------------------
    def _decide(self, eng, states, decisions, r, i):
        coins = self.coins[r]

        def body(st):
            if st is None:
                eng.write(decisions[r][i], DEAD)
                return
            eng.charge(1)
            pred, nxt, acc = st
            if coins[i] and pred != -1 and not coins[pred]:
                # Head with a Tail predecessor: absorbed, die; the payload
                # lets the absorber pick up my successor and accumulator.
                eng.write(decisions[r][i], ("die", st))
            elif not coins[i] and nxt != -1 and coins[nxt]:
                eng.write(decisions[r][i], ("absorb", st))
            else:
                eng.write(decisions[r][i], ("survive", st))

        eng.read(states[r][i], body)

    # ---- state phase --------------------------------------------------------
    def _advance(self, eng, states, decisions, r, i):
        def body(dec):
            kind, payload = dec
            if kind in ("dead", "die"):
                eng.write(states[r + 1][i], None)
                return
            pred, nxt, acc = payload
            eng.charge(1)
            mods, roles = [], []
            if pred != -1:
                mods.append(decisions[r][pred])
                roles.append("pred")
            if nxt != -1:
                mods.append(decisions[r][nxt])
                roles.append("next")
            if not mods:
                eng.write(states[r + 1][i], (pred, nxt, acc))
                return

            def combine(*ndecs):
                new_pred, new_nxt, new_acc = pred, nxt, acc
                for role, (nkind, npay) in zip(roles, ndecs):
                    if role == "pred" and nkind == "die":
                        # pred was absorbed by *its* pred, who becomes mine.
                        new_pred = npay[0]
                    elif role == "next" and nkind == "die":
                        # my absorb: successor's links and value fold in.
                        new_nxt = npay[1]
                        new_acc = acc + npay[2]
                eng.write(states[r + 1][i], (new_pred, new_nxt, new_acc))

            eng.read(tuple(mods), combine)

        eng.read(decisions[r][i], body)

    # ---- dynamic updates ----------------------------------------------------
    def apply_update(self, eng, k: int):
        idx = self.rng.sample(range(self.n), min(k, self.n))
        for i in idx:
            self.values[i] = self.rng.randrange(100)
            eng.write(self.val_mods[i], self.values[i])

    # ---- oracle ---------------------------------------------------------------
    def expected(self):
        return sum(self.values)

    def output(self):
        return self.result.peek()
