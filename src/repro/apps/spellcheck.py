"""Spellcheck benchmark (paper Table 1).

Computes the minimum edit distance from a set of n strings to a target
string.  Each string lives in a modifiable; readers compute the (O(l^2))
edit distance — heavy per-read work, so self-adjusting overhead is
negligible and work savings for small updates are enormous (the paper
reports ~819k x for k=1 of n=1e6).
"""
from __future__ import annotations

import random
import string as _string
from typing import List

__all__ = ["SpellcheckApp"]


def edit_distance(a: str, b: str, charge=None) -> int:
    la, lb = len(a), len(b)
    if charge:
        charge(la * lb)
    prev = list(range(lb + 1))
    for i in range(1, la + 1):
        cur = [i] + [0] * lb
        ai = a[i - 1]
        for j in range(1, lb + 1):
            cur[j] = min(
                prev[j] + 1,
                cur[j - 1] + 1,
                prev[j - 1] + (ai != b[j - 1]),
            )
        prev = cur
    return prev[lb]


class SpellcheckApp:
    name = "spellcheck"

    def __init__(self, n: int = 1000, str_len: int = 12, seed: int = 0):
        self.n = n
        self.str_len = str_len
        self.rng = random.Random(seed)
        self.target = self._rand_str()

    def _rand_str(self) -> str:
        return "".join(
            self.rng.choice(_string.ascii_lowercase)
            for _ in range(self.str_len)
        )

    # ---- engine-agnostic program ----------------------------------------
    def build_input(self, eng):
        self.strings = [self._rand_str() for _ in range(self.n)]
        self.mods = eng.alloc_array(self.n, "str")
        for m, s in zip(self.mods, self.strings):
            eng.write(m, s)
        self.result = eng.mod("min_dist")
        return self.mods

    def program(self, eng):
        """Divide-and-conquer min over per-string edit distances."""
        target = self.target

        def min_rec(lo, hi, res):
            if hi - lo == 1:
                def leaf(s):
                    d = edit_distance(s, target, eng.charge)
                    eng.write(res, d)

                eng.read(self.mods[lo], leaf)
                return
            mid = (lo + hi) // 2
            left, right = eng.mod(), eng.mod()
            eng.par(
                lambda: min_rec(lo, mid, left),
                lambda: min_rec(mid, hi, right),
            )
            eng.read((left, right), lambda x, y: eng.write(res, min(x, y)))

        min_rec(0, self.n, self.result)

    def run(self, eng):
        return eng.run(lambda: self.program(eng))

    # ---- dynamic updates --------------------------------------------------
    def apply_update(self, eng, k: int):
        idx = self.rng.sample(range(self.n), min(k, self.n))
        for i in idx:
            self.strings[i] = self._rand_str()
            eng.write(self.mods[i], self.strings[i])

    # ---- oracle -------------------------------------------------------------
    def expected(self) -> int:
        return min(edit_distance(s, self.target) for s in self.strings)

    def output(self):
        return self.result.peek()
