"""BST filter benchmark (paper Table 6).

Filters the elements of a binary search tree with respect to a predicate,
returning a new BST.  Nodes are modifiables holding (key, left, right);
the filter recursion forks over children (par) and reads node mods, so
updating a node's key re-runs only the readers on its root path.
"""
from __future__ import annotations

import random
from typing import Optional

__all__ = ["FilterApp"]


class FilterApp:
    name = "filter"

    def __init__(self, n: int = 4095, seed: int = 0, modulus: int = 3):
        self.n = n
        self.rng = random.Random(seed)
        self.modulus = modulus  # predicate: value % modulus != 0

    def pred(self, v: int) -> bool:
        return v % self.modulus != 0

    # Tree stored as arrays (implicit complete BST on keys 0..n-1, values
    # random); node i has children 2i+1, 2i+2.
    def build_input(self, eng):
        self.values = [self.rng.randrange(1 << 20) for _ in range(self.n)]
        self.mods = eng.alloc_array(self.n, "node")
        for m, v in zip(self.mods, self.values):
            eng.write(m, v)
        self.result = eng.mod("filtered")
        return self.mods

    def program(self, eng):
        def filt(i, res):
            if i >= self.n:
                eng.write(res, None)
                return
            lres, rres = eng.mod(), eng.mod()
            eng.par(lambda: filt(2 * i + 1, lres),
                    lambda: filt(2 * i + 2, rres))

            def combine_node(v, l, r):
                eng.charge(1)
                if self.pred(v):
                    eng.write(res, (v, l, r))
                else:
                    # merge children: attach right under rightmost of left
                    eng.write(res, self._merge(l, r))

            eng.read(
                (self.mods[i], lres, rres),
                lambda v, l, r: combine_node(v, l, r),
            )

        filt(0, self.result)

    @staticmethod
    def _merge(l, r):
        if l is None:
            return r
        if r is None:
            return l
        v, ll, lr = l
        return (v, ll, FilterApp._merge(lr, r))

    def run(self, eng):
        return eng.run(lambda: self.program(eng))

    def apply_update(self, eng, k: int):
        idx = self.rng.sample(range(self.n), min(k, self.n))
        for i in idx:
            self.values[i] = self.rng.randrange(1 << 20)
            eng.write(self.mods[i], self.values[i])

    # oracle: count of surviving values (tree shape is deterministic given
    # the merge rule; compare the multiset of kept values)
    def expected(self):
        return sorted(v for v in self.values if self.pred(v))

    def output(self):
        out = []

        def walk(node):
            if node is None:
                return
            v, l, r = node
            walk(l)
            out.append(v)
            walk(r)

        walk(self.result.peek())
        return sorted(out)
