"""BST filter benchmark (paper Table 6).

Filters the elements of a binary search tree with respect to a predicate,
returning a new BST.  Nodes are modifiables holding values; the filter
recursion forks over children (par) and reads node mods, so updating a
node's key re-runs only the readers on its root path.

**Hybrid mode (default)**: the per-level predicate sweep is statically
shaped — n lanes, data-dependent values — so it lowers onto the jitted
graph runtime as one ``map`` fragment producing per-node *keep* flags,
embedded in the host engine via ``EngineFragment``.  The data-dependent
skeleton — the recursion over tree shape that builds the filtered BST
(as a tree of node indices) — stays host readers over the keep-flag
boundary mods.  The boundary write cutoff is what makes this fast: a
value edit that does not flip the node's keep flag changes NO boundary
mod, so zero skeleton readers re-run; a flipped flag re-runs exactly
the root path, as in the pure host program.  ``hybrid=False`` keeps the
original all-host program; both filter the same multiset of values.
"""
from __future__ import annotations

import random
from typing import Optional

import numpy as np

__all__ = ["FilterApp"]


class FilterApp:
    name = "filter"

    def __init__(self, n: int = 4095, seed: int = 0, modulus: int = 3,
                 hybrid: bool = True):
        self.n = n
        self.hybrid = hybrid
        self.rng = random.Random(seed)
        self.modulus = modulus  # predicate: value % modulus != 0

    def pred(self, v: int) -> bool:
        return v % self.modulus != 0

    # Tree stored as arrays (implicit complete BST on keys 0..n-1, values
    # random); node i has children 2i+1, 2i+2.
    def build_input(self, eng):
        self.values = [self.rng.randrange(1 << 20) for _ in range(self.n)]
        self.mods = eng.alloc_array(self.n, "node")
        for m, v in zip(self.mods, self.values):
            eng.write(m, v)
        self.result = eng.mod("filtered")
        return self.mods

    def program(self, eng):
        if self.hybrid:
            return self._program_hybrid(eng)
        return self._program_host(eng)

    # ------------------------------------------------------------------
    # Hybrid: keep flags compiled, tree recursion host
    # ------------------------------------------------------------------
    def _traced_keep(self):
        import jax.numpy as jnp

        import repro.sac as sac

        m = self.modulus

        @sac.incremental(block=1)
        def keepmask(vals):
            return sac.map_blocks(
                lambda b: (b[0] % m != 0).astype(jnp.int32), vals,
                name="keep")

        return keepmask

    def _program_hybrid(self, eng):
        from repro.sac.host import EngineFragment

        self.fragment = EngineFragment(
            self._traced_keep(), {"vals": self.mods},
            dtypes={"vals": np.int32},
            cache_key=("filter", self.n, self.modulus),
            max_sparse=32, plan=False)
        (keep,) = self.fragment.install(eng)

        def filt(i, res):
            if i >= self.n:
                eng.write(res, None)
                return
            lres, rres = eng.mod(), eng.mod()
            eng.par(lambda: filt(2 * i + 1, lres),
                    lambda: filt(2 * i + 2, rres))

            def combine_node(k, l, r, _i=i):
                eng.charge(1)
                if int(k.a[0]):
                    # the filtered BST carries node *indices*; values
                    # stay interior (read out of the fragment at output
                    # time), so an edit that keeps the flag re-runs
                    # nothing out here.
                    eng.write(res, (_i, l, r))
                else:
                    eng.write(res, self._merge(l, r))

            eng.read((keep[i], lres, rres), combine_node)

        filt(0, self.result)

    # ------------------------------------------------------------------
    # Pure host: values in the tree (the paper's program, kept verbatim)
    # ------------------------------------------------------------------
    def _program_host(self, eng):
        def filt(i, res):
            if i >= self.n:
                eng.write(res, None)
                return
            lres, rres = eng.mod(), eng.mod()
            eng.par(lambda: filt(2 * i + 1, lres),
                    lambda: filt(2 * i + 2, rres))

            def combine_node(v, l, r):
                eng.charge(1)
                if self.pred(v):
                    eng.write(res, (v, l, r))
                else:
                    # merge children: attach right under rightmost of left
                    eng.write(res, self._merge(l, r))

            eng.read(
                (self.mods[i], lres, rres),
                lambda v, l, r: combine_node(v, l, r),
            )

        filt(0, self.result)

    @staticmethod
    def _merge(l, r):
        if l is None:
            return r
        if r is None:
            return l
        v, ll, lr = l
        return (v, ll, FilterApp._merge(lr, r))

    def run(self, eng):
        return eng.run(lambda: self.program(eng))

    def apply_update(self, eng, k: int):
        idx = self.rng.sample(range(self.n), min(k, self.n))
        for i in idx:
            self.values[i] = self.rng.randrange(1 << 20)
            eng.write(self.mods[i], self.values[i])

    # oracle: count of surviving values (tree shape is deterministic given
    # the merge rule; compare the multiset of kept values)
    def expected(self):
        return sorted(v for v in self.values if self.pred(v))

    def output(self):
        out = []

        def walk(node):
            if node is None:
                return
            v, l, r = node
            walk(l)
            # hybrid trees hold node indices; host trees hold values
            out.append(self.values[v] if self.hybrid else v)
            walk(r)

        walk(self.result.peek())
        return sorted(out)
