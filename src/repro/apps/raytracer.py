"""Raytracer benchmark (paper Table 2).

A 2D raycaster: W pixels shoot rays into a scene of reflective circles;
each pixel computes a color with one reflection bounce.  The scene uses a
two-level dependency structure so that moving one circle re-renders only
the pixels whose rays can reach it:

  circle mods  -->  tile index mods (which circles overlap a tile of
                    ray directions)  -->  pixel readers

This reproduces the paper's observation that raytracing creates
modifiables with many readers (every pixel in a tile reads that tile's
circles), giving higher self-adjusting overhead (their Table 2: 4.6x) but
strong work savings for localized scene edits.
"""
from __future__ import annotations

import math
import random
from typing import List, Tuple

__all__ = ["RaytracerApp"]

Circle = Tuple[float, float, float, float]  # (cx, cy, radius, albedo)


class RaytracerApp:
    name = "raytracer"

    def __init__(self, width: int = 512, n_circles: int = 12,
                 n_tiles: int = 16, seed: int = 0):
        self.w = width
        self.nc = n_circles
        self.nt = n_tiles
        self.rng = random.Random(seed)

    def _rand_circle(self) -> Circle:
        # Keep angular footprints small (distant-ish, modest radii) so a
        # moved circle's dirty tile set stays local — the regime where the
        # paper reports its raytracer work savings (6.25% of the image).
        return (
            self.rng.uniform(-4, 4),
            self.rng.uniform(4, 10),
            self.rng.uniform(0.2, 0.6),
            self.rng.uniform(0.2, 0.9),
        )

    # ---- geometry ---------------------------------------------------------
    @staticmethod
    def _ray_dir(t: float) -> Tuple[float, float]:
        ang = (t - 0.5) * (math.pi / 2)  # 90deg field of view, looking +y
        return math.sin(ang), math.cos(ang)

    @staticmethod
    def _hit(ox, oy, dx, dy, c: Circle):
        cx, cy, r, _ = c
        lx, ly = cx - ox, cy - oy
        tca = lx * dx + ly * dy
        if tca < 0:
            return None
        d2 = lx * lx + ly * ly - tca * tca
        if d2 > r * r:
            return None
        thc = math.sqrt(r * r - d2)
        t = tca - thc
        return t if t > 1e-6 else None

    def _trace(self, ox, oy, dx, dy, circles: List[Circle], depth: int, charge):
        charge(len(circles) + 1)
        best, bc = None, None
        for c in circles:
            t = self._hit(ox, oy, dx, dy, c)
            if t is not None and (best is None or t < best):
                best, bc = t, c
        if bc is None:
            return 0.1  # sky
        cx, cy, r, albedo = bc
        px, py = ox + dx * best, oy + dy * best
        nx, ny = (px - cx) / r, (py - cy) / r
        base = albedo * max(0.0, nx * 0.3 + ny * 0.8)  # fixed light dir
        if depth > 0:
            rdx = dx - 2 * (dx * nx + dy * ny) * nx
            rdy = dy - 2 * (dx * nx + dy * ny) * ny
            base = 0.7 * base + 0.3 * self._trace(
                px + nx * 1e-4, py + ny * 1e-4, rdx, rdy, circles, depth - 1,
                charge)
        return base

    def _tile_circles(self, circles: List[Circle], tile: int) -> Tuple[int, ...]:
        """Conservative: circle ids whose angular span intersects the tile's
        ray-angle range (widened so one reflection bounce stays inside)."""
        lo = (tile / self.nt - 0.5) * (math.pi / 2)
        hi = ((tile + 1) / self.nt - 0.5) * (math.pi / 2)
        out = []
        for i, (cx, cy, r, _) in enumerate(circles):
            ang = math.atan2(cx, cy)
            half = math.asin(min(0.999, r / max(1e-6, math.hypot(cx, cy))))
            pad = 0.1  # reflection slack (oracle uses the same cone)
            if ang + half + pad >= lo and ang - half - pad <= hi:
                out.append(i)
        return tuple(out)

    # ---- program ------------------------------------------------------------
    def build_input(self, eng):
        self.circles = [self._rand_circle() for _ in range(self.nc)]
        self.circle_mods = eng.alloc_array(self.nc, "circle")
        for m, c in zip(self.circle_mods, self.circles):
            eng.write(m, c)
        self.pixels = eng.alloc_array(self.w, "px")
        return self.circle_mods

    def program(self, eng):
        # Level 1: tile index — readers over all circles (cheap, nt tiles).
        tile_mods = eng.alloc_array(self.nt, "tile")

        def tile_reader(t):
            def body(*cs):
                eng.charge(self.nc)
                eng.write(tile_mods[t], self._tile_circles(list(cs), t))

            eng.read(tuple(self.circle_mods), body)

        eng.parallel_for(0, self.nt, tile_reader)

        # Level 2: pixels read their tile's list, then those circles.
        def pixel(i):
            t = min(i * self.nt // self.w, self.nt - 1)

            def with_ids(ids):
                def with_circles(*cs):
                    dx, dy = self._ray_dir((i + 0.5) / self.w)
                    col = self._trace(0.0, 0.0, dx, dy, list(cs), 1, eng.charge)
                    eng.write(self.pixels[i], round(col, 6))

                if ids:
                    eng.read(tuple(self.circle_mods[j] for j in ids), with_circles)
                else:
                    eng.write(self.pixels[i], 0.1)

            eng.read(tile_mods[t], with_ids)

        eng.parallel_for(0, self.w, pixel)

    def run(self, eng):
        return eng.run(lambda: self.program(eng))

    def apply_update(self, eng, k: int = 1):
        """Move k circles slightly (the paper's dynamic update)."""
        idx = self.rng.sample(range(self.nc), min(k, self.nc))
        for i in idx:
            cx, cy, r, a = self.circles[i]
            self.circles[i] = (cx + self.rng.uniform(-0.3, 0.3), cy, r, a)
            eng.write(self.circle_mods[i], self.circles[i])

    def expected(self):
        out = []
        charge = lambda *_: None
        for i in range(self.w):
            t = min(i * self.nt // self.w, self.nt - 1)
            ids = self._tile_circles(self.circles, t)
            cs = [self.circles[j] for j in ids]
            dx, dy = self._ray_dir((i + 0.5) / self.w)
            out.append(round(self._trace(0.0, 0.0, dx, dy, cs, 1, charge), 6))
        return out

    def output(self):
        return [m.peek() for m in self.pixels]
