"""Mamba-2 blocks via SSD (state-space duality), chunked matmul form.

The SSD algorithm splits the sequence into chunks; within a chunk the
recurrence is computed as dense (Q x Q) attention-like matmuls (MXU
friendly), and across chunks a first-order linear recurrence carries the
[H, P, N] state.  This is the TPU-native adaptation of Mamba's selective
scan: the hardware wants matmuls, not a length-S sequential scan
(DESIGN.md, hardware-adaptation notes).

Decode keeps an O(1)-per-token state: [B, H, P, N] SSM state plus a
(conv_width-1)-deep convolution window — no KV cache, which is why the
``long_500k`` shape runs on this family.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..shardlib import constrain
from .layers import residual_out_scale
from .params import ParamSpec

__all__ = ["ssm_specs", "ssm_fwd", "ssm_decode", "ssm_state_shapes"]


def _dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    H = di // cfg.ssm_head_dim
    return di, H, cfg.ssm_head_dim, cfg.ssm_state


def ssm_specs(cfg, L: int) -> dict:
    D = cfg.d_model
    di, H, Pd, N = _dims(cfg)
    conv_ch = di + 2 * N
    dt = cfg.pdtype
    lead: Tuple[int, ...] = (L,) if L else ()
    lax: Tuple[str, ...] = ("layers",) if L else ()
    return {
        # order: [z (di), x (di), B (N), C (N), dt (H)]
        "in_proj": ParamSpec(lead + (D, 2 * di + 2 * N + H), lax + ("embed", "rnn"), dt),
        "conv_w": ParamSpec(lead + (cfg.ssm_conv_width, conv_ch), lax + ("conv", "rnn"), dt, "normal", scale=0.5),
        "conv_b": ParamSpec(lead + (conv_ch,), lax + ("rnn",), dt, "zeros"),
        "A_log": ParamSpec(lead + (H,), lax + ("q_heads",), jnp.float32, "zeros"),
        "D": ParamSpec(lead + (H,), lax + ("q_heads",), jnp.float32, "ones"),
        "dt_bias": ParamSpec(lead + (H,), lax + ("q_heads",), jnp.float32, "zeros"),
        "gate_norm": ParamSpec(lead + (di,), lax + ("rnn",), dt, "ones"),
        "out_proj": ParamSpec(lead + (di, D), lax + ("rnn", "embed"), dt,
                              scale=residual_out_scale(cfg)),
    }


def ssm_state_shapes(cfg, batch: int):
    di, H, Pd, N = _dims(cfg)
    return {
        "ssm": ((batch, H, Pd, N), jnp.float32),
        "conv": ((batch, cfg.ssm_conv_width - 1, di + 2 * N), jnp.bfloat16),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d as shift-multiply-adds. xbc: [B,S,C]."""
    K = w.shape[0]
    out = xbc * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, : xbc.shape[1]]
        out = out + shifted * w[K - 1 - i]
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype)


def _segsum(x: jax.Array) -> jax.Array:
    """x: [..., Q] -> [..., Q, Q]; out[i,j] = sum_{j<t<=i} x[t], -inf above."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def _split_proj(cfg, zxbcdt: jax.Array):
    di, H, Pd, N = _dims(cfg)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N :]
    return z, xbc, dt


def _ssd_scan(cfg, xh, dt, A, Bm, Cm, init_state=None):
    """Chunked SSD.  xh: [B,S,H,P]; dt: [B,S,H] (softplus'd); A: [H] (<0);
    Bm/Cm: [B,S,N].  Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    Bb, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    nc = S // Q
    xc = xh.reshape(Bb, nc, Q, H, Pd)
    dtc = dt.reshape(Bb, nc, Q, H)
    Bc = Bm.reshape(Bb, nc, Q, N)
    Cc = Cm.reshape(Bb, nc, Q, N)

    dA = dtc * A  # [B,c,Q,H]
    dAh = jnp.moveaxis(dA, -1, 2)          # [B,c,H,Q]
    A_cs = jnp.cumsum(dAh, axis=-1)        # [B,c,H,Q]
    xdt = xc * dtc[..., None]              # [B,c,Q,H,P] (x weighted by dt)

    # 1) intra-chunk: (C B^T ∘ L) X
    L = jnp.exp(_segsum(dAh))              # [B,c,H,Q,Q]
    cb = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)            # [B,c,Q,Q]
    y_diag = jnp.einsum("bcls,bchls,bcshp->bclhp",
                        cb.astype(jnp.float32), L,
                        xdt.astype(jnp.float32))

    # 2) per-chunk end states
    decay_states = jnp.exp(A_cs[..., -1:] - A_cs)         # [B,c,H,Q]
    states = jnp.einsum("bcsn,bchs,bcshp->bchpn",
                        Bc.astype(jnp.float32), decay_states,
                        xdt.astype(jnp.float32))          # [B,c,H,P,N]

    # 3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(A_cs[..., -1])                  # [B,c,H]
    s0 = (
        jnp.zeros((Bb, H, Pd, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(s, inp):
        dec, st = inp  # dec: [B,H], st: [B,H,P,N]
        s_new = s * dec[..., None, None] + st
        return s_new, s

    decs = jnp.moveaxis(chunk_decay, 1, 0)                # [c,B,H]
    sts = jnp.moveaxis(states, 1, 0)                      # [c,B,H,P,N]
    final_state, prev_states = jax.lax.scan(step, s0, (decs, sts))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # [B,c,H,P,N]

    # 4) contribution of carried state to each position
    state_decay = jnp.exp(A_cs)                           # [B,c,H,Q]
    y_off = jnp.einsum("bcln,bchpn,bchl->bclhp",
                       Cc.astype(jnp.float32), prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bb, S, H, Pd)
    return y.astype(xh.dtype), final_state


def ssm_fwd(cfg, p: dict, x: jax.Array, init_state=None):
    """Full-sequence Mamba-2 block core (post-norm residual handled by
    caller).  x: [B,S,D].  Returns (out [B,S,D], {'ssm','conv'} state)."""
    from .layers import rmsnorm

    di, H, Pd, N = _dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xbc_raw, dt = _split_proj(cfg, zxbcdt)
    conv_tail = xbc_raw[:, -(cfg.ssm_conv_width - 1):, :].astype(
        jnp.dtype(cfg.cache_dtype))
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di]
    Bm = xbc[..., di : di + N]
    Cm = xbc[..., di + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(*xs.shape[:-1], H, Pd)
    xh = constrain(xh, ("batch", "seq", "q_heads", None))
    y, fin = _ssd_scan(cfg, xh, dt, A, Bm, Cm, init_state)
    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(*x.shape[:-1], di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(z.dtype),
                p["gate_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return constrain(out, ("batch", "seq", "embed")), {"ssm": fin, "conv": conv_tail}


def ssm_decode(cfg, p: dict, x: jax.Array, ssm_state: jax.Array, conv_state: jax.Array):
    """One-token step.  x: [B,1,D]; ssm_state: [B,H,P,N];
    conv_state: [B,K-1,C].  Returns (out, (ssm_state, conv_state))."""
    from .layers import rmsnorm

    di, H, Pd, N = _dims(cfg)
    K = cfg.ssm_conv_width
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    window = jnp.concatenate([conv_state, xbc[:, 0:1, :].astype(conv_state.dtype)], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc1 = jax.nn.silu(conv_out).astype(x.dtype)           # [B,C]
    xs = xbc1[..., :di]
    Bm = xbc1[..., di : di + N]
    Cm = xbc1[..., di + N :]
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(-1, H, Pd).astype(jnp.float32)
    dA = jnp.exp(dt1 * A)                                  # [B,H]
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt1, Bm.astype(jnp.float32), xh)
    new_state = ssm_state * dA[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), new_state)
    y = y + xh * p["D"][:, None]
    y = y.reshape(-1, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(z.dtype),
                p["gate_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    new_conv = window[:, 1:]
    return constrain(out, ("batch", None, "embed")), (new_state, new_conv)
