"""Mixture-of-experts FFN with expert parallelism.

Baseline dispatch is capacity-based (GShard-style) expressed with
scatter/gather so XLA/GSPMD shards the expert buffer over the 'model' mesh
axis.  Routing:

* ``softmax`` — classic top-k softmax router (Arctic).
* ``sigmoid`` — DeepSeek-V3: sigmoid affinities, top-k selection, combine
  weights are the selected affinities renormalized to sum to 1.

An auxiliary load-balance loss (Switch-style) is returned alongside the
output.  The optimized shard_map expert-parallel path lives in
``moe_fwd_ep`` (see EXPERIMENTS.md §Perf hillclimb B for the before/after).
"""
from __future__ import annotations

import contextlib
import os
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..shardlib import constrain, current_ctx, shard_map
from .layers import residual_out_scale
from .params import ParamSpec

__all__ = ["moe_specs", "moe_fwd", "moe_fwd_ref", "moe_fwd_dropless",
           "moe_fwd_ep", "dropless_moe", "ep_moe"]

# Trace-time switch for the dropless token-local MoE path.  Capacity
# dispatch is not token-local (tokens compete for capacity slots), so
# change propagation through a capacity-dispatch MoE is unsound; the
# incremental-prefill path and its full-prefill oracle both run under
# this context.  At 512-device scale dropless needs a grouped-GEMM
# (MegaBlocks-style) kernel to shard; see DESIGN.md §Arch-applicability.
_DROPLESS = [False]

# Trace-time switch for the shard_map expert-parallel dispatch (hillclimb
# B): identical routing, per-shard capacity quotas, one psum/layer instead
# of GSPMD's replicate-and-all-reduce resharding.
_EP = [False]


@contextlib.contextmanager
def dropless_moe(on: bool = True):
    prev = _DROPLESS[0]
    _DROPLESS[0] = on
    try:
        yield
    finally:
        _DROPLESS[0] = prev


@contextlib.contextmanager
def ep_moe(on: bool = True):
    prev = _EP[0]
    _EP[0] = on
    try:
        yield
    finally:
        _EP[0] = prev


def moe_specs(cfg, L: int) -> dict:
    D = cfg.d_model
    E = cfg.moe_experts
    Fe = cfg.d_ff
    dt = cfg.pdtype
    lead: Tuple[int, ...] = (L,) if L else ()
    lax: Tuple[str, ...] = ("layers",) if L else ()
    specs = {
        "router": ParamSpec(lead + (D, E), lax + ("embed", "experts"),
                            jnp.float32, "normal", scale=0.006),
        "gate": ParamSpec(lead + (E, D, Fe), lax + ("experts", "embed", "expert_mlp"), dt),
        "up": ParamSpec(lead + (E, D, Fe), lax + ("experts", "embed", "expert_mlp"), dt),
        "down": ParamSpec(lead + (E, Fe, D), lax + ("experts", "expert_mlp", "embed"), dt,
                          scale=residual_out_scale(cfg)),
    }
    if cfg.moe_shared_experts:
        f_sh = Fe * cfg.moe_shared_experts
        specs["shared"] = {
            "gate": ParamSpec(lead + (D, f_sh), lax + ("embed", "mlp"), dt),
            "up": ParamSpec(lead + (D, f_sh), lax + ("embed", "mlp"), dt),
            "down": ParamSpec(lead + (f_sh, D), lax + ("mlp", "embed"), dt,
                              scale=residual_out_scale(cfg)),
        }
    return specs


def _route(cfg, scores: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """scores [N,E] -> (weights [N,k], ids [N,k], probs-for-aux [N,E])."""
    k = cfg.moe_top_k
    if cfg.moe_router == "sigmoid":
        aff = jax.nn.sigmoid(scores)
        topw, topi = jax.lax.top_k(aff, k)
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
        probs = aff / jnp.maximum(aff.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(scores, axis=-1)
        topw, topi = jax.lax.top_k(probs, k)
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    return topw, topi, probs


def _aux_loss(cfg, probs: jax.Array, topi: jax.Array) -> jax.Array:
    """Switch-style load balance: E * mean(frac_tokens_e * mean_prob_e)."""
    E = cfg.moe_experts
    counts = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(counts.sum(), 1.0)
    mean_prob = probs.mean(axis=0)
    return E * jnp.sum(frac * mean_prob)


def moe_fwd(cfg, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN.  x: [B,S,D] -> (out [B,S,D], aux_loss).

    Default is capacity dispatch (GShard buffers, shardable over the
    expert axis — what the production dry-run lowers).  Inside a
    ``dropless_moe()`` context the token-local grouped path is used
    instead, which incremental prefill requires (see moe_fwd_dropless)."""
    if _DROPLESS[0]:
        return moe_fwd_dropless(cfg, p, x)
    if _EP[0] or os.environ.get("REPRO_MOE_EP", "") not in ("", "0"):
        return moe_fwd_ep(cfg, p, x)
    B, S, D = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    N = B * S
    xf = x.reshape(N, D)

    scores = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    topw, topi, probs = _route(cfg, scores)
    aux = _aux_loss(cfg, probs, topi)

    # Capacity per expert over the *global* token count; each device sees a
    # data shard, so dispatch below operates on global-logical arrays and
    # GSPMD partitions token dims over ('pod','data') and experts/buffers
    # over 'model'.
    C = max(int(N * k * cfg.moe_capacity_factor) // E, 8)

    # Flat assignments (token-major so earlier tokens win capacity slots).
    e_f = topi.reshape(-1)                      # [N*k]
    w_f = topw.reshape(-1)
    oh = jax.nn.one_hot(e_f, E, dtype=jnp.int32)           # [N*k, E]
    pos = jnp.cumsum(oh, axis=0) - oh                      # exclusive cumsum
    pos_f = jnp.take_along_axis(pos, e_f[:, None], axis=1)[:, 0]
    keep = pos_f < C
    slot = e_f * C + jnp.where(keep, pos_f, 0)

    x_rep = jnp.repeat(xf, k, axis=0)                      # [N*k, D]
    contrib = jnp.where(keep[:, None], x_rep, 0).astype(x.dtype)
    buf = jnp.zeros((E * C, D), x.dtype).at[slot].add(contrib)
    buf = buf.reshape(E, C, D)
    buf = constrain(buf, ("experts", None, "embed"))

    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    h = act(jnp.einsum("ecd,edf->ecf", buf, p["gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["up"]
    )
    h = constrain(h, ("experts", None, "expert_mlp"))
    y = jnp.einsum("ecf,efd->ecd", h, p["down"])
    y = constrain(y, ("experts", None, "embed"))

    y_f = y.reshape(E * C, D)[slot]                        # [N*k, D]
    y_f = y_f * (w_f * keep.astype(jnp.float32))[:, None].astype(y_f.dtype)
    out = y_f.reshape(N, k, D).sum(axis=1).reshape(B, S, D)

    if cfg.moe_shared_experts:
        from .layers import mlp_fwd

        out = out + mlp_fwd(cfg, p["shared"], x.reshape(B, S, D))
    return constrain(out, ("batch", "seq", "embed")), aux


def moe_fwd_ep(cfg, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map — the optimized dispatch.

    The einsum/scatter formulation above leaves GSPMD to reshard tokens
    (sharded over pod/data) against expert buffers (sharded over model);
    it gives up and replicates ("involuntary full rematerialization"),
    costing ~16 TB/device/step of all-reduce wire on deepseek train_4k
    (EXPERIMENTS.md §Perf, hillclimb B).  Here the dispatch never crosses
    the boundary: activations are replicated over 'model' within a data
    row, so each (data, model) device *locally* selects the tokens routed
    to its own E/TP experts, runs the FFN, and one psum over 'model'
    combines the k expert contributions per token — the same wire pattern
    as a Megatron TP matmul (2(g-1)/g x N_local x D per layer).

    Identical routing/capacity semantics to ``moe_fwd`` (token-major
    capacity, same C), numerics equal up to reduction order.
    """
    from ..shardlib import current_ctx

    ctx = current_ctx()
    if ctx is None or "model" not in ctx.axis_sizes \
            or ctx.axis_sizes["model"] <= 1 \
            or cfg.moe_experts % ctx.axis_sizes["model"] != 0:
        with ep_moe(False):
            return moe_fwd(cfg, p, x)
    mesh = ctx.mesh
    tp = ctx.axis_sizes["model"]
    B, S, D = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    N = B * S
    E_loc = E // tp
    batch_axes = tuple(a for a in ("pod", "data") if a in ctx.axis_sizes)
    n_data = 1
    for a in batch_axes:
        n_data *= ctx.axis_sizes[a]
    # Capacity is a *per-dispatch-group* quota (each data shard dispatches
    # its own tokens): size it from the shard-local token count, or the
    # expert buffers carry n_data x zero rows (measured: +53 s compute on
    # deepseek train_4k when sized globally — §Perf hillclimb B iter 2).
    C = max(int(max(N // n_data, 1) * k * cfg.moe_capacity_factor) // E, 8)

    def shard_fn(xf, router, gate, up, down):
        # xf: [N_loc, D] (data shard, replicated over model);
        # gate/up/down: [E_loc, ...] local experts; router replicated.
        m = jax.lax.axis_index("model")
        e0 = m * E_loc
        scores = (xf.astype(jnp.float32) @ router).astype(jnp.float32)
        topw, topi, probs = _route(cfg, scores)
        aux = _aux_loss(cfg, probs, topi) / tp     # psum'd below

        e_f = topi.reshape(-1)
        w_f = topw.reshape(-1)
        # token-major capacity positions computed over ALL experts (same
        # keep-set as the global dispatch), then restricted to local ones.
        oh = jax.nn.one_hot(e_f, E, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=0) - oh
        pos_f = jnp.take_along_axis(pos, e_f[:, None], axis=1)[:, 0]
        keep = pos_f < C
        local = (e_f >= e0) & (e_f < e0 + E_loc) & keep
        slot = (e_f - e0) * C + jnp.where(local, pos_f, 0)
        slot = jnp.where(local, slot, E_loc * C)   # OOB drop lane

        x_rep = jnp.repeat(xf, k, axis=0)
        contrib = jnp.where(local[:, None], x_rep, 0).astype(x.dtype)
        buf = jnp.zeros((E_loc * C + 1, D), x.dtype).at[slot].add(
            contrib, mode="drop").at[E_loc * C].set(0.0)
        buf = buf[:E_loc * C].reshape(E_loc, C, D)

        act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", buf, gate)) * jnp.einsum(
            "ecd,edf->ecf", buf, up)
        y = jnp.einsum("ecf,efd->ecd", h, down).reshape(E_loc * C, D)
        y = jnp.concatenate([y, jnp.zeros((1, D), y.dtype)])
        y_f = y.at[slot].get(mode="fill", fill_value=0)
        y_f = y_f * (w_f * local.astype(jnp.float32))[:, None].astype(y.dtype)
        out = y_f.reshape(-1, k, D).sum(axis=1)
        # combine expert contributions across model columns
        out = jax.lax.psum(out, "model")
        aux = jax.lax.psum(aux, "model")
        return out, aux

    xf = x.reshape(N, D)
    bspec = batch_axes if batch_axes else None
    out, aux = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(bspec, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(bspec, None), P()),
    )(xf, p["router"], p["gate"], p["up"], p["down"])
    out = out.reshape(B, S, D)
    if cfg.moe_shared_experts:
        from .layers import mlp_fwd

        out = out + mlp_fwd(cfg, p["shared"], x)
    return constrain(out, ("batch", "seq", "embed")), aux


def moe_fwd_dropless(cfg, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Dropless MoE: sort tokens by expert, grouped-GEMM via ragged_dot.

    Unlike capacity dispatch, every token reaches all of its top-k experts
    — no competition for capacity slots — so the op is *token-local*: a
    token's output depends only on its own hidden state.  This is what
    makes MoE layers compatible with incremental prefill (change
    propagation), and it is the quality-preserving choice for serving.
    Used automatically in inference mode; training keeps capacity
    dispatch (fixed buffers shard cleanly over the expert axis).
    """
    B, S, D = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    N = B * S
    xf = x.reshape(N, D)

    scores = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    topw, topi, probs = _route(cfg, scores)
    aux = _aux_loss(cfg, probs, topi)

    e_f = topi.reshape(-1)                        # [N*k] expert of each copy
    w_f = topw.reshape(-1)
    order = jnp.argsort(e_f)                      # stable: groups tokens by expert
    x_sorted = jnp.repeat(xf, k, axis=0)[order]
    group_sizes = jnp.bincount(e_f, length=E).astype(jnp.int32)

    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    h = act(jax.lax.ragged_dot(x_sorted, p["gate"], group_sizes)) * \
        jax.lax.ragged_dot(x_sorted, p["up"], group_sizes)
    y_sorted = jax.lax.ragged_dot(h.astype(x.dtype), p["down"], group_sizes)

    inv = jnp.argsort(order)                      # unsort back to token order
    y_f = y_sorted[inv] * w_f[:, None].astype(y_sorted.dtype)
    out = y_f.reshape(N, k, D).sum(axis=1).reshape(B, S, D)

    if cfg.moe_shared_experts:
        from .layers import mlp_fwd

        out = out + mlp_fwd(cfg, p["shared"], x)
    return constrain(out, ("batch", "seq", "embed")), aux


def moe_fwd_ref(cfg, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Oracle: loop over experts, no capacity drops.  Small shapes only."""
    B, S, D = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    xf = x.reshape(-1, D)
    scores = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    topw, topi, probs = _route(cfg, scores)
    aux = _aux_loss(cfg, probs, topi)
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    out = jnp.zeros_like(xf)
    for e in range(E):
        h = act(xf @ p["gate"][e]) * (xf @ p["up"][e])
        ye = h @ p["down"][e]
        w_e = jnp.sum(jnp.where(topi == e, topw, 0.0), axis=-1)
        out = out + ye * w_e[:, None].astype(ye.dtype)
    out = out.reshape(B, S, D)
    if cfg.moe_shared_experts:
        from .layers import mlp_fwd

        out = out + mlp_fwd(cfg, p["shared"], x)
    return out, aux
