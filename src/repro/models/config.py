"""Model and input-shape configuration for the assigned architectures.

Every architecture in the assigned pool is expressed as a ``ModelConfig``.
The configs are intentionally explicit (no "auto" fields): a config fully
determines parameter shapes, the layer pattern, and the serving cache
layout, so the multi-pod dry-run can build exact ``ShapeDtypeStruct``
stand-ins without touching device memory.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "shape_by_name"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape) cell of the assigned architecture x shape grid."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in SHAPES]}")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``family`` selects the assembly path:
      * ``dense``   — decoder-only transformer (GQA/MQA/MHA attention).
      * ``moe``     — decoder-only with mixture-of-experts FFNs.
      * ``hybrid``  — RG-LRU recurrent blocks + local attention (Griffin).
      * ``ssm``     — attention-free state-space model (Mamba-2 / SSD).
      * ``encdec``  — encoder-decoder (audio frontend stubbed).
      * ``vlm``     — decoder-only LM backbone with a stubbed ViT frontend
                      (patch embeddings arrive precomputed).
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 => d_model // num_heads
    # --- attention flavour -------------------------------------------------
    attention: str = "gqa"          # 'gqa' | 'mla' | 'local' | 'none'
    local_window: int = 2048        # for local attention layers
    rope_theta: float = 10_000.0
    # --- FFN ---------------------------------------------------------------
    activation: str = "silu"        # 'silu' (SwiGLU) | 'gelu' (GeGLU) | 'gelu_mlp'
    # --- norms / embeddings ------------------------------------------------
    norm: str = "rmsnorm"           # 'rmsnorm' | 'layernorm'
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    emb_scale: float = 1.0          # MiniCPM scale_emb; Gemma uses sqrt(d)
    emb_scale_sqrt_dim: bool = False
    residual_scale: float = 1.0     # MiniCPM scale_depth / sqrt(L)
    logit_softcap: float = 0.0      # Gemma-style final-logit soft capping
    # --- MoE ---------------------------------------------------------------
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared_experts: int = 0     # DeepSeek shared expert(s)
    moe_dense_layers: int = 0       # leading dense layers (DeepSeek: 3)
    moe_capacity_factor: float = 1.25
    moe_router: str = "softmax"     # 'softmax' | 'sigmoid' (DeepSeek v3)
    moe_dense_residual: bool = False  # Arctic: dense FFN residual in parallel
    d_ff_dense: int = 0             # FFN width for non-MoE layers in MoE archs
                                    # (DeepSeek-V3 dense layers: 18432)
    # --- MLA (DeepSeek) ----------------------------------------------------
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- MTP (DeepSeek multi-token prediction) -----------------------------
    mtp_depth: int = 0
    mtp_loss_weight: float = 0.3
    # --- hybrid (RecurrentGemma / Griffin) ---------------------------------
    # layer pattern repeats: e.g. ('rec', 'rec', 'attn')
    block_pattern: Tuple[str, ...] = ()
    rglru_width: int = 0            # 0 => d_model
    conv_width: int = 4
    # --- SSM (Mamba-2) ------------------------------------------------------
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    # --- enc-dec ------------------------------------------------------------
    enc_layers: int = 0             # encoder layers (decoder = num_layers)
    # --- VLM ----------------------------------------------------------------
    num_patches: int = 0            # stubbed ViT patch embeddings per example
    # --- numerics ----------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # --- training-time policies (overridable per run) -----------------------
    remat: str = "full"             # 'none' | 'full' | 'dots'
    grad_accum: int = 1             # microbatch count for train_step
    optimizer: str = "adamw"        # 'adamw' | 'adamw_bf16' | 'adafactor'
    lr_schedule: str = "cosine"     # 'cosine' | 'wsd'
    # --- serving ------------------------------------------------------------
    cache_dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_heads_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def supports_long_context(self) -> bool:
        """True for sub-quadratic architectures: SSM / hybrid local-attn.

        Pure full-attention architectures skip the ``long_500k`` shape (the
        skip is recorded in DESIGN.md as required)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (analytic), used for 6*N*D roofline terms.
    def param_count(self, active_only: bool = False) -> int:
        from . import params as _p  # local import to avoid cycles
        return _p.count_params(self, active_only=active_only)
