"""Shared neural-net building blocks (pure JAX, sharding-annotated)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..shardlib import constrain, pad_to_multiple
from .params import ParamSpec

__all__ = [
    "rmsnorm",
    "layernorm",
    "norm_spec",
    "apply_norm",
    "rope",
    "apply_rope",
    "mlp_specs",
    "mlp_fwd",
    "embed_specs",
    "embed_tokens",
    "lm_logits",
    "cross_entropy",
    "VOCAB_PAD_MULTIPLE",
]

VOCAB_PAD_MULTIPLE = 2048  # 16-way model sharding x 128-lane alignment


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float, plus_one: bool = False) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    s = scale.astype(jnp.float32)
    if plus_one:
        s = 1.0 + s
    return (x * s).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_spec(cfg, shape_prefix: Tuple[int, ...] = ()) -> dict:
    d = cfg.d_model
    lead = tuple("layers" for _ in shape_prefix)
    if cfg.norm == "layernorm":
        return {
            "scale": ParamSpec(shape_prefix + (d,), lead + ("embed",), cfg.pdtype, "ones"),
            "bias": ParamSpec(shape_prefix + (d,), lead + ("embed",), cfg.pdtype, "zeros"),
        }
    init = "zeros" if _gemma_style(cfg) else "ones"
    return {"scale": ParamSpec(shape_prefix + (d,), lead + ("embed",), cfg.pdtype, init)}


def _gemma_style(cfg) -> bool:
    return cfg.emb_scale_sqrt_dim  # gemma family: (1+scale) RMSNorm


def apply_norm(cfg, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps, plus_one=_gemma_style(cfg))


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """Return (sin, cos) of shape positions.shape + (dim/2,), float32."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: [..., seq, heads, dim]; sin/cos: [..., seq, dim/2]."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    s = sin[..., None, :]
    c = cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------
def residual_out_scale(cfg) -> float:
    """GPT-2/Megatron depth scaling for residual *output* projections:
    std = fan_in^-1/2 / sqrt(2L).  Without it the per-block backward gain
    at init compounds exponentially in depth (measured: grad norms x166
    going 4 -> 12 layers at d_model=768; EXPERIMENTS.md, 100M driver)."""
    import math as _m

    return 1.0 / _m.sqrt(2.0 * max(cfg.num_layers, 1))


def mlp_specs(cfg, L: int, d_ff: Optional[int] = None, d_in: Optional[int] = None) -> dict:
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    lead: Tuple[int, ...] = (L,) if L else ()
    lax: Tuple[str, ...] = ("layers",) if L else ()
    dt = cfg.pdtype
    return {
        "gate": ParamSpec(lead + (d, f), lax + ("embed", "mlp"), dt),
        "up": ParamSpec(lead + (d, f), lax + ("embed", "mlp"), dt),
        "down": ParamSpec(lead + (f, d), lax + ("mlp", "embed"), dt,
                          scale=residual_out_scale(cfg)),
    }


def mlp_fwd(cfg, p: dict, x: jax.Array) -> jax.Array:
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[
        "gelu" if cfg.activation.startswith("gelu") else "silu"
    ]
    h = act(x @ p["gate"]) * (x @ p["up"])
    h = constrain(h, ("batch", None, "mlp"))
    return h @ p["down"]


# ---------------------------------------------------------------------------
# Embeddings and logits
# ---------------------------------------------------------------------------
def padded_vocab(cfg) -> int:
    return pad_to_multiple(cfg.vocab_size, VOCAB_PAD_MULTIPLE)


def embed_specs(cfg) -> dict:
    v = padded_vocab(cfg)
    d = cfg.d_model
    specs = {
        "emb": ParamSpec((v, d), ("vocab", "embed"), cfg.pdtype, "embed", scale=0.02),
        "out_norm": norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, v), ("embed", "vocab"), cfg.pdtype)
    return specs


def embed_tokens(cfg, p: dict, tokens: jax.Array) -> jax.Array:
    x = p["emb"][tokens]
    if cfg.emb_scale_sqrt_dim:
        x = (x.astype(jnp.float32) * jnp.sqrt(float(cfg.d_model))).astype(x.dtype)
    elif cfg.emb_scale != 1.0:
        x = (x.astype(jnp.float32) * cfg.emb_scale).astype(x.dtype)
    return constrain(x, ("batch", "seq", "embed"))


def lm_logits(cfg, p: dict, x: jax.Array) -> jax.Array:
    x = apply_norm(cfg, p["out_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ p["emb"].T
        # MiniCPM-style logit scaling for tied mu-parameterized embeddings.
        if cfg.emb_scale != 1.0:
            logits = logits / (cfg.d_model / 256.0)
    else:
        logits = x @ p["lm_head"]
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    # Mask padded vocabulary entries.
    v = padded_vocab(cfg)
    if v != cfg.vocab_size:
        neg = jnp.asarray(-1e9, logits.dtype)
        mask = jnp.arange(v) < cfg.vocab_size
        logits = jnp.where(mask, logits, neg)
    return constrain(logits, ("batch", "seq", "vocab"))


def cross_entropy(
    cfg, logits: jax.Array, labels: jax.Array, z_loss: float = 1e-4
) -> jax.Array:
    """Token-mean CE in fp32 with optional z-loss; labels < 0 are masked."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    safe = jnp.maximum(labels, 0)
    picked = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# Materializing [tokens, vocab] fp32 logits dominates training memory for
# large vocabularies (gemma-7b: 4k tokens/device x 256k vocab x 4B = 4 GiB
# per microbatch, x2 for the cotangent).  Above this element threshold the
# loss switches to a chunked schedule: logits are produced and reduced one
# sequence chunk at a time under jax.checkpoint, so the backward pass
# recomputes each chunk's logits instead of storing them.
CHUNKED_XENT_THRESHOLD = 1 << 27


def chunked_cross_entropy(
    cfg,
    tok_params: dict,
    h: jax.Array,
    labels: jax.Array,
    z_loss: float = 1e-4,
    chunk: int = 1024,
) -> jax.Array:
    """CE over lm_logits(h) without materializing full logits.

    h: [B, S, D]; labels: [B, S] (negatives masked).  Returns token-mean
    NLL (+ z-loss), numerically identical to the direct path."""
    B, S, D = h.shape
    N = B * S
    v = padded_vocab(cfg)
    if N * v <= CHUNKED_XENT_THRESHOLD or N % chunk != 0:
        logits = lm_logits(cfg, tok_params, h)
        return cross_entropy(cfg, logits, labels, z_loss)

    hf = h.reshape(N, D)
    lf = labels.reshape(N)
    nc = N // chunk
    hc = hf.reshape(nc, chunk, D)
    lc = lf.reshape(nc, chunk)

    @jax.checkpoint
    def chunk_loss(args):
        hx, lx = args
        logits = lm_logits(cfg, tok_params, hx[None])[0].astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(lx, 0)[:, None], axis=-1
        )[:, 0]
        nll = lse - picked
        if z_loss:
            nll = nll + z_loss * jnp.square(lse)
        mask = (lx >= 0).astype(jnp.float32)
        return jnp.sum(nll * mask), jnp.sum(mask)

    def body(carry, args):
        tot, cnt = carry
        s, c = chunk_loss(args)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)
