"""Model zoo: the 10 assigned architectures in pure JAX."""
from .api import Model, build_model, input_specs, model_specs
from .config import ModelConfig, ShapeSpec, SHAPES, shape_by_name

__all__ = [
    "Model",
    "build_model",
    "input_specs",
    "model_specs",
    "ModelConfig",
    "ShapeSpec",
    "SHAPES",
    "shape_by_name",
]
