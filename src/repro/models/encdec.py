"""Encoder-decoder transformer (SeamlessM4T-large-v2 text/speech backbone).

The speech frontend (conformer feature encoder) is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings of
shape [B, S_enc, d_model].  This module implements the transformer
backbone: a bidirectional encoder over frames and a causal decoder with
cross-attention, sharing the layer building blocks with ``lm.py``.

Sequence budget: a shape cell with seq_len S is split S/2 encoder frames +
S/2 decoder tokens so each cell processes exactly S positions.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..shardlib import constrain
from . import attention as attn
from .layers import (
    apply_norm,
    chunked_cross_entropy,
    cross_entropy,
    embed_specs,
    embed_tokens,
    lm_logits,
    mlp_fwd,
    mlp_specs,
    norm_spec,
)

__all__ = ["encdec_specs", "encdec_loss", "encdec_prefill",
           "encdec_decode_step", "encdec_cache_shapes"]


def encdec_specs(cfg) -> dict:
    Le = cfg.enc_layers or cfg.num_layers
    Ld = cfg.num_layers
    return {
        "tok": embed_specs(cfg),
        "enc_blocks": {
            "ln1": norm_spec(cfg, (Le,)),
            "attn": attn.attn_specs(cfg, Le),
            "ln2": norm_spec(cfg, (Le,)),
            "mlp": mlp_specs(cfg, Le),
        },
        "enc_norm": norm_spec(cfg),
        "dec_blocks": {
            "ln1": norm_spec(cfg, (Ld,)),
            "self_attn": attn.attn_specs(cfg, Ld),
            "ln_x": norm_spec(cfg, (Ld,)),
            "cross_attn": attn.attn_specs(cfg, Ld),
            "ln2": norm_spec(cfg, (Ld,)),
            "mlp": mlp_specs(cfg, Ld),
        },
    }


def _encode(cfg, params, frames: jax.Array) -> jax.Array:
    """frames: [B, S_enc, D] (stubbed frontend output)."""
    x = frames.astype(cfg.cdtype)
    x = constrain(x, ("batch", "seq", "embed"))
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None, :], x.shape[:2])

    def blk(x, p):
        h = apply_norm(cfg, p["ln1"], x)
        a, _ = attn.attention_fwd(cfg, p["attn"], h, positions,
                                  causal=False, impl="blocked")
        x = x + a
        h = apply_norm(cfg, p["ln2"], x)
        x = x + mlp_fwd(cfg, p["mlp"], h)
        return x, None

    f = jax.checkpoint(blk) if cfg.remat != "none" else blk
    x, _ = jax.lax.scan(f, x, params["enc_blocks"])
    return apply_norm(cfg, params["enc_norm"], x)


def _decode_blocks(cfg, params, x, positions, enc_out, *, collect_cache=False):
    def blk(x, p):
        h = apply_norm(cfg, p["ln1"], x)
        a, kv = attn.attention_fwd(cfg, p["self_attn"], h, positions,
                                   causal=True, impl="blocked")
        x = x + a
        h = apply_norm(cfg, p["ln_x"], x)
        ckv = attn.cross_kv(cfg, p["cross_attn"], enc_out)
        x = x + attn.cross_attention_fwd(cfg, p["cross_attn"], h, ckv)
        h = apply_norm(cfg, p["ln2"], x)
        x = x + mlp_fwd(cfg, p["mlp"], h)
        return x, (kv, ckv) if collect_cache else None

    f = jax.checkpoint(blk) if cfg.remat != "none" else blk
    x, caches = jax.lax.scan(f, x, params["dec_blocks"])
    return x, caches


def encdec_loss(cfg, params, batch, **_) -> Tuple[jax.Array, Dict]:
    """batch: {'frames': [B,Se,D], 'tokens': [B,Sd], 'labels': [B,Sd]}."""
    enc_out = _encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params["tok"], tokens)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None, :], x.shape[:2])
    h, _ = _decode_blocks(cfg, params, x, positions, enc_out)
    loss = chunked_cross_entropy(cfg, params["tok"], h, batch["labels"])
    return loss, {"loss": loss, "ce": loss}


def encdec_cache_shapes(cfg, batch: int, cache_len: int):
    Ld = cfg.num_layers
    KV = cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    cdt = jnp.dtype(cfg.cache_dtype)
    enc_len = cache_len  # encoder length mirrors the decoder budget
    return {
        "k": jax.ShapeDtypeStruct((Ld, batch, cache_len, KV, hd), cdt),
        "v": jax.ShapeDtypeStruct((Ld, batch, cache_len, KV, hd), cdt),
        "xk": jax.ShapeDtypeStruct((Ld, batch, enc_len, KV, hd), cdt),
        "xv": jax.ShapeDtypeStruct((Ld, batch, enc_len, KV, hd), cdt),
    }


def encdec_prefill(cfg, params, batch, **_):
    """Encoder pass + decoder prefill.  Returns (last logits, cache)."""
    enc_out = _encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params["tok"], tokens)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None, :], x.shape[:2])
    h, caches = _decode_blocks(cfg, params, x, positions, enc_out,
                               collect_cache=True)
    (k, v), (xk, xv) = caches
    cdt = jnp.dtype(cfg.cache_dtype)
    cache = {"k": k.astype(cdt), "v": v.astype(cdt),
             "xk": xk.astype(cdt), "xv": xv.astype(cdt)}
    logits = lm_logits(cfg, params["tok"], h[:, -1:, :])
    return logits, cache


def encdec_decode_step(cfg, params, cache, tokens, pos, *, decode_impl="naive"):
    """One decoder step with cached self/cross KV."""
    x = embed_tokens(cfg, params["tok"], tokens)

    def blk(carry, inp):
        x = carry
        p, k, v, xk, xv = inp
        h = apply_norm(cfg, p["ln1"], x)
        a, (k2, v2) = attn.decode_attention(cfg, p["self_attn"], h, k, v, pos,
                                            impl=decode_impl)
        x = x + a
        h = apply_norm(cfg, p["ln_x"], x)
        x = x + attn.cross_attention_fwd(cfg, p["cross_attn"], h, (xk, xv))
        h = apply_norm(cfg, p["ln2"], x)
        x = x + mlp_fwd(cfg, p["mlp"], h)
        return x, (k2, v2)

    x, (k2, v2) = jax.lax.scan(
        blk, x, (params["dec_blocks"], cache["k"], cache["v"],
                 cache["xk"], cache["xv"]))
    logits = lm_logits(cfg, params["tok"], x)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = k2, v2
    return logits, new_cache
