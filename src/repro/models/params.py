"""Parameter declaration: one source of truth for shape/axes/init.

Model modules declare nested dicts of ``ParamSpec``; this module turns a
spec tree into (a) abstract ShapeDtypeStructs for the dry-run, (b) real
initialized arrays for smoke tests / training, (c) logical-axes trees for
sharding, and (d) analytic parameter counts for roofline math.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ParamSpec",
    "abstract_tree",
    "init_tree",
    "axes_tree",
    "count_tree",
    "count_params",
]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "fan_in"     # 'fan_in' | 'normal' | 'zeros' | 'ones' | 'embed'
    scale: float = 1.0
    fan_axis: int = -2       # which axis is fan-in for 'fan_in' init
    fan: Optional[int] = None  # explicit fan-in override (3D projections:
                               # (D,H,hd) contracts D, (H,hd,D) contracts
                               # H*hd — a single axis cannot express either)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def abstract_tree(specs) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        specs,
        is_leaf=_is_spec,
    )


def axes_tree(specs) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def init_tree(specs, key: jax.Array) -> Any:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def _init_leaf(spec: ParamSpec, key: jax.Array) -> jax.Array:
    dt = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "embed":
        std = spec.scale
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)
    if spec.init == "normal":
        return (jax.random.normal(key, spec.shape, jnp.float32) * spec.scale).astype(dt)
    # fan_in (truncated-normal / sqrt(fan_in)); 'layers' leading axes are
    # excluded from fan-in by convention (fan_axis counts from the right).
    if spec.fan is not None:
        fan = spec.fan
    else:
        fan = spec.shape[spec.fan_axis] if len(spec.shape) >= 2 else spec.shape[0]
    std = spec.scale / math.sqrt(max(fan, 1))
    x = jax.random.truncated_normal(key, -2.0, 2.0, spec.shape, jnp.float32)
    return (x * std).astype(dt)


def count_tree(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def count_params(cfg, active_only: bool = False) -> int:
    """Analytic parameter count; with ``active_only`` MoE experts count only
    top_k (+shared) of the routed experts — the 6*N_active*D roofline N."""
    from .api import model_specs  # late import to avoid cycles

    specs = model_specs(cfg)
    total = count_tree(specs)
    if active_only and cfg.moe_experts:
        # Subtract the inactive routed-expert fraction analytically.
        expert_leaves = jax.tree.leaves(
            _filter_experts(specs), is_leaf=_is_spec
        )
        routed = int(sum(np.prod(s.shape) for s in expert_leaves))
        active_frac = cfg.moe_top_k / cfg.moe_experts
        total -= int(routed * (1.0 - active_frac))
    return total


def _filter_experts(specs):
    """Sub-tree of specs whose logical axes include 'experts' with size>1."""
    out = {}
    def rec(node, path):
        if _is_spec(node):
            if "experts" in node.axes:
                i = node.axes.index("experts")
                if node.shape[i] > 1:
                    out["/".join(path)] = node
            return
        if isinstance(node, dict):
            for k, v in node.items():
                rec(v, path + [str(k)])
    rec(specs, [])
    return out
