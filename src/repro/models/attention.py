"""Attention: GQA/MQA/MHA, local (sliding-window), cross-attention, decode.

Three execution paths:

* ``naive``   — materializes the [Sq, Skv] score matrix.  Used for smoke
  tests and short sequences; the numerical oracle for everything else.
* ``blocked`` — flash-attention-style streaming softmax over KV blocks in
  pure JAX (lax.scan).  Bounded VMEM/temp footprint; this is what the
  multi-pod dry-run lowers, and it mirrors the Pallas kernel in
  ``repro.kernels.flash_attention`` op-for-op.
* decode      — single-token step against a long KV cache.  The baseline
  keeps the cache sharded along sequence and lets GSPMD insert the
  all-gather (paper-faithful naive propagation); the optimized path
  (``decode_impl='flash_sharded'``) computes per-shard partial softmax
  and combines with log-sum-exp via shard_map — flash-decoding on TPU.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..shardlib import constrain, current_ctx, shard_map
from .layers import apply_rope, residual_out_scale as _residual_out_scale, rope
from .params import ParamSpec

__all__ = [
    "attn_specs",
    "attention_fwd",
    "decode_attention",
    "cross_attention_fwd",
    "cross_kv",
    "inference_mode",
]

NEG_INF = -2.0e38

# Inference mode enables the dynamically-bounded causal block-skip in
# blocked attention (lax.fori_loop with a data-dependent trip count is not
# reverse-differentiable, so training uses the masked full sweep — the 2x
# causal FLOP waste it causes is tracked in EXPERIMENTS.md §Perf).
_INFERENCE = [False]


import contextlib


@contextlib.contextmanager
def inference_mode(on: bool = True):
    prev = _INFERENCE[0]
    _INFERENCE[0] = on
    try:
        yield
    finally:
        _INFERENCE[0] = prev


def attn_specs(
    cfg,
    L: int,
    heads: Optional[int] = None,
    kv_heads: Optional[int] = None,
    head_dim: Optional[int] = None,
) -> dict:
    H = heads or cfg.num_heads
    KV = kv_heads or cfg.num_kv_heads
    hd = head_dim or cfg.resolved_head_dim
    D = cfg.d_model
    lead: Tuple[int, ...] = (L,) if L else ()
    lax: Tuple[str, ...] = ("layers",) if L else ()
    dt = cfg.pdtype
    return {
        "wq": ParamSpec(lead + (D, H, hd), lax + ("embed", "q_heads", "head_dim"), dt, fan=D),
        "wk": ParamSpec(lead + (D, KV, hd), lax + ("embed", "kv_heads", "head_dim"), dt, fan=D),
        "wv": ParamSpec(lead + (D, KV, hd), lax + ("embed", "kv_heads", "head_dim"), dt, fan=D),
        "wo": ParamSpec(lead + (H, hd, D), lax + ("q_heads", "head_dim", "embed"), dt,
                        scale=_residual_out_scale(cfg), fan=H * hd),
    }


# ---------------------------------------------------------------------------
# Full-sequence attention (training / prefill)
# ---------------------------------------------------------------------------
# Runtime head padding: when the (assigned, immutable) head count does not
# divide the 'model' mesh axis, the sharding rules fall back to replicating
# the whole attention block — observed 12x excess attention FLOPs/bytes per
# device for minicpm-2b (36 heads on a 16-way axis; EXPERIMENTS.md §Perf,
# hillclimb A).  Padding Q/K/V/O with zero heads up to the next multiple
# restores even sharding and is exact: zero keys give uniform softmax over
# zero values -> zero head output -> zero O-projection rows contribute
# nothing.  Applies to MHA (H == KV) layers; GQA with non-dividing KV
# groups cannot pad this way (reshape resharding, see DESIGN.md).
_PAD_HEADS = [True]


@contextlib.contextmanager
def head_padding(on: bool = True):
    prev = _PAD_HEADS[0]
    _PAD_HEADS[0] = on
    try:
        yield
    finally:
        _PAD_HEADS[0] = prev


def _pad_axis(w: jax.Array, axis: int, to: int) -> jax.Array:
    pad = [(0, 0)] * w.ndim
    pad[axis] = (0, to - w.shape[axis])
    return jnp.pad(w, pad)


def attention_fwd(
    cfg,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    impl: str = "blocked",
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
    q_block: int = 512,
    kv_block: int = 512,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Self-attention over a full sequence.

    Returns (output, (k, v)) — k/v are returned so prefill can populate the
    decode cache (always at the architecture's true head count, even when
    compute ran head-padded).  ``kv_override`` makes it cross-attention.
    """
    B, S, D = x.shape
    H = p["wq"].shape[-2]
    KV0 = p["wk"].shape[-2]
    hd = p["wq"].shape[-1]

    wq, wk, wv, wo = p["wq"], p["wk"], p["wv"], p["wo"]
    ctx = current_ctx()
    tp = ctx.axis_sizes.get("model", 1) if ctx is not None else 1
    padded = False
    if (_PAD_HEADS[0] and kv_override is None and tp > 1 and H % tp
            and H == KV0):
        Hp = -(-H // tp) * tp
        wq = _pad_axis(wq, wq.ndim - 2, Hp)
        wk = _pad_axis(wk, wk.ndim - 2, Hp)
        wv = _pad_axis(wv, wv.ndim - 2, Hp)
        wo = _pad_axis(wo, wo.ndim - 3, Hp)
        padded = True

    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, wk)
        v = jnp.einsum("bsd,dhk->bshk", x, wv)
        sin, cos = rope(positions, hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    else:
        k, v = kv_override
    q = constrain(q, ("batch", "seq", "q_heads", "head_dim"))
    k = constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "seq", "kv_heads", "head_dim"))

    if impl == "blocked" and q.shape[1] >= 2 * q_block:
        o = _blocked_attention(q, k, v, causal=causal, window=window,
                               q_block=q_block, kv_block=kv_block)
    else:
        o = _naive_attention(q, k, v, causal=causal, window=window)
    o = constrain(o, ("batch", "seq", "q_heads", "head_dim"))
    out = jnp.einsum("bshk,hkd->bsd", o, wo)
    if padded:
        k = k[:, :, :KV0]       # decode cache keeps the true head count
        v = v[:, :, :KV0]
    return constrain(out, ("batch", "seq", "embed")), (k, v)


def _group(q: jax.Array, KV: int) -> jax.Array:
    """[B,S,H,hd] -> [B,S,KV,G,hd] grouping query heads by kv head."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, KV, H // KV, hd)


def _naive_attention(q, k, v, *, causal: bool, window: int) -> jax.Array:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    hv = v.shape[-1]
    qg = _group(q, KV)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    Skv = k.shape[1]
    iq = jnp.arange(Sq)[:, None] + (Skv - Sq)  # align ends (prefill offset)
    jk = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= jk <= iq
    if window:
        mask &= jk > iq - window
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return o.reshape(B, Sq, H, hv)


def _blocked_attention(q, k, v, *, causal: bool, window: int,
                       q_block: int, kv_block: int) -> jax.Array:
    """Flash attention (streaming softmax, custom VJP, block-skip).

    The block-skip — only visiting KV blocks the causal/window mask can
    reach — is the compiled-HLO analogue of change propagation's "do not
    descend unmarked subtrees".  See repro.models.flash for the VJP."""
    from .flash import flash_attention_grouped

    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    KV = k.shape[2]
    hv = v.shape[-1]
    if Sq % q_block or Skv % kv_block:
        return _naive_attention(q, k, v, causal=causal, window=window)
    qg = _group(q, KV)
    o = flash_attention_grouped(
        qg, k, v, causal=causal, window=window, offset=Skv - Sq,
        q_block=q_block, kv_block=kv_block, skip=True,
    )
    return o.reshape(B, Sq, H, hv)


# ---------------------------------------------------------------------------
# Decode (one new token against a cached context)
# ---------------------------------------------------------------------------
def decode_attention(
    cfg,
    p: dict,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    pos: jax.Array,
    *,
    window: int = 0,
    impl: str = "naive",
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One decode step.

    x: [B, 1, D]; cache_k/v: [B, S, KV, hd]; pos: [B] next position.
    Returns (out [B,1,D], updated cache).
    """
    B, _, D = x.shape
    H = p["wq"].shape[-2]
    hd = p["wq"].shape[-1]
    KV = cache_k.shape[2]
    S = cache_k.shape[1]

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    sin, cos = rope(pos[:, None], hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k_new = apply_rope(k_new, sin, cos)

    if window:
        # Ring-buffer cache for sliding-window attention.
        slot = (pos % S)[:, None]
    else:
        slot = pos[:, None]
    upd = lambda c, n, s: jax.vmap(
        lambda cb, nb, sb: jax.lax.dynamic_update_slice(cb, nb, (sb, 0, 0))
    )(c, n, s[:, 0])
    cache_k = upd(cache_k, k_new, slot)
    cache_v = upd(cache_v, v_new, slot)
    cache_k = constrain(cache_k, ("batch", "cache_seq", "kv_heads", "head_dim"))
    cache_v = constrain(cache_v, ("batch", "cache_seq", "kv_heads", "head_dim"))

    if impl == "flash_sharded" and current_ctx() is not None:
        o = _flash_decode_sharded(q, cache_k, cache_v, pos, window=window, ring=bool(window))
    else:
        o = _decode_ref(q, cache_k, cache_v, pos, window=window, ring=bool(window))
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return constrain(out, ("batch", None, "embed")), (cache_k, cache_v)


def _decode_mask(S: int, pos: jax.Array, window: int, ring: bool) -> jax.Array:
    """[B, S] validity mask of cache entries for the current token."""
    idx = jnp.arange(S)[None, :]
    if not window:
        return idx <= pos[:, None]
    if not ring:
        return (idx <= pos[:, None]) & (idx > pos[:, None] - window)
    # Ring buffer: entries wrap; slots hold positions within `window` of pos
    # by construction once warm; before warm-up only slots <= pos are valid.
    return (idx <= pos[:, None]) | (pos[:, None] >= S)


def _decode_ref(q, ck, cv, pos, *, window: int, ring: bool) -> jax.Array:
    B, one, H, hd = q.shape
    KV = ck.shape[2]
    S = ck.shape[1]
    qg = _group(q, KV)  # [B,1,KV,G,hd]
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, ck).astype(jnp.float32)
    s = s / math.sqrt(hd)
    mask = _decode_mask(S, pos, window, ring)[:, None, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, cv)
    return o.reshape(B, one, H, hd)


def _flash_decode_sharded(q, ck, cv, pos, *, window: int, ring: bool) -> jax.Array:
    """Flash-decoding: per-shard partial softmax over the sequence-sharded
    cache, combined across 'model' with a log-sum-exp reduction.

    This replaces GSPMD's all-gather of the whole KV cache (O(S) bytes on
    the wire per token) with an O(heads * head_dim) psum — the decode
    analogue of propagating only the affected frontier."""
    ctx = current_ctx()
    mesh = ctx.mesh
    axis = "model"
    if axis not in mesh.axis_names:
        return _decode_ref(q, ck, cv, pos, window=window, ring=ring)
    n_shards = ctx.axis_sizes[axis]
    S = ck.shape[1]
    if S % n_shards != 0:
        return _decode_ref(q, ck, cv, pos, window=window, ring=ring)
    B, one, H, hd = q.shape
    KV = ck.shape[2]
    G = H // KV
    other = tuple(a for a in mesh.axis_names if a != axis)

    def shard_fn(q_, ck_, cv_, pos_):
        # ck_/cv_: [B', S/n, KV, hd] local shard; q_ replicated over 'model'.
        i = jax.lax.axis_index(axis)
        S_loc = ck_.shape[1]
        base = i * S_loc
        idx = base + jnp.arange(S_loc)[None, :]
        if not window:
            mask = idx <= pos_[:, None]
        elif not ring:
            mask = (idx <= pos_[:, None]) & (idx > pos_[:, None] - window)
        else:
            mask = (idx <= pos_[:, None]) | (pos_[:, None] >= S)
        qg = _group(q_, KV)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, ck_).astype(jnp.float32)
        s = s / math.sqrt(hd)
        s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
        m = s.max(axis=-1)
        pe = jnp.exp(s - m[..., None])
        l = pe.sum(axis=-1)
        acc = jnp.einsum("bkgqs,bskh->bkgqh", pe.astype(cv_.dtype), cv_)
        acc = acc.astype(jnp.float32)
        # LSE-combine across shards.
        m_all = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - m_all)
        l_c = jax.lax.psum(l * corr, axis)
        acc_c = jax.lax.psum(acc * corr[..., None], axis)
        o = acc_c / jnp.maximum(l_c[..., None], 1e-30)
        o = jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(q_.shape[0], one, H, hd)
        return o.astype(q_.dtype)

    bspec = other if other else None  # batch dim shards over non-model axes
    out = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None, None),
            P(bspec, axis, None, None),
            P(bspec, axis, None, None),
            P(bspec),
        ),
        out_specs=P(bspec, None, None, None),
    )(q, ck, cv, pos)
    return out


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------
def cross_kv(cfg, p: dict, enc_out: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Precompute cross-attention K/V from encoder output (no RoPE)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v


def cross_attention_fwd(cfg, p: dict, x: jax.Array, kv: Tuple[jax.Array, jax.Array]):
    """Cross-attention: queries from x, keys/values precomputed."""
    B, Sq, D = x.shape
    hd = p["wq"].shape[-1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k, v = kv
    o = _naive_attention(q, k, v, causal=False, window=0)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return constrain(out, ("batch", None, "embed"))
