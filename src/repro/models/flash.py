"""Flash attention in pure JAX with a custom VJP.

Forward: streaming-softmax over KV blocks (saves only O(S * head_dim)
output + log-sum-exp, never the S x S score matrix).  Backward: two
block-sparse passes that *recompute* scores per block — dq in q-major
order, dk/dv in kv-major order.  Because the VJP is hand-written, the
causal/windowed block-skip (dynamic fori_loop bounds) is legal in both
directions; plain ``jax.grad`` over a lax.scan attention would instead
stack every block's probabilities (observed: 9 GiB fp32 per layer for a
4k sequence — see EXPERIMENTS.md §Perf, minicpm train_4k iteration 1).

This module is also the numerical oracle mirrored by the Pallas TPU
kernel in ``repro.kernels.flash_attention`` (same blocking, same
streaming-softmax algebra).

Layout: q [B, Sq, KV, G, hd] (grouped query heads), k/v [B, Skv, KV, hd].
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_grouped"]

NEG_INF = -2.0e38


def _mask(iq, jk, causal: bool, window: int):
    m = jnp.ones(jnp.broadcast_shapes(iq.shape, jk.shape), bool)
    if causal:
        m &= jk <= iq
    if window:
        m &= jk > iq - window
    return m


@functools.lru_cache(maxsize=64)
def _make_flash(causal: bool, window: int, offset: int,
                q_block: int, kv_block: int, skip: bool):
    """Build a custom-VJP flash attention for a static configuration."""

    def _bounds_q(qi, nk):
        """KV block range [lo, hi) visible to query block qi."""
        if not (causal or window):
            return 0, nk
        hi = ((offset + (qi + 1) * q_block + kv_block - 1) // kv_block) if causal else nk
        hi = jnp.minimum(hi, nk)
        lo = jnp.maximum((offset + qi * q_block - window) // kv_block, 0) if window else 0
        return lo, hi

    def _bounds_kv(kj, nq):
        """Q block range [lo, hi) that sees kv block kj."""
        lo = jnp.maximum((kj * kv_block - offset) // q_block, 0) if causal else 0
        if window:
            hi = ((kj + 1) * kv_block + window - offset + q_block - 1) // q_block
            hi = jnp.minimum(hi, nq)
        else:
            hi = nq
        return lo, hi

    def _scores(qblk, kblk, qi, kj, scale):
        s = jnp.einsum("bqkgh,bskh->bkgqs", qblk, kblk).astype(jnp.float32)
        s = s * scale
        iq = offset + qi * q_block + jnp.arange(q_block)[:, None]
        jk = kj * kv_block + jnp.arange(kv_block)[None, :]
        return jnp.where(_mask(iq, jk, causal, window), s, NEG_INF)

    def fwd(q, k, v):
        B, Sq, KV, G, hd = q.shape
        Skv = k.shape[1]
        hv = v.shape[-1]
        nq, nk = Sq // q_block, Skv // kv_block
        scale = 1.0 / math.sqrt(hd)
        qb = q.reshape(B, nq, q_block, KV, G, hd)
        kb = k.reshape(B, nk, kv_block, KV, hd)
        vb = v.reshape(B, nk, kv_block, KV, hv)

        def q_step(_, qi):
            qblk = qb[:, qi]

            def kv_body(kj, carry):
                m, l, acc = carry
                s = _scores(qblk, kb[:, kj], qi, kj, scale)
                m_new = jnp.maximum(m, s.max(axis=-1))
                alpha = jnp.exp(m - m_new)
                pe = jnp.exp(s - m_new[..., None])
                l_new = l * alpha + pe.sum(axis=-1)
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bkgqs,bskh->bkgqh", pe.astype(v.dtype), vb[:, kj]
                ).astype(jnp.float32)
                return m_new, l_new, acc_new

            m0 = jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
            a0 = jnp.zeros((B, KV, G, q_block, hv), jnp.float32)
            if skip:
                lo, hi = _bounds_q(qi, nk)
                m, l, acc = jax.lax.fori_loop(lo, hi, kv_body, (m0, l0, a0))
            else:
                def body(c, kj):
                    return kv_body(kj, c), None
                (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nk))
            l = jnp.maximum(l, 1e-30)
            o = (acc / l[..., None]).astype(q.dtype)   # [B,KV,G,qb,hv]
            lse = m + jnp.log(l)                       # [B,KV,G,qb]
            return None, (o, lse)

        _, (ob, lseb) = jax.lax.scan(q_step, None, jnp.arange(nq))
        # ob: [nq,B,KV,G,qb,hv] -> [B,Sq,KV,G,hv]
        o = jnp.transpose(ob, (1, 0, 4, 2, 3, 5)).reshape(B, Sq, KV, G, hv)
        lse = jnp.transpose(lseb, (1, 0, 4, 2, 3)).reshape(B, Sq, KV, G)
        return o, lse

    def flash(q, k, v):
        o, _ = fwd(q, k, v)
        return o

    def flash_fwd(q, k, v):
        o, lse = fwd(q, k, v)
        return o, (q, k, v, o, lse)

    def flash_bwd(res, do):
        q, k, v, o, lse = res
        B, Sq, KV, G, hd = q.shape
        Skv = k.shape[1]
        hv = v.shape[-1]
        nq, nk = Sq // q_block, Skv // kv_block
        scale = 1.0 / math.sqrt(hd)
        qb = q.reshape(B, nq, q_block, KV, G, hd)
        kb = k.reshape(B, nk, kv_block, KV, hd)
        vb = v.reshape(B, nk, kv_block, KV, hv)
        dob = do.reshape(B, nq, q_block, KV, G, hv)
        lseb = lse.reshape(B, nq, q_block, KV, G)
        # D_i = rowsum(do * o)  [B,nq,qb,KV,G]
        Dfull = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
        Db = Dfull.reshape(B, nq, q_block, KV, G)

        # ---- pass 1: dq (q-major) ----------------------------------------
        def dq_step(_, qi):
            qblk = qb[:, qi]
            doblk = dob[:, qi]        # [B,qb,KV,G,hv]
            lse_q = lseb[:, qi]       # [B,qb,KV,G]
            D_q = Db[:, qi]

            def kv_body(kj, dq_acc):
                s = _scores(qblk, kb[:, kj], qi, kj, scale)
                p = jnp.exp(s - jnp.transpose(lse_q, (0, 2, 3, 1))[..., None])
                dp = jnp.einsum("bqkgh,bskh->bkgqs", doblk, vb[:, kj]).astype(jnp.float32)
                ds = p * (dp - jnp.transpose(D_q, (0, 2, 3, 1))[..., None])
                dq_acc = dq_acc + jnp.einsum(
                    "bkgqs,bskh->bqkgh", ds.astype(q.dtype), kb[:, kj]
                ).astype(jnp.float32)
                return dq_acc

            dq0 = jnp.zeros((B, q_block, KV, G, hd), jnp.float32)
            if skip:
                lo, hi = _bounds_q(qi, nk)
                dq = jax.lax.fori_loop(lo, hi, kv_body, dq0)
            else:
                def body(c, kj):
                    return kv_body(kj, c), None
                dq, _ = jax.lax.scan(body, dq0, jnp.arange(nk))
            return None, (dq * scale).astype(q.dtype)

        _, dqb = jax.lax.scan(dq_step, None, jnp.arange(nq))
        dq = jnp.transpose(dqb, (1, 0, 2, 3, 4, 5)).reshape(B, Sq, KV, G, hd)

        # ---- pass 2: dk/dv (kv-major) -------------------------------------
        def dkv_step(_, kj):
            kblk = kb[:, kj]
            vblk = vb[:, kj]

            def q_body(qi, carry):
                dk_acc, dv_acc = carry
                qblk = qb[:, qi]
                doblk = dob[:, qi]
                lse_q = lseb[:, qi]
                D_q = Db[:, qi]
                s = _scores(qblk, kblk, qi, kj, scale)
                p = jnp.exp(s - jnp.transpose(lse_q, (0, 2, 3, 1))[..., None])
                dv_acc = dv_acc + jnp.einsum(
                    "bkgqs,bqkgh->bskh", p.astype(do.dtype), doblk
                ).astype(jnp.float32)
                dp = jnp.einsum("bqkgh,bskh->bkgqs", doblk, vblk).astype(jnp.float32)
                ds = p * (dp - jnp.transpose(D_q, (0, 2, 3, 1))[..., None])
                dk_acc = dk_acc + jnp.einsum(
                    "bkgqs,bqkgh->bskh", ds.astype(q.dtype), qblk
                ).astype(jnp.float32)
                return dk_acc, dv_acc

            dk0 = jnp.zeros((B, kv_block, KV, hd), jnp.float32)
            dv0 = jnp.zeros((B, kv_block, KV, hv), jnp.float32)
            if skip:
                lo, hi = _bounds_kv(kj, nq)
                dk, dv = jax.lax.fori_loop(lo, hi, q_body, (dk0, dv0))
            else:
                def body(c, qi):
                    return q_body(qi, c), None
                (dk, dv), _ = jax.lax.scan(body, (dk0, dv0), jnp.arange(nq))
            return None, ((dk * scale).astype(k.dtype), dv.astype(v.dtype))

        _, (dkb, dvb) = jax.lax.scan(dkv_step, None, jnp.arange(nk))
        dk = jnp.transpose(dkb, (1, 0, 2, 3, 4)).reshape(B, Skv, KV, hd)
        dv = jnp.transpose(dvb, (1, 0, 2, 3, 4)).reshape(B, Skv, KV, hv)
        return dq, dk, dv

    flash_vjp = jax.custom_vjp(flash)
    flash_vjp.defvjp(flash_fwd, flash_bwd)
    return flash_vjp


def flash_attention_grouped(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    offset: int = 0,
    q_block: int = 512,
    kv_block: int = 512,
    skip: bool = True,
) -> jax.Array:
    """q: [B,Sq,KV,G,hd]; k/v: [B,Skv,KV,hd(v)] -> o: [B,Sq,KV,G,hv].

    ``offset`` places query i at absolute position offset+i (prefill
    continuation); ``skip`` enables dynamic block-skip bounds."""
    fn = _make_flash(causal, int(window), int(offset),
                     int(q_block), int(kv_block), bool(skip))
    return fn(q, k, v)
