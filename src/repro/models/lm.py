"""Decoder-only language model assembly for all LM families.

One spec-builder + three entry points (loss / prefill / decode) cover the
``dense``, ``moe``, ``ssm``, ``hybrid`` and ``vlm`` families.  Layers are
stacked with ``jax.lax.scan`` over stacked parameter pytrees (compile time
stays flat in depth); activation checkpointing wraps the scanned block
according to ``cfg.remat``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..shardlib import constrain
from . import attention as attn
from . import mla as mla_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .layers import (
    apply_norm,
    chunked_cross_entropy,
    cross_entropy,
    embed_specs,
    embed_tokens,
    lm_logits,
    mlp_fwd,
    mlp_specs,
    norm_spec,
)
from .params import ParamSpec

__all__ = [
    "lm_specs",
    "lm_loss",
    "lm_prefill",
    "lm_decode_step",
    "init_cache_shapes",
]

AUX_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------
def _block_specs(cfg, L: int, kind: str) -> dict:
    """Specs for a stack of L identical blocks of the given kind."""
    if kind == "attn_dense":
        d_ff = cfg.d_ff_dense or cfg.d_ff
        s = {
            "ln1": norm_spec(cfg, (L,) if L else ()),
            "attn": (mla_mod.mla_specs(cfg, L) if cfg.attention == "mla"
                     else attn.attn_specs(cfg, L)),
            "ln2": norm_spec(cfg, (L,) if L else ()),
            "mlp": mlp_specs(cfg, L, d_ff=d_ff if cfg.moe_experts else cfg.d_ff),
        }
        return s
    if kind == "attn_moe":
        s = {
            "ln1": norm_spec(cfg, (L,) if L else ()),
            "attn": (mla_mod.mla_specs(cfg, L) if cfg.attention == "mla"
                     else attn.attn_specs(cfg, L)),
            "ln2": norm_spec(cfg, (L,) if L else ()),
            "moe": moe_mod.moe_specs(cfg, L),
        }
        if cfg.moe_dense_residual:
            s["mlp"] = mlp_specs(cfg, L, d_ff=cfg.d_ff_dense or cfg.d_ff)
        return s
    if kind == "ssm":
        return {"ln1": norm_spec(cfg, (L,) if L else ()), "ssm": ssm_mod.ssm_specs(cfg, L)}
    if kind == "rec":
        return {
            "ln1": norm_spec(cfg, (L,) if L else ()),
            "rec": rglru_mod.rglru_specs(cfg, L),
            "ln2": norm_spec(cfg, (L,) if L else ()),
            "mlp": mlp_specs(cfg, L),
        }
    if kind == "attn_local":
        return {
            "ln1": norm_spec(cfg, (L,) if L else ()),
            "attn": attn.attn_specs(cfg, L),
            "ln2": norm_spec(cfg, (L,) if L else ()),
            "mlp": mlp_specs(cfg, L),
        }
    raise ValueError(kind)


def _hybrid_layout(cfg) -> Tuple[int, Tuple[str, ...]]:
    """(#groups scanned, tail kinds) for hybrid pattern archs."""
    pat = cfg.block_pattern
    n_groups = cfg.num_layers // len(pat)
    tail = cfg.num_layers - n_groups * len(pat)
    return n_groups, pat[:tail]


def lm_specs(cfg) -> dict:
    specs: Dict[str, Any] = {"tok": embed_specs(cfg)}
    fam = cfg.family
    if fam in ("dense", "vlm"):
        specs["blocks"] = _block_specs(cfg, cfg.num_layers, "attn_dense")
    elif fam == "moe":
        nd = cfg.moe_dense_layers
        if nd:
            specs["dense_blocks"] = _block_specs(cfg, nd, "attn_dense")
        specs["blocks"] = _block_specs(cfg, cfg.num_layers - nd, "attn_moe")
        if cfg.mtp_depth:
            specs["mtp"] = {
                "proj": ParamSpec((2 * cfg.d_model, cfg.d_model),
                                  ("embed", "embed"), cfg.pdtype),
                "block": _block_specs(cfg, 0, "attn_dense"),
            }
    elif fam == "ssm":
        specs["blocks"] = _block_specs(cfg, cfg.num_layers, "ssm")
    elif fam == "hybrid":
        n_groups, tail = _hybrid_layout(cfg)
        group = {}
        for i, kind in enumerate(cfg.block_pattern):
            group[f"p{i}_{kind}"] = _block_specs(
                cfg, n_groups, "rec" if kind == "rec" else "attn_local"
            )
        specs["groups"] = group
        for i, kind in enumerate(tail):
            specs[f"tail{i}_{kind}"] = _block_specs(
                cfg, 0, "rec" if kind == "rec" else "attn_local"
            )
    else:
        raise ValueError(fam)
    if fam == "vlm":
        # Stubbed modality frontend: precomputed ViT patch embeddings are
        # projected into the LM embedding space (the frontend itself is out
        # of scope per the assignment; see DESIGN.md).
        specs["patch_proj"] = ParamSpec((1024, cfg.d_model), (None, "embed"), cfg.pdtype)
    return specs


# ---------------------------------------------------------------------------
# Block forward functions (single layer; scanned over stacked params)
# ---------------------------------------------------------------------------
def _res(cfg, x, delta):
    if cfg.residual_scale != 1.0:
        delta = (delta.astype(jnp.float32) * cfg.residual_scale).astype(delta.dtype)
    return x + delta


def _attn_dense_block(cfg, p, x, positions, *, window=0, impl="blocked"):
    h = apply_norm(cfg, p["ln1"], x)
    if cfg.attention == "mla":
        a, kv = mla_mod.mla_fwd(cfg, p["attn"], h, positions, impl=impl)
    else:
        a, kv = attn.attention_fwd(cfg, p["attn"], h, positions,
                                   causal=True, window=window, impl=impl)
    x = _res(cfg, x, a)
    h = apply_norm(cfg, p["ln2"], x)
    x = _res(cfg, x, mlp_fwd(cfg, p["mlp"], h))
    return x, kv


def _attn_moe_block(cfg, p, x, positions, *, impl="blocked"):
    h = apply_norm(cfg, p["ln1"], x)
    if cfg.attention == "mla":
        a, kv = mla_mod.mla_fwd(cfg, p["attn"], h, positions, impl=impl)
    else:
        a, kv = attn.attention_fwd(cfg, p["attn"], h, positions,
                                   causal=True, impl=impl)
    x = _res(cfg, x, a)
    h = apply_norm(cfg, p["ln2"], x)
    mo, aux = moe_mod.moe_fwd(cfg, p["moe"], h)
    if cfg.moe_dense_residual:
        mo = mo + mlp_fwd(cfg, p["mlp"], h)
    x = _res(cfg, x, mo)
    return x, kv, aux


def _ssm_block(cfg, p, x, init_state=None):
    h = apply_norm(cfg, p["ln1"], x)
    o, state = ssm_mod.ssm_fwd(cfg, p["ssm"], h, init_state)
    return x + o, state


def _rec_block(cfg, p, x, init_state=None):
    h = apply_norm(cfg, p["ln1"], x)
    o, state = rglru_mod.rglru_fwd(cfg, p["rec"], h, init_state)
    x = x + o
    h = apply_norm(cfg, p["ln2"], x)
    x = x + mlp_fwd(cfg, p["mlp"], h)
    return x, state


def _local_attn_block(cfg, p, x, positions, *, impl="blocked"):
    h = apply_norm(cfg, p["ln1"], x)
    a, kv = attn.attention_fwd(cfg, p["attn"], h, positions, causal=True,
                               window=cfg.local_window, impl=impl)
    x = x + a
    h = apply_norm(cfg, p["ln2"], x)
    x = x + mlp_fwd(cfg, p["mlp"], h)
    return x, kv


def _maybe_remat(cfg, f):
    if cfg.remat == "none":
        return f
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(f, policy=policy)
    return jax.checkpoint(f)


# ---------------------------------------------------------------------------
# Backbone forward (training/prefill), returns hidden states and aux
# ---------------------------------------------------------------------------
def lm_backbone(cfg, params, x, positions, *, impl="blocked", collect_cache=False):
    """x: [B,S,D] embedded input.  Returns (hidden, aux_losses, caches)."""
    fam = cfg.family
    aux_total = jnp.zeros((), jnp.float32)
    caches: Dict[str, Any] = {}

    if fam in ("dense", "vlm"):
        def blk(x, p):
            x, kv = _attn_dense_block(cfg, p, x, positions, impl=impl)
            return x, kv if collect_cache else None

        x, kvs = jax.lax.scan(_maybe_remat(cfg, blk), x, params["blocks"])
        if collect_cache:
            caches["kv"] = kvs
    elif fam == "moe":
        if cfg.moe_dense_layers:
            def dblk(x, p):
                x, kv = _attn_dense_block(cfg, p, x, positions, impl=impl)
                return x, kv if collect_cache else None

            x, dkvs = jax.lax.scan(_maybe_remat(cfg, dblk), x, params["dense_blocks"])
            if collect_cache:
                caches["dense_kv"] = dkvs

        def mblk(x, p):
            x, kv, aux = _attn_moe_block(cfg, p, x, positions, impl=impl)
            return x, (kv if collect_cache else None, aux)

        x, (kvs, auxs) = jax.lax.scan(_maybe_remat(cfg, mblk), x, params["blocks"])
        aux_total = aux_total + jnp.sum(auxs)
        if collect_cache:
            caches["kv"] = kvs
    elif fam == "ssm":
        def sblk(x, p):
            x, st = _ssm_block(cfg, p, x)
            return x, st if collect_cache else None

        x, states = jax.lax.scan(_maybe_remat(cfg, sblk), x, params["blocks"])
        if collect_cache:
            caches["ssm_state"] = states
    elif fam == "hybrid":
        n_groups, tail = _hybrid_layout(cfg)

        def gblk(x, p):
            outs = {}
            for i, kind in enumerate(cfg.block_pattern):
                key = f"p{i}_{kind}"
                if kind == "rec":
                    x, fin = _rec_block(cfg, p[key], x)
                    outs[key] = fin if collect_cache else None
                else:
                    x, kv = _local_attn_block(cfg, p[key], x, positions, impl=impl)
                    outs[key] = kv if collect_cache else None
            return x, outs

        x, gouts = jax.lax.scan(_maybe_remat(cfg, gblk), x, params["groups"])
        if collect_cache:
            caches["groups"] = gouts
        for i, kind in enumerate(tail):
            key = f"tail{i}_{kind}"
            if kind == "rec":
                x, fin = _rec_block(cfg, params[key], x)
                if collect_cache:
                    caches[key] = fin
            else:
                x, kv = _local_attn_block(cfg, params[key], x, positions, impl=impl)
                if collect_cache:
                    caches[key] = kv
    else:
        raise ValueError(fam)
    return x, aux_total, caches


# ---------------------------------------------------------------------------
# Loss (training)
# ---------------------------------------------------------------------------
def lm_loss(cfg, params, batch, *, impl: str = "blocked") -> Tuple[jax.Array, Dict]:
    """batch: {'tokens': [B,S], 'labels': [B,S]} (+ 'patches' for vlm)."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    B, S = tokens.shape
    x = embed_tokens(cfg, params["tok"], tokens)
    if cfg.family == "vlm":
        pe = batch["patches"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)
        labels = jnp.concatenate(
            [jnp.full((B, pe.shape[1]), -1, labels.dtype), labels], axis=1
        )
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None, :], x.shape[:2])
    h, aux, _ = lm_backbone(cfg, params, x, positions, impl=impl)
    loss = chunked_cross_entropy(cfg, params["tok"], h, labels)
    metrics = {"ce": loss, "aux": aux}
    if cfg.family == "moe" and cfg.moe_experts:
        loss = loss + AUX_WEIGHT * aux
    if cfg.mtp_depth:
        # DeepSeek-style multi-token prediction: one extra block predicts
        # token t+2 from [h_t ; emb(t_{t+1})] (simplified single-depth MTP).
        # Kept at full sequence length (labels masked at the boundary) so
        # the flash-attention path applies — a 4095-length naive attention
        # would materialize S^2 scores (observed 10 GiB, §Perf).
        emb_next = embed_tokens(cfg, params["tok"], tokens)
        mtp_in = jnp.concatenate([h, jnp.roll(emb_next, -1, axis=1)], axis=-1)
        mtp_h = mtp_in @ params["mtp"]["proj"]
        mtp_h, _ = _attn_dense_block(cfg, params["mtp"]["block"],
                                     mtp_h, positions, impl=impl)
        mtp_labels = jnp.roll(labels, -1, axis=1).at[:, -1].set(-1)
        mtp_loss = chunked_cross_entropy(cfg, params["tok"], mtp_h, mtp_labels)
        metrics["mtp"] = mtp_loss
        loss = loss + cfg.mtp_loss_weight * mtp_loss
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------
def init_cache_shapes(cfg, batch: int, cache_len: int):
    """Abstract cache pytree (shape/dtype) for decode at a given length."""
    fam = cfg.family
    hd = cfg.resolved_head_dim
    KV = cfg.num_kv_heads
    cdt = jnp.dtype(cfg.cache_dtype)
    if fam in ("dense", "vlm"):
        L = cfg.num_layers
        if cfg.attention == "mla":
            return {
                "ckv": jax.ShapeDtypeStruct((L, batch, cache_len, cfg.kv_lora_rank), cdt),
                "krope": jax.ShapeDtypeStruct((L, batch, cache_len, cfg.qk_rope_dim), cdt),
            }
        return {
            "k": jax.ShapeDtypeStruct((L, batch, cache_len, KV, hd), cdt),
            "v": jax.ShapeDtypeStruct((L, batch, cache_len, KV, hd), cdt),
        }
    if fam == "moe":
        nd = cfg.moe_dense_layers
        Lm = cfg.num_layers - nd
        out = {}
        if cfg.attention == "mla":
            out["ckv"] = jax.ShapeDtypeStruct((Lm, batch, cache_len, cfg.kv_lora_rank), cdt)
            out["krope"] = jax.ShapeDtypeStruct((Lm, batch, cache_len, cfg.qk_rope_dim), cdt)
            if nd:
                out["d_ckv"] = jax.ShapeDtypeStruct((nd, batch, cache_len, cfg.kv_lora_rank), cdt)
                out["d_krope"] = jax.ShapeDtypeStruct((nd, batch, cache_len, cfg.qk_rope_dim), cdt)
        else:
            out["k"] = jax.ShapeDtypeStruct((Lm, batch, cache_len, KV, hd), cdt)
            out["v"] = jax.ShapeDtypeStruct((Lm, batch, cache_len, KV, hd), cdt)
            if nd:
                out["d_k"] = jax.ShapeDtypeStruct((nd, batch, cache_len, KV, hd), cdt)
                out["d_v"] = jax.ShapeDtypeStruct((nd, batch, cache_len, KV, hd), cdt)
        return out
    if fam == "ssm":
        L = cfg.num_layers
        shapes = ssm_mod.ssm_state_shapes(cfg, batch)
        return {
            "ssm": jax.ShapeDtypeStruct((L,) + shapes["ssm"][0], shapes["ssm"][1]),
            "conv": jax.ShapeDtypeStruct((L,) + shapes["conv"][0], shapes["conv"][1]),
        }
    if fam == "hybrid":
        n_groups, tail = _hybrid_layout(cfg)
        W = cfg.rglru_width or cfg.d_model
        K = cfg.conv_width
        win = min(cfg.local_window, cache_len)
        out = {}
        n_rec = sum(1 for k in cfg.block_pattern if k == "rec")
        n_att = len(cfg.block_pattern) - n_rec
        out["rnn"] = jax.ShapeDtypeStruct((n_groups, n_rec, batch, W), jnp.float32)
        out["rnn_conv"] = jax.ShapeDtypeStruct((n_groups, n_rec, batch, K - 1, W), cdt)
        out["k"] = jax.ShapeDtypeStruct((n_groups, n_att, batch, win, KV, hd), cdt)
        out["v"] = jax.ShapeDtypeStruct((n_groups, n_att, batch, win, KV, hd), cdt)
        n_rec_t = sum(1 for k in tail if k == "rec")
        if n_rec_t:
            out["tail_rnn"] = jax.ShapeDtypeStruct((n_rec_t, batch, W), jnp.float32)
            out["tail_rnn_conv"] = jax.ShapeDtypeStruct((n_rec_t, batch, K - 1, W), cdt)
        return out
    raise ValueError(fam)


def lm_prefill(cfg, params, batch, *, impl: str = "blocked"):
    """Prefill: run the full prompt, return (last-token logits, cache)."""
    from .attention import inference_mode

    with inference_mode():
        return _lm_prefill(cfg, params, batch, impl=impl)


def _lm_prefill(cfg, params, batch, *, impl: str = "blocked"):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(cfg, params["tok"], tokens)
    if cfg.family == "vlm":
        pe = batch["patches"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None, :], x.shape[:2])
    h, _, caches = lm_backbone(cfg, params, x, positions, impl=impl,
                               collect_cache=True)
    logits = lm_logits(cfg, params["tok"], h[:, -1:, :])
    cache = _caches_to_decode_layout(cfg, caches, cache_len=x.shape[1])
    return logits, cache


def _caches_to_decode_layout(cfg, caches, cache_len: int):
    """Convert scan-collected prefill caches into the decode cache pytree."""
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        if cfg.attention == "mla":
            out = {}
            if "dense_kv" in caches:
                c, kr = caches["dense_kv"]
                out["d_ckv"], out["d_krope"] = c, kr
            c, kr = caches["kv"]
            out["ckv"], out["krope"] = c, kr
            return jax.tree.map(lambda a: a.astype(jnp.dtype(cfg.cache_dtype)), out)
        out = {}
        if "dense_kv" in caches:
            k, v = caches["dense_kv"]
            out["d_k"], out["d_v"] = k, v
        k, v = caches["kv"]
        out["k"], out["v"] = k, v
        return jax.tree.map(lambda a: a.astype(jnp.dtype(cfg.cache_dtype)), out)
    if fam == "ssm":
        st = caches["ssm_state"]
        return {"ssm": st["ssm"], "conv": st["conv"]}
    if fam == "hybrid":
        n_groups, tail = _hybrid_layout(cfg)
        win = cfg.local_window
        rnn, rconv, ks, vs = [], [], [], []
        g = caches["groups"]
        for i, kind in enumerate(cfg.block_pattern):
            key = f"p{i}_{kind}"
            if kind == "rec":
                rnn.append(g[key]["rnn"])
                rconv.append(g[key]["conv"])
            else:
                k, v = g[key]
                ks.append(_ring_slice(k, win, cache_len))
                vs.append(_ring_slice(v, win, cache_len))
        out = {
            "rnn": jnp.stack(rnn, axis=1),
            "rnn_conv": jnp.stack(rconv, axis=1).astype(jnp.dtype(cfg.cache_dtype)),
            "k": jnp.stack(ks, axis=1).astype(jnp.dtype(cfg.cache_dtype)),
            "v": jnp.stack(vs, axis=1).astype(jnp.dtype(cfg.cache_dtype)),
        }
        t_rnn, t_conv = [], []
        for i, kind in enumerate(tail):
            st = caches[f"tail{i}_{kind}"]
            t_rnn.append(st["rnn"])
            t_conv.append(st["conv"])
        if t_rnn:
            out["tail_rnn"] = jnp.stack(t_rnn)
            out["tail_rnn_conv"] = jnp.stack(t_conv).astype(jnp.dtype(cfg.cache_dtype))
        return out
    raise NotImplementedError(f"prefill cache layout for {fam}")


def _ring_slice(k: jax.Array, window: int, cache_len: int) -> jax.Array:
    """Take the last `window` positions of [G,B,S,KV,hd] into ring layout
    (ring slot i holds absolute position p with p % window == i)."""
    S = k.shape[2]
    if S <= window:
        return k
    tail = k[:, :, -window:]
    shift = (S - window) % window
    return jnp.roll(tail, shift, axis=2)


def lm_decode_step(cfg, params, cache, tokens, pos, *, decode_impl: str = "naive"):
    """One decode step.  tokens: [B,1]; pos: [B].  Returns (logits, cache)."""
    fam = cfg.family
    x = embed_tokens(cfg, params["tok"], tokens)
    new_cache = dict(cache)

    if fam in ("dense", "vlm", "moe"):
        aux = None
        if fam == "moe" and cfg.moe_dense_layers:
            def dblk(carry, inp):
                x = carry
                p, ck = inp
                h = apply_norm(cfg, p["ln1"], x)
                if cfg.attention == "mla":
                    a, upd = mla_mod.mla_decode(cfg, p["attn"], h, ck[0], ck[1], pos)
                else:
                    a, upd = attn.decode_attention(cfg, p["attn"], h, ck[0], ck[1],
                                                   pos, impl=decode_impl)
                x = _res(cfg, x, a)
                h = apply_norm(cfg, p["ln2"], x)
                x = _res(cfg, x, mlp_fwd(cfg, p["mlp"], h))
                return x, upd

            cpair = ((cache["d_ckv"], cache["d_krope"]) if cfg.attention == "mla"
                     else (cache["d_k"], cache["d_v"]))
            x, upd = jax.lax.scan(dblk, x, (params["dense_blocks"], cpair))
            if cfg.attention == "mla":
                new_cache["d_ckv"], new_cache["d_krope"] = upd
            else:
                new_cache["d_k"], new_cache["d_v"] = upd

        def blk(carry, inp):
            x = carry
            p, ck = inp
            h = apply_norm(cfg, p["ln1"], x)
            if cfg.attention == "mla":
                a, upd = mla_mod.mla_decode(cfg, p["attn"], h, ck[0], ck[1], pos)
            else:
                a, upd = attn.decode_attention(cfg, p["attn"], h, ck[0], ck[1],
                                               pos, impl=decode_impl)
            x = _res(cfg, x, a)
            h = apply_norm(cfg, p["ln2"], x)
            if fam == "moe":
                mo, _aux = moe_mod.moe_fwd(cfg, p["moe"], h)
                if cfg.moe_dense_residual:
                    mo = mo + mlp_fwd(cfg, p["mlp"], h)
                x = _res(cfg, x, mo)
            else:
                x = _res(cfg, x, mlp_fwd(cfg, p["mlp"], h))
            return x, upd

        cpair = ((cache["ckv"], cache["krope"]) if cfg.attention == "mla"
                 else (cache["k"], cache["v"]))
        x, upd = jax.lax.scan(blk, x, (params["blocks"], cpair))
        if cfg.attention == "mla":
            new_cache["ckv"], new_cache["krope"] = upd
        else:
            new_cache["k"], new_cache["v"] = upd

    elif fam == "ssm":
        def blk(carry, inp):
            x = carry
            p, s, cv = inp
            h = apply_norm(cfg, p["ln1"], x)
            o, (s2, cv2) = ssm_mod.ssm_decode(cfg, p["ssm"], h, s, cv)
            return x + o, (s2, cv2)

        x, (s2, cv2) = jax.lax.scan(blk, x, (params["blocks"], cache["ssm"], cache["conv"]))
        new_cache["ssm"], new_cache["conv"] = s2, cv2

    elif fam == "hybrid":
        n_groups, tail = _hybrid_layout(cfg)

        def gblk(carry, inp):
            x = carry
            p, rnn, rnn_conv, ck, cv = inp
            ri = ai = 0
            rnn_o, conv_o, k_o, v_o = [], [], [], []
            for i, kind in enumerate(cfg.block_pattern):
                key = f"p{i}_{kind}"
                if kind == "rec":
                    h = apply_norm(cfg, p[key]["ln1"], x)
                    o, (s2, w2) = rglru_mod.rglru_decode(
                        cfg, p[key]["rec"], h, rnn[ri], rnn_conv[ri])
                    x = x + o
                    h = apply_norm(cfg, p[key]["ln2"], x)
                    x = x + mlp_fwd(cfg, p[key]["mlp"], h)
                    rnn_o.append(s2); conv_o.append(w2)
                    ri += 1
                else:
                    h = apply_norm(cfg, p[key]["ln1"], x)
                    a, (k2, v2) = attn.decode_attention(
                        cfg, p[key]["attn"], h, ck[ai], cv[ai], pos,
                        window=cfg.local_window, impl=decode_impl)
                    x = x + a
                    h = apply_norm(cfg, p[key]["ln2"], x)
                    x = x + mlp_fwd(cfg, p[key]["mlp"], h)
                    k_o.append(k2); v_o.append(v2)
                    ai += 1
            return x, (jnp.stack(rnn_o), jnp.stack(conv_o),
                       jnp.stack(k_o), jnp.stack(v_o))

        x, (rnn2, rconv2, k2, v2) = jax.lax.scan(
            gblk, x,
            (params["groups"], cache["rnn"], cache["rnn_conv"],
             cache["k"], cache["v"]))
        new_cache.update({"rnn": rnn2, "rnn_conv": rconv2, "k": k2, "v": v2})
        ti = 0
        t_rnn, t_conv = [], []
        for i, kind in enumerate(tail):
            key = f"tail{i}_{kind}"
            h = apply_norm(cfg, params[key]["ln1"], x)
            o, (s2, w2) = rglru_mod.rglru_decode(
                cfg, params[key]["rec"], h, cache["tail_rnn"][ti],
                cache["tail_rnn_conv"][ti])
            x = x + o
            h = apply_norm(cfg, params[key]["ln2"], x)
            x = x + mlp_fwd(cfg, params[key]["mlp"], h)
            t_rnn.append(s2); t_conv.append(w2)
            ti += 1
        if t_rnn:
            new_cache["tail_rnn"] = jnp.stack(t_rnn)
            new_cache["tail_rnn_conv"] = jnp.stack(t_conv)
    else:
        raise ValueError(fam)

    logits = lm_logits(cfg, params["tok"], x)
    return logits, new_cache
