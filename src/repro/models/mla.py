"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and keys/values are projected through low-rank bottlenecks; the
decode cache stores only the compressed latent ``c_kv`` (kv_lora_rank) plus
the shared RoPE key (qk_rope_dim) per token — 576 values/token for V3
instead of 2 * 128 heads * 128 dims.  Decode uses the *absorbed* form:
q_nope is folded through W_UK so scores contract directly against the
latent cache, and attention output is expanded through W_UV afterwards.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..shardlib import constrain
from .attention import _blocked_attention, _naive_attention, NEG_INF
from .layers import apply_rope, rope
from .params import ParamSpec

__all__ = ["mla_specs", "mla_fwd", "mla_decode", "mla_cache_width"]


def mla_specs(cfg, L: int) -> dict:
    D = cfg.d_model
    H = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = cfg.pdtype
    lead: Tuple[int, ...] = (L,) if L else ()
    lax: Tuple[str, ...] = ("layers",) if L else ()
    return {
        "wq_a": ParamSpec(lead + (D, qr), lax + ("embed", "qlora"), dt),
        "q_norm": ParamSpec(lead + (qr,), lax + ("qlora",), dt, "ones"),
        "wq_b": ParamSpec(lead + (qr, H, dn + dr), lax + ("qlora", "q_heads", "head_dim"), dt, fan=qr),
        "wkv_a": ParamSpec(lead + (D, kvr), lax + ("embed", "kvlora"), dt),
        "kv_norm": ParamSpec(lead + (kvr,), lax + ("kvlora",), dt, "ones"),
        "wkr": ParamSpec(lead + (D, dr), lax + ("embed", "head_dim"), dt),
        "wk_b": ParamSpec(lead + (kvr, H, dn), lax + ("kvlora", "q_heads", "head_dim"), dt, fan=kvr),
        "wv_b": ParamSpec(lead + (kvr, H, dv), lax + ("kvlora", "q_heads", "head_dim"), dt, fan=kvr),
        "wo": ParamSpec(lead + (H, dv, D), lax + ("q_heads", "head_dim", "embed"), dt, fan=H * dv),
    }


def mla_cache_width(cfg) -> int:
    return cfg.kv_lora_rank + cfg.qk_rope_dim


def _project_q(cfg, p, x, positions):
    """x -> q_nope [B,S,H,dn], q_rope [B,S,H,dr] (RoPE applied)."""
    from .layers import rmsnorm

    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rmsnorm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsq,qhk->bshk", cq, p["wq_b"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    sin, cos = rope(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    return q_nope, q_rope


def _project_kv_latent(cfg, p, x, positions):
    """x -> c_kv [B,S,kvr] (normed latent), k_rope [B,S,dr] (RoPE applied)."""
    from .layers import rmsnorm

    dr = cfg.qk_rope_dim
    c_kv = rmsnorm(x @ p["wkv_a"], p["kv_norm"], cfg.norm_eps)
    k_rope = x @ p["wkr"]
    sin, cos = rope(positions, dr, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], sin, cos)[:, :, 0, :]
    return c_kv, k_rope


def mla_fwd(
    cfg,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    impl: str = "blocked",
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Training/prefill MLA in the expanded form.

    Returns (out, (c_kv, k_rope)) — the compressed decode cache."""
    B, S, D = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _project_q(cfg, p, x, positions)
    c_kv, k_rope = _project_kv_latent(cfg, p, x, positions)
    k_nope = jnp.einsum("bsc,chk->bshk", c_kv, p["wk_b"])
    v = jnp.einsum("bsc,chk->bshk", c_kv, p["wv_b"])

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))], axis=-1
    )
    q = constrain(q, ("batch", "seq", "q_heads", "head_dim"))
    k = constrain(k, ("batch", "seq", "q_heads", "head_dim"))
    v = constrain(v, ("batch", "seq", "q_heads", "head_dim"))
    if impl == "blocked" and S >= 1024:
        o = _blocked_attention(q, k, v, causal=True, window=0, q_block=512, kv_block=512)
    else:
        o = _naive_attention(q, k, v, causal=True, window=0)
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
    return constrain(out, ("batch", "seq", "embed")), (c_kv, k_rope)


def mla_decode(
    cfg,
    p: dict,
    x: jax.Array,
    cache_ckv: jax.Array,
    cache_krope: jax.Array,
    pos: jax.Array,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Absorbed-form MLA decode step.

    x: [B,1,D]; cache_ckv: [B,S,kvr]; cache_krope: [B,S,dr]; pos: [B].
    """
    B = x.shape[0]
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    S = cache_ckv.shape[1]

    q_nope, q_rope = _project_q(cfg, p, x, pos[:, None])
    c_new, kr_new = _project_kv_latent(cfg, p, x, pos[:, None])

    upd2 = jax.vmap(lambda c, n, s: jax.lax.dynamic_update_slice(c, n, (s, 0)))
    cache_ckv = upd2(cache_ckv, c_new, pos)
    cache_krope = upd2(cache_krope, kr_new, pos)
    cache_ckv = constrain(cache_ckv, ("batch", "cache_seq", "kvlora"))
    cache_krope = constrain(cache_krope, ("batch", "cache_seq", "head_dim"))

    # Absorb W_UK into the query: scores contract against the latent cache.
    q_abs = jnp.einsum("bqhn,chn->bqhc", q_nope, p["wk_b"])
    scores = jnp.einsum("bqhc,bsc->bhqs", q_abs, cache_ckv).astype(jnp.float32)
    scores += jnp.einsum("bqhr,bsr->bhqs", q_rope, cache_krope).astype(jnp.float32)
    scores = scores / math.sqrt(dn + dr)
    mask = (jnp.arange(S)[None, :] <= pos[:, None])[:, None, None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_c = jnp.einsum("bhqs,bsc->bqhc", w, cache_ckv)
    o = jnp.einsum("bqhc,chv->bqhv", o_c, p["wv_b"])
    out = jnp.einsum("bqhv,hvd->bqd", o, p["wo"])
    return constrain(out, ("batch", None, "embed")), (cache_ckv, cache_krope)
