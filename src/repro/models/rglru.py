"""RG-LRU recurrent block (RecurrentGemma / Griffin).

The recurrence h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) is a
first-order linear recurrence, so training computes it with
``jax.lax.associative_scan`` (log-depth — the RSP-tree-friendly shape: a
balanced reduction tree, exactly the structure the paper's change
propagation exploits).  Decode carries a [B, rnn_width] state.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..shardlib import constrain
from .layers import residual_out_scale
from .params import ParamSpec

__all__ = ["rglru_specs", "rglru_fwd", "rglru_decode", "rglru_state_shapes"]

_C = 8.0  # Griffin's fixed gate temperature


def _width(cfg) -> int:
    return cfg.rglru_width or cfg.d_model


def rglru_specs(cfg, L: int) -> dict:
    D = cfg.d_model
    W = _width(cfg)
    K = cfg.conv_width
    dt = cfg.pdtype
    lead: Tuple[int, ...] = (L,) if L else ()
    lax: Tuple[str, ...] = ("layers",) if L else ()
    return {
        "w_x": ParamSpec(lead + (D, W), lax + ("embed", "rnn"), dt),
        "w_y": ParamSpec(lead + (D, W), lax + ("embed", "rnn"), dt),
        "conv_w": ParamSpec(lead + (K, W), lax + ("conv", "rnn"), dt, "normal", scale=0.5),
        "conv_b": ParamSpec(lead + (W,), lax + ("rnn",), dt, "zeros"),
        "w_rgate": ParamSpec(lead + (W, W), lax + ("rnn", "state"), dt),
        "b_rgate": ParamSpec(lead + (W,), lax + ("rnn",), dt, "zeros"),
        "w_igate": ParamSpec(lead + (W, W), lax + ("rnn", "state"), dt),
        "b_igate": ParamSpec(lead + (W,), lax + ("rnn",), dt, "zeros"),
        "lam": ParamSpec(lead + (W,), lax + ("rnn",), jnp.float32, "normal", scale=0.6),
        "w_out": ParamSpec(lead + (W, D), lax + ("rnn", "embed"), dt,
                           scale=residual_out_scale(cfg)),
    }


def rglru_state_shapes(cfg, batch: int):
    W = _width(cfg)
    return {
        "rnn": ((batch, W), jnp.float32),
        "conv": ((batch, cfg.conv_width - 1, W), jnp.bfloat16),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    K = w.shape[0]
    out = x * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[K - 1 - i]
    return (out + b).astype(x.dtype)


def _gates(cfg, p, xr: jax.Array):
    """log_a [.., W] (<=0) and gated input u."""
    r = jax.nn.sigmoid((xr @ p["w_rgate"] + p["b_rgate"]).astype(jnp.float32))
    i = jax.nn.sigmoid((xr @ p["w_igate"] + p["b_igate"]).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(p["lam"])  # log a_t  (a in (0,1))
    a2 = jnp.exp(2.0 * log_a)
    u = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * xr.astype(jnp.float32))
    return log_a, u


def rglru_fwd(cfg, p: dict, x: jax.Array, init_state=None):
    """x: [B,S,D] -> (out [B,S,D], {'rnn','conv'} carried state)."""
    B, S, D = x.shape
    xw = x @ p["w_x"]
    conv_tail = xw[:, -(cfg.conv_width - 1):, :]
    xr = _causal_conv(xw, p["conv_w"], p["conv_b"])
    xr = constrain(xr, ("batch", "seq", "rnn"))
    log_a, u = _gates(cfg, p, xr)
    if init_state is not None:
        # Fold the carried state in as a virtual step 0.
        u = jnp.concatenate([init_state.astype(jnp.float32)[:, None], u], axis=1)
        log_a = jnp.concatenate([jnp.zeros_like(log_a[:, :1]), log_a], axis=1)

    def combine(c1, c2):
        a1, u1 = c1
        a2, u2 = c2
        return a1 + a2, u1 * jnp.exp(a2) + u2

    la, h = jax.lax.associative_scan(combine, (log_a, u), axis=1)
    if init_state is not None:
        h = h[:, 1:]
    final = h[:, -1]
    y = jax.nn.gelu((x @ p["w_y"]).astype(jnp.float32))
    out = (h * y).astype(x.dtype) @ p["w_out"]
    state = {"rnn": final, "conv": conv_tail}
    return constrain(out, ("batch", "seq", "embed")), state


def rglru_decode(cfg, p: dict, x: jax.Array, rnn_state: jax.Array, conv_state: jax.Array):
    """x: [B,1,D]; rnn_state: [B,W]; conv_state: [B,K-1,W]."""
    K = cfg.conv_width
    xw = x @ p["w_x"]                                     # [B,1,W]
    window = jnp.concatenate([conv_state, xw.astype(conv_state.dtype)], axis=1)
    xr = (
        jnp.einsum("bkw,kw->bw", window.astype(jnp.float32),
                   p["conv_w"].astype(jnp.float32))
        + p["conv_b"].astype(jnp.float32)
    ).astype(x.dtype)                                      # [B,W]
    log_a, u = _gates(cfg, p, xr)
    h = rnn_state * jnp.exp(log_a) + u
    y = jax.nn.gelu((x[:, 0] @ p["w_y"]).astype(jnp.float32))
    out = ((h * y).astype(x.dtype) @ p["w_out"])[:, None]
    return constrain(out, ("batch", None, "embed")), (h, window[:, 1:])
