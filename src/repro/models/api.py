"""Unified model API: specs, losses, serving steps, and input specs.

``build_model(cfg)`` returns a ``Model`` facade used by the launcher, the
dry-run, smoke tests and examples.  All functions are pure; parameters and
caches are explicit pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, ShapeSpec
from . import encdec as encdec_mod
from . import lm as lm_mod
from . import params as params_mod

__all__ = ["Model", "build_model", "model_specs", "input_specs"]


def model_specs(cfg: ModelConfig) -> dict:
    if cfg.family == "encdec":
        return encdec_mod.encdec_specs(cfg)
    return lm_mod.lm_specs(cfg)


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # ---- parameters ---------------------------------------------------
    def specs(self) -> dict:
        return model_specs(self.cfg)

    def abstract_params(self):
        return params_mod.abstract_tree(self.specs())

    def param_axes(self):
        return params_mod.axes_tree(self.specs())

    def init_params(self, key: jax.Array):
        return params_mod.init_tree(self.specs(), key)

    def param_count(self, active_only: bool = False) -> int:
        return params_mod.count_params(self.cfg, active_only=active_only)

    # ---- training -----------------------------------------------------
    def loss(self, params, batch, *, impl: str = "blocked"):
        if self.cfg.family == "encdec":
            return encdec_mod.encdec_loss(self.cfg, params, batch)
        return lm_mod.lm_loss(self.cfg, params, batch, impl=impl)

    # ---- serving ------------------------------------------------------
    def prefill(self, params, batch, *, impl: str = "blocked"):
        if self.cfg.family == "encdec":
            return encdec_mod.encdec_prefill(self.cfg, params, batch)
        return lm_mod.lm_prefill(self.cfg, params, batch, impl=impl)

    def decode_step(self, params, cache, tokens, pos, *, decode_impl="naive"):
        if self.cfg.family == "encdec":
            return encdec_mod.encdec_decode_step(
                self.cfg, params, cache, tokens, pos, decode_impl=decode_impl)
        return lm_mod.lm_decode_step(
            self.cfg, params, cache, tokens, pos, decode_impl=decode_impl)

    def cache_shapes(self, batch: int, cache_len: int):
        if self.cfg.family == "encdec":
            return encdec_mod.encdec_cache_shapes(self.cfg, batch, cache_len)
        return lm_mod.init_cache_shapes(self.cfg, batch, cache_len)

    def init_cache(self, batch: int, cache_len: int):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_shapes(batch, cache_len),
        )

    # ---- inputs ---------------------------------------------------------
    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        return input_specs(self.cfg, shape)

    def input_axes(self, shape: ShapeSpec) -> Dict[str, Any]:
        return input_axes(self.cfg, shape)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------------------
# Input specs per (family x shape kind): ShapeDtypeStruct stand-ins, no
# device allocation — the dry-run contract.
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    fam = cfg.family

    if shape.kind in ("train", "prefill"):
        if fam == "encdec":
            half = S // 2
            out = {
                "frames": sd((B, half, cfg.d_model), jnp.bfloat16),
                "tokens": sd((B, half), i32),
            }
            if shape.kind == "train":
                out["labels"] = sd((B, half), i32)
            return out
        if fam == "vlm":
            text = S - cfg.num_patches
            out = {
                "patches": sd((B, cfg.num_patches, 1024), jnp.bfloat16),
                "tokens": sd((B, text), i32),
            }
            if shape.kind == "train":
                out["labels"] = sd((B, text), i32)
            return out
        out = {"tokens": sd((B, S), i32)}
        if shape.kind == "train":
            out["labels"] = sd((B, S), i32)
        return out

    # decode: one token against a cache of length S
    model = build_model(cfg)
    cache_len = S // 2 if fam == "encdec" else S
    return {
        "cache": model.cache_shapes(B, cache_len),
        "tokens": sd((B, 1), i32),
        "pos": sd((B,), i32),
    }


def input_axes(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Logical axes for each input leaf (same structure as input_specs)."""
    fam = cfg.family
    if shape.kind in ("train", "prefill"):
        out: Dict[str, Any] = {"tokens": ("batch", "seq")}
        if shape.kind == "train":
            out["labels"] = ("batch", "seq")
        if fam == "encdec":
            out["frames"] = ("batch", "seq", "embed")
        if fam == "vlm":
            out["patches"] = ("batch", "patches", None)
        return out

    cache_axes = _cache_axes(cfg)
    return {
        "cache": cache_axes,
        "tokens": ("batch", None),
        "pos": ("batch",),
    }


def _cache_axes(cfg: ModelConfig) -> Dict[str, Any]:
    fam = cfg.family
    if fam == "encdec":
        kv = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
        return {"k": kv, "v": kv, "xk": kv, "xv": kv}
    if fam in ("dense", "vlm", "moe"):
        out: Dict[str, Any] = {}
        if cfg.attention == "mla":
            out["ckv"] = ("layers", "batch", "cache_seq", "kvlora")
            out["krope"] = ("layers", "batch", "cache_seq", "head_dim")
            if fam == "moe" and cfg.moe_dense_layers:
                out["d_ckv"] = out["ckv"]
                out["d_krope"] = out["krope"]
        else:
            kv = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
            out["k"] = kv
            out["v"] = kv
            if fam == "moe" and cfg.moe_dense_layers:
                out["d_k"] = kv
                out["d_v"] = kv
        return out
    if fam == "ssm":
        return {
            "ssm": ("layers", "batch", "q_heads", None, "state"),
            "conv": ("layers", "batch", "conv", "rnn"),
        }
    if fam == "hybrid":
        out = {
            "rnn": ("layers", None, "batch", "rnn"),
            "rnn_conv": ("layers", None, "batch", "conv", "rnn"),
            "k": ("layers", None, "batch", None, "kv_heads", "head_dim"),
            "v": ("layers", None, "batch", None, "kv_heads", "head_dim"),
        }
        n_groups, tail = lm_mod._hybrid_layout(cfg)
        if tail:
            out["tail_rnn"] = ("layers", "batch", "rnn")
            out["tail_rnn_conv"] = ("layers", "batch", "conv", "rnn")
        return out
    raise ValueError(fam)
