"""Serving steps: prefill and decode wrappers used by the dry-run and the
serving example.  Pure functions over (params, batch/cache)."""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["make_prefill_step", "make_decode_step"]


def make_prefill_step(model, *, impl: str = "blocked") -> Callable:
    from ..models.attention import inference_mode

    def prefill_step(params, batch):
        with inference_mode():
            logits, cache = model.prefill(params, batch, impl=impl)
        return logits, cache

    return prefill_step


def make_decode_step(model, *, decode_impl: str = "naive") -> Callable:
    def serve_step(params, cache, tokens, pos):
        logits, new_cache = model.decode_step(
            params, cache, tokens, pos, decode_impl=decode_impl
        )
        # Greedy next-token (serving returns token ids + updated cache).
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache

    return serve_step
