"""Serving entry points.

Two layers live here:

  * prefill / decode step wrappers used by the dry-run and the serving
    example — pure functions over (params, batch/cache);
  * ``run_session_workload`` — the launcher for the multi-tenant
    session server (repro.serve): open one session per concurrent
    editor over a warm handle, stream each editor's edits through the
    admission queue, return per-session results plus the server's
    latency/batching summary.  The serving example's ``--server`` mode
    and the serve smoke test drive this one function.
"""
from __future__ import annotations

import asyncio
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["make_prefill_step", "make_decode_step",
           "run_session_workload"]


def make_prefill_step(model, *, impl: str = "blocked") -> Callable:
    from ..models.attention import inference_mode

    def prefill_step(params, batch):
        with inference_mode():
            logits, cache = model.prefill(params, batch, impl=impl)
        return logits, cache

    return prefill_step


def run_session_workload(handle, edit_streams: List[List[Dict[str, Any]]],
                         **server_opts) -> Tuple[List[List[Dict]], Dict]:
    """Serve N concurrent editors against one warm handle.

    ``edit_streams[i]`` is editor i's ordered list of edits (each a
    ``{input_name: array}`` dict).  Each editor gets its own session
    (a COW fork of the handle's warm state) and submits its edits in
    order; *across* editors the submissions race, so same-round edits
    land in one admission wave and batch when their dirty signatures
    match.  Returns (per-editor result lists, server summary).

    Synchronous facade over the asyncio server — safe to call from
    ordinary scripts/tests (no running loop required).
    """

    async def _editor(server, stream):
        sid = await server.open()
        results = []
        for edit in stream:
            results.append(await server.submit(sid, edit))
        return results

    async def _main():
        async with handle.serve(**server_opts) as server:
            results = await asyncio.gather(
                *[_editor(server, s) for s in edit_streams])
            summary = server.summary()
            await server.shutdown()
        return list(results), summary

    return asyncio.run(_main())


def make_decode_step(model, *, decode_impl: str = "naive") -> Callable:
    def serve_step(params, cache, tokens, pos):
        logits, new_cache = model.decode_step(
            params, cache, tokens, pos, decode_impl=decode_impl
        )
        # Greedy next-token (serving returns token ids + updated cache).
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache

    return serve_step
