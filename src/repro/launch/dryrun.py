import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
# ^^ MUST run before any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell against the production meshes and record memory / cost / collective
analyses.

For each cell this driver:
  1. builds the exact assigned config and ShapeDtypeStruct inputs,
  2. resolves parameter/optimizer/input shardings for the mode
     (train_step for train shapes, prefill/serve_step for serving shapes),
  3. ``jax.jit(...).lower(...).compile()`` on the 16x16 single-pod mesh
     and the 2x16x16 multi-pod mesh,
  4. records ``memory_analysis()`` (proves the cell fits per-device HBM),
    ``cost_analysis()`` and the HLO-derived roofline inputs (FLOPs, bytes,
    per-collective bytes with loop trip counts applied) into
    ``results/dryrun/<mesh>/<arch>__<shape>.json``.

Runs are resumable: existing result files are skipped unless --force.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import gc
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, canonical, get_config
from ..models import SHAPES, build_model, shape_by_name
from ..models.api import input_axes as input_axes_fn
from ..optim import make_optimizer, make_schedule
from ..shardlib import rules_for_mode, shard_ctx
from .hlo_analysis import analyze_hlo
from .mesh import make_production_mesh
from .partition import fsdp_axes_tree, tree_to_shardings
from .train import abstract_train_state, make_train_step
from .serve import make_decode_step, make_prefill_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


# ---------------------------------------------------------------------------
def skip_reason(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("full-attention architecture: 512k-token KV cache/attention is "
                "quadratic — shape skipped per assignment (see DESIGN.md "
                "§Arch-applicability)")
    return None


def _replicated(mesh):
    return NamedSharding(mesh, P())


def build_cell(cfg, shape, mesh, *, impl_overrides=None):
    """Return (fn, example_args, in_shardings, out_shardings) for one cell."""
    from ..models import params as params_mod
    from ..models.api import model_specs

    impl_overrides = impl_overrides or {}
    mode = shape.kind
    rules = rules_for_mode(mode)
    if shape.name == "long_500k":
        rules = [(k, None if k == "batch" else v) for k, v in rules]

    with shard_ctx(mesh, rules) as ctx:
        model = build_model(cfg)
        specs = model_specs(cfg)
        in_specs = model.input_specs(shape)
        in_ax = input_axes_fn(cfg, shape)
        from .partition import tree_to_shardings

        input_shardings = tree_to_shardings(in_ax, ctx, in_specs)

        if mode == "train":
            optimizer = make_optimizer(cfg)
            schedule = make_schedule(cfg.lr_schedule, 3e-4, 10_000)
            step_fn = make_train_step(model, optimizer, schedule)
            state_abs = abstract_train_state(model, optimizer)
            p_axes = fsdp_axes_tree(specs, ctx)
            p_shard = tree_to_shardings(p_axes, ctx, state_abs["params"])
            from .partition import state_shardings

            opt_shard = state_shardings(cfg, ctx, state_abs["opt"], p_axes,
                                        state_abs["params"])
            state_shard = {"params": p_shard, "opt": opt_shard,
                           "step": _replicated(mesh)}
            metrics_shard = None  # let XLA replicate scalars
            fn = step_fn
            args = (state_abs, in_specs)
            in_sh = (state_shard, input_shardings)
            out_sh = (state_shard, None)
            return fn, args, in_sh, out_sh, ctx

        # serving modes: parameters TP-sharded (no FSDP overlay)
        p_axes = params_mod.axes_tree(specs)
        p_shard = tree_to_shardings(p_axes, ctx, model.abstract_params())
        if mode == "prefill":
            fn = make_prefill_step(model, impl=impl_overrides.get("impl", "blocked"))
            args = (jax.tree.map(lambda s: s, model.abstract_params()), in_specs)
            in_sh = (p_shard, input_shardings)
            out_sh = None
            return fn, args, in_sh, out_sh, ctx

        # decode
        fn = make_decode_step(
            model, decode_impl=impl_overrides.get("decode_impl", "naive"))
        cache_abs = in_specs["cache"]
        args = (model.abstract_params(), cache_abs,
                in_specs["tokens"], in_specs["pos"])
        in_sh = (p_shard, input_shardings["cache"],
                 input_shardings["tokens"], input_shardings["pos"])
        out_sh = (None, None, input_shardings["cache"])
        return fn, args, in_sh, out_sh, ctx


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             force: bool = False, impl_overrides=None, tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    outdir = RESULTS_DIR / mesh_kind
    outdir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    outfile = outdir / f"{canonical(arch)}__{shape_name}{suffix}.json"
    if outfile.exists() and not force:
        return json.loads(outfile.read_text())

    record: dict = {
        "arch": cfg.name, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
        "kind": shape.kind, "timestamp": time.time(),
    }
    reason = skip_reason(cfg, shape)
    if reason:
        record["status"] = "skipped"
        record["reason"] = reason
        outfile.write_text(json.dumps(record, indent=2))
        return record

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_dev = mesh.devices.size
    try:
        t0 = time.time()
        fn, args, in_sh, out_sh, ctx = build_cell(
            cfg, shape, mesh, impl_overrides=impl_overrides)
        with shard_ctx(mesh, ctx.rules.items()):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo_text = compiled.as_text()
        costs = analyze_hlo(hlo_text, n_dev)
        record.update({
            "status": "ok",
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "devices": int(n_dev),
            "memory": {
                "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
                "generated_code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
                "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
            },
            "xla_cost_analysis": {
                "flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            },
            "hlo_costs": {
                "flops_per_device": costs.flops,
                "dot_flops_per_device": costs.dot_flops,
                "conv_flops_per_device": costs.conv_flops,
                "bytes_per_device": costs.bytes,
                "collective_bytes_per_device": costs.collective_bytes,
                "collective_wire_bytes_per_device": costs.collective_wire_bytes,
                "unparsed_whiles": costs.unparsed_whiles,
                "collectives": {
                    k: {"count": v.count, "bytes": v.bytes,
                        "wire_bytes": v.wire_bytes}
                    for k, v in costs.collectives.items()
                },
            },
            "hlo_len": len(hlo_text),
        })
        del compiled, lowered, jitted
    except Exception as e:  # record failures — they are dry-run bugs
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    finally:
        gc.collect()
        jax.clear_caches()

    outfile.write_text(json.dumps(record, indent=2))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--decode-impl", default="naive")
    ap.add_argument("--impl", default="blocked")
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-head-pad", action="store_true",
                    help="disable runtime head padding (hillclimb-A baseline)")
    ap.add_argument("--moe-ep", action="store_true",
                    help="shard_map expert-parallel MoE dispatch (hillclimb B)")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells.append((args.arch, args.shape))

    overrides = {"decode_impl": args.decode_impl, "impl": args.impl}
    if args.no_head_pad:
        from ..models.attention import head_padding
        head_padding(False).__enter__()
    if args.moe_ep:
        from ..models.moe import ep_moe
        ep_moe(True).__enter__()
    n_ok = n_skip = n_err = 0
    for mesh_kind in meshes:
        for arch, shape_name in cells:
            t0 = time.time()
            rec = run_cell(arch, shape_name, mesh_kind, force=args.force,
                           impl_overrides=overrides, tag=args.tag)
            dt = time.time() - t0
            status = rec.get("status")
            n_ok += status == "ok"
            n_skip += status == "skipped"
            n_err += status == "error"
            mem = rec.get("memory", {})
            tot = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)) / 2**30
            print(f"[{mesh_kind}] {arch:24s} {shape_name:12s} {status:8s} "
                  f"{dt:6.1f}s  mem/dev={tot:6.2f}GiB  "
                  f"{rec.get('error', '')[:80]}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} errors={n_err}")


if __name__ == "__main__":
    main()
