"""Training step: grad accumulation, clipping, optimizer update, metrics.

``make_train_step(model, optimizer, schedule)`` builds a pure function
``train_step(state, batch) -> (state, metrics)`` suitable for jit with
explicit in/out shardings.  Gradient accumulation runs as a lax.scan over
microbatches with fp32 accumulators (sharded like the FSDP'd parameters,
so accumulation memory is ZeRO-partitioned too).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..optim import Optimizer, clip_by_global_norm

__all__ = ["TrainState", "make_train_step", "init_train_state", "abstract_train_state"]

TrainState = Dict[str, Any]  # {'params', 'opt', 'step'}


def init_train_state(model, optimizer: Optimizer, key) -> TrainState:
    params = model.init_params(key)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(model, optimizer: Optimizer) -> TrainState:
    params_abs = model.abstract_params()

    def mk():
        params = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params_abs)
        return {"params": params, "opt": optimizer.init(params),
                "step": jnp.zeros((), jnp.int32)}

    return jax.eval_shape(mk)


def make_train_step(
    model,
    optimizer: Optimizer,
    schedule: Callable,
    *,
    max_grad_norm: float = 1.0,
    grad_compression: Any = None,
) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    cfg = model.cfg
    accum = max(cfg.grad_accum, 1)

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        params = state["params"]
        if accum > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch,
            )

            def micro(g_acc, mb):
                (loss, metrics), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return g_acc, metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics_seq = jax.lax.scan(micro, g0, mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda m: m.mean(), metrics_seq)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        if grad_compression is not None:
            grads = grad_compression(grads)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = schedule(state["step"])
        new_params, new_opt = optimizer.update(
            grads, state["opt"], params, state["step"], lr
        )
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return new_state, metrics

    return train_step
