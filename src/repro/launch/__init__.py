"""Launch layer: meshes, partitioning, dry-run, train/serve entry points.

NOTE: ``repro.launch.dryrun`` sets XLA_FLAGS at import (512 host devices)
and must only be imported as a __main__ script, never from library code.
"""
from .mesh import make_local_mesh, make_production_mesh

__all__ = ["make_production_mesh", "make_local_mesh"]
