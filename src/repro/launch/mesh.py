"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds meshes.

Topology (TPU v5e): one pod = 16 x 16 = 256 chips, axes (data, model);
multi-pod = 2 x 16 x 16 = 512 chips, axes (pod, data, model).  The 'pod'
axis carries only data parallelism (gradient all-reduce over DCI), 'model'
carries tensor/expert parallelism (intra-pod ICI), 'data' carries data
parallelism + ZeRO sharding.
"""
from __future__ import annotations

import jax

from repro.shardlib import make_mesh

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: int = 0):
    """Mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    model = min(model, n)
    data = data or (n // model)
    return make_mesh((data, model), ("data", "model"))
