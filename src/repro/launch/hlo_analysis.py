"""Static analysis of compiled (post-SPMD) HLO for roofline accounting.

``compiled.cost_analysis()`` on XLA:CPU counts a ``while`` body ONCE —
lax.scan-stacked layers would be undercounted by a factor of L.  This
module re-derives FLOPs / HBM bytes / collective bytes from the optimized
HLO text, multiplying loop bodies by their trip counts (parsed from the
loop-condition constants), so the roofline terms reflect what a TPU would
actually execute per step.

Cost model:
  * FLOPs — 2 * prod(result_dims) * prod(lhs_contracting_dims) for every
    ``dot``; convolutions analogously.  Elementwise FLOPs are excluded
    (sub-2% for these workloads; dominated by matmuls).
  * bytes — for every substantive instruction: sum of operand sizes plus
    result size (the standard HLO bytes-accessed model: every operand is
    read once from HBM, every result written once; fusions count as one
    instruction so fused intermediates are free, matching TPU behaviour).
  * collectives — operand bytes per device, recorded per collective type
    with the participating group size, plus estimated wire bytes using
    ring-algorithm factors (all-reduce 2(n-1)/n, gather/scatter (n-1)/n).

Shapes in post-SPMD HLO are per-device, so every term is per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCosts", "CollectiveStats"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "partition-id",
    "replica-id", "iota",
}


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.elems * _DTYPE_BYTES.get(self.dtype, 4)


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    shapes: List[Shape]            # result shapes (tuple flattened)
    operands: List[str]
    attrs: str
    raw_operands: str = ""

    @property
    def out_bytes(self) -> int:
        return sum(s.bytes for s in self.shapes)


@dataclasses.dataclass
class CollectiveStats:
    count: int = 0
    bytes: int = 0            # operand bytes per device (x trip counts)
    wire_bytes: float = 0.0   # estimated per-device wire traffic


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, CollectiveStats] = dataclasses.field(default_factory=dict)
    dot_flops: float = 0.0
    conv_flops: float = 0.0
    unparsed_whiles: int = 0

    @property
    def collective_bytes(self) -> float:
        return sum(c.bytes for c in self.collectives.values())

    @property
    def collective_wire_bytes(self) -> float:
        return sum(c.wire_bytes for c in self.collectives.values())

    def add(self, other: "HloCosts", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.dot_flops += other.dot_flops * mult
        self.conv_flops += other.conv_flops * mult
        self.unparsed_whiles += other.unparsed_whiles
        for k, v in other.collectives.items():
            c = self.collectives.setdefault(k, CollectiveStats())
            c.count += int(v.count * mult)
            c.bytes += int(v.bytes * mult)
            c.wire_bytes += v.wire_bytes * mult


# ---------------------------------------------------------------------------
# Parsing
# ---------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*?)\)(.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->\s+.+\s*\{\s*$")


def _parse_shapes(type_str: str) -> List[Shape]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype = m.group(1)
        if dtype not in _DTYPE_BYTES and dtype != "token":
            # e.g. 'f32' without brackets won't match; scalars appear as
            # f32[] with empty dims.
            pass
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        if dtype in _DTYPE_BYTES:
            out.append(Shape(dtype, dims))
    if not out and "[]" in type_str:
        dt = type_str.split("[")[0].strip().lstrip("(")
        if dt in _DTYPE_BYTES:
            out.append(Shape(dt, ()))
    return out


def _parse_operands(s: str) -> List[str]:
    ops = []
    for part in s.split(","):
        part = part.strip()
        if part.startswith("%"):
            ops.append(part[1:])
        else:
            # typed operand like "f32[8,16] %name" or a literal
            m = re.search(r"%([\w\.\-]+)", part)
            if m:
                ops.append(m.group(1))
    return ops


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        # name -> result shapes, across all computations (names are unique
        # module-wide in optimized HLO).
        self.shape_of: Dict[str, List[Shape]] = {}
        for comp in self.computations.values():
            for ins in comp:
                self.shape_of[ins.name] = ins.shapes

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            hdr = _COMP_HDR_RE.match(line.strip())
            if hdr and line.strip().endswith("{"):
                cur = hdr.group(1)
                self.computations[cur] = []
                if line.strip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, type_str, op, operands, attrs = m.groups()
            self.computations[cur].append(
                Instr(name, op, _parse_shapes(type_str),
                      _parse_operands(operands), attrs, raw_operands=operands)
            )


# ---------------------------------------------------------------------------
# Cost walking
# ---------------------------------------------------------------------------
def _attr_called(attrs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w\.\-]+)", attrs)
    return m.group(1) if m else None


def _group_size(attrs: str, total_devices: int) -> int:
    # replica_groups=[2,4]<=[8]  -> groups of 4
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    # explicit groups {{0,1,2,3},{...}}
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return total_devices


class _Walker:
    def __init__(self, module: HloModule, total_devices: int):
        self.module = module
        self.n = total_devices

    def comp_cost(self, comp_name: str, _depth=0) -> HloCosts:
        costs = HloCosts()
        comp = self.module.computations.get(comp_name)
        if comp is None or _depth > 12:
            return costs
        for ins in comp:
            if ins.op == "while":
                cond = _attr_called(ins.attrs, "condition")
                body = _attr_called(ins.attrs, "body")
                trips = self._trip_count(cond)
                if trips is None:
                    trips = 1
                    costs.unparsed_whiles += 1
                if body:
                    costs.add(self.comp_cost(body, _depth + 1), trips)
                if cond:
                    costs.add(self.comp_cost(cond, _depth + 1), trips)
                continue
            if ins.op in ("call", "async-start"):
                tgt = _attr_called(ins.attrs, "to_apply") or _attr_called(ins.attrs, "called_computation")
                if tgt:
                    costs.add(self.comp_cost(tgt, _depth + 1))
                continue
            if ins.op == "conditional":
                for key in ("true_computation", "false_computation"):
                    tgt = _attr_called(ins.attrs, key)
                    if tgt:
                        costs.add(self.comp_cost(tgt, _depth + 1))
                m = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
                if m:
                    for t in m.group(1).split(","):
                        costs.add(self.comp_cost(t.strip().lstrip("%"), _depth + 1))
                continue
            if ins.op == "fusion":
                tgt = _attr_called(ins.attrs, "calls")
                if tgt:
                    sub = self.comp_cost(tgt, _depth + 1)
                    # fused intermediates are registers: count only flops
                    costs.flops += sub.flops
                    costs.dot_flops += sub.dot_flops
                    costs.conv_flops += sub.conv_flops
                # fusion bytes: operands + result
                costs.bytes += self._io_bytes(ins)
                continue
            if ins.op in COLLECTIVE_OPS or (
                ins.op == "custom-call" and any(c in ins.attrs for c in COLLECTIVE_OPS)
            ):
                opname = ins.op if ins.op in COLLECTIVE_OPS else "custom-collective"
                b = self._operand_bytes(ins)
                g = _group_size(ins.attrs, self.n)
                st = costs.collectives.setdefault(opname, CollectiveStats())
                st.count += 1
                st.bytes += b
                st.wire_bytes += _wire_factor(opname, g) * _wire_base(opname, ins, b)
                costs.bytes += self._io_bytes(ins)
                continue
            if ins.op == "dot":
                f = self._dot_flops(ins)
                costs.flops += f
                costs.dot_flops += f
                costs.bytes += self._io_bytes(ins)
                continue
            if ins.op == "convolution":
                f = self._conv_flops(ins)
                costs.flops += f
                costs.conv_flops += f
                costs.bytes += self._io_bytes(ins)
                continue
            if ins.op == "custom-call" and "matmul" in ins.attrs:
                f = self._custom_matmul_flops(ins)
                costs.flops += f
                costs.dot_flops += f
                costs.bytes += self._io_bytes(ins)
                continue
            if ins.op in _SKIP_BYTES_OPS:
                continue
            costs.bytes += self._io_bytes(ins)
        return costs

    # -- helpers ---------------------------------------------------------
    def _trip_count(self, cond_name: Optional[str]) -> Optional[int]:
        if cond_name is None:
            return None
        comp = self.module.computations.get(cond_name)
        if comp is None:
            return None
        best: Optional[int] = None
        for ins in comp:
            if ins.op == "constant" and ins.shapes and ins.shapes[0].dims == ():
                m = re.fullmatch(r"\s*(\d+)\s*", ins.raw_operands or "")
                if m:
                    v = int(m.group(1))
                    best = v if best is None else max(best, v)
        return best

    def _operand_bytes(self, ins: Instr) -> int:
        total = 0
        for op in ins.operands:
            shapes = self.module.shape_of.get(op)
            if shapes:
                total += sum(s.bytes for s in shapes)
        return total

    def _io_bytes(self, ins: Instr) -> int:
        # In-place slice semantics (TPU DMA reality): a dynamic-slice reads
        # only the slice, a dynamic-update-slice read-modify-writes only the
        # update region, a gather reads only the gathered rows.  Counting
        # their full operands would charge a lax.scan over stacked layer
        # parameters the whole stack per iteration — a 40x overcount
        # observed on every scanned LM (EXPERIMENTS.md §Perf, hillclimb A).
        if ins.op in ("dynamic-slice", "gather"):
            return 2 * ins.out_bytes
        if ins.op in ("dynamic-update-slice", "scatter"):
            upd = 0
            if len(ins.operands) >= 2:
                shapes = self.module.shape_of.get(ins.operands[1])
                if shapes:
                    upd = sum(s.bytes for s in shapes)
            return max(2 * upd, 1)
        if ins.op == "fusion":
            return self._fusion_bytes(ins)
        return self._operand_bytes(ins) + ins.out_bytes

    def _fusion_bytes(self, ins: Instr) -> int:
        """Fusion bytes with slice-aware parameter accounting.

        A fused computation's parameter that is consumed *only* by
        dynamic-slice/gather ops is streamed at slice granularity; the
        fusion output, when rooted at dynamic-update-slice, writes only
        the update region (XLA aliases the buffer in place)."""
        tgt = _attr_called(ins.attrs, "calls")
        comp = self.module.computations.get(tgt) if tgt else None
        if comp is None:
            return self._operand_bytes(ins) + ins.out_bytes
        # Parameters are matched to fusion operands by their declared index
        # (``parameter(4)``), NOT by order of appearance in the body.
        params_with_idx = []
        for pos, i in enumerate(p for p in comp if p.op == "parameter"):
            m = re.fullmatch(r"\s*(\d+)\s*", i.raw_operands or "")
            params_with_idx.append((int(m.group(1)) if m else pos, i.name))
        params_with_idx.sort()
        param_order: List[str] = [name for _, name in params_with_idx]
        # Layout/dtype plumbing between a parameter and its slice must not
        # hide the slice: follow single-operand transparent chains.
        _TRANSPARENT = {"bitcast", "copy", "reshape", "transpose", "convert",
                        "bitcast-convert"}
        alias: Dict[str, str] = {p: p for p in param_order}
        for inner in comp:
            if inner.op in _TRANSPARENT and inner.operands and \
                    inner.operands[0] in alias:
                alias[inner.name] = alias[inner.operands[0]]
        sliced_reads: Dict[str, int] = {}
        full_params: set = set()
        for inner in comp:
            if inner.op == "parameter" or inner.name in alias and \
                    inner.op in _TRANSPARENT:
                continue
            for opnd in inner.operands:
                src = alias.get(opnd)
                if src is None:
                    continue
                if inner.op in ("dynamic-slice", "gather") and \
                        opnd == inner.operands[0]:
                    sliced_reads[src] = sliced_reads.get(src, 0) + \
                        inner.out_bytes
                elif inner.op == "dynamic-update-slice" and \
                        opnd == inner.operands[0]:
                    pass  # written through in place; charged at the root
                else:
                    full_params.add(src)
        total = 0
        for i, pname in enumerate(param_order):
            if i >= len(ins.operands):
                break
            shapes = self.module.shape_of.get(ins.operands[i])
            full = sum(s.bytes for s in shapes) if shapes else 0
            if pname in full_params:
                total += full
            else:
                total += min(sliced_reads.get(pname, 0), full)
        root = comp[-1] if comp else None
        if root is not None and root.op == "dynamic-update-slice" and \
                len(root.operands) >= 2:
            upd_shapes = self.module.shape_of.get(root.operands[1])
            total += 2 * (sum(s.bytes for s in upd_shapes)
                          if upd_shapes else 0)
        else:
            total += ins.out_bytes
        return total

    def _dot_flops(self, ins: Instr) -> float:
        if not ins.shapes or not ins.operands:
            return 0.0
        out_elems = ins.shapes[0].elems
        lhs = self.module.shape_of.get(ins.operands[0])
        if not lhs:
            return 0.0
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        k = 1
        if m and m.group(1):
            for d in m.group(1).split(","):
                di = int(d)
                if di < len(lhs[0].dims):
                    k *= lhs[0].dims[di]
        return 2.0 * out_elems * k

    def _conv_flops(self, ins: Instr) -> float:
        if not ins.shapes or len(ins.operands) < 2:
            return 0.0
        out_elems = ins.shapes[0].elems
        ker = self.module.shape_of.get(ins.operands[1])
        if not ker:
            return 0.0
        ker_elems = ker[0].elems
        # per output element: kernel_elems / output_features MACs
        m = re.search(r"dim_labels=\S*->\S*", ins.attrs)
        out_feat = ins.shapes[0].dims[-1] if ins.shapes[0].dims else 1
        fg = 1
        g = re.search(r"feature_group_count=(\d+)", ins.attrs)
        if g:
            fg = int(g.group(1))
        return 2.0 * out_elems * max(ker_elems // max(out_feat, 1), 1) / max(fg, 1) * fg

    def _custom_matmul_flops(self, ins: Instr) -> float:
        if not ins.shapes or len(ins.operands) < 2:
            return 0.0
        out = ins.shapes[0]
        lhs = self.module.shape_of.get(ins.operands[0])
        if not lhs:
            return 0.0
        k = lhs[0].dims[-1] if lhs[0].dims else 1
        return 2.0 * out.elems * k


def _wire_factor(op: str, group: int) -> float:
    if group <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (group - 1) / group
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return (group - 1) / group
    return 1.0  # collective-permute


def _wire_base(op: str, ins: Instr, operand_bytes: int) -> float:
    # all-gather wire volume scales with the *output* (gathered) size.
    if op == "all-gather":
        return float(ins.out_bytes)
    return float(operand_bytes)


def analyze_hlo(text: str, total_devices: int) -> HloCosts:
    module = HloModule(text)
    walker = _Walker(module, total_devices)
    if module.entry is None:
        return HloCosts()
    return walker.comp_cost(module.entry)
