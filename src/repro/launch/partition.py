"""Parameter / optimizer-state partitioning: TP rules + ZeRO/FSDP overlay.

Base sharding comes from each ParamSpec's logical axes resolved through
the mode's rule table (repro.shardlib).  In training mode we additionally
apply a ZeRO-3/FSDP overlay: every parameter's largest still-unsharded,
divisible dimension is sharded over the 'zero' (== 'data', and 'pod' when
present) axis.  GSPMD then materializes the classic FSDP schedule:
all-gather params per layer on use, reduce-scatter grads, and a fully
sharded optimizer update.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding

from ..models import params as params_mod
from ..shardlib import ShardCtx

__all__ = [
    "fsdp_axes",
    "fsdp_axes_tree",
    "param_shardings",
    "state_shardings",
    "tree_to_shardings",
]


def fsdp_axes(
    axes: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
    ctx: ShardCtx,
    zero_size: int,
) -> Tuple[Optional[str], ...]:
    """Overlay 'zero' onto the largest unsharded dim divisible by zero_size."""
    if zero_size <= 1:
        return axes
    resolved = ctx.resolve(axes, shape)
    best = -1
    best_size = 0
    for i, (ax, dim) in enumerate(zip(axes, shape)):
        spec_entry = resolved[i] if i < len(resolved) else None
        if spec_entry is not None:
            continue  # already sharded by TP rules
        if ax == "conv":
            continue  # tiny
        if dim % zero_size == 0 and dim > best_size:
            best, best_size = i, dim
    if best < 0:
        return axes
    new = list(axes)
    new[best] = "zero"
    return tuple(new)


def fsdp_axes_tree(specs, ctx: ShardCtx) -> Any:
    zero_size = 1
    for ax in ("pod", "data"):
        zero_size *= ctx.axis_sizes.get(ax, 1)
    # 'zero' maps to ('pod','data')? rules map 'zero'->'data'; extend to pod
    # by resolving through the rule table (rules define the target axes).
    zero_target = ctx.rules.get("zero")
    if zero_target is None:
        return params_mod.axes_tree(specs)
    if isinstance(zero_target, str):
        zero_target = (zero_target,)
    zero_size = 1
    for ax in zero_target:
        zero_size *= ctx.axis_sizes.get(ax, 1)

    def leaf(s):
        return fsdp_axes(s.axes, s.shape, ctx, zero_size)

    return jax.tree.map(leaf, specs, is_leaf=lambda x: isinstance(x, params_mod.ParamSpec))


def tree_to_shardings(axes_tree: Any, ctx: ShardCtx, shapes_tree: Any = None) -> Any:
    """axes_tree of logical-axes tuples -> NamedShardings.  When
    ``shapes_tree`` (same structure, leaves with .shape) is given, the
    resolution drops mesh axes that don't divide the concrete dims."""
    def is_axes(x):
        return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)

    if shapes_tree is None:
        return jax.tree.map(
            lambda axes: NamedSharding(ctx.mesh, ctx.resolve(axes)),
            axes_tree,
            is_leaf=is_axes,
        )
    flat_axes, treedef = jax.tree.flatten(axes_tree, is_leaf=is_axes)
    flat_shapes = jax.tree.leaves(shapes_tree)
    assert len(flat_axes) == len(flat_shapes), (len(flat_axes), len(flat_shapes))
    out = [
        NamedSharding(ctx.mesh, ctx.resolve(a, s.shape))
        for a, s in zip(flat_axes, flat_shapes)
    ]
    return jax.tree.unflatten(treedef, out)


def param_shardings(cfg, ctx: ShardCtx, *, fsdp: bool) -> Any:
    from ..models.api import model_specs

    specs = model_specs(cfg)
    axes = fsdp_axes_tree(specs, ctx) if fsdp else params_mod.axes_tree(specs)
    shapes = params_mod.abstract_tree(specs)
    return tree_to_shardings(axes, ctx, shapes)


def state_shardings(cfg, ctx: ShardCtx, opt_state_abstract, param_axes_tree,
                    params_abstract) -> Any:
    """Optimizer states mirror their parameter's sharding; factored
    (reduced-rank) leaves drop the sharded dims they no longer have."""
    pshard = tree_to_shardings(param_axes_tree, ctx, params_abstract)

    def match(path_shard, leaf):
        # leaf shapes may differ (factored second moments); fall back to
        # replicated when dims don't line up.
        return path_shard

    # AdamW states mirror params exactly (same treedef under m/v).
    import jax.tree_util as jtu

    def map_state(state):
        # state is a NamedTuple of pytrees shaped like params (or reduced).
        out = []
        for field in state:
            try:
                jtu.tree_structure(field)
                mapped = jax.tree.map(
                    lambda p_sh, leaf: _fit_sharding(p_sh, leaf, ctx),
                    pshard,
                    field,
                )
            except Exception:
                mapped = jax.tree.map(lambda l: NamedSharding(ctx.mesh, jax.sharding.PartitionSpec()), field)
            out.append(mapped)
        return type(state)(*out)

    return map_state(opt_state_abstract)


def _fit_sharding(param_sharding: NamedSharding, leaf, ctx: ShardCtx) -> NamedSharding:
    from jax.sharding import PartitionSpec as P

    spec = param_sharding.spec
    shape = leaf.shape
    if len(spec) == len(shape):
        # verify divisibility; drop axes that no longer divide
        entries = []
        for ax, dim in zip(spec, shape):
            if ax is None:
                entries.append(None)
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= ctx.axis_sizes.get(a, 1)
            entries.append(ax if dim % size == 0 else None)
        return NamedSharding(ctx.mesh, P(*entries))
    # factored leaf (fewer dims): keep the prefix entries that still divide
    entries = []
    for i, dim in enumerate(shape):
        ax = spec[i] if i < len(spec) else None
        if ax is None:
            entries.append(None)
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= ctx.axis_sizes.get(a, 1)
        entries.append(ax if dim % size == 0 else None)
    return NamedSharding(ctx.mesh, P(*entries))
