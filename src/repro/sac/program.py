"""@sac.incremental: trace once, compile, then run / update.

The single public entry point of the ``repro.sac`` frontend::

    @sac.incremental(block=16)
    def pipeline(x):
        y = x * 2.0 + 1.0
        s = sac.stencil(lambda w: w[16:32] + 0.5 * (w[:16] + w[32:]),
                        y, radius=1)
        return sac.reduce(jnp.add, s, identity=0.0)

    h = pipeline.compile(x=4096)          # trace + lower + jit
    total = h.run(x=data)                 # initial run (memoize all)
    total = h.update(x=edited)            # change propagation
    h.stats["recomputed"]                 # realized computation distance

``compile(backend="graph")`` (default) lowers onto the jit-compiled
SP-dag runtime (``repro.jaxsac.graph_compile``); ``backend="host"``
lowers the *same* traced dag onto the paper-faithful host engine
(``repro.core.engine``) — per-block modifiables, reader sets, RSP-tree
change propagation — giving exact work/span accounting for the identical
program.  Outputs are bitwise-identical across backends.
"""
from __future__ import annotations

import inspect
from typing import Any, Dict, List, Optional, Tuple, Union

import jax

from repro.jaxsac.graph import GraphBuilder, Handle
from repro.obs import PropagationRecorder
from repro.obs.recorder import MODES, TraceMethods
from . import tracer as _tracer
from .tracer import BlockArray

__all__ = ["incremental", "IncrementalProgram", "GraphHandle"]


def incremental(fn=None, *, block: Union[int, Dict[str, int]] = 1):
    """Decorator: mark an ordinary array function as an incremental
    program.  ``block`` is the dependency-tracking granularity of the
    inputs (elements of the leading axis per modifiable block); pass a
    dict to set it per input name."""
    if fn is not None:
        return IncrementalProgram(fn, block)

    def deco(f):
        return IncrementalProgram(f, block)

    return deco


def _leading_size(spec: Any) -> int:
    """Input size from an int n, a shape tuple, or an array."""
    if isinstance(spec, int):
        return spec
    if isinstance(spec, tuple):
        return int(spec[0])
    if hasattr(spec, "shape"):
        return int(spec.shape[0])
    raise TypeError(f"input spec must be int, shape tuple, or array; "
                    f"got {type(spec).__name__}")


class IncrementalProgram:
    """A traceable incremental program (the decorator's return value)."""

    def __init__(self, fn, block: Union[int, Dict[str, int]] = 1):
        self.fn = fn
        self.block = block
        self.__name__ = getattr(fn, "__name__", "incremental")
        self.__doc__ = fn.__doc__

    def _block_of(self, name: str) -> int:
        if isinstance(self.block, dict):
            return int(self.block.get(name, 1))
        return int(self.block)

    # ------------------------------------------------------------------
    def trace(self, **input_specs) -> Tuple[GraphBuilder, List[Handle], bool]:
        """Run ``fn`` over BlockArray tracers; returns the recorded dag,
        the output handles, and whether the output was a single array."""
        params = list(inspect.signature(self.fn).parameters)
        missing = [p for p in params if p not in input_specs]
        if missing:
            raise TypeError(
                f"compile() needs a size for every input of "
                f"{self.__name__}(); missing {missing} "
                f"(pass name=<n | shape | array>)")

        g = GraphBuilder()
        tracers = {}
        for name in params:
            n = _leading_size(input_specs[name])
            tracers[name] = BlockArray(
                g.input(name, n=n, block=self._block_of(name)))

        _tracer._TRACES.append(g)
        try:
            out = self.fn(**tracers)
        finally:
            _tracer._TRACES.pop()

        single = isinstance(out, BlockArray)
        outs = (out,) if single else tuple(out)
        for o in outs:
            if not isinstance(o, BlockArray):
                raise TypeError(
                    f"{self.__name__}() must return BlockArray(s); got "
                    f"{type(o).__name__}")
        g.output(*[o._h for o in outs])
        return g, [o._h for o in outs], single

    # ------------------------------------------------------------------
    def compile(self, backend: str = "graph", *, max_sparse="auto",
                use_pallas="auto", interpret: Optional[bool] = None,
                pallas_tile: int = 8, dirty: str = "mask",
                donate: bool = True, block_skip="auto", plan: bool = True,
                mesh=None, shards: Optional[int] = None,
                plan_cache: int = 64, trace: Optional[str] = None,
                trace_flight: int = 64, **input_specs):
        """Trace and lower.  ``input_specs`` give every input's leading
        size (int, shape tuple, or example array); remaining kwargs are
        backend options (see ``GraphBuilder.compile``).  ``backend``
        picks the substrate: ``"graph"`` (jitted runtime), ``"host"``
        (paper-faithful engine), or ``"hybrid"`` — every maximal
        ``sac.static_region`` run compiled as its own ``CompiledGraph``
        fragment with host-orchestrated boundary dirty transfer
        (repro.sac.hybrid).  Remaining options: ``donate``
        donates the propagation state to the jitted update (in-place
        scatters, no per-update copy of untouched node values — reads
        from a superseded state become invalid), ``block_skip`` routes
        escan/carry-causal recomputes through the cached-carry block-skip
        path (``"auto"`` = exact dtypes only).

        ``shards=N`` (or an explicit one-axis ``mesh=``) shards the
        block axis of the compiled program over N devices: per-shard
        dirty masks and recomputes, collectives only at level barriers,
        outputs and stats bitwise identical to single-device (graph and
        hybrid backends; see DESIGN.md §Sharded-propagation).  On a
        CPU-only host expose devices with
        ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
        ``plan_cache`` bounds the dirty-signature LRU of frozen
        propagation plans (``stats["plan_cache"]`` reports
        hits/misses/evictions).

        ``trace="counters"`` attaches a ``PropagationRecorder`` (one
        ``PropagationRecord`` per update in a bounded flight ring of
        ``trace_flight``; zero extra host syncs on the planned path) and
        ``trace="deep"`` additionally fences per-level executions for
        real per-level wall-clock; ``handle.record`` / ``.records()`` /
        ``.profile()`` read them back (repro.obs)."""
        if shards is not None:
            assert mesh is None, "pass shards= or mesh=, not both"
            from repro.shardlib import block_mesh

            mesh = block_mesh(shards)
        if trace is not None:
            assert trace in MODES, (
                f"trace={trace!r} (expected one of {MODES} or None)")
        g, outs, single = self.trace(**input_specs)
        if backend == "graph":
            cg = g.compile(max_sparse=max_sparse, use_pallas=use_pallas,
                           interpret=interpret, pallas_tile=pallas_tile,
                           dirty=dirty, donate=donate, block_skip=block_skip,
                           plan=plan, mesh=mesh, plan_cache=plan_cache)
            handle = GraphHandle(cg, outs, single)
        elif backend == "host":
            assert mesh is None, (
                "backend='host' runs on the host engine; sharding applies "
                "to the graph and hybrid backends")
            from .host import HostHandle

            handle = HostHandle(g, outs, single)
        elif backend == "hybrid":
            from .hybrid import HybridHandle

            handle = HybridHandle(g, outs, single, max_sparse=max_sparse,
                                  use_pallas=use_pallas, interpret=interpret,
                                  pallas_tile=pallas_tile, dirty=dirty,
                                  donate=donate, block_skip=block_skip,
                                  plan=plan, mesh=mesh, plan_cache=plan_cache)
        else:
            raise ValueError(f"unknown backend {backend!r} "
                             "(expected 'graph', 'host', or 'hybrid')")
        if trace is not None:
            handle._attach_recorder(
                PropagationRecorder(mode=trace, flight=trace_flight))
        return handle


class GraphHandle(TraceMethods):
    """Compiled program on the jitted graph runtime (stateful facade)."""

    backend = "graph"

    def __init__(self, cg, outs: List[Handle], single: bool):
        self.cg = cg                     # underlying CompiledGraph
        self.out_handles = outs
        self._single = single
        self._state = None               # raw dict or serve.ForestState
        self._stats: Dict[str, Any] = {}
        self._undo: List[Any] = []       # snapshot() stack (forest nodes)

    def _attach_recorder(self, rec) -> None:
        super()._attach_recorder(rec)
        self.cg.attach_recorder(rec)

    # ------------------------------------------------------------------
    def run(self, inputs: Optional[Dict[str, Any]] = None, **kw):
        """Initial run: forward every node, memoize every block."""
        self._release_states()
        self._state = self.cg.init({**(inputs or {}), **kw})
        self._stats = {"phase": "run",
                       "recomputed": self.cg.total_blocks,
                       "affected": self.cg.total_blocks}
        return self.outputs()

    def update(self, inputs: Optional[Dict[str, Any]] = None, **changed):
        """Change propagation; omitted inputs are taken unchanged."""
        if self._state is None:
            raise RuntimeError("update() before run()")
        ins = {**(inputs or {}), **changed}
        if isinstance(self._state, dict):
            self._state, st = self.cg.propagate(self._state, ins)
        else:                            # forest node: COW propagate
            st = self._state.propagate(ins)
        # Keep the device-resident scalars: converting here would block
        # on the async propagate even when stats are never read.
        self._stats = {"phase": "update", **st}
        return self.outputs()

    # ------------------------------------------------------------------
    # COW forest: forking, speculative edit / undo, serving
    # ------------------------------------------------------------------
    def _forest(self):
        """Promote this handle's state into the COW forest (first fork /
        snapshot pays one O(#nodes) host-side wrap; no device copies)."""
        from repro.serve.forest import ForestState

        if self._state is None:
            raise RuntimeError("state operation before run()")
        if isinstance(self._state, dict):
            self._state = ForestState.adopt(self.cg, self._state)
        return self._state

    def fork(self):
        """A new independent handle branching this one's current state.

        The child's per-node buffers alias this handle's until either
        side first writes them (copy-on-first-scatter in the planned
        propagate), so forking a warm base is host metadata only —
        no ``donate=False`` full copy.  Both handles keep full
        ``update``/``fork``/``undo`` capability."""
        base = self._forest()
        child = GraphHandle(self.cg, self.out_handles, self._single)
        child._state = base.fork()
        child._stats = dict(self._stats)
        # Share the recorder python-side only; the cg-level attachment
        # is already in place (same CompiledGraph).
        child._recorder = self._recorder
        return child

    def snapshot(self) -> None:
        """Mark the current state restorable by ``undo()`` (speculative
        edit): keeps the current forest node and continues on a fork."""
        base = self._forest()
        self._undo.append(base)
        self._state = base.fork()

    def undo(self) -> None:
        """Discard every update since the last ``snapshot()`` — a fork
        discard: the speculative node releases its buffer claims and the
        snapshot becomes current again."""
        if not self._undo:
            raise RuntimeError("undo() without snapshot()")
        self._state.release()
        self._state = self._undo.pop()

    def commit(self) -> None:
        """Accept the updates since the last ``snapshot()``: drops the
        saved node (its exclusively-held buffers free)."""
        if not self._undo:
            raise RuntimeError("commit() without snapshot()")
        self._undo.pop().release()

    def serve(self, **opts):
        """A ``repro.serve.SessionServer`` over this handle's warm
        state: many concurrent sessions fork the base, edits stream
        through an asyncio admission queue with cross-session batching
        of compatible dirty signatures (see repro/serve)."""
        from repro.serve import SessionServer

        return SessionServer(self, **opts)

    def close(self) -> None:
        """Release forest claims held by this handle (no-op for a plain
        linear-state handle)."""
        self._release_states()

    def _release_states(self) -> None:
        if self._state is not None and not isinstance(self._state, dict):
            self._state.release()
        for st in self._undo:
            st.release()
        self._undo = []
        self._state = None

    # ------------------------------------------------------------------
    @property
    def stats(self) -> Dict[str, Any]:
        """Counters of the last phase (graph backend: ``recomputed`` =
        realized computation distance in blocks, ``affected`` =
        value-changed blocks post-cutoff; under ``shards=`` also
        ``recomputed_per_shard``, each shard's local masked work, and
        ``plan_cache`` hit/miss/eviction counters).  Reading this
        property syncs with the device (the counters materialize as
        Python ints)."""
        def conv(v):
            if hasattr(v, "dtype"):
                import numpy as _np

                a = _np.asarray(v)
                return int(a) if a.ndim == 0 else a.tolist()
            return v

        return {k: conv(v) for k, v in self._stats.items()}

    def value(self, out: Union[BlockArray, Handle]) -> jax.Array:
        h = out._h if isinstance(out, BlockArray) else out
        return self.cg.value(self._state, h)

    def outputs(self):
        vals = tuple(self.cg.value(self._state, h) for h in self.out_handles)
        return vals[0] if self._single else vals
