"""repro.sac — one tracing frontend for incremental array programs.

Write the ordinary program once; the system derives the incremental
version (the language-level framing of self-adjusting computation:
Acar et al.'s consistent semantics, Hammer et al.'s stack machines).
A function decorated with ``@sac.incremental`` is traced over
operator-overloaded ``BlockArray`` tracers into a static SP-dag, then
lowered onto either execution substrate:

  * ``backend="graph"`` — the jit-compiled TPU runtime
    (``repro.jaxsac``): level-scheduled dirty-mask propagation, sparse/
    dense recompute regimes, Pallas dirty-tile routing;
  * ``backend="host"``  — the paper-faithful host engine
    (``repro.core``): RSP tree, reader sets, exact work/span accounting.

Same trace, bitwise-identical outputs, one ``run/update/stats`` facade::

    import repro.sac as sac

    @sac.incremental(block=64)
    def hashed(text):
        pairs = sac.map_blocks(block_hash, text, out_block=1)
        return sac.reduce(combine, pairs, identity=0)

    h = hashed.compile(text=65536)        # backend="graph" by default
    h.run(text=codes)
    h.update(text=edited_codes)           # change propagation
    h.stats["recomputed"]                 # realized computation distance

The structured combinators (``reduce``, ``stencil``, ``scan``,
``causal``) and S/P context managers (``seq``, ``par``) live alongside
plain operators and intercepted numpy ufuncs (``np.tanh(x)`` lowers to
``jnp.tanh`` per block).  ``GraphBuilder`` — the imperative,
method-per-op builder this frontend replaces — remains available as a
deprecated shim (it is the IR the tracer records into).

Handles are also *forkable* and *servable* (repro.serve)::

    child = h.fork()          # COW branch: buffers alias until written
    h.snapshot(); h.update(...); h.undo()     # speculative edit
    server = h.serve()        # async multi-tenant session server
    sid = await server.open(); await server.submit(sid, text=edited)

``fork()`` on the graph backend is host metadata only — the COW state
forest copies a node's buffers on first write, so many sessions branch
one warm base without full state copies (``repro.serve.forest``).
"""
from .program import GraphHandle, IncrementalProgram, incremental
from .host import EngineFragment, HostHandle
from .hybrid import HybridHandle
from .tracer import (BlockArray, causal, elementwise, gather, map_blocks,
                     par, reduce, scan, seq, static_region, stencil,
                     zip_blocks)

__all__ = [
    "incremental",
    "IncrementalProgram",
    "GraphHandle",
    "HostHandle",
    "HybridHandle",
    "EngineFragment",
    "BlockArray",
    "map_blocks",
    "zip_blocks",
    "elementwise",
    "reduce",
    "stencil",
    "scan",
    "causal",
    "gather",
    "seq",
    "par",
    "static_region",
]
