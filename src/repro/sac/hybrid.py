"""Hybrid skeleton/interior runtime: compiled fragments, host boundary.

``compile(backend="hybrid")`` lowers a traced program onto *both*
substrates at once: every maximal statically-shaped region of the SP-dag
becomes its own jit-compiled ``CompiledGraph`` fragment (the interior),
while the cross-region structure — which fragment feeds which, and
whether anything a fragment produced actually changed — stays on the
host (the skeleton).  Dirty sets cross the boundary in both directions:

  * **host -> fragment**: an update hands each fragment only the inputs
    that changed (graph inputs named in the update, boundary arrays
    whose producing fragment reported changed lanes); the fragment's own
    mark phase re-diffs them into exact per-block masks — the
    Algorithm-2 value cutoff at the boundary comes for free.
  * **fragment -> host**: ``propagate`` reports per-output changed-lane
    masks (``stats["out_changed"]``); a downstream fragment whose every
    upstream mask is empty is *skipped entirely* — the skeleton analogue
    of an unaffected reader.  Because lanes outside a fragment's dirty
    set are never recomputed, the boundary re-diff recovers exactly the
    post-cutoff changed set the monolithic graph backend would have
    pushed, so ``recomputed`` / ``affected`` / outputs are identical
    across graph, host, and hybrid backends (fuzz-tested).

Regions come from ``sac.static_region`` tags: a region is a maximal run
of same-tag nodes (untagged programs form one region per tag-change
layer — one fragment in the common case, so hybrid degrades to the
graph backend plus a thin shell).  Cross-region edges always point from
an earlier tag-change layer to a later one, so regions execute in a
fixed topological order.

The engine-embedded sibling — a fragment as a *reader* inside a dynamic
host-engine program, for apps whose skeleton is genuinely
data-dependent (tree contraction, BST filter) — is
``repro.sac.host.EngineFragment``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.jaxsac.graph import GraphBuilder, Handle
from repro.obs.record import PhaseSpan, merge_records
from repro.obs.recorder import PropagationRecorder, TraceMethods
from .tracer import BlockArray

__all__ = ["HybridHandle", "partition_regions", "Region"]


@dataclasses.dataclass
class Region:
    """One statically-shaped region of the dag: a CompiledGraph fragment
    plus its boundary (external inputs read, nodes exported)."""

    key: Tuple[Optional[str], int]      # (tag, tag-change layer)
    nodes: List[int]                    # member op nodes (topo order)
    ext_inputs: List[Tuple[int, str]] = dataclasses.field(
        default_factory=list)           # (source node idx, input name)
    out_nodes: List[int] = dataclasses.field(default_factory=list)
    local: Dict[int, int] = dataclasses.field(default_factory=dict)
    cg: Any = None                      # CompiledGraph


def partition_regions(nodes) -> List[Region]:
    """Group op nodes into maximal same-tag regions.

    A node's *layer* counts the tag changes along its longest path from
    an input (over data and control edges); a region is one (tag, layer)
    class.  Any cross-region edge strictly increases the layer (same-tag
    edges keep it, cross-tag edges bump it), so sorting regions by layer
    yields a topological order of the region dag — the fixed schedule
    the hybrid skeleton walks.
    """
    layer: Dict[int, int] = {}
    for nd in nodes:
        if nd.kind == "input":
            layer[nd.idx] = 0
            continue
        r = 0
        for p in tuple(nd.deps) + tuple(nd.control):
            pn = nodes[p]
            if pn.kind == "input":
                continue
            r = max(r, layer[p] + (0 if pn.region == nd.region else 1))
        layer[nd.idx] = r
    groups: Dict[Tuple[Optional[str], int], List[int]] = {}
    for nd in nodes:
        if nd.kind != "input":
            groups.setdefault((nd.region, layer[nd.idx]),
                              []).append(nd.idx)
    return [Region(key=k, nodes=v) for k, v in
            sorted(groups.items(), key=lambda kv: (kv[0][1], kv[1][0]))]


class HybridHandle(TraceMethods):
    """Compiled program on the hybrid runtime (same facade as
    GraphHandle / HostHandle)."""

    backend = "hybrid"

    def __init__(self, builder: GraphBuilder, outs: List[Handle],
                 single: bool, **compile_opts):
        self.nodes = list(builder.nodes)
        self.input_names: Dict[str, int] = dict(builder.inputs)
        assert self.input_names, "graph has no inputs"
        self.out_handles = outs
        self._single = single
        self._opts = compile_opts

        prog_outputs = [h.idx for h in outs]
        self.regions = partition_regions(self.nodes)
        owner: Dict[int, int] = {}
        for pos, reg in enumerate(self.regions):
            for i in reg.nodes:
                owner[i] = pos
        self._owner = owner
        # Nodes that must cross a boundary: read by another region, or
        # program outputs (the facade reads them).
        exported = {i for i in prog_outputs
                    if self.nodes[i].kind != "input"}
        for nd in self.nodes:
            for d in nd.deps:
                if (self.nodes[d].kind != "input"
                        and owner[d] != owner.get(nd.idx, owner[d])):
                    exported.add(d)
        for reg in self.regions:
            self._build_fragment(reg, exported)

        self.total_blocks = sum(r.cg.total_blocks for r in self.regions)
        self.num_fragments = len(self.regions)
        self._states: List[Any] = []
        self._inp: Dict[str, jax.Array] = {}
        self._bvals: Dict[int, jax.Array] = {}
        self._stats: Dict[str, Any] = {}
        self._child_rec: Optional[PropagationRecorder] = None

    def _attach_recorder(self, rec) -> None:
        """The hybrid handle records through ONE shared child recorder
        attached to every fragment's CompiledGraph; each update drains
        the per-fragment records and merges them into a single parent
        record (the consumer sees one record per update, fragments as
        drill-down children)."""
        super()._attach_recorder(rec)
        if rec is None:
            self._child_rec = None
            for reg in self.regions:
                reg.cg.attach_recorder(None)
            return
        self._child_rec = PropagationRecorder(mode=rec.mode, flight=0)
        for reg in self.regions:
            reg.cg.attach_recorder(self._child_rec)

    def _plan_cache_merged(self) -> Dict[str, Any]:
        """The fragments' plan caches as one stats entry: cumulative
        hit/miss/eviction counters summed, size/cap reported per
        fragment (summing capacities would suggest one shared LRU)."""
        snaps = [reg.cg.plan_cache_snapshot() for reg in self.regions]
        return {"hits": sum(s["hits"] for s in snaps),
                "misses": sum(s["misses"] for s in snaps),
                "evictions": sum(s["evictions"] for s in snaps),
                "size": [s["size"] for s in snaps],
                "cap": [s["cap"] for s in snaps]}

    # ------------------------------------------------------------------
    def _build_fragment(self, reg: Region, exported) -> None:
        sub = GraphBuilder()
        region_set = set(reg.nodes)
        for i in reg.nodes:
            nd = self.nodes[i]
            for d in nd.deps:
                if d in reg.local:
                    continue
                dn = self.nodes[d]
                name = dn.name if dn.kind == "input" else f"__b{d}"
                h = sub.input(name, n=dn.n, block=dn.block)
                reg.local[d] = h.idx
                reg.ext_inputs.append((d, name))
            # Intra-region control edges survive; cross-region ordering
            # is the skeleton's fixed region schedule.
            control = tuple(reg.local[c] for c in nd.control
                            if c in region_set)
            clone = dataclasses.replace(
                nd, idx=len(sub.nodes),
                deps=tuple(reg.local[d] for d in nd.deps),
                control=control)
            sub.nodes.append(clone)
            reg.local[i] = clone.idx
        reg.out_nodes = [i for i in reg.nodes if i in exported]
        sub.output(*[Handle(sub, reg.local[i]) for i in reg.out_nodes])
        reg.cg = sub.compile(**self._opts)

    # ------------------------------------------------------------------
    def run(self, inputs: Optional[Dict[str, Any]] = None, **kw):
        inputs = {**(inputs or {}), **kw}
        assert set(inputs) == set(self.input_names), (
            f"inputs {sorted(inputs)} != declared "
            f"{sorted(self.input_names)}")
        self._inp = {k: jnp.asarray(v) for k, v in inputs.items()}
        self._release_states()
        self._bvals = {}
        for reg in self.regions:
            ins = {name: self._fresh(d) for d, name in reg.ext_inputs}
            st = reg.cg.init(ins)
            self._states.append(st)
            for i in reg.out_nodes:
                self._bvals[i] = jnp.array(st["v"][reg.local[i]])
        self._stats = {"phase": "run", "recomputed": self.total_blocks,
                       "affected": self.total_blocks,
                       "fragments_run": len(self.regions)}
        return self.outputs()

    def _fresh(self, d: int) -> jax.Array:
        """A private copy of an external input's current value.  Every
        hand-off is copied because the receiving fragment stores the
        array in its (donated) state: sharing one buffer across
        fragments would let one fragment's donation invalidate
        another's memoized input."""
        nd = self.nodes[d]
        src = self._inp[nd.name] if nd.kind == "input" else self._bvals[d]
        return jnp.array(src)

    # ------------------------------------------------------------------
    def update(self, inputs: Optional[Dict[str, Any]] = None, **changed):
        if not self._states:
            raise RuntimeError("update() before run()")
        changed = {**(inputs or {}), **changed}
        unknown = set(changed) - set(self.input_names)
        assert not unknown, f"unknown inputs {sorted(unknown)}"
        parent = self._recorder
        t_start = parent.clock() if parent is not None else 0.0
        if self._child_rec is not None:
            self._child_rec.mode = parent.mode   # profile() may flip it
            self._child_rec.clear()
        new_inp = dict(self._inp)
        for k, v in changed.items():
            new_inp[k] = jnp.asarray(v)
        old_inp, self._inp = self._inp, new_inp

        changed_nodes: set = set()
        rec = aff = 0
        in_dirty: Dict[str, int] = {}
        frags_run = 0
        for pos, reg in enumerate(self.regions):
            ins = {}
            for d, name in reg.ext_inputs:
                nd = self.nodes[d]
                if nd.kind == "input":
                    if nd.name in changed:
                        ins[name] = self._fresh(d)
                elif d in changed_nodes:
                    ins[name] = self._fresh(d)
            if not ins:
                continue        # skeleton skip: no upstream change
            frags_run += 1
            st = self._states[pos]
            if isinstance(st, dict):
                st, stats = reg.cg.propagate(st, ins)
                self._states[pos] = st
            else:               # forest node (after fork): COW propagate
                stats = st.propagate(ins)
            rec += int(stats["recomputed"])
            aff += int(stats["affected"])
            for d, name in reg.ext_inputs:
                nd = self.nodes[d]
                if nd.kind == "input" and nd.name in changed:
                    in_dirty[nd.name] = int(stats["in_dirty"][name])
            for i in reg.out_nodes:
                mask = np.asarray(stats["out_changed"][str(reg.local[i])])
                if mask.any():
                    changed_nodes.add(i)
                    self._bvals[i] = jnp.array(st["v"][reg.local[i]])
        # Inputs no fragment reads still count toward dirty_inputs
        # (parity with the monolithic backends, which diff every input).
        for name in changed:
            if name not in in_dirty:
                in_dirty[name] = self._count_diff(name, old_inp[name],
                                                  self._inp[name])
        self._stats = {
            "phase": "update", "recomputed": rec, "affected": aff,
            "dirty_inputs": sum(in_dirty.values()),
            "fragments_run": frags_run,
            "plan_cache": self._plan_cache_merged(),
        }
        if parent is not None:
            children = (self._child_rec.drain()
                        if self._child_rec is not None else [])
            t_end = parent.clock()
            merged = merge_records(
                children, substrate="hybrid", seq=parent.next_seq(),
                mode=parent.mode, t_start=t_start,
                phases=[PhaseSpan("execute", t_start, t_end - t_start)],
                plan_cache=self._stats["plan_cache"])
            # The merged child counters sum per-fragment dirty_inputs,
            # which also counts boundary (inter-fragment) inputs; the
            # program-level number is the real-input one.
            merged.counters["dirty_inputs"] = self._stats["dirty_inputs"]
            merged.counters["fragments_run"] = frags_run
            parent.emit(merged)
        return self.outputs()

    # ------------------------------------------------------------------
    # COW forest
    # ------------------------------------------------------------------
    def fork(self):
        """A new independent hybrid handle branching this one's state:
        every fragment's propagation state becomes a COW forest node and
        the child forks each (buffers alias until first write).  The
        skeleton metadata (boundary values, current inputs) is
        host-side and copied by reference-swap dicts."""
        from repro.serve.forest import ForestState

        if not self._states:
            raise RuntimeError("fork() before run()")
        for pos, st in enumerate(self._states):
            if isinstance(st, dict):
                self._states[pos] = ForestState.adopt(
                    self.regions[pos].cg, st)
        child = object.__new__(HybridHandle)
        child.__dict__.update(self.__dict__)   # shares fragments/recorder
        child._states = [st.fork() for st in self._states]
        child._inp = dict(self._inp)
        child._bvals = dict(self._bvals)       # values replaced, never
        child._stats = dict(self._stats)       # mutated -> safe to alias
        return child

    def close(self) -> None:
        """Release forest claims held by this handle's fragments."""
        self._release_states()

    def _release_states(self) -> None:
        for st in self._states:
            if not isinstance(st, dict):
                st.release()
        self._states = []

    def _count_diff(self, name: str, old, new) -> int:
        nd = self.nodes[self.input_names[name]]
        o = np.asarray(old).reshape((nd.num_blocks, -1))
        n = np.asarray(new).reshape((nd.num_blocks, -1))
        return int(np.any(o != n, axis=1).sum())

    # ------------------------------------------------------------------
    @property
    def stats(self) -> Dict[str, Any]:
        """Counters of the last phase; ``recomputed`` / ``affected`` /
        ``dirty_inputs`` match the graph backend exactly.
        ``fragments_run`` counts fragments the skeleton did not skip."""
        return dict(self._stats)

    def value(self, out: Union[BlockArray, Handle]) -> jax.Array:
        h = out._h if isinstance(out, BlockArray) else out
        return self._node_value(h.idx)

    def outputs(self):
        vals = tuple(self._node_value(h.idx) for h in self.out_handles)
        return vals[0] if self._single else vals

    def _node_value(self, idx: int) -> jax.Array:
        nd = self.nodes[idx]
        if nd.kind == "input":
            return self._inp[nd.name]
        reg = self.regions[self._owner[idx]]
        return self._states[self._owner[idx]]["v"][reg.local[idx]]
