"""BlockArray tracers: ordinary Python expressions -> static SP-dag.

The ``repro.sac`` frontend is jax-style: the user writes a plain Python
function over arrays; calling it with ``BlockArray`` tracers records a
static SP-dag of block-granular ops (the IR of ``repro.jaxsac.graph``),
which then lowers to either the jit-compiled graph runtime or the
paper-faithful host engine (see program.py / host.py).

A ``BlockArray`` stands for a block-modifiable tensor.  Tracing happens
through:

  * **operators** — ``+ - * / ** abs neg`` between tracers and/or
    scalars/arrays lower to ``map``/``zip_map`` nodes whose per-block
    kernels are the matching jnp ops;
  * **ufunc interception** — applying a numpy ufunc to a tracer
    (``np.tanh(x)``, ``np.maximum(x, y)``) is intercepted via
    ``__array_ufunc__`` and lowered to the *jnp* ufunc of the same name
    applied per block (so the compiled program runs the XLA kernel, not
    numpy).  jnp functions themselves eagerly coerce their arguments and
    cannot see the tracer — calling one raises a pointed error naming
    the spellings that do trace (``np.tanh(x)``, ``sac.elementwise``);
  * **named combinators** — ``sac.reduce`` / ``sac.stencil`` /
    ``sac.scan`` / ``sac.causal`` / ``sac.map_blocks`` /
    ``sac.zip_blocks`` for the structured ops;
  * **S/P composition** — ``with sac.seq():`` / ``with sac.par():``
    context managers mirroring the host engine's S and P nodes.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional

import jax.numpy as jnp

from repro.jaxsac.graph import GraphBuilder, Handle

__all__ = [
    "BlockArray", "map_blocks", "zip_blocks", "elementwise",
    "reduce", "stencil", "scan", "causal", "gather", "seq", "par",
    "static_region",
]

# Ambient trace stack: pushed by IncrementalProgram.compile while the
# user function runs; consulted by seq()/par() which take no tracer.
_TRACES: List[GraphBuilder] = []


def _current_builder() -> GraphBuilder:
    if not _TRACES:
        raise RuntimeError(
            "sac.seq()/sac.par() used outside an @sac.incremental trace")
    return _TRACES[-1]


class BlockArray:
    """Tracer for one block-modifiable tensor (wraps a dag Handle)."""

    __slots__ = ("_h",)

    def __init__(self, handle: Handle):
        self._h = handle

    # ------------------------------------------------------------------
    @property
    def num_blocks(self) -> int:
        return self._h.num_blocks

    @property
    def block(self) -> int:
        return self._h.block

    @property
    def n(self) -> int:
        return self._h.node.n

    @property
    def _g(self) -> GraphBuilder:
        return self._h.builder

    def __repr__(self) -> str:
        nd = self._h.node
        return (f"BlockArray(<{nd.kind} '{nd.name}' "
                f"{nd.num_blocks}x{nd.block}>)")

    # ------------------------------------------------------------------
    # Elementwise lowering
    # ------------------------------------------------------------------
    def _map(self, f: Callable, name: str) -> "BlockArray":
        return BlockArray(self._g.map(f, self._h, name=name))

    def _binop(self, other: Any, f: Callable, name: str,
               reverse: bool = False) -> "BlockArray":
        if isinstance(other, BlockArray):
            a, b = (other, self) if reverse else (self, other)
            return BlockArray(a._g.zip_map(f, a._h, b._h, name=name))
        # Constant operand: bake it into a map kernel.  Scalars and
        # block-broadcastable arrays both work (jnp broadcasting).
        if reverse:
            return self._map(lambda blk, _c=other, _f=f: _f(_c, blk), name)
        return self._map(lambda blk, _c=other, _f=f: _f(blk, _c), name)

    def __add__(self, o):
        return self._binop(o, jnp.add, "add")

    def __radd__(self, o):
        return self._binop(o, jnp.add, "add", reverse=True)

    def __sub__(self, o):
        return self._binop(o, jnp.subtract, "sub")

    def __rsub__(self, o):
        return self._binop(o, jnp.subtract, "sub", reverse=True)

    def __mul__(self, o):
        return self._binop(o, jnp.multiply, "mul")

    def __rmul__(self, o):
        return self._binop(o, jnp.multiply, "mul", reverse=True)

    def __truediv__(self, o):
        return self._binop(o, jnp.divide, "div")

    def __rtruediv__(self, o):
        return self._binop(o, jnp.divide, "div", reverse=True)

    def __pow__(self, o):
        return self._binop(o, jnp.power, "pow")

    def __rpow__(self, o):
        return self._binop(o, jnp.power, "pow", reverse=True)

    def __neg__(self):
        return self._map(jnp.negative, "neg")

    def __abs__(self):
        return self._map(jnp.abs, "abs")

    # ------------------------------------------------------------------
    # numpy-ufunc interception: np.tanh(x) etc. lower to the jnp ufunc
    # of the same name applied per block.
    # ------------------------------------------------------------------
    __array_priority__ = 5000            # win over ndarray operands

    def __array_ufunc__(self, ufunc, method, *inputs, **kwargs):
        if method != "__call__" or kwargs:
            return NotImplemented
        jfn = getattr(jnp, ufunc.__name__, None)
        if jfn is None:
            return NotImplemented
        return _lower_elementwise(jfn, inputs, name=ufunc.__name__)

    def __jax_array__(self):
        raise TypeError(
            "a sac.BlockArray tracer cannot be materialized as a jax "
            "array: jnp functions coerce their arguments eagerly.  Use "
            "the numpy spelling (np.tanh(x) is intercepted and lowered "
            "to jnp.tanh per block), an operator, or "
            "sac.elementwise(jnp.tanh)(x).")

    # ------------------------------------------------------------------
    # Conveniences
    # ------------------------------------------------------------------
    def astype(self, dtype) -> "BlockArray":
        return self._map(lambda b, _d=dtype: b.astype(_d), "astype")

    def sum(self, identity: Any = 0.0) -> "BlockArray":
        return reduce(jnp.add, self, identity=identity, name="sum")

    def max(self, identity: Any = -jnp.inf) -> "BlockArray":
        return reduce(jnp.maximum, self, identity=identity, name="max")

    def min(self, identity: Any = jnp.inf) -> "BlockArray":
        return reduce(jnp.minimum, self, identity=identity, name="min")


def _lower_elementwise(jfn: Callable, operands, name: str) -> BlockArray:
    tracers = [(i, o) for i, o in enumerate(operands)
               if isinstance(o, BlockArray)]
    if len(tracers) == 1:
        (pos, x), = tracers
        consts = list(operands)

        def kernel(blk, _f=jfn, _consts=consts, _pos=pos):
            args = list(_consts)
            args[_pos] = blk
            return _f(*args)

        return x._map(kernel, name)
    if len(tracers) == 2:
        (pa, xa), (pb, xb) = tracers
        consts = list(operands)

        def kernel2(ba, bb, _f=jfn, _consts=consts, _pa=pa, _pb=pb):
            args = list(_consts)
            args[_pa], args[_pb] = ba, bb
            return _f(*args)

        return BlockArray(xa._g.zip_map(kernel2, xa._h, xb._h, name=name))
    raise TypeError(
        f"cannot lower {name}: at most two BlockArray operands supported")


def elementwise(fn: Callable, name: str = "") -> Callable:
    """Lift an arbitrary (jnp) elementwise function to tracers:
    ``sac.elementwise(jnp.tanh)(x)``."""

    def lowered(*operands):
        return _lower_elementwise(fn, operands,
                                  name or getattr(fn, "__name__", "elem"))

    return lowered


# ---------------------------------------------------------------------------
# Structured combinators
# ---------------------------------------------------------------------------
def map_blocks(f: Callable, x: BlockArray, out_block: Optional[int] = None,
               name: str = "") -> BlockArray:
    """Apply ``f`` to each block ``[block, *feat]`` independently."""
    return BlockArray(x._g.map(f, x._h, out_block=out_block, name=name))


def zip_blocks(f: Callable, x: BlockArray, y: BlockArray,
               out_block: Optional[int] = None, name: str = "") -> BlockArray:
    """Apply ``f`` to aligned block pairs of two tracers."""
    return BlockArray(x._g.zip_map(f, x._h, y._h, out_block=out_block,
                                   name=name))


def reduce(op: Callable, x: BlockArray, identity: Any = 0.0,
           name: str = "") -> BlockArray:
    """Balanced-tree reduction of an associative ``op`` (Algorithm 1);
    any block count (odd levels pad with ``identity``)."""
    return BlockArray(x._g.reduce_tree(op, x._h, identity=identity,
                                       name=name))


def stencil(f: Callable, x: BlockArray, radius: int = 1, fill: Any = None,
            name: str = "") -> BlockArray:
    """Sliding-window op: out block i reads blocks i-r .. i+r."""
    return BlockArray(x._g.stencil(f, x._h, radius=radius, fill=fill,
                                   name=name))


def scan(op: Callable, x: BlockArray, identity: Any = 0.0,
         name: str = "") -> BlockArray:
    """Inclusive prefix scan of an associative ``op``."""
    return BlockArray(x._g.scan(op, x._h, identity=identity, name=name))


def causal(f: Optional[Callable], x: BlockArray,
           out_block: Optional[int] = None, name: str = "", *,
           lift: Optional[Callable] = None, op: Optional[Callable] = None,
           finalize: Optional[Callable] = None,
           identity: Any = 0.0) -> BlockArray:
    """Causal op (the interval-carrying edge): out block i reads blocks
    0..i; ``f(x_full, i)`` must restrict itself to rows < (i+1)*block.

    Carry form: pass ``lift``/``op``/``finalize`` (and ``op``'s
    ``identity``) to declare the prefix dependence as a monoid —
    ``out_i = finalize(fold(op, lift(b_0)..lift(b_i)), b_i)``.  The
    runtime caches the per-block carry states so a dirty suffix reseeds
    from the cached prefix instead of rescanning it (the flash-style
    block-skip; see ``GraphBuilder.causal``)."""
    return BlockArray(x._g.causal(f, x._h, out_block=out_block, name=name,
                                  lift=lift, op=op, finalize=finalize,
                                  identity=identity))


def gather(f: Optional[Callable], idx_fn: Callable, x: BlockArray,
           arity: int = 1, out_block: Optional[int] = None,
           name: str = "", packed: Optional[Callable] = None) -> BlockArray:
    """Data-dependent reader sets with statically-bounded arity: out
    block i reads block i plus up to ``arity`` neighbour blocks chosen
    by ``idx_fn`` from block i's own contents (tree parent/child
    pointers, linked-list successors).  ``f(x_full, i)`` computes the
    block from the full parent but must restrict its value dependence to
    the declared reader set — see ``GraphBuilder.gather`` for the exact
    contract.  This is the edge kind the hybrid apps (tree contraction,
    BST filter) lower their per-round phases onto.

    The **packed form** — ``packed(own, nbrs)`` with ``f=None`` —
    receives the lane's own block plus exactly its ``arity`` neighbour
    blocks in ``idx_fn`` row order; the sparse recompute then gathers
    only the ``k * (1 + arity)`` blocks the dirty lanes read instead of
    assembling a full-parent view per lane (same recomputed counts;
    ``idx_fn`` must be row-wise position-independent)."""
    return BlockArray(x._g.gather(f, idx_fn, x._h, arity=arity,
                                  out_block=out_block, name=name,
                                  packed=packed))


# ---------------------------------------------------------------------------
# S/P composition
# ---------------------------------------------------------------------------
def static_region(tag: str):
    """Hybrid-runtime region annotation: ``with sac.static_region("a"):``
    tags every op traced inside as one statically-shaped region.  The
    graph and host backends ignore tags; ``compile(backend="hybrid")``
    compiles each maximal same-tag run as one jitted ``CompiledGraph``
    fragment and carries dirty sets across the region boundary on the
    host (see repro.sac.hybrid)."""
    return _current_builder().static_region(tag)


def seq(*thunks: Callable[[], Any]):
    """S-composition.  ``with sac.seq(): ...`` orders every op traced in
    the block strictly after the previous one (control edges in the
    level scheduler); ``sac.seq(f, g)`` is the thunk form."""
    g = _current_builder()
    if thunks:
        return g.seq(*thunks)
    return g.seq_region()


def par(*thunks: Callable[[], Any]):
    """P-composition.  ``with sac.par(): ...`` makes the ops traced in
    the block mutually independent (level-sharable), suspending the
    innermost ``seq`` chain; ``sac.par(f, g)`` is the thunk form."""
    g = _current_builder()
    if thunks:
        return g.par(*thunks)
    return g.par_region()
