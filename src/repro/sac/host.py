"""Host-engine backend: the traced dag on the paper-faithful runtime.

``compile(backend="host")`` lowers the same SP-dag that the graph
backend jits onto ``repro.core.engine.Engine`` — dynamic RSP tree,
per-block modifiables, reader sets, mark-walks — so one traced program
yields both the TPU artifact and the paper's exact work/span accounting.

Lowering: every block of every node becomes one ``Mod``.  Per node kind:

  * map / zip_map / stencil — one reader per output block, reading the
    block's static reader set (the window mods for stencil) and writing
    the recomputed block; lowered under ``parallel_for`` so the RSP tree
    records the P-structure (span = max over blocks).
  * reduce_level — one reader per pair; an odd level's last reader
    combines its single child with the op identity (same padding rule as
    the compiled backend).
  * escan — a **Ladner-Fischer reader tree**: the carry pass lowers into
    O(n) two-input combine readers arranged exactly like
    ``jax.lax.associative_scan``'s odd/even recursion (pairwise reduce ->
    recursive scan -> even interleave), so values stay bitwise identical
    to the graph backend while propagation gets the paper's bounds — a
    late edit re-executes O(log n) combines instead of the whole carry
    pass, and the critical path of the tree is O(log n) per recursion
    level instead of the O(n) monolithic reader the backend used to
    lower.  Internal tree mods write with ``counted=False`` so
    'affected' (changed node blocks) stays comparable across backends.
  * causal — out block i reads parent blocks 0..i; rows past the prefix
    are zero-filled before calling ``fn(x, i)`` (the causal contract:
    fn must not look at them).  Carry-causal nodes (a declared monoid)
    lower as lift readers -> a Ladner-Fischer scan tree over the lifted
    states -> per-block finalize readers, matching the graph backend's
    cached-carry structure reader-for-reader.

Block values are stored wrapped (``_Blk``) so the engine's Algorithm-2
write cutoff compares them with numpy array equality (NaN-unequal,
matching the compiled backend's ``!=`` diff semantics).

Levels execute in sequence (S composition); the nodes of one level run
under a binary ``par`` tree (P composition) — exactly the schedule the
compiled backend fuses, so the two backends agree on both values and
changed-block counts.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import Engine
from repro.jaxsac.graph import GNode, GraphBuilder, Handle, level_schedule
from repro.obs.record import LevelRecord, PhaseSpan, PropagationRecord
from repro.obs.recorder import TraceMethods
from .tracer import BlockArray

__all__ = ["HostHandle", "EngineFragment"]


class _LevelCountingEngine:
    """Engine facade that attributes reader (re-)executions to one dag
    level: every reader registered through it increments the shared
    per-level counter when it runs.  This is the host backend's exact
    per-level recompute attribution — pure host Python, always on (the
    engine is synchronous; one list increment per reader execution)."""

    __slots__ = ("eng", "counts", "level")

    def __init__(self, eng, counts: List[int], level: int):
        self.eng = eng
        self.counts = counts
        self.level = level

    def read(self, mods, reader):
        counts, lvl = self.counts, self.level

        def counting(*vals):
            counts[lvl] += 1
            return reader(*vals)

        return self.eng.read(mods, counting)

    def __getattr__(self, name):
        return getattr(self.eng, name)


class _Blk:
    """A block value with bitwise-style equality for the write cutoff."""

    __slots__ = ("a",)

    def __init__(self, a):
        self.a = np.asarray(a)

    def __eq__(self, other):
        return (isinstance(other, _Blk)
                and self.a.dtype == other.a.dtype
                and bool(np.array_equal(self.a, other.a)))

    def __ne__(self, other):
        return not self.__eq__(other)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"_Blk{self.a.shape}"


def _store(nd: GNode, res) -> _Blk:
    """Canonical block layout: [block, *feat] (fns return [*feat] when
    out_block == 1, mirroring graph_ops._pack)."""
    a = np.asarray(res)
    if nd.block == 1:
        a = a[None]
    return _Blk(a)


class HostHandle(TraceMethods):
    """Compiled program on the host engine (same facade as GraphHandle)."""

    backend = "host"

    def __init__(self, builder: GraphBuilder, outs: List[Handle],
                 single: bool):
        self.nodes: List[GNode] = list(builder.nodes)
        self.input_names: Dict[str, int] = dict(builder.inputs)
        assert self.input_names, "graph has no inputs"
        self.out_handles = outs
        self._single = single
        # The one level schedule both backends share (graph.py).
        self.level_of, self.schedule = level_schedule(self.nodes)

        self._eng: Optional[Engine] = None
        self._comp = None
        self._mods: List[List] = []
        self._inputs_np: Dict[str, np.ndarray] = {}
        self._stats: Dict[str, Any] = {}
        # Per-level reader-execution counts (always maintained; a
        # recorder reads update deltas out of them).
        self._reexec: List[int] = [0] * len(self.schedule)

    def _eng_for(self, idx: int) -> _LevelCountingEngine:
        return _LevelCountingEngine(self._eng, self._reexec,
                                    self.level_of[idx])

    # ------------------------------------------------------------------
    # Initial run
    # ------------------------------------------------------------------
    def run(self, inputs: Optional[Dict[str, Any]] = None, **kw):
        inputs = {**(inputs or {}), **kw}
        assert set(inputs) == set(self.input_names), (
            f"inputs {sorted(inputs)} != declared "
            f"{sorted(self.input_names)}")
        self._eng = eng = Engine()
        self._reexec = [0] * len(self.schedule)
        self._mods = [[eng.mod(f"{nd.name}[{i}]")
                       for i in range(nd.num_blocks)] for nd in self.nodes]
        for name, idx in self.input_names.items():
            nd = self.nodes[idx]
            arr = np.asarray(inputs[name])
            assert arr.shape[0] == nd.n, (
                f"input {name!r}: leading size {arr.shape[0]}, "
                f"traced with {nd.n}")
            self._inputs_np[name] = arr.copy()
            for i in range(nd.num_blocks):
                eng.write(self._mods[idx][i],
                          _Blk(arr[i * nd.block:(i + 1) * nd.block].copy()))
        self._comp = eng.run(self._program)
        st = self._comp.initial_stats
        self._stats = {"phase": "run", "work": st.work, "span": st.span,
                       "reads": st.reads,
                       "recomputed": st.reads, "affected": st.writes}
        return self.outputs()

    def _program(self) -> None:
        eng = self._eng
        for lvl in self.schedule:
            ops = [i for i in lvl if self.nodes[i].kind != "input"]
            if ops:                      # one level = one P group
                eng.parallel_for(0, len(ops),
                                 lambda j, _ops=ops: self._lower(_ops[j]))

    # ------------------------------------------------------------------
    # Node lowering (readers)
    # ------------------------------------------------------------------
    def _lower(self, idx: int) -> None:
        nd = self.nodes[idx]
        eng = self._eng_for(idx)
        out = self._mods[idx]
        par0 = self._mods[nd.deps[0]]

        if nd.kind == "map":
            def body(i, _nd=nd, _out=out, _in=par0):
                eng.read(_in[i], lambda v, _i=i: eng.write(
                    _out[_i], _store(_nd, _nd.fn(jnp.asarray(v.a)))))
            eng.parallel_for(0, nd.num_blocks, body)

        elif nd.kind == "zip_map":
            par1 = self._mods[nd.deps[1]]

            def body(i, _nd=nd, _out=out, _x=par0, _y=par1):
                eng.read((_x[i], _y[i]), lambda vx, vy, _i=i: eng.write(
                    _out[_i],
                    _store(_nd, _nd.fn(jnp.asarray(vx.a),
                                       jnp.asarray(vy.a)))))
            eng.parallel_for(0, nd.num_blocks, body)

        elif nd.kind == "reduce_level":
            nb_in = self.nodes[nd.deps[0]].num_blocks

            def body(i, _nd=nd, _out=out, _in=par0, _nb=nb_in):
                li, ri = 2 * i, 2 * i + 1
                if ri < _nb:
                    eng.read((_in[li], _in[ri]),
                             lambda vl, vr, _i=i: eng.write(
                                 _out[_i], _Blk(np.asarray(_nd.op(
                                     jnp.asarray(vl.a[0]),
                                     jnp.asarray(vr.a[0])))[None])))
                else:                    # odd level: identity right child
                    eng.read(_in[li], lambda vl, _i=i: eng.write(
                        _out[_i], _Blk(np.asarray(_nd.op(
                            jnp.asarray(vl.a[0]),
                            jnp.broadcast_to(
                                jnp.asarray(_nd.identity, vl.a.dtype),
                                vl.a[0].shape)))[None])))
            eng.parallel_for(0, nd.num_blocks, body)

        elif nd.kind == "stencil":
            p = self.nodes[nd.deps[0]]

            def body(i, _nd=nd, _out=out, _in=par0, _p=p):
                reads, slots = [], []    # slots: index into reads, or fill
                for off in range(-_nd.radius, _nd.radius + 1):
                    j = i + off
                    oob = j < 0 or j >= _p.num_blocks
                    if oob and _nd.fill is not None:
                        slots.append(None)
                    else:
                        reads.append(_in[min(max(j, 0), _p.num_blocks - 1)])
                        slots.append(len(reads) - 1)

                def reader(*vals, _i=i):
                    ref = vals[0].a      # dtype/shape template
                    parts = [np.full_like(ref, _nd.fill) if s is None
                             else vals[s].a for s in slots]
                    win = jnp.asarray(np.concatenate(parts, axis=0))
                    eng.write(_out[_i], _store(_nd, _nd.fn(win)))

                eng.read(tuple(reads), reader)
            eng.parallel_for(0, nd.num_blocks, body)

        elif nd.kind == "escan":
            inclusive = self._lf_scan_tree(nd, par0)
            # Exclusive outputs: out[0] = identity (its reader only looks
            # at leaf 0 for dtype/shape and always rewrites the identity,
            # so the cutoff kills it); out[j] copies inclusive[j-1].

            def seed_reader(v, _nd=nd, _out=out):
                row = np.broadcast_to(
                    np.asarray(np.asarray(_nd.identity), v.a.dtype),
                    v.a[0].shape)
                eng.write(_out[0], _Blk(row[None]))

            eng.read(par0[0], seed_reader)

            def body(j, _out=out, _inc=inclusive):
                eng.read(_inc[j], lambda v, _j=j: eng.write(
                    _out[_j + 1], _Blk(v.a)))
            eng.parallel_for(0, nd.num_blocks - 1, body)

        elif nd.kind == "causal" and nd.op is not None:
            # Carry-causal: lift each block into its state contribution,
            # scan the contributions through the reader tree, finalize
            # per block from (state, own block).
            lifted = [eng.mod(f"{nd.name}.lift[{i}]")
                      for i in range(nd.num_blocks)]

            def lift_body(i, _nd=nd, _in=par0, _lift=lifted):
                eng.read(_in[i], lambda v, _i=i: eng.write(
                    _lift[_i],
                    _Blk(np.asarray(_nd.lift(jnp.asarray(v.a)))),
                    counted=False))
            eng.parallel_for(0, nd.num_blocks, lift_body)

            states = self._lf_scan_tree(nd, lifted, rows=False)

            def fin_body(i, _nd=nd, _out=out, _in=par0, _st=states):
                def reader(vs, vx, _i=i):
                    res = _nd.finalize(jnp.asarray(vs.a), jnp.asarray(vx.a))
                    eng.write(_out[_i], _store(_nd, res))
                eng.read((_st[i], _in[i]), reader)
            eng.parallel_for(0, nd.num_blocks, fin_body)

        elif nd.kind == "causal":
            p = self.nodes[nd.deps[0]]

            def body(i, _nd=nd, _out=out, _in=par0, _p=p):
                def reader(*vals, _i=i):
                    pre = np.concatenate([v.a for v in vals], axis=0)
                    pad = np.zeros(
                        ((_p.num_blocks - _i - 1) * _p.block,)
                        + pre.shape[1:], pre.dtype)
                    x = jnp.asarray(np.concatenate([pre, pad], axis=0))
                    eng.write(_out[_i], _store(_nd, _nd.fn(x, _i)))

                eng.read(tuple(_in[:i + 1]), reader)
            eng.parallel_for(0, nd.num_blocks, body)

        elif nd.kind == "gather" and nd.packed_fn is not None:
            # Packed gather: the outer reader recomputes the neighbour
            # indices from the lane's own block; the inner reader hands
            # ``packed_fn`` the own block plus exactly the ``arity``
            # neighbour blocks in idx_fn row order — no full-parent
            # reassembly at all (idx_fn is row-wise by the packed
            # contract, so it sees a one-row view here).
            p = self.nodes[nd.deps[0]]

            def body(i, _nd=nd, _out=out, _in=par0, _p=p):
                def outer(v, _i=i):
                    idx = np.asarray(_nd.idx_fn(
                        jnp.asarray(v.a[None])))[0]
                    js = [int(j) for j in
                          np.clip(idx, 0, _p.num_blocks - 1)]
                    uniq = sorted({_i, *js})

                    def inner(*vals, _i=_i, _js=js, _uniq=uniq):
                        by = dict(zip(_uniq, vals))
                        own = jnp.asarray(by[_i].a)
                        nbrs = jnp.stack(
                            [jnp.asarray(by[j].a) for j in _js])
                        eng.write(_out[_i], _store(
                            _nd, _nd.packed_fn(own, nbrs)))

                    eng.read(tuple(_in[j] for j in uniq), inner)

                eng.read(_in[i], outer)
            eng.parallel_for(0, nd.num_blocks, body)

        elif nd.kind == "gather":
            # Data-dependent reader sets, host-natively: an outer reader
            # on the lane's own block recomputes the neighbour indices
            # and (re)issues an inner reader on exactly those mods — the
            # dynamic dependency tracking the engine was built for.  The
            # inner reader zero-fills the blocks outside the reader set
            # (the gather contract: fn must not depend on them).
            p = self.nodes[nd.deps[0]]

            def body(i, _nd=nd, _out=out, _in=par0, _p=p):
                def outer(v, _i=i):
                    # idx_fn sees the full blocked shape (it may use
                    # positions), with only block i live — row i depends
                    # only on block i by the gather contract.
                    xb = np.zeros((_p.num_blocks,) + v.a.shape, v.a.dtype)
                    xb[_i] = v.a
                    idx = np.asarray(_nd.idx_fn(jnp.asarray(xb)))[_i]
                    js = sorted({_i} | {int(j) for j in
                                        np.clip(idx, 0, _p.num_blocks - 1)})

                    def inner(*vals, _i=_i, _js=js):
                        full = np.zeros((_p.num_blocks * _p.block,)
                                        + vals[0].a.shape[1:],
                                        vals[0].a.dtype)
                        for j, vb in zip(_js, vals):
                            full[j * _p.block:(j + 1) * _p.block] = vb.a
                        eng.write(_out[_i], _store(
                            _nd, _nd.fn(jnp.asarray(full), _i)))

                    eng.read(tuple(_in[j] for j in js), inner)

                eng.read(_in[i], outer)
            eng.parallel_for(0, nd.num_blocks, body)

        else:
            raise ValueError(f"cannot lower node kind {nd.kind!r}")

    # ------------------------------------------------------------------
    # Ladner-Fischer scan tree (escan / carry-causal)
    # ------------------------------------------------------------------
    def _lf_scan_tree(self, nd: GNode, leaves: List, rows: bool = True):
        """Lower an inclusive scan over ``leaves`` as a reader tree with
        the exact odd/even recursion of ``jax.lax.associative_scan`` —
        combine-for-combine, so the values are bitwise identical to the
        graph backend's scan for any dtype.

        Work is O(n) combine readers; a change in leaf i re-executes only
        the combines whose fold covers i at each of the O(log n)
        recursion depths (plus whatever the value cutoff lets through
        downstream), and each depth's combines run under ``parallel_for``
        — O(log n) span per depth instead of the O(n) monolithic carry
        reader.  Internal mods write ``counted=False``.

        ``rows=True`` treats values as one-row blocks (``v.a[0]``,
        escan); ``rows=False`` combines raw state arrays (carry-causal).
        """
        eng = self._eng_for(nd.idx)
        op = nd.op

        def combine(a, b, name):
            m = eng.mod(name)

            if rows:
                def reader(va, vb, _m=m):
                    eng.write(_m, _Blk(np.asarray(
                        op(jnp.asarray(va.a[0]), jnp.asarray(vb.a[0])))[None]),
                        counted=False)
            else:
                def reader(va, vb, _m=m):
                    eng.write(_m, _Blk(np.asarray(
                        op(jnp.asarray(va.a), jnp.asarray(vb.a)))),
                        counted=False)
            eng.read((a, b), reader)
            return m

        def scan(elems, depth):
            n = len(elems)
            if n < 2:
                return list(elems)
            red = [None] * (n // 2)

            def mk_red(i, _elems=elems, _red=red, _d=depth):
                _red[i] = combine(_elems[2 * i], _elems[2 * i + 1],
                                  f"{nd.name}.lf{_d}[{i}]")
            eng.parallel_for(0, n // 2, mk_red)
            odd = scan(red, depth + 1)
            n_even = len(range(2, n, 2))
            even = [None] * n_even

            def mk_even(i, _elems=elems, _odd=odd, _even=even, _d=depth):
                _even[i] = combine(_odd[i], _elems[2 * i + 2],
                                   f"{nd.name}.lfe{_d}[{i}]")
            eng.parallel_for(0, n_even, mk_even)
            res = [None] * n
            res[0] = elems[0]
            for i, m in enumerate(odd):
                res[2 * i + 1] = m
            for i, m in enumerate(even):
                res[2 * i + 2] = m
            return res

        return scan(list(leaves), 0)

    # ------------------------------------------------------------------
    # Change propagation
    # ------------------------------------------------------------------
    def update(self, inputs: Optional[Dict[str, Any]] = None, **changed):
        if self._comp is None:
            raise RuntimeError("update() before run()")
        changed = {**(inputs or {}), **changed}
        unknown = set(changed) - set(self.input_names)
        assert not unknown, f"unknown inputs {sorted(unknown)}"
        rec = self._recorder
        t_start = rec.clock() if rec is not None else 0.0
        pre = list(self._reexec)
        eng = self._eng
        dirty_inputs = 0
        for name, new in changed.items():
            idx = self.input_names[name]
            nd = self.nodes[idx]
            arr = np.asarray(new)
            assert arr.shape == self._inputs_np[name].shape
            old = self._inputs_np[name]
            for i in range(nd.num_blocks):
                sl = slice(i * nd.block, (i + 1) * nd.block)
                blk = arr[sl]
                if not np.array_equal(old[sl], blk):
                    dirty_inputs += 1
                eng.write(self._mods[idx][i], _Blk(blk.copy()))
            self._inputs_np[name] = arr.copy()
        t_mark = rec.clock() if rec is not None else 0.0
        st = self._comp.propagate()
        self._stats = {
            "phase": "update",
            "recomputed": st.affected_readers,
            "affected": st.changed_writes,
            "dirty_inputs": dirty_inputs,
            "work": st.work, "span": st.span, "reads": st.reads,
            "mark_work": st.mark_work,
        }
        if rec is not None:
            rec.emit(self._build_record(rec, t_start, t_mark, rec.clock(),
                                        pre, dirty_inputs, st))
        return self.outputs()

    def _build_record(self, rec, t_start, t_mark, t_end, pre,
                      dirty_inputs, st) -> PropagationRecord:
        """One PropagationRecord in the shared schema: per-level
        ``recomputed`` is the exact count of re-executed readers per dag
        level (the ``_LevelCountingEngine`` deltas), and the engine is
        synchronous, so every timing is real wall-clock — host records
        are always 'fenced'."""
        deltas = [self._reexec[li] - pre[li]
                  for li in range(len(self.schedule))]
        levels = []
        for li, lvl in enumerate(self.schedule):
            ops = [i for i in lvl if self.nodes[i].kind != "input"]
            levels.append(LevelRecord(
                level=li, nodes=len(ops),
                regimes=({"readers": len(ops)} if ops
                         else {"input": len(lvl)}),
                recomputed=deltas[li]))
        return PropagationRecord(
            substrate="host", seq=rec.next_seq(), mode=rec.mode,
            t_start=t_start,
            phases=[PhaseSpan("mark", t_start, t_mark - t_start),
                    PhaseSpan("execute", t_mark, t_end - t_mark)],
            levels=levels,
            counters={"recomputed": st.affected_readers,
                      "affected": st.changed_writes,
                      "dirty_inputs": dirty_inputs,
                      "work": st.work, "span": st.span,
                      "reads": st.reads, "mark_work": st.mark_work,
                      "rec_per_level": deltas},
            fenced=True)

    # ------------------------------------------------------------------
    @property
    def stats(self) -> Dict[str, Any]:
        """Counters of the last phase.  ``affected`` (value-changed
        blocks) matches the graph backend exactly; ``recomputed`` counts
        re-executed readers (the escan carry pass is one reader);
        ``work``/``span`` are the paper's exact accounting."""
        return dict(self._stats)

    # ------------------------------------------------------------------
    def fork(self) -> "HostHandle":
        """An independent handle over the same traced dag, seeded with
        this handle's current inputs.  The host engine is the reference
        semantics: fork = rebuild from the current inputs (the engine is
        deterministic, so the child's values are bitwise this handle's),
        at full re-run cost — the COW forest's O(changed-nodes) fork is
        the graph runtime's optimization of exactly this operation."""
        if self._eng is None:
            raise RuntimeError("fork() before run()")
        import types

        shim = types.SimpleNamespace(nodes=self.nodes,
                                     inputs=self.input_names)
        child = HostHandle(shim, self.out_handles, self._single)
        child._recorder = None           # reference fork: not recorded
        child.run(**{k: v.copy() for k, v in self._inputs_np.items()})
        return child

    def value(self, out) -> jax.Array:
        h = out._h if isinstance(out, BlockArray) else out
        return self._node_value(h.idx)

    def outputs(self):
        vals = tuple(self._node_value(h.idx) for h in self.out_handles)
        return vals[0] if self._single else vals

    def _node_value(self, idx: int) -> jax.Array:
        return jnp.asarray(np.concatenate(
            [m.peek().a for m in self._mods[idx]], axis=0))


# ---------------------------------------------------------------------------
# Engine-embedded fragments (the hybrid runtime's dynamic-skeleton side)
# ---------------------------------------------------------------------------
class EngineFragment:
    """A ``CompiledGraph`` fragment embedded as a *reader* inside a
    dynamic host-engine program.

    This is the hybrid runtime for apps whose skeleton is genuinely
    data-dependent (tree contraction, BST filter): the statically-shaped
    hot loop — fixed lane count, data-dependent values including
    dead/None payloads encoded as masked lanes — runs on the jitted
    graph runtime, while recursion over tree shape and the final
    consumers stay ordinary engine readers.  Dirty sets cross the
    boundary in both directions:

      * **host -> fragment**: the fragment installs one reader over all
        of its input mods; any input write marks it, and on re-execution
        it hands the reassembled arrays to ``CompiledGraph.propagate``,
        whose mark phase re-diffs them into exact per-block masks.
      * **fragment -> host**: only output blocks whose lanes actually
        changed (``stats["out_changed"]``) are written back to the
        per-block boundary mods, so downstream host readers re-run
        exactly as if the fragment had been a host subtree with the
        Algorithm-2 write cutoff.

    The realized computation distance (``stats["recomputed"]`` blocks)
    is charged to the engine via ``charge``, keeping work/span
    accounting meaningful across the boundary.

    Usage, inside the host program (while ``eng.run`` is tracing)::

        frag = EngineFragment(traced_program, {"x": mods}, ...)
        out_mods = frag.install(eng)      # [per-output] per-block mods
        eng.read(out_mods[0][0], consumer)
    """

    # Process-wide fragment cache: (cache_key) -> (CompiledGraph, outs).
    # A CompiledGraph is stateless apart from its jitted executables, so
    # app instances with identical traces (same n / seed / coins) share
    # one compilation; each fragment still owns its propagation state.
    _CG_CACHE: Dict[Any, Tuple[Any, List[Handle]]] = {}

    def __init__(self, program, input_mods: Dict[str, List],
                 dtypes: Optional[Dict[str, Any]] = None,
                 cache_key: Any = None, **compile_opts):
        self.program = program            # an IncrementalProgram
        self.input_mods = {k: list(v) for k, v in input_mods.items()}
        self.dtypes = dict(dtypes or {})
        self._opts = compile_opts
        self._cache_key = cache_key
        self._order = list(self.input_mods)
        self.cg = None                    # compiled lazily at install
        self._state = None
        self.out_handles: List[Handle] = []
        self.out_mods: List[List] = []
        self.last_stats: Dict[str, Any] = {}

    # ------------------------------------------------------------------
    def _n_of(self, name: str) -> int:
        return len(self.input_mods[name]) * self.program._block_of(name)

    def _assemble(self, vals) -> Dict[str, np.ndarray]:
        arrays, pos = {}, 0
        for name in self._order:
            k = len(self.input_mods[name])
            rows = np.asarray([v for v in vals[pos:pos + k]])
            pos += k
            block = self.program._block_of(name)
            if block > 1:       # mods hold [block, *feat] rows
                rows = rows.reshape((k * block,) + rows.shape[2:])
            dt = self.dtypes.get(name)
            arrays[name] = rows.astype(dt) if dt is not None else rows
        return arrays

    def install(self, eng) -> List[List]:
        """Compile the fragment, allocate its per-block boundary mods,
        and install the boundary reader.  Must be called while the host
        program is tracing (inside ``eng.run``); the boundary mods are
        allocated in the *calling* scope, so they persist as long as the
        caller does (a re-executed fragment reader rewrites them, it
        does not reallocate them)."""
        if self.cg is None:
            # Compile options are part of the cache identity: two
            # fragments sharing a caller key but compiled differently
            # (plan, dirty rep, max_sparse) must not share executables.
            full_key = None
            if self._cache_key is not None:
                full_key = (self._cache_key,
                            tuple(sorted(self._opts.items())),
                            tuple(sorted((k, np.dtype(v).name)
                                         for k, v in self.dtypes.items())))
            cached = (self._CG_CACHE.get(full_key)
                      if full_key is not None else None)
            if cached is not None:
                self.cg, self.out_handles = cached
            else:
                g, outs, _single = self.program.trace(
                    **{n: self._n_of(n) for n in self._order})
                self.cg = g.compile(**self._opts)
                self.out_handles = outs
                if full_key is not None:
                    self._CG_CACHE[full_key] = (self.cg, outs)
        # A (re)install starts a fresh computation over fresh boundary
        # mods: forget any previous propagation state so the first
        # reader execution initializes and writes every block.
        self._state = None
        self.out_mods = [
            [eng.mod(f"{self.program.__name__}.out{j}[{b}]")
             for b in range(h.node.num_blocks)]
            for j, h in enumerate(self.out_handles)]
        all_mods = tuple(m for name in self._order
                         for m in self.input_mods[name])
        eng.read(all_mods, self._reader(eng))
        return self.out_mods

    def _reader(self, eng):
        def reader(*vals):
            arrays = self._assemble(vals)
            if self._state is None:
                self._state = self.cg.init(arrays)
                eng.charge(self.cg.total_blocks, self.cg.num_levels)
                for j, h in enumerate(self.out_handles):
                    self._write_blocks(eng, j, h, None)
            else:
                self._state, stats = self.cg.propagate(self._state,
                                                       arrays)
                self.last_stats = stats
                eng.charge(int(stats["recomputed"]), self.cg.num_levels)
                for j, h in enumerate(self.out_handles):
                    mask = np.asarray(stats["out_changed"][str(h.idx)])
                    self._write_blocks(eng, j, h, np.flatnonzero(mask))
        return reader

    def _write_blocks(self, eng, j: int, h: Handle, blocks) -> None:
        nd = h.node
        v = np.asarray(self._state["v"][h.idx])
        vb = v.reshape((nd.num_blocks, nd.block) + v.shape[1:])
        if blocks is None:
            blocks = range(nd.num_blocks)
        for b in blocks:
            # Copy each written row: np.asarray of a CPU jax array is
            # zero-copy, and the mod holds this value across updates as
            # the write-cutoff baseline — it must not alias the donated
            # state a later propagate reuses in place (the same
            # copy-on-handoff rule as hybrid.py's boundary values).
            eng.write(self.out_mods[j][int(b)], _Blk(vb[int(b)].copy()))
