"""Host-engine backend: the traced dag on the paper-faithful runtime.

``compile(backend="host")`` lowers the same SP-dag that the graph
backend jits onto ``repro.core.engine.Engine`` — dynamic RSP tree,
per-block modifiables, reader sets, mark-walks — so one traced program
yields both the TPU artifact and the paper's exact work/span accounting.

Lowering: every block of every node becomes one ``Mod``.  Per node kind:

  * map / zip_map / stencil — one reader per output block, reading the
    block's static reader set (the window mods for stencil) and writing
    the recomputed block; lowered under ``parallel_for`` so the RSP tree
    records the P-structure (span = max over blocks).
  * reduce_level — one reader per pair; an odd level's last reader
    combines its single child with the op identity (same padding rule as
    the compiled backend).
  * escan — ONE reader for the whole carry pass: it reads every block
    aggregate and rewrites all carries with the same
    ``jax.lax.associative_scan`` the graph backend runs (bitwise parity);
    the engine's value-equality write cutoff then marks only the readers
    of carries that actually changed.
  * causal — out block i reads parent blocks 0..i; rows past the prefix
    are zero-filled before calling ``fn(x, i)`` (the causal contract:
    fn must not look at them).

Block values are stored wrapped (``_Blk``) so the engine's Algorithm-2
write cutoff compares them with numpy array equality (NaN-unequal,
matching the compiled backend's ``!=`` diff semantics).

Levels execute in sequence (S composition); the nodes of one level run
under a binary ``par`` tree (P composition) — exactly the schedule the
compiled backend fuses, so the two backends agree on both values and
changed-block counts.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import Engine
from repro.jaxsac.graph import GNode, GraphBuilder, Handle, level_schedule
from .tracer import BlockArray

__all__ = ["HostHandle"]


class _Blk:
    """A block value with bitwise-style equality for the write cutoff."""

    __slots__ = ("a",)

    def __init__(self, a):
        self.a = np.asarray(a)

    def __eq__(self, other):
        return (isinstance(other, _Blk)
                and self.a.dtype == other.a.dtype
                and bool(np.array_equal(self.a, other.a)))

    def __ne__(self, other):
        return not self.__eq__(other)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"_Blk{self.a.shape}"


def _store(nd: GNode, res) -> _Blk:
    """Canonical block layout: [block, *feat] (fns return [*feat] when
    out_block == 1, mirroring graph_ops._pack)."""
    a = np.asarray(res)
    if nd.block == 1:
        a = a[None]
    return _Blk(a)


class HostHandle:
    """Compiled program on the host engine (same facade as GraphHandle)."""

    backend = "host"

    def __init__(self, builder: GraphBuilder, outs: List[Handle],
                 single: bool):
        self.nodes: List[GNode] = list(builder.nodes)
        self.input_names: Dict[str, int] = dict(builder.inputs)
        assert self.input_names, "graph has no inputs"
        self.out_handles = outs
        self._single = single
        # The one level schedule both backends share (graph.py).
        self.level_of, self.schedule = level_schedule(self.nodes)

        self._eng: Optional[Engine] = None
        self._comp = None
        self._mods: List[List] = []
        self._inputs_np: Dict[str, np.ndarray] = {}
        self._stats: Dict[str, Any] = {}

    # ------------------------------------------------------------------
    # Initial run
    # ------------------------------------------------------------------
    def run(self, inputs: Optional[Dict[str, Any]] = None, **kw):
        inputs = {**(inputs or {}), **kw}
        assert set(inputs) == set(self.input_names), (
            f"inputs {sorted(inputs)} != declared "
            f"{sorted(self.input_names)}")
        self._eng = eng = Engine()
        self._mods = [[eng.mod(f"{nd.name}[{i}]")
                       for i in range(nd.num_blocks)] for nd in self.nodes]
        for name, idx in self.input_names.items():
            nd = self.nodes[idx]
            arr = np.asarray(inputs[name])
            assert arr.shape[0] == nd.n, (
                f"input {name!r}: leading size {arr.shape[0]}, "
                f"traced with {nd.n}")
            self._inputs_np[name] = arr.copy()
            for i in range(nd.num_blocks):
                eng.write(self._mods[idx][i],
                          _Blk(arr[i * nd.block:(i + 1) * nd.block].copy()))
        self._comp = eng.run(self._program)
        st = self._comp.initial_stats
        self._stats = {"phase": "run", "work": st.work, "span": st.span,
                       "reads": st.reads,
                       "recomputed": st.reads, "affected": st.writes}
        return self.outputs()

    def _program(self) -> None:
        eng = self._eng
        for lvl in self.schedule:
            ops = [i for i in lvl if self.nodes[i].kind != "input"]
            if ops:                      # one level = one P group
                eng.parallel_for(0, len(ops),
                                 lambda j, _ops=ops: self._lower(_ops[j]))

    # ------------------------------------------------------------------
    # Node lowering (readers)
    # ------------------------------------------------------------------
    def _lower(self, idx: int) -> None:
        nd = self.nodes[idx]
        eng = self._eng
        out = self._mods[idx]
        par0 = self._mods[nd.deps[0]]

        if nd.kind == "map":
            def body(i, _nd=nd, _out=out, _in=par0):
                eng.read(_in[i], lambda v, _i=i: eng.write(
                    _out[_i], _store(_nd, _nd.fn(jnp.asarray(v.a)))))
            eng.parallel_for(0, nd.num_blocks, body)

        elif nd.kind == "zip_map":
            par1 = self._mods[nd.deps[1]]

            def body(i, _nd=nd, _out=out, _x=par0, _y=par1):
                eng.read((_x[i], _y[i]), lambda vx, vy, _i=i: eng.write(
                    _out[_i],
                    _store(_nd, _nd.fn(jnp.asarray(vx.a),
                                       jnp.asarray(vy.a)))))
            eng.parallel_for(0, nd.num_blocks, body)

        elif nd.kind == "reduce_level":
            nb_in = self.nodes[nd.deps[0]].num_blocks

            def body(i, _nd=nd, _out=out, _in=par0, _nb=nb_in):
                li, ri = 2 * i, 2 * i + 1
                if ri < _nb:
                    eng.read((_in[li], _in[ri]),
                             lambda vl, vr, _i=i: eng.write(
                                 _out[_i], _Blk(np.asarray(_nd.op(
                                     jnp.asarray(vl.a[0]),
                                     jnp.asarray(vr.a[0])))[None])))
                else:                    # odd level: identity right child
                    eng.read(_in[li], lambda vl, _i=i: eng.write(
                        _out[_i], _Blk(np.asarray(_nd.op(
                            jnp.asarray(vl.a[0]),
                            jnp.broadcast_to(
                                jnp.asarray(_nd.identity, vl.a.dtype),
                                vl.a[0].shape)))[None])))
            eng.parallel_for(0, nd.num_blocks, body)

        elif nd.kind == "stencil":
            p = self.nodes[nd.deps[0]]

            def body(i, _nd=nd, _out=out, _in=par0, _p=p):
                reads, slots = [], []    # slots: index into reads, or fill
                for off in range(-_nd.radius, _nd.radius + 1):
                    j = i + off
                    oob = j < 0 or j >= _p.num_blocks
                    if oob and _nd.fill is not None:
                        slots.append(None)
                    else:
                        reads.append(_in[min(max(j, 0), _p.num_blocks - 1)])
                        slots.append(len(reads) - 1)

                def reader(*vals, _i=i):
                    ref = vals[0].a      # dtype/shape template
                    parts = [np.full_like(ref, _nd.fill) if s is None
                             else vals[s].a for s in slots]
                    win = jnp.asarray(np.concatenate(parts, axis=0))
                    eng.write(_out[_i], _store(_nd, _nd.fn(win)))

                eng.read(tuple(reads), reader)
            eng.parallel_for(0, nd.num_blocks, body)

        elif nd.kind == "escan":
            # One reader = the whole carry pass (see module docstring).
            def carry_pass(*vals, _nd=nd, _out=out):
                x = jnp.asarray(np.concatenate([v.a for v in vals], axis=0))
                inclusive = jax.lax.associative_scan(_nd.op, x, axis=0)
                seed = jnp.broadcast_to(jnp.asarray(_nd.identity, x.dtype),
                                        x[:1].shape)
                rows = np.asarray(
                    jnp.concatenate([seed, inclusive[:-1]], axis=0))
                eng.charge(len(vals) - 1, span=max(len(vals), 1).bit_length())
                for i, m in enumerate(_out):
                    eng.write(m, _Blk(rows[i][None]))

            eng.read(tuple(par0), carry_pass)

        elif nd.kind == "causal":
            p = self.nodes[nd.deps[0]]

            def body(i, _nd=nd, _out=out, _in=par0, _p=p):
                def reader(*vals, _i=i):
                    pre = np.concatenate([v.a for v in vals], axis=0)
                    pad = np.zeros(
                        ((_p.num_blocks - _i - 1) * _p.block,)
                        + pre.shape[1:], pre.dtype)
                    x = jnp.asarray(np.concatenate([pre, pad], axis=0))
                    eng.write(_out[_i], _store(_nd, _nd.fn(x, _i)))

                eng.read(tuple(_in[:i + 1]), reader)
            eng.parallel_for(0, nd.num_blocks, body)

        else:
            raise ValueError(f"cannot lower node kind {nd.kind!r}")

    # ------------------------------------------------------------------
    # Change propagation
    # ------------------------------------------------------------------
    def update(self, inputs: Optional[Dict[str, Any]] = None, **changed):
        if self._comp is None:
            raise RuntimeError("update() before run()")
        changed = {**(inputs or {}), **changed}
        unknown = set(changed) - set(self.input_names)
        assert not unknown, f"unknown inputs {sorted(unknown)}"
        eng = self._eng
        dirty_inputs = 0
        for name, new in changed.items():
            idx = self.input_names[name]
            nd = self.nodes[idx]
            arr = np.asarray(new)
            assert arr.shape == self._inputs_np[name].shape
            old = self._inputs_np[name]
            for i in range(nd.num_blocks):
                sl = slice(i * nd.block, (i + 1) * nd.block)
                blk = arr[sl]
                if not np.array_equal(old[sl], blk):
                    dirty_inputs += 1
                eng.write(self._mods[idx][i], _Blk(blk.copy()))
            self._inputs_np[name] = arr.copy()
        st = self._comp.propagate()
        self._stats = {
            "phase": "update",
            "recomputed": st.affected_readers,
            "affected": st.changed_writes,
            "dirty_inputs": dirty_inputs,
            "work": st.work, "span": st.span, "reads": st.reads,
            "mark_work": st.mark_work,
        }
        return self.outputs()

    # ------------------------------------------------------------------
    @property
    def stats(self) -> Dict[str, Any]:
        """Counters of the last phase.  ``affected`` (value-changed
        blocks) matches the graph backend exactly; ``recomputed`` counts
        re-executed readers (the escan carry pass is one reader);
        ``work``/``span`` are the paper's exact accounting."""
        return dict(self._stats)

    def value(self, out) -> jax.Array:
        h = out._h if isinstance(out, BlockArray) else out
        return self._node_value(h.idx)

    def outputs(self):
        vals = tuple(self._node_value(h.idx) for h in self.out_handles)
        return vals[0] if self._single else vals

    def _node_value(self, idx: int) -> jax.Array:
        return jnp.asarray(np.concatenate(
            [m.peek().a for m in self._mods[idx]], axis=0))
