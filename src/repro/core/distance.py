"""Computation distance between two RSP trees (Definition 4.2).

Given two executions of the same deterministic algorithm on different
inputs, the computation distance is the summed cost of the *affected*
read nodes — cognate reads that observed different values and are not
subsumed by another such read.  Because programs in the framework are
deterministic, two cognate subtrees whose reads all observed equal values
are structurally identical, so the recursion below only descends while
structures agree.

This module is used by tests and benchmarks to validate the stability
bounds the paper proves (e.g. Theorem 4.2: O(k log(1 + n/k)) affected
reads for the divide-and-conquer sum under k-element updates).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from .rsp import Node, PNode, RNode, SNode

__all__ = ["Distance", "computation_distance"]


@dataclasses.dataclass
class Distance:
    work: int = 0             # W_delta: summed reader work over affected reads
    affected_reads: int = 0   # R_delta (counted over both trees' frontiers)

    def __iadd__(self, other: "Distance") -> "Distance":
        self.work += other.work
        self.affected_reads += other.affected_reads
        return self


def computation_distance(a: Optional[Node], b: Optional[Node]) -> Distance:
    """delta(T, T') per Definition 4.2, computed over annotated RSP trees."""
    d = Distance()
    _walk(a, b, d)
    return d


def _walk(a: Optional[Node], b: Optional[Node], d: Distance) -> None:
    if a is None and b is None:
        return
    if a is None or b is None or type(a) is not type(b):
        # Structural divergence outside an affected read frontier can only
        # happen for non-deterministic programs; charge conservatively.
        d.work += _subtree_work(a) + _subtree_work(b)
        d.affected_reads += _subtree_reads(a) + _subtree_reads(b)
        return
    if isinstance(a, RNode):
        assert isinstance(b, RNode)
        if a.last_values != b.last_values:
            # Affected pair: charge both reader executions, do not descend
            # (nested differing reads are subsumed, Definition 4.1).
            d.work += a.last_work + b.last_work
            d.affected_reads += 2
            return
        _walk(a.left, b.left, d)
        _walk(a.right, b.right, d)
        return
    if isinstance(a, (SNode, PNode)):
        _walk(a.left, b.left, d)  # type: ignore[union-attr]
        _walk(a.right, b.right, d)  # type: ignore[union-attr]


def _subtree_work(node: Optional[Node]) -> int:
    total = 0
    stack = [node] if node is not None else []
    while stack:
        n = stack.pop()
        if isinstance(n, RNode):
            total += n.last_work
            continue  # reader work already includes nested work
        if isinstance(n, (SNode, PNode)):
            for c in (n.left, n.right):
                if c is not None:
                    stack.append(c)
    return total


def _subtree_reads(node: Optional[Node]) -> int:
    total = 0
    stack = [node] if node is not None else []
    while stack:
        n = stack.pop()
        if isinstance(n, RNode):
            total += 1
            continue
        if isinstance(n, (SNode, PNode)):
            for c in (n.left, n.right):
                if c is not None:
                    stack.append(c)
    return total
