"""Parallel self-adjusting computation — the paper's core contribution.

``Engine`` is the paper-faithful host engine (dynamic RSP tree, change
propagation, Algorithms 2-5).  ``StaticEngine`` runs the same programs
without dependency tracking (the static baselines of the paper's tables).
``computation_distance`` implements Definition 4.2 for stability analysis.

The TPU-native compiled adaptation is in ``repro.jaxsac``.
"""
from .engine import Computation, Engine, PhaseStats, StaticEngine
from .modref import Mod, ReaderSet
from .rsp import Node, PNode, RNode, SNode
from .distance import Distance, computation_distance

__all__ = [
    "Computation",
    "Engine",
    "PhaseStats",
    "StaticEngine",
    "Mod",
    "ReaderSet",
    "Node",
    "PNode",
    "RNode",
    "SNode",
    "Distance",
    "computation_distance",
]
