"""Modifiable references ("modifiables") and reader sets.

A modifiable is a write-once-per-execution reference whose readers are
tracked so that change propagation can find exactly the computations that
depend on a changed value (paper, Section 2).

Reader sets use the hybrid representation from Section 5 of the paper: a
single reader is stored inline with no extra allocation; sets grow into a
dict (standing in for the paper's concurrent hash table / treap — the
asymptotics the analysis needs are expected O(1) insert/delete, which a
dict provides).
"""
from __future__ import annotations

from typing import Any, Iterator, Optional

__all__ = ["Mod", "ReaderSet"]

_UNWRITTEN = object()


class ReaderSet:
    """Hybrid inline-single-reader / hashed reader set."""

    __slots__ = ("_single", "_many")

    def __init__(self):
        self._single = None
        self._many: Optional[dict] = None

    def add(self, reader) -> None:
        if self._many is not None:
            self._many[id(reader)] = reader
        elif self._single is None:
            self._single = reader
        elif self._single is reader:
            pass
        else:
            # Convert to the linked/hashed representation.
            self._many = {id(self._single): self._single, id(reader): reader}
            self._single = None

    def discard(self, reader) -> None:
        if self._many is not None:
            self._many.pop(id(reader), None)
        elif self._single is reader:
            self._single = None

    def __iter__(self) -> Iterator:
        if self._many is not None:
            # Snapshot: marking may trigger lazy cleanup of dead readers.
            return iter(list(self._many.values()))
        if self._single is not None:
            return iter((self._single,))
        return iter(())

    def __len__(self) -> int:
        if self._many is not None:
            return len(self._many)
        return 0 if self._single is None else 1


class Mod:
    """A modifiable reference.

    Restrictions (paper, Section 2): written at most once per execution of
    the computation; never read before written; only read/written inside the
    dynamic scope of the computation that allocated it.
    """

    __slots__ = ("val", "readers", "writer", "write_epoch", "name")

    def __init__(self, name: str = ""):
        self.val: Any = _UNWRITTEN
        self.readers = ReaderSet()
        self.writer: Any = None      # R node (or root scope) that wrote it
        self.write_epoch = -1        # engine epoch of the last write
        self.name = name

    # ------------------------------------------------------------------
    @property
    def written(self) -> bool:
        return self.val is not _UNWRITTEN

    def peek(self) -> Any:
        """Read the value outside of tracked computation (e.g. to inspect
        outputs after run/propagate).  Does not register a dependency."""
        if not self.written:
            raise RuntimeError(f"mod {self.name or id(self)} read before written")
        return self.val

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        v = "?" if not self.written else repr(self.val)
        return f"Mod({self.name or hex(id(self))}={v}, readers={len(self.readers)})"
