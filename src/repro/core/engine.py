"""The parallel self-adjusting computation engine (host reference engine).

Implements the primitives of Figure 1 and the change-propagation algorithm
of Algorithms 2-5 from Anderson et al. (2021).  This is the *paper-faithful*
engine: a dynamic RSP tree with mod reader-sets, mark-walks, and a
propagation traversal that re-executes affected readers.

Because this container exposes a single CPU core, ``par`` executes its two
thunks sequentially but the engine keeps exact *work/span* accounting
through the RSP structure (span of a P node = max of children, span of an
S node = sum).  Benchmarks report measured wall-clock work savings (real)
plus simulated p-processor time via Brent's bound W/p + s, which is the
model the paper's analysis is stated in (Section 1.3).

The TPU-native adaptation of this algorithm lives in ``repro.jaxsac``.
"""
from __future__ import annotations

import dataclasses
import sys
from typing import Any, Callable, List, Optional, Sequence, Tuple, Union

from .modref import Mod, _UNWRITTEN
from .rsp import Node, PNode, RNode, SNode

__all__ = ["Engine", "Computation", "PhaseStats", "StaticEngine"]

sys.setrecursionlimit(200_000)


@dataclasses.dataclass
class PhaseStats:
    """Work/span and event counters for one phase (a run or a propagate)."""

    work: int = 0              # user + primitive work
    span: int = 0              # critical-path length under the RSP structure
    reads: int = 0             # reader executions
    writes: int = 0
    changed_writes: int = 0    # writes whose value differed (trigger marks)
    mark_work: int = 0         # nodes marked by mark-walks
    affected_readers: int = 0  # readers re-executed during propagation
    traversed: int = 0         # RSP nodes visited by the propagation traversal
    nodes_created: int = 0

    def simulated_time(self, p: int) -> float:
        """Brent's bound: time on p processors is O(W/p + s)."""
        return self.work / p + self.span


class Computation:
    """Handle to a self-adjusting computation (the root of its RSP tree)."""

    def __init__(self, engine: "Engine", root: SNode, stats: PhaseStats):
        self.engine = engine
        self.root = root
        self.initial_stats = stats

    def propagate(self) -> PhaseStats:
        return self.engine.propagate(self)


class Engine:
    """A parallel self-adjusting computation engine instance.

    Typical usage::

        eng = Engine()
        xs = [eng.mod(f"x{i}") for i in range(n)]
        for x, v in zip(xs, values): eng.write(x, v)
        res = eng.mod("res")
        comp = eng.run(lambda: my_sum(eng, xs, res))
        ...
        eng.write(xs[3], 42)          # input update
        comp.propagate()              # change propagation
        print(res.peek())
    """

    def __init__(self):
        self.epoch = 0
        self.current_scope: Optional[SNode] = None
        self.stats = PhaseStats()           # the *current* phase's stats
        self.live_nodes = 0                 # RSP nodes alive (memory table)
        self.live_mods = 0
        self.garbage: List[Node] = []       # detached subtrees awaiting GC
        self.garbage_mods: List[Mod] = []   # scope-owned mods awaiting GC
        self._in_computation = False

    # ------------------------------------------------------------------
    # Allocation
    # ------------------------------------------------------------------
    def mod(self, name: str = "") -> Mod:
        """Allocate a modifiable.  If called inside a computation, its
        lifetime is tied to the allocating scope (paper, Section 2)."""
        m = Mod(name)
        self.live_mods += 1
        if self._in_computation and self.current_scope is not None:
            self.current_scope.own(m)
        return m

    def alloc_array(self, n: int, name: str = "") -> List[Mod]:
        return [self.mod(f"{name}[{i}]") for i in range(n)]

    # ------------------------------------------------------------------
    # Work accounting
    # ------------------------------------------------------------------
    def charge(self, work: int, span: Optional[int] = None) -> None:
        """Charge explicit user work (e.g. the inner loop of an edit-distance
        reader) to the current phase."""
        self.stats.work += work
        self.stats.span += work if span is None else span

    # ------------------------------------------------------------------
    # write (Algorithm 2)
    # ------------------------------------------------------------------
    def write(self, dest: Mod, value: Any, *, counted: bool = True) -> None:
        """Algorithm-2 write.  ``counted=False`` writes (internal mods of
        a lowered combinator, e.g. the host backend's Ladner-Fischer scan
        tree) keep the value-equality cutoff and mark-walk semantics but
        stay out of ``changed_writes``, so per-block 'affected' counts
        remain comparable across backends."""
        self.stats.writes += 1
        self.stats.work += 1
        self.stats.span += 1
        unwritten = not dest.written
        if unwritten or not _values_equal(dest.val, value):
            if self._in_computation:
                # Write-once restriction: at most one writer per execution.
                if dest.write_epoch == self.epoch and dest.writer is not self.current_scope:
                    raise RuntimeError(
                        f"write-once violation on mod {dest.name or hex(id(dest))}"
                    )
                dest.writer = self.current_scope
                dest.write_epoch = self.epoch
            dest.val = value
            if not unwritten and counted:
                self.stats.changed_writes += 1
            # Mark all readers (and their ancestors) as pending re-execution.
            for reader in dest.readers:
                if reader.dead:
                    dest.readers.discard(reader)  # lazy deletion (Section 5)
                    continue
                reader.affected = True
                self.stats.mark_work += reader.mark()
        elif self._in_computation:
            dest.writer = self.current_scope
            dest.write_epoch = self.epoch

    # ------------------------------------------------------------------
    # read (Algorithm 3)
    # ------------------------------------------------------------------
    def read(
        self,
        mods: Union[Mod, Sequence[Mod]],
        reader_f: Callable[..., None],
    ) -> None:
        if isinstance(mods, Mod):
            mods = (mods,)
        else:
            mods = tuple(mods)
        cur = self._scope_slot()
        r = RNode(cur, mods, reader_f)
        self.live_nodes += 1
        self.stats.nodes_created += 1
        self._attach(cur, r)
        for m in mods:
            if not m.written:
                raise RuntimeError(
                    f"mod {m.name or hex(id(m))} read before written"
                )
            m.readers.add(r)
        self._do_read(r)
        # The continuation S node is created lazily by _scope_slot() only if
        # the enclosing scope performs further operations (Section 3).

    def _do_read(self, r: RNode) -> None:
        """R::DO_READ — run the reader body in the scope of the R node."""
        self.stats.reads += 1
        values = tuple(m.val for m in r.mods)
        r.last_values = values
        saved_scope = self.current_scope
        self.current_scope = r
        w0, s0 = self.stats.work, self.stats.span
        self.stats.work += 1
        self.stats.span += 1
        r.reader_f(*values)
        r.last_work = self.stats.work - w0
        r.last_span = self.stats.span - s0
        self.current_scope = saved_scope

    # ------------------------------------------------------------------
    # par (Algorithm 4)
    # ------------------------------------------------------------------
    def par(self, left_f: Callable[[], None], right_f: Callable[[], None]) -> None:
        cur = self._scope_slot()
        p = PNode(cur)
        p.left = SNode(p)
        p.right = SNode(p)
        self.live_nodes += 3
        self.stats.nodes_created += 3
        self._attach(cur, p)
        saved_scope = self.current_scope
        # Sequential execution with parallel span accounting: span of the P
        # node is the max of the two branch spans.
        s_before = self.stats.span
        self.current_scope = p.left
        left_f()
        left_span = self.stats.span - s_before
        self.stats.span = s_before
        self.current_scope = p.right
        right_f()
        right_span = self.stats.span - s_before
        self.stats.span = s_before + max(left_span, right_span) + 1
        self.stats.work += 1
        self.current_scope = saved_scope

    def parallel_for(
        self, lo: int, hi: int, body: Callable[[int], None], grain: int = 1
    ) -> None:
        """Binary divide-and-conquer parallel loop (paper, Section 2)."""
        if hi - lo <= grain:
            for i in range(lo, hi):
                body(i)
            return
        mid = lo + (hi - lo) // 2
        self.par(
            lambda: self.parallel_for(lo, mid, body, grain),
            lambda: self.parallel_for(mid, hi, body, grain),
        )

    # ------------------------------------------------------------------
    # run (Algorithm 5)
    # ------------------------------------------------------------------
    def run(self, f: Callable[[], None]) -> Computation:
        if self._in_computation:
            raise RuntimeError("nested run() is not supported")
        self.epoch += 1
        self.stats = PhaseStats()
        root = SNode(None)
        self.live_nodes += 1
        self.stats.nodes_created += 1
        self.current_scope = root
        self._in_computation = True
        try:
            f()
        finally:
            self._in_computation = False
            self.current_scope = None
        return Computation(self, root, self.stats)

    # ------------------------------------------------------------------
    # propagate (Algorithm 5)
    # ------------------------------------------------------------------
    def propagate(self, comp: Computation) -> PhaseStats:
        self.epoch += 1
        self.stats = PhaseStats()
        self._in_computation = True
        try:
            if comp.root.marked:
                self._propagate_node(comp.root)
        finally:
            self._in_computation = False
            self.current_scope = None
        return self.stats

    def _propagate_node(self, node: Node) -> int:
        """Propagate through one marked node; returns the span consumed."""
        self.stats.traversed += 1
        self.stats.work += 1
        if isinstance(node, RNode):
            span = self._propagate_r(node)
        elif isinstance(node, PNode):
            span = self._propagate_p(node)
        else:
            span = self._propagate_s(node)
        node.marked = False
        return span + 1

    def _propagate_s(self, node: SNode) -> int:
        # Sequential: left strictly before right; re-check right's mark after
        # left runs, since left's re-execution may have marked it.
        span = 0
        if node.left is not None and node.left.marked:
            span += self._propagate_node(node.left)
        if node.right is not None and node.right.marked:
            span += self._propagate_node(node.right)
        return span

    def _propagate_p(self, node: PNode) -> int:
        # Parallel: both children may propagate simultaneously (no control
        # or data dependence can cross a P node in a race-free program), so
        # span is the max.  Executed sequentially here; span accounted.
        left_m = node.left is not None and node.left.marked
        right_m = node.right is not None and node.right.marked
        if left_m and right_m:
            ls = self._propagate_node(node.left)
            rs = self._propagate_node(node.right)
            return max(ls, rs)
        if left_m:
            return self._propagate_node(node.left)
        if right_m:
            return self._propagate_node(node.right)
        return 0

    def _propagate_r(self, r: RNode) -> int:
        if r.affected:
            self.stats.affected_readers += 1
            # Discard the old body subtree to the garbage pile; sever parent
            # pointers so marks on dead nodes cannot escape into the live
            # tree (Section 5, garbage collection).
            for child in (r.left, r.right):
                if child is not None:
                    child.detach()
                    self.garbage.append(child)
            if r.owned_mods:
                self.garbage_mods.extend(r.owned_mods)
                r.owned_mods = None
            r.left = None
            r.right = None
            r.affected = False
            s0 = self.stats.span
            self._do_read(r)
            return self.stats.span - s0
        # Unaffected read node: behaves as a scope, recurse into marked
        # children (some nested reader needs re-execution).
        return self._propagate_s(r)

    # ------------------------------------------------------------------
    # Garbage collection (Section 5)
    # ------------------------------------------------------------------
    def collect(self) -> int:
        """Destroy detached subtrees: unregister dead readers from reader
        sets and free scope-owned modifiables.  Returns nodes collected."""
        collected = 0
        stack = list(self.garbage)
        self.garbage.clear()
        while stack:
            node = stack.pop()
            collected += 1
            self.live_nodes -= 1
            if isinstance(node, RNode):
                node.dead = True
                for m in node.mods:
                    m.readers.discard(node)
            if isinstance(node, (SNode, PNode)):
                if isinstance(node, SNode) and node.owned_mods:
                    self.live_mods -= len(node.owned_mods)
                    node.owned_mods = None
                for child in (node.left, node.right):
                    if child is not None:
                        stack.append(child)
        self.live_mods -= len(self.garbage_mods)
        self.garbage_mods.clear()
        return collected

    # ------------------------------------------------------------------
    # Scope plumbing
    # ------------------------------------------------------------------
    def _scope_slot(self) -> SNode:
        """Return the scope S node that has a free child slot, descending
        into a (lazily created) continuation S node if needed."""
        cur = self.current_scope
        if cur is None:
            raise RuntimeError("primitive used outside run()/propagate()")
        while cur.left is not None:
            if cur.right is None:
                nxt = SNode(cur)
                self.live_nodes += 1
                self.stats.nodes_created += 1
                cur.right = nxt
                cur = nxt
            else:
                # Continuation scope already exists (shouldn't normally
                # happen since scopes advance as ops occur), descend.
                cur = cur.right  # pragma: no cover
        self.current_scope = cur
        return cur

    @staticmethod
    def _attach(scope: SNode, child: Node) -> None:
        assert scope.left is None
        scope.left = child

    # ------------------------------------------------------------------
    def tree_size(self, comp: Computation) -> int:
        """Count live RSP nodes under a computation (Table 7 analogue)."""
        n = 0
        stack: List[Node] = [comp.root]
        while stack:
            node = stack.pop()
            n += 1
            if isinstance(node, (SNode, PNode)):
                for child in (node.left, node.right):
                    if child is not None:
                        stack.append(child)
        return n


def _values_equal(a: Any, b: Any) -> bool:
    if a is b:
        return True
    try:
        return bool(a == b)
    except Exception:
        return False


class StaticEngine:
    """Duck-typed engine that runs the same program *without* building an
    RSP tree or tracking dependencies — the "static algorithm" baseline of
    the paper's benchmark tables.  Work/span are still counted so work
    savings and self-speedup can be computed against it."""

    def __init__(self):
        self.stats = PhaseStats()
        self._in_computation = False

    def mod(self, name: str = "") -> Mod:
        return Mod(name)

    def alloc_array(self, n: int, name: str = "") -> List[Mod]:
        return [Mod(f"{name}[{i}]") for i in range(n)]

    def charge(self, work: int, span: Optional[int] = None) -> None:
        self.stats.work += work
        self.stats.span += work if span is None else span

    def write(self, dest: Mod, value: Any, *, counted: bool = True) -> None:
        self.stats.writes += 1
        self.stats.work += 1
        self.stats.span += 1
        dest.val = value

    def read(self, mods, reader_f) -> None:
        if isinstance(mods, Mod):
            mods = (mods,)
        self.stats.reads += 1
        self.stats.work += 1
        self.stats.span += 1
        reader_f(*(m.val for m in mods))

    def par(self, left_f, right_f) -> None:
        s_before = self.stats.span
        left_f()
        left_span = self.stats.span - s_before
        self.stats.span = s_before
        right_f()
        right_span = self.stats.span - s_before
        self.stats.span = s_before + max(left_span, right_span) + 1
        self.stats.work += 1

    def parallel_for(self, lo, hi, body, grain: int = 1) -> None:
        if hi - lo <= grain:
            for i in range(lo, hi):
                body(i)
            return
        mid = lo + (hi - lo) // 2
        self.par(
            lambda: self.parallel_for(lo, mid, body, grain),
            lambda: self.parallel_for(mid, hi, body, grain),
        )

    def run(self, f: Callable[[], None]) -> PhaseStats:
        self.stats = PhaseStats()
        f()
        return self.stats
