"""RSP-tree node types (Series, Parallel, Read nodes).

The RSP tree records the control structure of a nested-parallel
self-adjusting computation, per Anderson et al., "Efficient Parallel
Self-Adjusting Computation" (2021):

  * ``S`` nodes compose two computations sequentially (left before right).
  * ``P`` nodes compose two computations in parallel (order irrelevant).
  * ``R`` nodes record a read of one or more modifiables together with the
    reader closure; the reader body executes in the scope of the R node
    itself, so an R node behaves as an S node with extra fields.

Change propagation marks paths from affected readers to the root and then
re-traverses only marked paths, re-executing affected readers — in parallel
below P nodes, sequentially below S nodes (Algorithms 2-5 of the paper).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

__all__ = ["Node", "SNode", "PNode", "RNode"]


class Node:
    """Base RSP node: parent pointer plus the propagation mark."""

    __slots__ = ("parent", "marked")

    def __init__(self, parent: Optional["Node"]):
        self.parent = parent
        self.marked = False

    # ---- marking (Algorithm 5, Node::mark) --------------------------------
    def mark(self) -> int:
        """Mark this node and all unmarked ancestors.

        Returns the number of nodes newly marked (used for work accounting:
        the paper amortizes this against later traversal/destruction).
        """
        n = 0
        node: Optional[Node] = self
        while node is not None and not node.marked:
            node.marked = True
            n += 1
            node = node.parent
        return n

    def detach(self) -> None:
        """Sever this node from its parent (used when a subtree moves to the
        garbage pile, so marks on dead nodes cannot escape into live tree)."""
        self.parent = None


class SNode(Node):
    """Sequential composition node; also the unit of *scope*.

    ``left`` runs strictly before ``right``.  Dynamically allocated
    modifiables are owned by the scope that allocated them (``owned_mods``)
    so their lifetime is tied to the subtree (paper, Section 2).
    """

    __slots__ = ("left", "right", "owned_mods")

    def __init__(self, parent: Optional[Node]):
        super().__init__(parent)
        self.left: Optional[Node] = None
        self.right: Optional[Node] = None
        self.owned_mods: Optional[list] = None  # lazily allocated

    def own(self, mod) -> None:
        if self.owned_mods is None:
            self.owned_mods = []
        self.owned_mods.append(mod)


class PNode(Node):
    """Parallel composition node: two child S scopes, run in parallel."""

    __slots__ = ("left", "right")

    def __init__(self, parent: Optional[Node]):
        super().__init__(parent)
        self.left: Optional[SNode] = None
        self.right: Optional[SNode] = None


class RNode(SNode):
    """Read node.

    Reads ``mods`` and runs ``reader_f`` on their values; the reader body's
    own RSP structure hangs off this node (it doubles as an S scope).  On
    change propagation, if ``affected`` the old body subtree is discarded to
    the garbage pile and ``reader_f`` re-executes in a fresh scope.

    ``last_values``/``last_work``/``last_span`` annotate the node for
    computation-distance analysis (Definition 4.2).
    """

    __slots__ = (
        "mods",
        "reader_f",
        "affected",
        "dead",
        "last_values",
        "last_work",
        "last_span",
    )

    def __init__(
        self,
        parent: Optional[Node],
        mods: Tuple[Any, ...],
        reader_f: Callable[..., None],
    ):
        super().__init__(parent)
        self.mods = mods
        self.reader_f = reader_f
        self.affected = False
        self.dead = False  # set when subtree is moved to the garbage pile
        self.last_values: Optional[Tuple[Any, ...]] = None
        self.last_work = 0
        self.last_span = 0
