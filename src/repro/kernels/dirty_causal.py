"""Pallas TPU kernel: block-skip causal carry scan.

The flash-style fast path of the interval-carrying ``causal`` edge and
the ``escan`` carry pass (``repro.jaxsac``): an edit dirties a *suffix*
of blocks, so the carry states of every block before the suffix are
exactly the states memoized by the previous run.  Instead of rescanning
the full prefix, the kernel

  * copies clean tiles (tile index < the scalar-prefetched dirty start)
    straight from the cached states — their body never executes the
    combine;
  * reseeds the boundary tile from the cached state just before the
    suffix (``seeds[t] = states[t*block - 1]``, gathered outside the
    kernel);
  * recomputes only the dirty suffix sequentially, carrying the running
    state across grid steps in a VMEM scratch accumulator (the TPU grid
    is sequential, so the scratch persists between tiles — the same
    pattern flash attention uses for its running softmax state, which is
    itself such a carry monoid).

Work for a k-block dirty suffix is O(k) combines instead of the O(P)
rescan of the dense path — the kernel-level realization of the paper's
computation-distance bound for suffix-shaped edits.

Bitwise contract: re-bracketing a fold is only bitwise-stable for
exactly-associative dtypes (ints/bools); the graph runtime gates routing
accordingly (``block_skip="auto"``) and keeps the dense
``associative_scan`` path as the oracle — ``tests/test_kernels.py``
property-tests the kernel against it over random edit suffixes.

Layout: contributions and cached states are [P, W] rows (row i = block
i's flattened contribution / state); ``state_shape`` restores the real
per-block state shape inside the kernel so ``op`` sees what it was
traced with.  W should be a multiple of 128 lanes on real TPUs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["dirty_causal_scan_call"]


@functools.partial(jax.jit, static_argnames=("op", "state_shape", "block",
                                             "interpret"))
def dirty_causal_scan_call(
    contrib: jax.Array,      # [P, W] per-block contributions m[i]
    old_states: jax.Array,   # [P, W] cached inclusive states s[i]
    seeds: jax.Array,        # [tiles, W] cached state before each tile
    start_tile: jax.Array,   # [1] int32 — first tile with a dirty block
    *,
    op,                      # associative combine on state_shape arrays
    state_shape: tuple,
    block: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """New inclusive states: ``s'[i] = old`` for tiles before
    ``start_tile``; from the boundary tile on, ``s'[i] = op(s'[i-1],
    contrib[i])`` seeded with ``seeds[start_tile]``."""
    P, W = contrib.shape
    assert old_states.shape == (P, W)
    assert P % block == 0, (P, block)
    tiles = P // block
    assert seeds.shape == (tiles, W)

    def kernel(start_ref, contrib_ref, old_ref, seeds_ref, out_ref,
               carry_ref):
        t = pl.program_id(0)
        s = start_ref[0]

        @pl.when(t < s)
        def _keep():
            out_ref[...] = old_ref[...]

        @pl.when(t >= s)
        def _recompute():
            # Reseed at the boundary tile from the cached prefix state;
            # later tiles continue from the scratch carry.
            carry = jnp.where(t == s, seeds_ref[...], carry_ref[...])
            c = carry[0].reshape(state_shape)
            for r in range(block):
                c = op(c, contrib_ref[r].reshape(state_shape))
                out_ref[r, :] = c.reshape(W)
            carry_ref[...] = c.reshape(1, W)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(tiles,),
            in_specs=[
                pl.BlockSpec((block, W), lambda t, s: (t, 0)),
                pl.BlockSpec((block, W), lambda t, s: (t, 0)),
                pl.BlockSpec((1, W), lambda t, s: (t, 0)),
            ],
            out_specs=pl.BlockSpec((block, W), lambda t, s: (t, 0)),
            scratch_shapes=[pltpu.VMEM((1, W), old_states.dtype)],
        ),
        out_shape=jax.ShapeDtypeStruct((P, W), old_states.dtype),
        interpret=interpret,
    )(start_tile, contrib, old_states, seeds)
