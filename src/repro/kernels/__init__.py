"""Pallas TPU kernels for the compute hot-spots of the serving path.

  * flash_attention — grouped-query streaming-softmax attention with
    causal/window block skip and query offset (incremental prefill).
  * dirty_reduce    — dirty-masked tree-reduction level: change
    propagation's "skip unmarked subtrees" as BlockSpec machinery.
  * grouped_matmul  — block-diagonal expert GEMM (dropless MoE tile map).

Each kernel is written against TPU (pl.pallas_call + BlockSpec VMEM
tiling) and validated on CPU via interpret mode against the pure-jnp
oracles in ``ref.py`` (tests/test_kernels.py sweeps shapes and dtypes).
"""
from .ops import flash_attention, dirty_reduce_level, grouped_matmul

__all__ = ["flash_attention", "dirty_reduce_level", "grouped_matmul"]
