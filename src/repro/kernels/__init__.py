"""Pallas TPU kernels for the compute hot-spots of the serving path.

  * flash_attention — grouped-query streaming-softmax attention with
    causal/window block skip and query offset (incremental prefill).
  * dirty_reduce    — dirty-masked tree-reduction level: change
    propagation's "skip unmarked subtrees" as BlockSpec machinery.
  * dirty_map       — the generalized dirty-tile kernel (arbitrary
    combining function, N inputs); the graph runtime's dense-path lane.
  * dirty_causal    — block-skip causal carry scan: clean tiles copy
    their cached carry states without executing; the dirty suffix
    reseeds from the cached prefix (escan / carry-causal fast path).
  * grouped_matmul  — block-diagonal expert GEMM (dropless MoE tile map).

Each kernel is written against TPU (pl.pallas_call + BlockSpec VMEM
tiling) and validated on CPU via interpret mode against the pure-jnp
oracles in ``ref.py`` (tests/test_kernels.py sweeps shapes and dtypes).
"""
from .ops import (dirty_causal_scan, dirty_map, dirty_reduce_level,
                  flash_attention, grouped_matmul)

__all__ = ["flash_attention", "dirty_reduce_level", "dirty_map",
           "dirty_causal_scan", "grouped_matmul"]
