"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "dirty_reduce_level_ref", "dirty_map_ref",
           "grouped_matmul_ref"]

NEG_INF = -2.0e38


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, window: int = 0,
                        offset: int = 0) -> jax.Array:
    """Grouped-query attention, materialized scores, fp32 softmax.

    q: [B, Sq, KV, G, hd]; k: [B, Skv, KV, hd]; v: [B, Skv, KV, hv]
    -> [B, Sq, KV, G, hv].  Query row i sits at absolute position
    offset + i; kv row j at position j.
    """
    B, Sq, KV, G, hd = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32)
    s = s / math.sqrt(hd)
    iq = offset + jnp.arange(Sq)[:, None]
    jk = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= jk <= iq
    if window:
        mask &= jk > iq - window
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)
    return o.astype(q.dtype)


def dirty_reduce_level_ref(children: jax.Array, old_parents: jax.Array,
                           dirty: jax.Array) -> jax.Array:
    """children: [P, 2, W]; dirty parents recompute, clean keep old."""
    new = children[:, 0, :] + children[:, 1, :]
    return jnp.where(dirty[:, None], new.astype(old_parents.dtype),
                     old_parents)


def dirty_map_ref(fn, inputs, old_out: jax.Array,
                  dirty: jax.Array) -> jax.Array:
    """Row-wise oracle for dirty_map: dirty rows get fn(*inputs), clean
    rows keep old (tile granularity is applied by the caller)."""
    new = fn(*inputs).astype(old_out.dtype)
    return jnp.where(dirty[:, None], new, old_out)


def grouped_matmul_ref(x: jax.Array, w: jax.Array,
                       group_sizes: jax.Array) -> jax.Array:
    """x: [M, D] grouped by expert; w: [E, D, F]; -> [M, F].

    Row m belongs to group g iff sum(group_sizes[:g]) <= m <
    sum(group_sizes[:g+1]); rows past sum(group_sizes) produce zeros.
    """
    M, D = x.shape
    E, _, F = w.shape
    bounds = jnp.cumsum(group_sizes)
    gid = jnp.searchsorted(bounds, jnp.arange(M), side="right")
    valid = jnp.arange(M) < bounds[-1]
    w_rows = w[jnp.minimum(gid, E - 1)]               # [M, D, F]
    out = jnp.einsum("md,mdf->mf", x, w_rows)
    return jnp.where(valid[:, None], out, 0).astype(x.dtype)
