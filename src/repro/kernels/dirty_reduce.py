"""Pallas TPU kernel: one level of dirty-masked tree reduction.

The compute hot-spot of ``repro.jaxsac.reduce``: combining children into
parents during change propagation, where most parents are *clean* (their
children's aggregates did not change).  The kernel skips clean parent
tiles entirely — the scalar-prefetched per-tile dirty flags steer
``pl.when``, so a clean tile's body never executes.  Because a "clean"
parent recomputes to a bitwise-identical value by determinism (paper,
Definition 4.1), dirty tiles can recompute *all* their rows; no per-row
select is needed.

This is the paper's mark-guided traversal as BlockSpec machinery: the
dirty flags are the marks, tiles are subtrees, skipped tiles are unmarked
branches change propagation never descends.

Layout: children [P, 2, W] (parent-major pairs), parents [P, W]; tiles of
``block`` parents; W should be a multiple of 128 lanes on real TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["dirty_reduce_level_call", "dirty_map_call"]


def _kernel(tile_dirty_ref, kids_ref, old_ref, out_ref):
    t = pl.program_id(0)

    @pl.when(tile_dirty_ref[t] != 0)
    def _recompute():
        out_ref[...] = kids_ref[:, 0, :] + kids_ref[:, 1, :]

    @pl.when(tile_dirty_ref[t] == 0)
    def _keep():
        out_ref[...] = old_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dirty_reduce_level_call(
    children: jax.Array,     # [P, 2, W]
    old_parents: jax.Array,  # [P, W]
    dirty: jax.Array,        # [P] bool — parent-level marks
    *,
    block: int = 8,
    interpret: bool = True,
) -> jax.Array:
    P, two, W = children.shape
    assert two == 2 and old_parents.shape == (P, W)
    assert P % block == 0, (P, block)
    tiles = P // block
    tile_dirty = jnp.any(dirty.reshape(tiles, block), axis=1).astype(jnp.int32)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(tiles,),
            in_specs=[
                pl.BlockSpec((block, 2, W), lambda t, s: (t, 0, 0)),
                pl.BlockSpec((block, W), lambda t, s: (t, 0)),
            ],
            out_specs=pl.BlockSpec((block, W), lambda t, s: (t, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((P, W), old_parents.dtype),
        interpret=interpret,
    )(tile_dirty, children, old_parents)


# ---------------------------------------------------------------------------
# Generalized dirty-tile map: arbitrary combining function, N inputs.
#
# The graph runtime (repro.jaxsac.graph_compile) lowers every elementwise /
# pair level of an SP-dag through this one kernel shape: row i of each
# input holds the flattened payload read by output block i (for a map
# node that is the input block itself; for a reduce level, the two
# children).  ``fn`` is the node's combining function, traced *into the
# kernel body* — tiles whose scalar-prefetched dirty flag is clear never
# execute it and copy the old output instead, exactly the mark-guided
# skip of dirty_reduce_level_call but for any op, not just ``+``.
# ---------------------------------------------------------------------------
def dirty_map_call(
    fn,                       # (*tiles [block, W_i]) -> [block, W_out]
    inputs,                   # sequence of [P, W_i]
    old_out: jax.Array,       # [P, W_out]
    dirty: jax.Array,         # [P] bool — per-output-block marks
    *,
    block: int = 8,
    interpret: bool = True,
) -> jax.Array:
    inputs = tuple(inputs)
    assert inputs, "dirty_map_call needs at least one input"
    P, W = old_out.shape
    for x in inputs:
        assert x.ndim == 2 and x.shape[0] == P, (x.shape, P)
    assert dirty.shape == (P,)
    assert P % block == 0, (P, block)
    tiles = P // block
    tile_dirty = jnp.any(dirty.reshape(tiles, block), axis=1).astype(jnp.int32)
    n_in = len(inputs)

    def kernel(tile_dirty_ref, *refs):
        in_refs, old_ref, out_ref = refs[:n_in], refs[n_in], refs[n_in + 1]
        t = pl.program_id(0)

        @pl.when(tile_dirty_ref[t] != 0)
        def _recompute():
            out_ref[...] = fn(*(r[...] for r in in_refs)).astype(out_ref.dtype)

        @pl.when(tile_dirty_ref[t] == 0)
        def _keep():
            out_ref[...] = old_ref[...]

    in_specs = [
        pl.BlockSpec((block, x.shape[1]), lambda t, s: (t, 0)) for x in inputs
    ]
    in_specs.append(pl.BlockSpec((block, W), lambda t, s: (t, 0)))

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(tiles,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((block, W), lambda t, s: (t, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((P, W), old_out.dtype),
        interpret=interpret,
    )(tile_dirty, *inputs, old_out)
