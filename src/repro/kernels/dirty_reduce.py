"""Pallas TPU kernel: one level of dirty-masked tree reduction.

The compute hot-spot of ``repro.jaxsac.reduce``: combining children into
parents during change propagation, where most parents are *clean* (their
children's aggregates did not change).  The kernel skips clean parent
tiles entirely — the scalar-prefetched per-tile dirty flags steer
``pl.when``, so a clean tile's body never executes.  Because a "clean"
parent recomputes to a bitwise-identical value by determinism (paper,
Definition 4.1), dirty tiles can recompute *all* their rows; no per-row
select is needed.

This is the paper's mark-guided traversal as BlockSpec machinery: the
dirty flags are the marks, tiles are subtrees, skipped tiles are unmarked
branches change propagation never descends.

Layout: children [P, 2, W] (parent-major pairs), parents [P, W]; tiles of
``block`` parents; W should be a multiple of 128 lanes on real TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["dirty_reduce_level_call"]


def _kernel(tile_dirty_ref, kids_ref, old_ref, out_ref):
    t = pl.program_id(0)

    @pl.when(tile_dirty_ref[t] != 0)
    def _recompute():
        out_ref[...] = kids_ref[:, 0, :] + kids_ref[:, 1, :]

    @pl.when(tile_dirty_ref[t] == 0)
    def _keep():
        out_ref[...] = old_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dirty_reduce_level_call(
    children: jax.Array,     # [P, 2, W]
    old_parents: jax.Array,  # [P, W]
    dirty: jax.Array,        # [P] bool — parent-level marks
    *,
    block: int = 8,
    interpret: bool = True,
) -> jax.Array:
    P, two, W = children.shape
    assert two == 2 and old_parents.shape == (P, W)
    assert P % block == 0, (P, block)
    tiles = P // block
    tile_dirty = jnp.any(dirty.reshape(tiles, block), axis=1).astype(jnp.int32)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(tiles,),
            in_specs=[
                pl.BlockSpec((block, 2, W), lambda t, s: (t, 0, 0)),
                pl.BlockSpec((block, W), lambda t, s: (t, 0)),
            ],
            out_specs=pl.BlockSpec((block, W), lambda t, s: (t, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((P, W), old_parents.dtype),
        interpret=interpret,
    )(tile_dirty, children, old_parents)
