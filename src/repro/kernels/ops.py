"""Public jit'd wrappers for the Pallas kernels.

Each op validates shapes, reshapes model-layout tensors into kernel
layout, and picks interpret mode automatically (Pallas interprets the
kernel body in Python off-TPU; on TPU hardware it compiles via Mosaic).
Every op has a pure-jnp oracle in ``ref.py`` and an allclose sweep in
``tests/test_kernels.py``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_kernel_call
from .dirty_causal import dirty_causal_scan_call
from .dirty_reduce import dirty_map_call, dirty_reduce_level_call
from .grouped_matmul import grouped_matmul_call

__all__ = ["flash_attention", "dirty_reduce_level", "dirty_map",
           "dirty_causal_scan", "grouped_matmul"]


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, offset: int = 0,
                    q_block: int = 128, kv_block: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """Grouped-query flash attention (model layout).

    q: [B, Sq, KV, G, hd]; k: [B, Skv, KV, hd]; v: [B, Skv, KV, hv]
    -> [B, Sq, KV, G, hv].
    """
    B, Sq, KV, G, hd = q.shape
    Skv = k.shape[1]
    hv = v.shape[-1]
    qh = jnp.transpose(q, (0, 2, 3, 1, 4)).reshape(B * KV * G, Sq, hd)
    kh = jnp.transpose(k, (0, 2, 1, 3)).reshape(B * KV, Skv, hd)
    vh = jnp.transpose(v, (0, 2, 1, 3)).reshape(B * KV, Skv, hv)
    oh = flash_attention_kernel_call(
        qh, kh, vh, g=G, causal=causal, window=window, offset=offset,
        q_block=q_block, kv_block=kv_block,
        interpret=_default_interpret() if interpret is None else interpret)
    o = oh.reshape(B, KV, G, Sq, hv)
    return jnp.transpose(o, (0, 3, 1, 2, 4))


def dirty_reduce_level(children: jax.Array, old_parents: jax.Array,
                       dirty: jax.Array, *, block: int = 8,
                       interpret: bool | None = None) -> jax.Array:
    """One dirty-masked reduction level: children [P,2,W] -> parents [P,W]."""
    return dirty_reduce_level_call(
        children, old_parents, dirty, block=block,
        interpret=_default_interpret() if interpret is None else interpret)


def dirty_map(fn, inputs, old_out: jax.Array, dirty: jax.Array, *,
              block: int = 8, interpret: bool | None = None) -> jax.Array:
    """Dirty-tile masked map with an arbitrary combining function.

    ``inputs``: sequence of [P, W_i] row-payloads (row i = what output
    block i reads); ``fn``: (*tiles) -> [tile, W_out]; clean tiles keep
    ``old_out`` without executing ``fn``.
    """
    return dirty_map_call(
        fn, inputs, old_out, dirty, block=block,
        interpret=_default_interpret() if interpret is None else interpret)


def dirty_causal_scan(contrib: jax.Array, old_states: jax.Array,
                      start_block: jax.Array, op, *, identity=0.0,
                      block: int = 8,
                      interpret: bool | None = None) -> jax.Array:
    """Block-skip causal carry scan (see ``dirty_causal.py``).

    ``contrib``: [P, *feat] per-block contributions; ``old_states``:
    [P, *feat] cached inclusive states from the previous run;
    ``start_block``: first dirty block (P = all clean).  Returns the new
    inclusive states — cached before the dirty suffix, recomputed from
    the cached seed onward.  Exact (int/bool) dtypes only for bitwise
    parity with the dense scan (the caller gates).
    """
    P = contrib.shape[0]
    state_shape = contrib.shape[1:]
    W = max(int(math.prod(state_shape)), 1)
    rows = contrib.reshape(P, W)
    old_rows = old_states.reshape(P, W)
    pad = (-P) % block
    if pad:
        ident = jnp.broadcast_to(
            jnp.asarray(identity, contrib.dtype),
            (pad,) + state_shape).reshape(pad, W)
        rows = jnp.concatenate([rows, ident])
        old_rows = jnp.concatenate(
            [old_rows, jnp.zeros((pad, W), old_rows.dtype)])
    tiles = (P + pad) // block
    # Cached state just before each tile boundary (identity before t=0):
    # only the boundary tile's seed is read, the rest ride along.
    boundary = jnp.maximum(jnp.arange(tiles) * block - 1, 0)
    seeds = old_rows[boundary]
    ident_row = jnp.broadcast_to(
        jnp.asarray(identity, old_states.dtype),
        state_shape).reshape(1, W)
    seeds = jnp.where(jnp.arange(tiles)[:, None] == 0, ident_row, seeds)
    start_tile = (jnp.minimum(jnp.asarray(start_block, jnp.int32), P)
                  // block).reshape(1)
    out = dirty_causal_scan_call(
        rows, old_rows, seeds, start_tile, op=op, state_shape=state_shape,
        block=block,
        interpret=_default_interpret() if interpret is None else interpret)
    return out[:P].reshape(old_states.shape)


def grouped_matmul(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
                   mb: int = 128, fb: int = 128,
                   interpret: bool | None = None) -> jax.Array:
    """Dropless-MoE grouped matmul, ragged_dot semantics."""
    return grouped_matmul_call(
        x, w, group_sizes, mb=mb, fb=fb,
        interpret=_default_interpret() if interpret is None else interpret)
