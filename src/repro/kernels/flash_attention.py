"""Pallas TPU flash-attention forward kernel (grouped-query, causal/window).

Mirrors the pure-JAX oracle in ``repro.models.flash`` block-for-block:
streaming softmax over KV tiles with fp32 running (m, l, acc) carried in
VMEM scratch across the innermost (sequential) grid dimension.  The
causal/window *block skip* — tiles that the mask fully excludes perform
no compute — is the kernel-level analogue of change propagation never
descending unmarked RSP subtrees.

Grid: (B*KV*G heads, query tiles, kv tiles); the kv axis iterates
sequentially per TPU grid semantics, so scratch persists across it.
BlockSpecs keep one (q_block, head_dim) query tile, one (kv_block,
head_dim) KV tile and the fp32 accumulators resident in VMEM:

    VMEM footprint ~ q_block*hd + 2*kv_block*hd + q_block*(hd+256) floats
    (for the default 128/512 blocks and hd=128: ~0.6 MiB << 16 MiB/core)

``offset`` places query row i at absolute position offset+i, which is how
incremental prefill re-runs only suffix rows against the full cache.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel_call"]

NEG_INF = -2.0e38
LANES = 128  # TPU lane width: (q_block, LANES) layout for m/l scratch


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: int, offset: int, scale: float,
            q_block: int, kv_block: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Tile-level mask reach: absolute query rows [q_lo, q_lo + q_block),
    # kv columns [k_lo, k_lo + kv_block).
    q_lo = offset + qi * q_block
    k_lo = kj * kv_block
    relevant = True
    if causal:
        relevant = jnp.asarray(k_lo <= q_lo + q_block - 1)
    if window:
        relevant = jnp.logical_and(
            relevant, k_lo + kv_block - 1 > q_lo - window)

    @pl.when(relevant)
    def _tile():
        q = q_ref[0]                          # [qb, hd]
        k = k_ref[0]                          # [kb, hd]
        v = v_ref[0]                          # [kb, hv]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [qb, kb]
        if causal or window:
            iq = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            jk = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            mask = jnp.ones_like(s, dtype=jnp.bool_)
            if causal:
                mask = jnp.logical_and(mask, jk <= iq)
            if window:
                mask = jnp.logical_and(mask, jk > iq - window)
            s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                  # [qb]
        l_prev = l_ref[:, 0]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])       # [qb, kb] f32
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(kj == nk - 1)
    def _fin():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "offset", "q_block", "kv_block",
                     "g", "interpret"),
)
def flash_attention_kernel_call(
    qh: jax.Array,      # [BH, Sq, hd]  (BH = B * KV * G)
    kh: jax.Array,      # [BKV, Skv, hd]
    vh: jax.Array,      # [BKV, Skv, hv]
    *,
    g: int,             # query heads per kv head (BH = BKV * g)
    causal: bool,
    window: int = 0,
    offset: int = 0,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = True,
) -> jax.Array:
    BH, Sq, hd = qh.shape
    BKV, Skv, hv = vh.shape
    assert BH == BKV * g, (BH, BKV, g)
    assert Sq % q_block == 0 and Skv % kv_block == 0
    nq, nk = Sq // q_block, Skv // kv_block
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, offset=offset, scale=scale,
        q_block=q_block, kv_block=kv_block)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda bh, qi, kj, g=g: (bh // g, kj, 0)),
            pl.BlockSpec((1, kv_block, hv), lambda bh, qi, kj, g=g: (bh // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hv), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hv), qh.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, LANES), jnp.float32),   # running max m
            pltpu.VMEM((q_block, LANES), jnp.float32),   # running sum l
            pltpu.VMEM((q_block, hv), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(qh, kh, vh)
