"""Pallas TPU kernel: grouped (ragged) matmul for dropless MoE.

``repro.models.moe.moe_fwd_dropless`` is token-local (what incremental
prefill requires) but relies on ``jax.lax.ragged_dot``, which GSPMD
cannot shard at pod scale (observed: near-total replication, 1.1 TiB/dev
for arctic prefill).  This kernel is the per-device building block that
makes dropless MoE deployable: tokens arrive sorted by expert and padded
so each expert's rows occupy whole tiles; a scalar-prefetched tile→expert
map steers each tile's weight BlockSpec, so tile (m, f) performs

    out[m*mb:(m+1)*mb, f*fb:(f+1)*fb] = x_tile @ w[expert_of_tile[m]]

— a block-diagonal GEMM with expert-indexed weight fetches (the
MegaBlocks construction adapted to TPU BlockSpec prefetch).

VMEM per tile: mb*D + D*fb + mb*fb floats; for mb=fb=128, D=8192, fp32:
~8.5 MiB — fits a v5e core; shrink fb for larger D.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["grouped_matmul_call", "pad_groups"]


def _kernel(be_ref, x_ref, w_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        x_ref[...], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def pad_groups(x: jax.Array, group_sizes: jax.Array, mb: int,
               num_groups: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Repack group-sorted rows so each group occupies whole mb-tiles.

    Returns (x_padded [Mp, D], tile_expert [Mp//mb] int32,
    row_map [M] int32 — the padded position of each original row).
    Mp = M + num_groups*mb (static worst case); padded rows are zero and
    belong to whichever expert their tile maps to (they produce garbage
    that is never gathered back).
    """
    M, D = x.shape
    E = num_groups
    Mp = M + E * mb
    bounds = jnp.cumsum(group_sizes)
    starts = bounds - group_sizes
    gid = jnp.searchsorted(bounds, jnp.arange(M), side="right")
    gid = jnp.minimum(gid, E - 1)
    rank = jnp.arange(M) - starts[gid]
    padded_sizes = ((group_sizes + mb - 1) // mb) * mb
    padded_starts = jnp.cumsum(padded_sizes) - padded_sizes
    row_map = (padded_starts[gid] + rank).astype(jnp.int32)
    x_padded = jnp.zeros((Mp, D), x.dtype).at[row_map].set(x)
    # tile -> expert: tile t covers padded rows [t*mb, (t+1)*mb), all of
    # one group by construction.
    tile_starts = jnp.arange(Mp // mb) * mb
    tile_expert = jnp.searchsorted(
        jnp.cumsum(padded_sizes), tile_starts, side="right").astype(jnp.int32)
    tile_expert = jnp.minimum(tile_expert, E - 1)
    return x_padded, tile_expert, row_map


@functools.partial(jax.jit,
                   static_argnames=("mb", "fb", "interpret"))
def grouped_matmul_call(x: jax.Array, w: jax.Array, group_sizes: jax.Array,
                        *, mb: int = 128, fb: int = 128,
                        interpret: bool = True) -> jax.Array:
    """ragged_dot semantics: x [M,D] grouped rows, w [E,D,F] -> [M,F]."""
    M, D = x.shape
    E, _, F = w.shape
    assert F % fb == 0, (F, fb)
    x_p, tile_expert, row_map = pad_groups(x, group_sizes, mb, E)
    Mp = x_p.shape[0]

    out_p = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(Mp // mb, F // fb),
            in_specs=[
                pl.BlockSpec((mb, D), lambda m, f, be: (m, 0)),
                pl.BlockSpec((1, D, fb), lambda m, f, be: (be[m], 0, f)),
            ],
            out_specs=pl.BlockSpec((mb, fb), lambda m, f, be: (m, f)),
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, F), x.dtype),
        interpret=interpret,
    )(tile_expert, x_p, w)
    bounds = jnp.cumsum(group_sizes)
    valid = jnp.arange(M) < bounds[-1]
    return jnp.where(valid[:, None], out_p[row_map], 0)
