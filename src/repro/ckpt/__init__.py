"""Sharded, atomic, async checkpointing (the fault-tolerance substrate).

Layout on disk::

    <dir>/step_000100/
        MANIFEST.json        # pytree structure, shapes, dtypes, step, mesh
        p0_l00000.npy ...    # one file per leaf per process
        COMMITTED            # written last: restore ignores uncommitted dirs

Write protocol (crash-safe): leaves are written into ``step_N.tmp``,
fsynced, the directory is atomically renamed to ``step_N``, and only then
the COMMITTED marker is created.  A process killed at any point leaves
either a complete committed checkpoint or an ignorable partial one —
restart always finds the newest committed step (checkpoint/restart fault
tolerance; exercised by tests/test_runtime.py::test_supervisor_restart).

Integrity (crash-consistent *reads*): the manifest records a CRC32 per
leaf.  ``restore`` verifies every leaf as it loads and raises
:class:`CorruptCheckpoint` on a mismatch, a truncated manifest, or an
unreadable leaf file; when no explicit ``step`` was requested it then
falls back to the previous committed step, so bit rot or a torn write
costs the edits since the prior checkpoint, never a wrong restore.
``latest_step(..., verify=True)`` applies the same check up front and
only returns verified steps.  Skipped checkpoints are counted as
``ckpt.corrupt_skipped`` on the registry passed to ``set_registry``.

Fault-injection sites (``repro.runtime.faults``): ``ckpt.save`` before
leaf I/O, ``ckpt.commit`` just before the atomic rename (a fault there
leaves an ignorable partial), ``ckpt.load`` before reads.

On a multi-host pod each process saves only the leaf shards it owns
(``process_index`` names the files); restore device_puts with the target
sharding, so a checkpoint written on one mesh can be read onto another
(elastic remesh path — see repro.runtime.elastic).

``save_async`` copies leaves to host synchronously (cheap) and does the
file I/O on a background thread so the train loop never blocks on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "list_steps",
           "load_meta", "gc_old", "CorruptCheckpoint", "set_registry"]

_MANIFEST = "MANIFEST.json"
_COMMITTED = "COMMITTED"


class CorruptCheckpoint(RuntimeError):
    """A committed checkpoint failed verification at load: truncated or
    unparsable manifest, missing leaf file, or a leaf whose bytes no
    longer match the manifest's recorded CRC32."""


# Optional metrics routing (one registry per process is the obs-layer
# convention): corrupt-skip events surface as ``ckpt.corrupt_skipped``.
_REGISTRY = None


def set_registry(registry) -> None:
    """Route checkpoint-integrity events through a
    ``repro.obs.MetricRegistry`` (or ``None`` to detach)."""
    global _REGISTRY
    _REGISTRY = registry


def _note_corrupt(directory: Path, step: int, why: str) -> None:
    if _REGISTRY is not None:
        _REGISTRY.counter("ckpt.corrupt_skipped").inc()
        _REGISTRY.event("ckpt.corrupt", dir=str(directory), step=step,
                        error=why)


def _inject(site: str, **ctx) -> None:
    # Late import: repro.runtime.__init__ imports the supervisor, which
    # imports this module — a top-level import here would cycle.
    from repro.runtime.faults import inject

    inject(site, **ctx)


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def _step_dir(directory: Path, step: int) -> Path:
    return directory / f"step_{step:08d}"


def save(directory: str | os.PathLike, state: Any, step: int,
         process_index: Optional[int] = None,
         meta: Optional[Dict[str, Any]] = None) -> Path:
    """Write a committed checkpoint for ``state`` at ``step``.

    ``meta``, when given, is JSON-serializable side data stored in the
    manifest — non-array parts of the state (e.g. a serving session's
    dirty representation and warmed plan signatures) that ride the same
    commit protocol as the arrays.
    """
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    pidx = jax.process_index() if process_index is None else process_index
    final = _step_dir(directory, step)
    tmp = final.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    _inject("ckpt.save", step=step)
    leaves = _leaf_paths(state)
    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "leaves": [],
        "process_count": jax.process_count(),
        "meta": meta or {},
    }
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"p{pidx}_l{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype),
             "crc32": zlib.crc32(arr.tobytes())})
    with (tmp / _MANIFEST).open("w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    # A fault between here and COMMITTED leaves step_N.tmp (or an
    # unmarked step_N): both invisible to the loader — the atomic-commit
    # crash window the chaos suite exercises.
    _inject("ckpt.commit", step=step)
    if final.exists():  # pragma: no cover - overwrite semantics
        shutil.rmtree(final)
    os.replace(tmp, final)
    (final / _COMMITTED).touch()
    return final


class _AsyncSaver:
    """One in-flight save at a time; join() before the next or at exit."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def submit(self, directory, state, step, meta=None):
        self.join()
        host_state = jax.tree.map(lambda l: np.asarray(jax.device_get(l)),
                                  state)

        def work():
            try:
                save(directory, host_state, step, meta=meta)
            except BaseException as e:  # pragma: no cover
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def join(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:  # pragma: no cover
            e, self._error = self._error, None
            raise e


_SAVER = _AsyncSaver()


def save_async(directory, state, step, meta=None) -> None:
    """Device->host copy now, disk I/O on a background thread."""
    _SAVER.submit(directory, state, step, meta=meta)


def wait_for_async_saves() -> None:
    _SAVER.join()


def _verify_step(directory: Path, step: int) -> None:
    """Integrity check of a committed step: manifest parses and every
    leaf file loads with its recorded CRC32.  Raises
    :class:`CorruptCheckpoint` (committedness itself is the caller's
    listing concern)."""
    d = _step_dir(directory, step)
    try:
        manifest = json.loads((d / _MANIFEST).read_text())
        leaves = manifest["leaves"]
    except Exception as e:
        raise CorruptCheckpoint(f"{d}: unreadable manifest ({e!r})") from e
    for entry in leaves:
        try:
            arr = np.load(d / entry["file"])
        except Exception as e:
            raise CorruptCheckpoint(
                f"{d}: unreadable leaf {entry['file']} ({e!r})") from e
        want = entry.get("crc32")
        if want is not None and zlib.crc32(arr.tobytes()) != want:
            raise CorruptCheckpoint(
                f"{d}: leaf {entry['file']} checksum mismatch")


def list_steps(directory, verify: bool = False) -> List[int]:
    directory = Path(directory)
    if not directory.exists():
        return []
    steps = []
    for d in directory.iterdir():
        if d.is_dir() and d.name.startswith("step_") and \
                (d / _COMMITTED).exists():
            steps.append(int(d.name.split("_")[1]))
    steps = sorted(steps)
    if not verify:
        return steps
    ok = []
    for s in steps:
        try:
            _verify_step(directory, s)
        except CorruptCheckpoint as e:
            _note_corrupt(directory, s, str(e))
        else:
            ok.append(s)
    return ok


def latest_step(directory, verify: bool = False) -> Optional[int]:
    """Newest committed step; with ``verify=True``, newest committed
    step that passes manifest + per-leaf checksum verification (corrupt
    ones are skipped and counted as ``ckpt.corrupt_skipped``)."""
    steps = list_steps(directory, verify=verify)
    return steps[-1] if steps else None


def load_meta(directory, step: Optional[int] = None) -> Dict[str, Any]:
    """The ``meta`` side data of a committed checkpoint (``{}`` for
    checkpoints written before meta existed)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory}")
    d = _step_dir(directory, step)
    if not (d / _COMMITTED).exists():
        raise FileNotFoundError(f"checkpoint {d} not committed")
    manifest = json.loads((d / _MANIFEST).read_text())
    return manifest.get("meta", {})


def restore(directory, abstract_state: Any, step: Optional[int] = None,
            shardings: Any = None, process_index: Optional[int] = None) -> Any:
    """Read a committed checkpoint into the structure of abstract_state.

    ``shardings`` (same pytree structure, or None) controls device_put —
    pass shardings resolved on the *current* mesh to restore onto a
    different topology than the one that saved (elastic restart).

    Every leaf is checksum-verified as it loads.  With an explicit
    ``step``, corruption raises :class:`CorruptCheckpoint`; with
    ``step=None`` corrupt steps are skipped (counted as
    ``ckpt.corrupt_skipped``) and the previous committed step restores
    instead — a torn or rotted newest checkpoint costs the updates
    since the prior one, never a wrong restore.
    """
    directory = Path(directory)
    if step is not None:
        if not (_step_dir(directory, step) / _COMMITTED).exists():
            raise FileNotFoundError(
                f"checkpoint {_step_dir(directory, step)} not committed")
        return _restore_step(directory, abstract_state, step, shardings,
                             process_index)
    steps = list_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoint under {directory}")
    last_err: Optional[CorruptCheckpoint] = None
    for st in reversed(steps):
        try:
            return _restore_step(directory, abstract_state, st, shardings,
                                 process_index)
        except CorruptCheckpoint as e:
            _note_corrupt(directory, st, str(e))
            last_err = e
    raise CorruptCheckpoint(
        f"every committed checkpoint under {directory} failed "
        f"verification") from last_err


def _restore_step(directory: Path, abstract_state: Any, step: int,
                  shardings: Any, process_index: Optional[int]) -> Any:
    d = _step_dir(directory, step)
    _inject("ckpt.load", step=step)
    try:
        manifest = json.loads((d / _MANIFEST).read_text())
        entries = manifest["leaves"]
        num_leaves = manifest["num_leaves"]
    except Exception as e:
        raise CorruptCheckpoint(f"{d}: unreadable manifest ({e!r})") from e
    pidx = jax.process_index() if process_index is None else process_index

    flat, treedef = jax.tree_util.tree_flatten(abstract_state)
    if len(flat) != num_leaves:
        raise ValueError(
            f"checkpoint has {num_leaves} leaves, "
            f"state expects {len(flat)}")
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    out = []
    for i, (spec, sh) in enumerate(zip(flat, shard_flat)):
        entry = entries[i]
        fname = entry["file"].replace("p0_", f"p{pidx}_") \
            if jax.process_count() > 1 else entry["file"]
        try:
            arr = np.load(d / fname)
        except Exception as e:
            raise CorruptCheckpoint(
                f"{d}: unreadable leaf {fname} ({e!r})") from e
        want_crc = entry.get("crc32")
        if want_crc is not None and zlib.crc32(arr.tobytes()) != want_crc:
            raise CorruptCheckpoint(
                f"{d}: leaf {fname} checksum mismatch")
        want_shape = tuple(getattr(spec, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"leaf {entry['key']}: checkpoint shape {arr.shape} != "
                f"state shape {want_shape}")
        want_dtype = getattr(spec, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def gc_old(directory, keep: int = 3) -> List[int]:
    """Delete all but the newest ``keep`` committed checkpoints."""
    steps = list_steps(directory)
    victims = steps[:-keep] if keep > 0 else steps
    for s in victims:
        shutil.rmtree(_step_dir(Path(directory), s), ignore_errors=True)
    return victims
