"""Sharded, atomic, async checkpointing (the fault-tolerance substrate).

Layout on disk::

    <dir>/step_000100/
        MANIFEST.json        # pytree structure, shapes, dtypes, step, mesh
        p0_l00000.npy ...    # one file per leaf per process
        COMMITTED            # written last: restore ignores uncommitted dirs

Write protocol (crash-safe): leaves are written into ``step_N.tmp``,
fsynced, the directory is atomically renamed to ``step_N``, and only then
the COMMITTED marker is created.  A process killed at any point leaves
either a complete committed checkpoint or an ignorable partial one —
restart always finds the newest committed step (checkpoint/restart fault
tolerance; exercised by tests/test_runtime.py::test_supervisor_restart).

On a multi-host pod each process saves only the leaf shards it owns
(``process_index`` names the files); restore device_puts with the target
sharding, so a checkpoint written on one mesh can be read onto another
(elastic remesh path — see repro.runtime.elastic).

``save_async`` copies leaves to host synchronously (cheap) and does the
file I/O on a background thread so the train loop never blocks on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "list_steps",
           "load_meta", "gc_old"]

_MANIFEST = "MANIFEST.json"
_COMMITTED = "COMMITTED"


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def _step_dir(directory: Path, step: int) -> Path:
    return directory / f"step_{step:08d}"


def save(directory: str | os.PathLike, state: Any, step: int,
         process_index: Optional[int] = None,
         meta: Optional[Dict[str, Any]] = None) -> Path:
    """Write a committed checkpoint for ``state`` at ``step``.

    ``meta``, when given, is JSON-serializable side data stored in the
    manifest — non-array parts of the state (e.g. a serving session's
    dirty representation and warmed plan signatures) that ride the same
    commit protocol as the arrays.
    """
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    pidx = jax.process_index() if process_index is None else process_index
    final = _step_dir(directory, step)
    tmp = final.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = _leaf_paths(state)
    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "leaves": [],
        "process_count": jax.process_count(),
        "meta": meta or {},
    }
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"p{pidx}_l{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    with (tmp / _MANIFEST).open("w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():  # pragma: no cover - overwrite semantics
        shutil.rmtree(final)
    os.replace(tmp, final)
    (final / _COMMITTED).touch()
    return final


class _AsyncSaver:
    """One in-flight save at a time; join() before the next or at exit."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def submit(self, directory, state, step, meta=None):
        self.join()
        host_state = jax.tree.map(lambda l: np.asarray(jax.device_get(l)),
                                  state)

        def work():
            try:
                save(directory, host_state, step, meta=meta)
            except BaseException as e:  # pragma: no cover
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def join(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:  # pragma: no cover
            e, self._error = self._error, None
            raise e


_SAVER = _AsyncSaver()


def save_async(directory, state, step, meta=None) -> None:
    """Device->host copy now, disk I/O on a background thread."""
    _SAVER.submit(directory, state, step, meta=meta)


def wait_for_async_saves() -> None:
    _SAVER.join()


def list_steps(directory) -> List[int]:
    directory = Path(directory)
    if not directory.exists():
        return []
    steps = []
    for d in directory.iterdir():
        if d.is_dir() and d.name.startswith("step_") and \
                (d / _COMMITTED).exists():
            steps.append(int(d.name.split("_")[1]))
    return sorted(steps)


def latest_step(directory) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def load_meta(directory, step: Optional[int] = None) -> Dict[str, Any]:
    """The ``meta`` side data of a committed checkpoint (``{}`` for
    checkpoints written before meta existed)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory}")
    d = _step_dir(directory, step)
    if not (d / _COMMITTED).exists():
        raise FileNotFoundError(f"checkpoint {d} not committed")
    manifest = json.loads((d / _MANIFEST).read_text())
    return manifest.get("meta", {})


def restore(directory, abstract_state: Any, step: Optional[int] = None,
            shardings: Any = None, process_index: Optional[int] = None) -> Any:
    """Read a committed checkpoint into the structure of abstract_state.

    ``shardings`` (same pytree structure, or None) controls device_put —
    pass shardings resolved on the *current* mesh to restore onto a
    different topology than the one that saved (elastic restart).
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory}")
    d = _step_dir(directory, step)
    if not (d / _COMMITTED).exists():
        raise FileNotFoundError(f"checkpoint {d} not committed")
    manifest = json.loads((d / _MANIFEST).read_text())
    pidx = jax.process_index() if process_index is None else process_index

    flat, treedef = jax.tree_util.tree_flatten(abstract_state)
    if len(flat) != manifest["num_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['num_leaves']} leaves, "
            f"state expects {len(flat)}")
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    out = []
    for i, (spec, sh) in enumerate(zip(flat, shard_flat)):
        entry = manifest["leaves"][i]
        fname = entry["file"].replace("p0_", f"p{pidx}_") \
            if jax.process_count() > 1 else entry["file"]
        arr = np.load(d / fname)
        want_shape = tuple(getattr(spec, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"leaf {entry['key']}: checkpoint shape {arr.shape} != "
                f"state shape {want_shape}")
        want_dtype = getattr(spec, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def gc_old(directory, keep: int = 3) -> List[int]:
    """Delete all but the newest ``keep`` committed checkpoints."""
    steps = list_steps(directory)
    victims = steps[:-keep] if keep > 0 else steps
    for s in victims:
        shutil.rmtree(_step_dir(Path(directory), s), ignore_errors=True)
    return victims
