"""Training supervisor: checkpoint/restart fault tolerance + stragglers.

The supervisor owns the train loop.  Every ``ckpt_every`` steps it saves
asynchronously (device->host copy on the loop thread, disk I/O off it).
When a step raises — a real XLA/runtime error on hardware, or an injected
fault in tests — it rebuilds state from the newest committed checkpoint
and replays.  Determinism of the data pipeline (batch = f(seed, step))
makes the replay exact: the loss curve after a crash is bitwise the curve
without one, which tests assert.

Straggler mitigation: on real pods, a slow host shows up as a slow
*step* (SPMD barriers).  ``StepTimer`` keeps an EWMA and flags steps
slower than ``straggler_factor`` x the mean; the supervisor records the
event and (configurably) triggers a checkpoint so the launcher can evict
the slow host and resume elastically — the remesh itself is
``repro.runtime.elastic``.

Both the timer and the supervisor route their events through a
``repro.obs.MetricRegistry`` when one is passed (straggler / restart /
checkpoint events, ``step_ms`` histogram) — the same registry the
propagation recorder feeds, so one JSONL sink captures the whole run.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax

from .. import ckpt as ckpt_lib
from ..obs.metrics import MetricRegistry

__all__ = ["Supervisor", "FaultInjector", "StepTimer"]


class FaultInjector:
    """Raise at given steps (once each) — the test stand-in for node loss."""

    def __init__(self, fail_at: Optional[List[int]] = None):
        self.fail_at = set(fail_at or [])
        self.fired: List[int] = []

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.fired.append(step)
            raise RuntimeError(f"injected fault at step {step}")


class StepTimer:
    """EWMA step timer; flags stragglers."""

    def __init__(self, alpha: float = 0.2, straggler_factor: float = 3.0,
                 warmup: int = 3,
                 registry: Optional[MetricRegistry] = None):
        self.alpha = alpha
        self.factor = straggler_factor
        self.warmup = warmup
        self.mean: Optional[float] = None
        self.count = 0
        self.straggler_steps: List[int] = []
        self.registry = registry

    def observe(self, step: int, dt: float) -> bool:
        self.count += 1
        if self.registry is not None:
            self.registry.histogram("step_ms").observe(dt * 1e3)
        if self.mean is None:
            self.mean = dt
            return False
        is_straggler = (self.count > self.warmup
                        and dt > self.factor * self.mean)
        if is_straggler:
            self.straggler_steps.append(step)
            if self.registry is not None:
                self.registry.counter("stragglers").inc()
                self.registry.event("straggler", step=step, dt_ms=dt * 1e3,
                                    mean_ms=self.mean * 1e3)
        else:
            # stragglers don't pollute the baseline
            self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
        return is_straggler


@dataclasses.dataclass
class Supervisor:
    """Run ``total_steps`` of ``step_fn`` with checkpoint/restart.

    Restart discipline: restarts are budgeted over a **sliding window**
    (``max_restarts`` within ``restart_window_s``), not over the
    process lifetime — a long healthy run does not accumulate license
    to hot-loop later — and consecutive failures back off
    exponentially (``restart_backoff_s`` doubling up to
    ``restart_backoff_max_s``) so a persistent fault cannot spin the
    restore path.  Device loss (an exception flagging
    ``device_loss=True``, e.g. ``runtime.faults.DeviceLost``) routes
    through ``remesh_fn`` first, which rebuilds the execution context
    on the surviving topology (``elastic.remesh_shards`` picks the new
    shard count) before the checkpoint restore replays onto it.
    """

    step_fn: Callable[[Any, Dict], tuple]     # (state, batch) -> (state, metrics)
    pipeline: Any                             # repro.data.DataPipeline
    ckpt_dir: str
    init_state: Callable[[], Any]             # build step-0 state
    ckpt_every: int = 50
    keep: int = 3
    fault_injector: Optional[FaultInjector] = None
    max_restarts: int = 10
    restart_window_s: float = 300.0
    restart_backoff_s: float = 0.05
    restart_backoff_max_s: float = 2.0
    on_straggler: Optional[Callable[[int], None]] = None
    registry: Optional[MetricRegistry] = None
    # Pluggable restore: (ckpt_dir, step) -> state.  Defaults to the
    # train-shaped path (eval_shape over init_state + ckpt.restore);
    # states whose abstract shape is not derivable from init_state —
    # e.g. a serving session's propagation state — pass their own
    # (repro.serve.forest.restore_session is the serving one).
    restore_fn: Optional[Callable[[str, int], Any]] = None
    # Device-loss hook: rebuild the execution context (smaller mesh,
    # re-frozen plans) before restore.  Receives the exception.
    remesh_fn: Optional[Callable[[BaseException], None]] = None

    def __post_init__(self):
        self.timer = StepTimer(registry=self.registry)
        self.restarts = 0
        self.device_losses = 0
        self.metrics_log: List[Dict] = []
        self._restart_times: List[float] = []
        self._failstreak = 0

    def _emit(self, event: str, **fields) -> None:
        if self.registry is not None:
            self.registry.counter(event + "s").inc()
            self.registry.event(event, **fields)

    # ------------------------------------------------------------------
    def _restore_or_init(self):
        # verify=True: a corrupt/partial newest checkpoint is skipped
        # (and counted) in favor of the previous committed step, so a
        # crash during save can never wedge the restart path.
        step = ckpt_lib.latest_step(self.ckpt_dir, verify=True)
        if step is None:
            state = self.init_state()
            return state, 0
        if self.restore_fn is not None:
            return self.restore_fn(self.ckpt_dir, step), step
        abstract = jax.eval_shape(self.init_state)
        state = ckpt_lib.restore(self.ckpt_dir, abstract, step=step)
        return state, step

    def _log_metrics(self, step: int, metrics: Dict) -> None:
        # Replay after a restore re-runs steps already logged: truncate
        # the tail at the replay point so the log holds one entry per
        # step (the final, surviving trajectory — which determinism
        # makes bitwise equal to the discarded one anyway).
        while self.metrics_log and self.metrics_log[-1]["step"] >= step:
            self.metrics_log.pop()
        self.metrics_log.append(
            {"step": step, **{k: float(v) for k, v in metrics.items()}})

    def _recover(self, exc: BaseException):
        """One rung of the restart ladder: budget check, backoff,
        optional remesh, restore."""
        now = time.monotonic()
        self.restarts += 1
        self._failstreak += 1
        self._restart_times.append(now)
        cutoff = now - self.restart_window_s
        self._restart_times = [t for t in self._restart_times if t >= cutoff]
        if len(self._restart_times) > self.max_restarts:
            raise exc
        backoff = min(self.restart_backoff_s * (2 ** (self._failstreak - 1)),
                      self.restart_backoff_max_s)
        time.sleep(backoff)
        ckpt_lib.wait_for_async_saves()
        if getattr(exc, "device_loss", False):
            self.device_losses += 1
            if self.registry is not None:
                self.registry.counter("device_losses").inc()
                self.registry.event("device_loss", error=repr(exc))
            if self.remesh_fn is not None:
                self.remesh_fn(exc)
        t0 = time.perf_counter()
        state, step = self._restore_or_init()
        self._emit("restart", step=step, restarts=self.restarts,
                   backoff_s=backoff,
                   recovery_ms=(time.perf_counter() - t0) * 1e3)
        return state, step

    def run(self, total_steps: int) -> Any:
        state, start = self._restore_or_init()
        self.pipeline.step = start
        step = start
        while step < total_steps:
            try:
                batch = self.pipeline.batch_at(step)
                if self.fault_injector is not None:
                    self.fault_injector.maybe_fail(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(metrics)
                dt = time.perf_counter() - t0
                if self.timer.observe(step, dt) and self.on_straggler:
                    self.on_straggler(step)
                self._log_metrics(step, metrics)
                step += 1
            except Exception as e:
                state, step = self._recover(e)
                continue
            self._failstreak = 0
            # Checkpoint I/O runs outside the step's try scope: a save
            # failure is an operator problem, not a step failure — the
            # restart path must not re-run (and double-log) a step that
            # already succeeded.
            if step % self.ckpt_every == 0:
                ckpt_lib.save_async(self.ckpt_dir, state, step)
                ckpt_lib.gc_old(self.ckpt_dir, keep=self.keep)
                self._emit("checkpoint", step=step, kind="async")
        ckpt_lib.wait_for_async_saves()
        ckpt_lib.save(self.ckpt_dir, state, total_steps)
        self._emit("checkpoint", step=total_steps, kind="final")
        return state
