"""Training supervisor: checkpoint/restart fault tolerance + stragglers.

The supervisor owns the train loop.  Every ``ckpt_every`` steps it saves
asynchronously (device->host copy on the loop thread, disk I/O off it).
When a step raises — a real XLA/runtime error on hardware, or an injected
fault in tests — it rebuilds state from the newest committed checkpoint
and replays.  Determinism of the data pipeline (batch = f(seed, step))
makes the replay exact: the loss curve after a crash is bitwise the curve
without one, which tests assert.

Straggler mitigation: on real pods, a slow host shows up as a slow
*step* (SPMD barriers).  ``StepTimer`` keeps an EWMA and flags steps
slower than ``straggler_factor`` x the mean; the supervisor records the
event and (configurably) triggers a checkpoint so the launcher can evict
the slow host and resume elastically — the remesh itself is
``repro.runtime.elastic``.

Both the timer and the supervisor route their events through a
``repro.obs.MetricRegistry`` when one is passed (straggler / restart /
checkpoint events, ``step_ms`` histogram) — the same registry the
propagation recorder feeds, so one JSONL sink captures the whole run.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax

from .. import ckpt as ckpt_lib
from ..obs.metrics import MetricRegistry

__all__ = ["Supervisor", "FaultInjector", "StepTimer"]


class FaultInjector:
    """Raise at given steps (once each) — the test stand-in for node loss."""

    def __init__(self, fail_at: Optional[List[int]] = None):
        self.fail_at = set(fail_at or [])
        self.fired: List[int] = []

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.fired.append(step)
            raise RuntimeError(f"injected fault at step {step}")


class StepTimer:
    """EWMA step timer; flags stragglers."""

    def __init__(self, alpha: float = 0.2, straggler_factor: float = 3.0,
                 warmup: int = 3,
                 registry: Optional[MetricRegistry] = None):
        self.alpha = alpha
        self.factor = straggler_factor
        self.warmup = warmup
        self.mean: Optional[float] = None
        self.count = 0
        self.straggler_steps: List[int] = []
        self.registry = registry

    def observe(self, step: int, dt: float) -> bool:
        self.count += 1
        if self.registry is not None:
            self.registry.histogram("step_ms").observe(dt * 1e3)
        if self.mean is None:
            self.mean = dt
            return False
        is_straggler = (self.count > self.warmup
                        and dt > self.factor * self.mean)
        if is_straggler:
            self.straggler_steps.append(step)
            if self.registry is not None:
                self.registry.counter("stragglers").inc()
                self.registry.event("straggler", step=step, dt_ms=dt * 1e3,
                                    mean_ms=self.mean * 1e3)
        else:
            # stragglers don't pollute the baseline
            self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
        return is_straggler


@dataclasses.dataclass
class Supervisor:
    """Run ``total_steps`` of ``step_fn`` with checkpoint/restart."""

    step_fn: Callable[[Any, Dict], tuple]     # (state, batch) -> (state, metrics)
    pipeline: Any                             # repro.data.DataPipeline
    ckpt_dir: str
    init_state: Callable[[], Any]             # build step-0 state
    ckpt_every: int = 50
    keep: int = 3
    fault_injector: Optional[FaultInjector] = None
    max_restarts: int = 10
    on_straggler: Optional[Callable[[int], None]] = None
    registry: Optional[MetricRegistry] = None
    # Pluggable restore: (ckpt_dir, step) -> state.  Defaults to the
    # train-shaped path (eval_shape over init_state + ckpt.restore);
    # states whose abstract shape is not derivable from init_state —
    # e.g. a serving session's propagation state — pass their own
    # (repro.serve.forest.restore_session is the serving one).
    restore_fn: Optional[Callable[[str, int], Any]] = None

    def __post_init__(self):
        self.timer = StepTimer(registry=self.registry)
        self.restarts = 0
        self.metrics_log: List[Dict] = []

    def _emit(self, event: str, **fields) -> None:
        if self.registry is not None:
            self.registry.counter(event + "s").inc()
            self.registry.event(event, **fields)

    # ------------------------------------------------------------------
    def _restore_or_init(self):
        step = ckpt_lib.latest_step(self.ckpt_dir)
        if step is None:
            state = self.init_state()
            return state, 0
        if self.restore_fn is not None:
            return self.restore_fn(self.ckpt_dir, step), step
        abstract = jax.eval_shape(self.init_state)
        state = ckpt_lib.restore(self.ckpt_dir, abstract, step=step)
        return state, step

    def run(self, total_steps: int) -> Any:
        state, start = self._restore_or_init()
        self.pipeline.step = start
        step = start
        while step < total_steps:
            try:
                batch = self.pipeline.batch_at(step)
                if self.fault_injector is not None:
                    self.fault_injector.maybe_fail(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(metrics)
                dt = time.perf_counter() - t0
                if self.timer.observe(step, dt) and self.on_straggler:
                    self.on_straggler(step)
                self.metrics_log.append(
                    {"step": step,
                     **{k: float(v) for k, v in metrics.items()}})
                step += 1
                if step % self.ckpt_every == 0:
                    ckpt_lib.save_async(self.ckpt_dir, state, step)
                    ckpt_lib.gc_old(self.ckpt_dir, keep=self.keep)
                    self._emit("checkpoint", step=step, kind="async")
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                ckpt_lib.wait_for_async_saves()
                state, step = self._restore_or_init()
                self._emit("restart", step=step, restarts=self.restarts)
        ckpt_lib.wait_for_async_saves()
        ckpt_lib.save(self.ckpt_dir, state, total_steps)
        self._emit("checkpoint", step=total_steps, kind="final")
        return state
