"""Distributed-runtime services: supervision, elasticity, compression.

  * ``supervisor``  — checkpoint/restart training supervisor with fault
    injection hooks, step-time straggler tracking, and periodic async
    checkpoints.  The restart path is exactly what a pod-level launcher
    executes after a node failure.
  * ``elastic``     — reshard a training state + data pipeline onto a new
    mesh (scale down after failures / scale up after repair).
  * ``compression`` — gradient compression hooks for the cross-pod
    all-reduce (top-k with error feedback, int8 quantization).
  * ``faults``      — deterministic chaos injection: a seeded,
    schedule-driven ``ChaosInjector`` firing at named sites threaded
    through the stack (host syncs, forest commits, checkpoint I/O,
    session evict/revive, simulated device loss).
"""
from .supervisor import Supervisor, FaultInjector, StepTimer
from .elastic import reshard_state, remesh_plan, remesh_shards
from .compression import make_compressor
from .faults import (ChaosInjector, DeviceLost, FatalInjectedFault,
                     FaultSpec, InjectedFault, is_transient)

__all__ = ["Supervisor", "FaultInjector", "StepTimer", "reshard_state",
           "remesh_plan", "remesh_shards", "make_compressor",
           "ChaosInjector", "FaultSpec", "InjectedFault",
           "FatalInjectedFault", "DeviceLost", "is_transient"]
