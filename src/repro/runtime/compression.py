"""Gradient compression for the cross-pod (DCI) all-reduce.

The 'pod' mesh axis rides data-center interconnect at a fraction of ICI
bandwidth, so the cross-pod gradient reduction is the first wire
bottleneck at multi-pod scale.  Hooks (plugged into
``make_train_step(grad_compression=...)``):

  * ``topk``  — per-leaf magnitude top-k sparsification with **error
    feedback**: the un-sent residual is carried and added to the next
    step's gradient, preserving convergence (Stich et al.; Lin et al.,
    Deep Gradient Compression).
  * ``int8``  — symmetric per-leaf quantization with stochastic
    rounding; 4x wire reduction, unbiased.

Both are pure pytree->pytree functions applied *before* the optimizer,
mirroring where a production system hooks the reducer.  The compressor
carries its residual state functionally (returned alongside the grads).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["make_compressor"]


def _topk_leaf(g: jax.Array, frac: float) -> jax.Array:
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(int(flat.size * frac), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
    return kept.reshape(g.shape)


@dataclasses.dataclass
class TopKCompressor:
    """Magnitude top-k with error feedback; stateful via ``residual``."""

    frac: float = 0.05
    residual: Optional[Any] = None

    def __call__(self, grads: Any) -> Any:
        if self.residual is None:
            self.residual = jax.tree.map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads)
        corrected = jax.tree.map(
            lambda g, r: g.astype(jnp.float32) + r, grads, self.residual)
        sent = jax.tree.map(lambda g: _topk_leaf(g, self.frac), corrected)
        self.residual = jax.tree.map(lambda g, s: g - s, corrected, sent)
        return jax.tree.map(lambda s, g: s.astype(g.dtype), sent, grads)


def _int8_roundtrip(g: jax.Array, key: jax.Array) -> jax.Array:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    scaled = gf / scale
    noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * scale).astype(g.dtype)


@dataclasses.dataclass
class Int8Compressor:
    seed: int = 0

    def __post_init__(self):
        self._step = 0

    def __call__(self, grads: Any) -> Any:
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        keys = jax.random.split(
            jax.random.PRNGKey(self.seed + self._step), len(leaves))
        self._step += 1
        out = [_int8_roundtrip(g, k) for g, k in zip(leaves, keys)]
        return jax.tree_util.tree_unflatten(treedef, out)


def make_compressor(kind: Optional[str], **kw) -> Optional[Callable]:
    if kind in (None, "none"):
        return None
    if kind == "topk":
        return TopKCompressor(**kw)
    if kind == "int8":
        return Int8Compressor(**kw)
    raise ValueError(f"unknown compressor {kind!r}")
