"""Elastic scaling: reshard training state onto a new mesh.

After a node failure the launcher restarts with fewer (or, post-repair,
more) hosts.  Two paths re-establish the run:

  * **checkpoint path** — ``ckpt.restore`` with shardings resolved on the
    new mesh (each process reads its new shard range from the committed
    checkpoint).  Works across any topology change; costs a disk read.
  * **live path** — ``reshard_state``: device-to-device redistribution of
    a live state via ``jax.device_put`` with the new NamedShardings (XLA
    inserts the minimal collective-permute/all-gather schedule).  Used
    for planned elasticity (scale-up) where the old devices still exist.

``remesh_plan`` picks the largest (data, model)-factorization that the
surviving chip count supports while keeping the model axis unchanged —
TP degree is baked into layout/kernels, while the data axis is freely
re-divisible as long as it divides the global batch (the deterministic
pipeline re-slices exactly; see repro.data.DataPipeline.reshard).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from ..shardlib import ShardCtx, rules_for_mode

__all__ = ["remesh_plan", "remesh_shards", "reshard_state"]


def remesh_shards(surviving_devices: int, num_blocks: int) -> int:
    """New shard count for a block-sharded propagation handle after
    device loss: the largest count ≤ ``surviving_devices`` that divides
    ``num_blocks`` (the mesh axis must divide the block grid), down to
    1 (single-device fallback always works)."""
    assert surviving_devices >= 1, surviving_devices
    s = max(1, min(int(surviving_devices), int(num_blocks)))
    while s > 1 and num_blocks % s != 0:
        s -= 1
    return s


def remesh_plan(surviving_chips: int, model_parallel: int,
                global_batch: int) -> Tuple[int, int]:
    """(data, model) for the new mesh.  Keeps TP fixed; maximizes DP.

    Drops chips that don't fit the factorization (a 255-chip survivor
    set runs as 15x16 with one idle chip, etc.)."""
    assert surviving_chips >= model_parallel, "cannot keep TP degree"
    data = surviving_chips // model_parallel
    while data > 1 and global_batch % data != 0:
        data -= 1
    return data, model_parallel


def reshard_state(state: Any, axes_tree: Any, new_mesh: Mesh,
                  mode: str = "train") -> Any:
    """device_put every leaf with its sharding resolved on ``new_mesh``.

    ``axes_tree`` carries logical axes per leaf (same structure as state;
    None leaves replicate).  XLA emits the redistribution collectives.
    """
    ctx = ShardCtx(new_mesh, rules_for_mode(mode))

    def put(leaf, axes):
        if axes is None:
            return jax.device_put(leaf, NamedSharding(new_mesh, ctx.resolve(())))
        spec = ctx.resolve(axes, getattr(leaf, "shape", None))
        return jax.device_put(leaf, NamedSharding(new_mesh, spec))

    return jax.tree.map(put, state, axes_tree,
                        is_leaf=lambda x: x is None or not isinstance(x, (dict, list, tuple)))
