"""Deterministic chaos injection: seeded, schedule-driven fault sites.

The paper's determinism contract — change propagation reproduces the
from-scratch run exactly — makes recovery *verifiable*: after any
crash, retry, or rollback the served state must be bitwise identical
to a fault-free replay.  Verifying that needs faults that are
themselves reproducible, so this module injects them from a **seeded
schedule** rather than ad-hoc monkeypatching: the same
``(schedule, seed)`` fires the same faults at the same site visits on
every run, and a chaos test that fails replays exactly.

Named injection sites are threaded through the stack (each is one
``inject(site)`` call, a no-op global load when no injector is
installed):

=================  ========================================================
``sync.<tag>``     every host sync (``obs.syncpoints.HOOK`` — the injector
                   chains onto the existing hook while installed)
``forest.commit``  COW commit dispatch, *before* the split executable runs
                   (a fault here is side-effect-free by the forest's
                   staged-refcount contract, hence retryable)
``forest.oracle``  the ``plan=False`` copy-oracle fallback dispatch
``ckpt.save``      checkpoint write entry (before leaf I/O)
``ckpt.commit``    just before the atomic rename — a fault here leaves a
                   partial ``step_N.tmp`` the loader must ignore
``ckpt.load``      checkpoint read entry
``session.evict``  session checkpoint-out (before ``save_session``)
``session.revive`` session restore (before ``restore_session``)
``device.loss``    sharded (``mesh=``) propagate dispatch — the simulated
                   shard/device failure (raises :class:`DeviceLost`)
=================  ========================================================

Schedules are lists of :class:`FaultSpec`: fire at the n-th visit of a
site (``at=``), with per-visit probability (``p=``), bounded by
``times=``.  Probability draws are keyed on ``(seed, spec, site,
visit)`` — not on a shared stream — so the decision for a given site
visit is independent of how other sites interleave: concurrency or
scheduling changes elsewhere cannot shift which faults fire.

Usage::

    schedule = [FaultSpec("forest.commit", p=0.25),
                FaultSpec("ckpt.commit", at=(2,))]
    inj = ChaosInjector(schedule, seed=7)
    with inj:            # installs the global injector + sync hook
        ...serve under chaos...
    inj.fired            # the reproducible fault log
"""
from __future__ import annotations

import dataclasses
import fnmatch
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["InjectedFault", "FatalInjectedFault", "DeviceLost",
           "FaultSpec", "ChaosInjector", "inject", "install", "uninstall",
           "is_transient"]


class InjectedFault(RuntimeError):
    """A scheduled fault.  ``transient=True``: the operation is safe to
    retry (the site guarantees failure before side effects)."""

    transient = True
    device_loss = False

    def __init__(self, site: str, visit: int):
        super().__init__(f"injected fault at {site} (visit {visit})")
        self.site = site
        self.visit = visit


class FatalInjectedFault(InjectedFault):
    """A scheduled non-retryable fault (poison request / corrupt-state
    class of failure): retry must NOT be attempted."""

    transient = False


class DeviceLost(InjectedFault):
    """Simulated device/shard loss: not retryable in place — recovery
    is restore-from-checkpoint onto a surviving mesh
    (``runtime.elastic.remesh_shards`` + ``Supervisor.remesh_fn``)."""

    transient = False
    device_loss = True


_KINDS = {"transient": InjectedFault, "fatal": FatalInjectedFault,
          "device_loss": DeviceLost}


def is_transient(exc: BaseException) -> bool:
    """Retry policy predicate: an exception is retryable iff it marks
    itself so (``exc.transient``).  Injected transient faults qualify;
    anything else — including real runtime errors of unknown
    provenance — defaults to non-retryable."""
    return bool(getattr(exc, "transient", False))


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One line of a chaos schedule.

    ``site`` is an ``fnmatch`` pattern over site names (``"sync.*"``
    matches every host sync).  The spec fires at the listed 1-based
    ``at`` visit numbers of each matching site, and/or with per-visit
    probability ``p``; ``times`` bounds total fires (default: ``len(at)``
    when only ``at`` is given, unlimited otherwise)."""

    site: str
    at: Tuple[int, ...] = ()
    p: float = 0.0
    times: Optional[int] = None
    kind: str = "transient"          # transient | fatal | device_loss

    def __post_init__(self):
        assert self.kind in _KINDS, self.kind
        assert 0.0 <= self.p <= 1.0, self.p
        if self.times is None and not self.p:
            object.__setattr__(self, "times", len(self.at) or None)


class ChaosInjector:
    """Fires a seeded :class:`FaultSpec` schedule at named sites.

    Use as a context manager: ``__enter__`` installs it as the global
    injector (``inject(site)`` routes here) and chains onto
    ``obs.syncpoints.HOOK`` so every host sync becomes a ``sync.<tag>``
    site; ``__exit__`` restores both.  ``fired`` is the fault log:
    ``(site, visit, kind)`` in fire order — identical across runs with
    the same schedule, seed and per-site visit sequences."""

    def __init__(self, schedule: Sequence[FaultSpec], seed: int = 0):
        self.schedule = list(schedule)
        self.seed = int(seed)
        self.visits: Dict[str, int] = {}
        self.fired: List[Tuple[str, int, str]] = []
        self._remaining = [s.times for s in self.schedule]
        self._prev_hook: Any = None
        self._installed = False

    # -- deterministic per-(spec, site, visit) probability draw --------
    def _draw(self, spec_idx: int, site: str, visit: int) -> float:
        key = (self.seed, spec_idx, zlib.crc32(site.encode()), visit)
        return float(np.random.default_rng(key).random())

    def fire(self, site: str, **ctx) -> None:
        """Visit ``site``; raise if the schedule says so."""
        visit = self.visits.get(site, 0) + 1
        self.visits[site] = visit
        for i, spec in enumerate(self.schedule):
            if self._remaining[i] == 0:
                continue
            if not fnmatch.fnmatchcase(site, spec.site):
                continue
            hit = visit in spec.at or (
                spec.p > 0.0 and self._draw(i, site, visit) < spec.p)
            if not hit:
                continue
            if self._remaining[i] is not None:
                self._remaining[i] -= 1
            self.fired.append((site, visit, spec.kind))
            raise _KINDS[spec.kind](site, visit)

    def fired_sites(self) -> set:
        return {site for site, _v, _k in self.fired}

    # -- installation --------------------------------------------------
    def __enter__(self) -> "ChaosInjector":
        install(self)
        from repro.obs import syncpoints

        self._prev_hook = syncpoints.HOOK
        prev = self._prev_hook

        def hook(tag: str, kind: str) -> None:
            if prev is not None:
                prev(tag, kind)
            self.fire(f"sync.{tag}")

        syncpoints.HOOK = hook
        self._installed = True
        return self

    def __exit__(self, *exc) -> None:
        from repro.obs import syncpoints

        if self._installed:
            syncpoints.HOOK = self._prev_hook
            self._installed = False
        uninstall(self)


# ---------------------------------------------------------------------------
# The global injection point.  Module-global (not a contextvar): faults
# must reach code running on worker threads too (the async checkpoint
# saver), and chaos tests install exactly one injector at a time.
# ---------------------------------------------------------------------------
_INJECTOR: Optional[ChaosInjector] = None


def install(injector: ChaosInjector) -> None:
    global _INJECTOR
    assert _INJECTOR is None or _INJECTOR is injector, \
        "another ChaosInjector is already installed"
    _INJECTOR = injector


def uninstall(injector: Optional[ChaosInjector] = None) -> None:
    global _INJECTOR
    if injector is None or _INJECTOR is injector:
        _INJECTOR = None


def inject(site: str, **ctx) -> None:
    """The per-site hook: a no-op global load unless a
    :class:`ChaosInjector` is installed."""
    if _INJECTOR is not None:
        _INJECTOR.fire(site, **ctx)
