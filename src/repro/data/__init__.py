"""Deterministic, sharded, resumable token pipeline.

Design constraints at pod scale:

  * **Determinism** — batch contents are a pure function of (seed, step,
    shard), via a counter-mode PRNG over document indices.  No iterator
    state lives anywhere but the integer ``step``, so checkpoint/restart
    reproduces the exact batch sequence (the data-side requirement for
    the paper's determinism restriction AND for elastic restart).
  * **Sharding** — each data-parallel rank draws a disjoint slice of the
    global batch; re-slicing under a different rank count is exact as
    long as the global batch divides, so an elastic remesh (Section
    repro.runtime.elastic) replays without sample loss or duplication.
  * **Resumability** — ``state_dict()`` is just {'step': int}.

The corpus here is a synthetic-but-structured token stream (mixture of
Zipfian unigrams and repeated n-gram motifs so models have learnable
signal); a production deployment swaps ``TokenSource`` for a tokenized
corpus reader with the same (seed, index) -> document contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

__all__ = ["TokenSource", "DataPipeline"]


class TokenSource:
    """(seed, doc_index) -> token document; stateless and O(1) seekable."""

    def __init__(self, vocab_size: int, seed: int = 0, doc_len: int = 1024):
        self.vocab_size = vocab_size
        self.seed = seed
        self.doc_len = doc_len
        base = np.random.default_rng(seed)
        # Zipfian unigram table + a bank of n-gram motifs shared corpus-wide.
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._motifs = base.integers(0, vocab_size,
                                     size=(64, 16)).astype(np.int32)

    def document(self, index: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed << 32) ^ (index * 0x9E3779B9))
        toks = rng.choice(self.vocab_size, size=self.doc_len,
                          p=self._probs).astype(np.int32)
        # plant motifs: repeated structure gives the LM something to learn
        n_motifs = rng.integers(2, 8)
        for _ in range(n_motifs):
            m = self._motifs[rng.integers(0, len(self._motifs))]
            at = rng.integers(0, self.doc_len - len(m))
            toks[at:at + len(m)] = m
        return toks


@dataclasses.dataclass
class DataPipeline:
    """Deterministic global-batch pipeline with per-rank sharding."""

    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    shard_id: int = 0
    num_shards: int = 1
    step: int = 0

    def __post_init__(self):
        assert self.global_batch % self.num_shards == 0, \
            (self.global_batch, self.num_shards)
        self.local_batch = self.global_batch // self.num_shards
        self._source = TokenSource(self.vocab_size, self.seed,
                                   doc_len=self.seq_len + 1)

    # -- resumability ------------------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        assert state["seed"] == self.seed, "restoring a different stream"
        self.step = int(state["step"])

    def reshard(self, shard_id: int, num_shards: int) -> "DataPipeline":
        """Same stream, new rank layout (elastic remesh): batches at any
        step are globally identical, sliced differently."""
        return DataPipeline(self.vocab_size, self.global_batch, self.seq_len,
                            self.seed, shard_id, num_shards, self.step)

    # -- batches ---------------------------------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The shard-local batch for an absolute step (pure function)."""
        base = step * self.global_batch + self.shard_id * self.local_batch
        docs = [self._source.document(base + i)
                for i in range(self.local_batch)]
        arr = np.stack(docs)
        return {"tokens": arr[:, :self.seq_len],
                "labels": arr[:, 1:self.seq_len + 1]}

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self
