"""Typed serving errors — the session server's failure vocabulary.

Every error a caller can see from ``SessionServer`` is one of these (or
a propagated application error from their own edit).  The types carry
the retry contract: ``retryable=True`` means the request had no effect
and resubmitting is safe (and, for :class:`ServerOverloaded`, expected
— it is backpressure, not failure).
"""
from __future__ import annotations

__all__ = ["ServeError", "UnknownSession", "ServerOverloaded",
           "ServerClosed", "DeadlineExceeded", "SessionQuarantined"]


class ServeError(RuntimeError):
    """Base of every server-raised error."""

    retryable = False


class UnknownSession(ServeError):
    """The session id does not exist or was closed."""

    def __init__(self, sid):
        super().__init__(f"unknown or closed session {sid!r}")
        self.sid = sid


class ServerOverloaded(ServeError):
    """Backpressure: the admission queue is full.  The request was never
    enqueued — retry after a backoff."""

    retryable = True

    def __init__(self, queued: int, max_queue: int):
        super().__init__(
            f"admission queue full ({queued}/{max_queue}) — retry later")
        self.queued = queued
        self.max_queue = max_queue


class ServerClosed(ServeError):
    """submit() before ``start()`` or after ``stop()``."""


class DeadlineExceeded(ServeError):
    """The request's deadline expired before its plan/commit ran; no
    propagation work was paid and the session state is untouched."""

    def __init__(self, sid, waited_ms: float):
        super().__init__(
            f"deadline exceeded for session {sid!r} after "
            f"{waited_ms:.1f}ms in queue")
        self.sid = sid
        self.waited_ms = waited_ms


class SessionQuarantined(ServeError):
    """The session's commits failed repeatedly; it was rolled back to
    its last good snapshot and quarantined.  Reads still serve the
    rolled-back state; ``SessionServer.reinstate()`` re-admits edits."""

    def __init__(self, sid):
        super().__init__(
            f"session {sid!r} is quarantined (rolled back to its last "
            f"good snapshot) — reinstate() to resume edits")
        self.sid = sid
