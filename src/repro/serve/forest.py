"""Copy-on-write state forest: cheap forks of donated propagation state.

The source paper's propagation state is nothing but node values plus
dirty metadata, so *branching* a live computation is conceptually O(1):
a fork shares every buffer with its parent until one of them writes.
This module makes that real for ``CompiledGraph``'s donated state:

  * a ``ForestState`` wraps the ``{"v": ..., "c": ...}`` propagation
    state as a flat leaf map (``"v<i>"`` node values, ``"c<i>"`` carry
    caches) with one shared refcount cell per buffer;
  * ``fork()`` is pure host metadata — the child aliases every leaf and
    bumps the refcells (no device work at all), which is what lets many
    sessions branch one warm base state, and what makes *undo* a fork
    discard (``release()``);
  * ``propagate()`` keeps the donation fast path: the mark pass freezes
    the quantized plan (``CompiledGraph.plan_update``), and only the
    leaves the plan actually touches are materialized — a touched leaf
    that is still shared is copied exactly once (copy-on-first-scatter),
    then donated to the split planned executable
    (``CompiledGraph.cow_entry``), whose in-place scatters run exactly
    as in the non-forest path.  Untouched leaves never cross the
    executable, so an edit moves O(changed nodes) buffers, not O(state).

Graphs without a single-device planned path (``plan=False`` or
``mesh=``) fall back to ``CompiledGraph.propagate_copy`` — a
non-donating propagate whose outputs are all fresh buffers, so
isolation holds there too (at full-copy cost; the sharded planned
executable donates whole-state, which an aliased state cannot allow).

Checkpoint/restore (``save_session`` / ``restore_session``) round-trips
a forest node through ``repro.ckpt`` — the array pytree bitwise, plus
the non-array parts a restored session needs to resume identically:
the dirty-representation name and the plan signatures it had warmed, so
the first post-restore propagate replans on the same algebra and hits
the shared plan cache instead of re-freezing.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import ckpt as ckpt_lib
from repro.jaxsac.graph_compile import CompiledGraph, PendingUpdate
from repro.jaxsac.plancache import plan_from_json, plan_to_json
from repro.obs import syncpoints
from repro.runtime import faults

__all__ = ["ForestState", "save_session", "restore_session"]


class _RefCell:
    """Shared refcount of one device buffer: every ForestState whose
    leaf aliases the buffer holds the same cell."""

    __slots__ = ("count",)

    def __init__(self, count: int = 1):
        self.count = count


class ForestState:
    """One node of the COW forest — a propagation state whose leaves may
    alias other forest nodes' leaves until first write."""

    def __init__(self, cg: CompiledGraph, leaves: Dict[str, jax.Array],
                 cells: Dict[str, _RefCell],
                 parent: Optional["ForestState"] = None):
        self.cg = cg
        self._leaves = leaves
        self._cells = cells
        self.parent = parent
        self.alive = True
        self.cow_copies = 0              # leaves copied-on-write, total
        self.updates = 0
        self.plan_history: List[Tuple[Any, ...]] = []

    # ------------------------------------------------------------------
    # Construction / structure
    # ------------------------------------------------------------------
    @classmethod
    def adopt(cls, cg: CompiledGraph, state: Dict[str, Any],
              ) -> "ForestState":
        """Wrap a raw ``init``/``propagate`` state.  The caller must
        stop using the raw state afterwards (the forest now owns its
        buffers and will donate them on propagate)."""
        assert isinstance(state, dict) and "v" in state, state
        leaves: Dict[str, jax.Array] = {
            f"v{i}": arr for i, arr in enumerate(state["v"])}
        for k, arr in state.get("c", {}).items():
            leaves[f"c{k}"] = arr
        cells = {key: _RefCell(1) for key in leaves}
        return cls(cg, leaves, cells)

    @property
    def state(self) -> Dict[str, Any]:
        """The raw ``{"v": tuple, "c": dict}`` view (python-side only —
        reassembling it moves no device data)."""
        n = len(self.cg.nodes)
        return {"v": tuple(self._leaves[f"v{i}"] for i in range(n)),
                "c": {str(i): self._leaves[f"c{i}"]
                      for i in self.cg.carry_nodes}}

    def __getitem__(self, key: str):
        # Duck-types the raw state dict, so ``CompiledGraph.value`` and
        # the handle facades read through a ForestState unchanged.
        return self.state[key]

    # ------------------------------------------------------------------
    # Forking
    # ------------------------------------------------------------------
    def fork(self) -> "ForestState":
        """O(leaves) host metadata, zero device work: the child aliases
        every buffer; refcells record the sharing so either side copies
        on its first write to a shared leaf."""
        assert self.alive, "fork() of a released ForestState"
        for cell in self._cells.values():
            cell.count += 1
        return ForestState(self.cg, dict(self._leaves), dict(self._cells),
                           parent=self)

    def release(self) -> None:
        """Discard this forest node (undo = fork + release): drops its
        claim on every shared buffer.  Idempotent."""
        if not self.alive:
            return
        self.alive = False
        for cell in self._cells.values():
            cell.count -= 1
        self._leaves = {}
        self._cells = {}

    # ------------------------------------------------------------------
    # Introspection (tests, benchmarks, server accounting)
    # ------------------------------------------------------------------
    def shared_keys(self) -> List[str]:
        return [k for k, c in self._cells.items() if c.count > 1]

    def aliased_keys(self, other: "ForestState") -> List[str]:
        """Leaves physically shared with ``other`` (same buffer)."""
        return [k for k, arr in self._leaves.items()
                if other._leaves.get(k) is arr]

    @property
    def num_leaves(self) -> int:
        return len(self._leaves)

    # ------------------------------------------------------------------
    # Propagation
    # ------------------------------------------------------------------
    def plan(self, new_inputs: Dict[str, Any]) -> Optional[PendingUpdate]:
        """Phase 1: mark + freeze the plan without touching state (safe
        on aliased leaves).  ``None`` means no planned path — use
        ``propagate`` which takes the copy fallback."""
        assert self.alive, "plan() on a released ForestState"
        return self.cg.plan_update(self.state, new_inputs)

    def commit(self, pending: PendingUpdate, *, t_start: float = 0.0,
               t_mark: float = 0.0) -> Dict[str, Any]:
        """Phase 2: execute a pending update through the split COW
        executable.  Copies exactly the touched-and-shared leaves first
        (each copy is then donated, so the scatter lands in the private
        buffer), dispatches, and swaps the touched leaves in."""
        assert self.alive, "commit() on a released ForestState"
        cg = self.cg
        rec = cg._recorder
        entry, hit = cg.cow_entry(pending.plan)
        t_plan = rec.clock() if rec is not None else 0.0
        donated_keys, _touched = cg.cow_touched_keys(pending.plan)
        # Copies and ownership changes are staged in temporaries and
        # applied only after the executable returns: if it raises, this
        # node still aliases the shared buffers under the old refcounts
        # (the staged private copies are simply discarded), so a failed
        # commit cannot leave a leaf claiming exclusive ownership of a
        # buffer siblings still alias.
        donated: Dict[str, jax.Array] = {}
        privatized: Dict[str, _RefCell] = {}
        copies = 0
        for key in donated_keys:
            arr = self._leaves[key]
            if self._cells[key].count > 1:   # copy-on-first-scatter
                arr = jnp.copy(arr)
                privatized[key] = _RefCell(1)
                copies += 1
            donated[key] = arr
        kept = {k: v for k, v in self._leaves.items() if k not in donated}
        # Chaos site: a fault here (or inside the dispatch) aborts with
        # the staged state intact — the retry-safety contract.
        faults.inject("forest.commit")
        out, stats = entry.fn(donated, kept, pending.inputs,
                              pending.in_masks, pending.node_masks)
        for key, cell in privatized.items():
            self._cells[key].count -= 1  # drop the shared claim
            self._cells[key] = cell
        for key, arr in out.items():
            cell = self._cells[key]
            if cell.count > 1:           # updated-input leaf still shared
                cell.count -= 1
                self._cells[key] = _RefCell(1)
            self._leaves[key] = arr
        self.cow_copies += copies
        self.updates += 1
        self._remember_plan(pending.plan)
        stats = {**stats, "cow_copies": copies,
                 "plan_cache": cg.plan_cache_snapshot()}
        if rec is not None:
            if rec.mode == "deep":
                syncpoints.fence(out, "execute")
            rec.emit(cg._build_record(
                rec, plan=pending.plan, counts_np=pending.counts, hit=hit,
                t_start=t_start or t_plan, t_mark=t_mark or t_plan,
                t_plan=t_plan, t_end=rec.clock(), stats=stats,
                level_ms=None, input_key=frozenset(pending.inputs)))
        return stats

    def propagate(self, new_inputs: Dict[str, Any]) -> Dict[str, Any]:
        """One full COW update: plan, then commit (or the non-donating
        copy fallback when the graph has no planned path)."""
        assert self.alive, "propagate() on a released ForestState"
        cg = self.cg
        rec = cg._recorder
        t_start = rec.clock() if rec is not None else 0.0
        pending = self.plan(new_inputs)
        if pending is None:
            return self.propagate_oracle(new_inputs, t_start=t_start)
        t_mark = rec.clock() if rec is not None else 0.0
        return self.commit(pending, t_start=t_start, t_mark=t_mark)

    def propagate_oracle(self, new_inputs: Dict[str, Any], *,
                         t_start: float = 0.0) -> Dict[str, Any]:
        """The ``plan=False`` copy-oracle path: non-donating propagate,
        every output leaf a fresh buffer.  Also the server's degraded
        mode — correct whenever the planned COW path misbehaves, at
        full-copy cost."""
        assert self.alive, "propagate_oracle() on a released ForestState"
        cg = self.cg
        rec = cg._recorder
        if rec is not None and not t_start:
            t_start = rec.clock()
        faults.inject("forest.oracle")
        new_state, stats = cg.propagate_copy(self.state, new_inputs)
        self._replace_all(new_state)
        self.updates += 1
        if rec is not None:
            if rec.mode == "deep":
                syncpoints.fence(new_state, "execute")
            rec.emit(cg._build_record(
                rec, plan=None, counts_np=None, hit=None,
                t_start=t_start, t_mark=t_start, t_plan=t_start,
                t_end=rec.clock(), stats=stats, level_ms=None,
                input_key=frozenset(new_inputs)))
        return stats

    # ------------------------------------------------------------------
    def _replace_all(self, new_state: Dict[str, Any]) -> None:
        """Swap in a fully fresh state (every leaf a new buffer): the
        copy-fallback epilogue.  Old claims on shared buffers drop."""
        for i, arr in enumerate(new_state["v"]):
            self._set_leaf(f"v{i}", arr)
        for k, arr in new_state.get("c", {}).items():
            self._set_leaf(f"c{k}", arr)

    def _set_leaf(self, key: str, arr: jax.Array) -> None:
        cell = self._cells[key]
        if cell.count > 1:
            cell.count -= 1
            self._cells[key] = _RefCell(1)
        self._leaves[key] = arr

    def _remember_plan(self, plan, cap: int = 16) -> None:
        if plan in self.plan_history:
            self.plan_history.remove(plan)
        self.plan_history.append(plan)
        del self.plan_history[:-cap]


# ---------------------------------------------------------------------------
# Durable sessions: checkpoint / restore of a forest node
# ---------------------------------------------------------------------------
def save_session(directory: str | os.PathLike, fstate: ForestState,
                 step: int = 0, meta: Optional[Dict[str, Any]] = None):
    """Checkpoint a forest node: the state pytree (bitwise, via
    ``repro.ckpt``'s committed-atomic protocol) plus the non-array parts
    of propagation state — dirty representation and the warmed plan
    signatures — in the manifest's ``meta``."""
    m = {"kind": "forest_session",
         "dirty_rep": fstate.cg.dirty_rep,
         "updates": fstate.updates,
         "plan_sigs": [plan_to_json(p) for p in fstate.plan_history],
         **(meta or {})}
    return ckpt_lib.save(directory, fstate.state, step, meta=m)


def restore_session(cg: CompiledGraph, directory: str | os.PathLike,
                    step: Optional[int] = None,
                    ) -> Tuple[ForestState, Dict[str, Any]]:
    """Restore a checkpointed session onto ``cg``.  The restored state
    is bitwise the saved one (every leaf a fresh exclusive buffer), and
    the saved plan signatures are re-inserted into the shared plan
    cache, so the session's next same-shaped edit is a signature hit
    even in a fresh process."""
    if step is None:
        # Pin a verified step up front so the meta and the arrays come
        # from the same checkpoint even when the newest one is corrupt.
        step = ckpt_lib.latest_step(directory, verify=True)
        if step is None:
            raise FileNotFoundError(
                f"no verifiable session checkpoint under {directory}")
    meta = ckpt_lib.load_meta(directory, step=step)
    rep = meta.get("dirty_rep", cg.dirty_rep)
    assert rep == cg.dirty_rep, (
        f"session saved under dirty rep {rep!r}, restoring onto a graph "
        f"compiled with {cg.dirty_rep!r} — plans would not be comparable")
    state = ckpt_lib.restore(directory, cg.abstract_state(), step=step)
    fstate = ForestState.adopt(cg, state)
    fstate.updates = int(meta.get("updates", 0))
    for sig in meta.get("plan_sigs", []):
        plan = plan_from_json(sig)
        fstate.plan_history.append(plan)
        cg.cow_entry(plan)               # re-warm the shared signature LRU
    return fstate, meta
