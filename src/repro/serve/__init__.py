"""repro.serve — propagation-as-a-service.

The serving subsystem turns a warm compiled handle into shared
infrastructure (the Incoop framing: incremental computation pays off
when it is *a service*, not a library call):

  * ``forest``  — the COW state forest: ``fork()`` a donated
    propagation state in O(host metadata), copy-on-first-scatter only
    the nodes the frozen plan touches, ``release()`` as undo; durable
    via ``save_session`` / ``restore_session`` (repro.ckpt);
  * ``session`` — one tenant: a forest node plus live/evicted/closed
    lifecycle;
  * ``batcher`` — the compatibility predicate and grouping: same trace
    + same quantized dirty signature → one shared plan-cache entry;
  * ``server``  — the asyncio admission queue: concurrent ``submit()``s
    admitted in waves, batched across sessions, latency-accounted
    through ``repro.obs``; hardened with backpressure, deadlines,
    retry, degradation, and quarantine (``errors`` is the typed
    failure vocabulary).

Entry point: ``handle.serve()`` on a graph-backend ``sac`` handle, or
``SessionServer(handle)`` directly.
"""
from .batcher import Batch, EditBatcher, EditRequest, compatible
from .errors import (DeadlineExceeded, ServeError, ServerClosed,
                     ServerOverloaded, SessionQuarantined, UnknownSession)
from .forest import ForestState, restore_session, save_session
from .server import SessionServer
from .session import Session

__all__ = [
    "ForestState",
    "save_session",
    "restore_session",
    "Session",
    "SessionServer",
    "EditBatcher",
    "EditRequest",
    "Batch",
    "compatible",
    "ServeError",
    "UnknownSession",
    "ServerOverloaded",
    "ServerClosed",
    "DeadlineExceeded",
    "SessionQuarantined",
]
