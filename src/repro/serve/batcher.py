"""Cross-session edit batching: group compatible pending updates.

Two pending edits are *compatible* — may share one plan-cache entry and
therefore one plan freeze — iff they target the same compiled trace
(the same ``CompiledGraph``) and their mark passes quantized to the
same dirty signature (``PendingUpdate.plan``).  Compatibility says
nothing about the edited *values*: the signature is the per-node
skip/sparse/dense regime plan, so two sessions editing different
blocks of the same input with the same sparsity bucket still batch.

The batcher is pure host logic (no asyncio, no jax): the server drains
its admission queue, plans every admitted request (the jitted mark pass
per session — states differ, plans often don't), hands the planned
requests here, and executes batch by batch.  Within a batch the first
commit freezes (or LRU-hits) the shared ``("cow", plan)`` executable
and every subsequent member dispatches straight into it — the freeze
cost is paid once per batch, not once per request, and since the plan
cache is owned by the ``CompiledGraph`` the entry stays shared across
later batches and across sessions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["EditRequest", "Batch", "EditBatcher", "compatible"]


@dataclasses.dataclass
class EditRequest:
    """One admitted edit: the session it belongs to, the raw inputs, and
    the planned (marked) update — ``pending=None`` means the graph has
    no planned path and the request takes the unbatched fallback."""

    session: Any                       # serve.session.Session
    inputs: Dict[str, Any]
    pending: Optional[Any] = None      # jaxsac.graph_compile.PendingUpdate
    t_enqueue: float = 0.0
    t_admit: float = 0.0
    plan_ms: float = 0.0               # this request's own mark/plan span
    deadline: Optional[float] = None   # perf_counter() instant; None = never
    use_oracle: bool = False           # route to the copy oracle (degraded)


@dataclasses.dataclass
class Batch:
    """Requests sharing one (trace, dirty-signature) plan-cache key."""

    key: Optional[Tuple[Any, ...]]
    requests: List[EditRequest]

    def __len__(self) -> int:
        return len(self.requests)


def _key_of(req: EditRequest) -> Optional[Tuple[Any, ...]]:
    if req.pending is None:
        return None
    return (req.session.cg, req.pending.plan)


def compatible(a: EditRequest, b: EditRequest) -> bool:
    """The batching predicate: same compiled trace, same quantized
    dirty signature (documented in DESIGN.md §Serving-layer)."""
    ka, kb = _key_of(a), _key_of(b)
    return ka is not None and ka == kb


class EditBatcher:
    """Group planned requests into batches of compatible edits.

    Grouping is stable (first-arrival order decides batch order and
    order within a batch) and bounded: a signature with more than
    ``max_batch`` requests splits, so one hot signature cannot starve
    the rest of a drain cycle indefinitely.  Unplannable requests
    (``pending=None``) are singleton batches.
    """

    def __init__(self, max_batch: int = 16):
        assert max_batch >= 1, max_batch
        self.max_batch = int(max_batch)
        self.batches_formed = 0
        self.requests_batched = 0      # members beyond each batch's first

    def group(self, requests: List[EditRequest]) -> List[Batch]:
        order: List[Optional[Tuple[Any, ...]]] = []
        groups: Dict[Any, List[EditRequest]] = {}
        singles: List[Batch] = []
        for req in requests:
            key = _key_of(req)
            if key is None:
                singles.append(Batch(None, [req]))
                continue
            if key not in groups:
                groups[key] = []
                order.append(key)
            groups[key].append(req)
        out: List[Batch] = []
        for key in order:
            members = groups[key]
            for i in range(0, len(members), self.max_batch):
                chunk = members[i:i + self.max_batch]
                out.append(Batch(key, chunk))
                self.batches_formed += 1
                self.requests_batched += len(chunk) - 1
        out.extend(singles)
        self.batches_formed += len(singles)
        return out
