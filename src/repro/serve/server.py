"""Async multi-tenant session server over one warm compiled handle.

Propagation-as-a-service: many logical sessions — each a COW fork of
one warm base state — with edits streaming in concurrently.  The server
is a single-process asyncio component:

  * **admission queue** — ``submit()`` enqueues an edit and parks on a
    future; the drain loop admits everything queued at once (one drain
    cycle = one admission wave), so concurrent submitters are batched
    by arrival, not serialized by lock order.  The queue is bounded
    (``max_queue``): a full queue rejects fast with a retryable
    :class:`ServerOverloaded` instead of buffering unbounded latency;
  * **deadlines** — a request carrying a deadline that expires while
    queued resolves with :class:`DeadlineExceeded` *before* paying its
    plan or commit, and session state is untouched;
  * **cross-session batching** — every admitted edit runs its (cheap,
    non-mutating) mark pass, then the ``EditBatcher`` groups requests
    whose (trace, quantized dirty signature) match: the batch shares
    one ``("cow", plan)`` plan-cache entry, so the freeze is paid once
    per batch and hot signatures stop freezing entirely — across
    sessions, because the cache belongs to the ``CompiledGraph``;
  * **the failure ladder** — transient faults (``faults.is_transient``)
    retry with exponential backoff, safe because the forest stages a
    commit's refcount changes: a failed commit is side-effect-free.
    A planned path that keeps failing degrades to the ``plan=False``
    copy oracle (counted ``serve.degraded``; sticky per session after
    ``degrade_after`` plan failures).  A session whose requests fail
    ``quarantine_after`` times in a row is rolled back to its last
    good snapshot and quarantined — reads still serve, edits fail fast
    with :class:`SessionQuarantined` until ``reinstate()`` — while
    every other session's rounds proceed untouched;
  * **eviction** — sessions idle past ``evict_idle_s`` are checkpointed
    to disk (committed ``repro.ckpt`` protocol) and their device
    buffers released; the next edit revives them bitwise, plan
    signatures re-warmed.  ``runtime.Supervisor`` restores the same
    checkpoints through its pluggable ``restore_fn``;
  * **latency accounting** — per-request queue-wait / plan / propagate
    spans flow into a ``repro.obs.MetricRegistry`` (histograms for
    p50/p99, one ``serve.request`` event per request for JSONL sinks),
    plus the hardening counters: ``serve.retries``, ``serve.rejected``,
    ``serve.deadline_exceeded``, ``serve.quarantines``,
    ``serve.degraded``, and ``serve.recovery_ms`` spans for revival
    and quarantine rollback.

The jax work itself (mark, commit) runs synchronously on the loop
thread: propagation is the service's unit of work, not something to
overlap against itself — concurrency buys admission batching and
fairness, not parallel device mutation.
"""
from __future__ import annotations

import asyncio
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import ckpt as ckpt_lib
from repro.obs.metrics import MetricRegistry
from repro.runtime import faults

from .batcher import EditBatcher, EditRequest
from .errors import (DeadlineExceeded, ServerClosed, ServerOverloaded,
                     SessionQuarantined, UnknownSession)
from .session import Session

__all__ = ["SessionServer"]


class SessionServer:
    """Serve a compiled graph handle to many concurrent sessions.

    ``handle`` must be a graph-backend handle with a warm state
    (``run()`` already called); it becomes the forest base every
    session forks.  Use as an async context manager::

        async with handle.serve(ckpt_dir=d) as server:
            sid = await server.open()
            res = await server.submit(sid, x=edited)
            res["outputs"], res["stats"], res["latency"]
    """

    def __init__(self, handle, *, max_batch: int = 16,
                 max_sessions: int = 256,
                 evict_idle_s: Optional[float] = None,
                 ckpt_dir: Optional[str] = None,
                 registry: Optional[MetricRegistry] = None,
                 max_queue: Optional[int] = None,
                 default_deadline_s: Optional[float] = None,
                 max_retries: int = 2,
                 retry_backoff_s: float = 0.005,
                 degrade_after: int = 2,
                 quarantine_after: int = 3):
        assert getattr(handle, "backend", None) == "graph", (
            "serve() runs on the graph backend (the COW forest lives in "
            "the compiled runtime's donated state)")
        self.handle = handle
        self.cg = handle.cg
        self.base = handle._forest()     # warm base every session forks
        self.registry = registry if registry is not None else MetricRegistry()
        ckpt_lib.set_registry(self.registry)
        self.batcher = EditBatcher(max_batch=max_batch)
        self.max_sessions = int(max_sessions)
        self.evict_idle_s = evict_idle_s
        self.ckpt_dir = ckpt_dir
        self.max_queue = None if max_queue is None else int(max_queue)
        self.default_deadline_s = default_deadline_s
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.degrade_after = int(degrade_after)
        self.quarantine_after = int(quarantine_after)
        self.sessions: Dict[str, Session] = {}
        self._queue: List[Tuple[EditRequest, asyncio.Future]] = []
        self._wake: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._running = False
        self._next_sid = 0

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------
    async def __aenter__(self) -> "SessionServer":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    def start(self) -> None:
        assert self._task is None, "server already started"
        self._wake = asyncio.Event()
        self._running = True
        self._task = asyncio.get_running_loop().create_task(
            self._drain_loop())

    async def stop(self) -> None:
        """Drain outstanding requests, then stop: every future parked at
        stop() time resolves (served or failed, never abandoned).
        Sessions stay usable for reads (``outputs``) until
        ``shutdown``."""
        if self._task is None:
            return
        self._running = False
        self._wake.set()
        await self._task
        self._task = None

    async def shutdown(self) -> None:
        """Stop and release every session's forest claims."""
        await self.stop()
        for s in list(self.sessions.values()):
            s.close()
        self.sessions.clear()

    # ------------------------------------------------------------------
    # Session management
    # ------------------------------------------------------------------
    def _session(self, sid: str) -> Session:
        s = self.sessions.get(sid)
        if s is None or s.status == "closed":
            raise UnknownSession(sid)
        return s

    async def open(self, sid: Optional[str] = None) -> str:
        """Admit a new session: a COW fork of the warm base (host
        metadata only — no device copies until its first edit)."""
        live = sum(1 for s in self.sessions.values()
                   if s.status != "closed")
        if live >= self.max_sessions:
            raise RuntimeError(
                f"session limit reached ({self.max_sessions})")
        if sid is None:
            sid = f"s{self._next_sid}"
            self._next_sid += 1
        assert (sid not in self.sessions
                or self.sessions[sid].status == "closed"), \
            f"duplicate session id {sid!r}"
        ck = (f"{self.ckpt_dir}/{sid}" if self.ckpt_dir is not None
              else None)
        self.sessions[sid] = Session(
            sid, self.base.fork(), self.handle.out_handles,
            self.handle._single, ckpt_dir=ck)
        self.registry.counter("serve.sessions_opened").inc()
        return sid

    async def close_session(self, sid: str) -> None:
        """Close a session.  Idempotent: closing an already-closed (or
        unknown) sid is a no-op."""
        s = self.sessions.get(sid)
        if s is None:
            return
        s.close()

    async def evict(self, sid: str) -> str:
        """Checkpoint a live session to disk and release its buffers.
        Idempotent for an already-evicted session."""
        s = self._session(sid)
        if s.status == "evicted":
            return s.ckpt_dir
        return s.evict()

    async def reinstate(self, sid: str) -> None:
        """Re-admit edits on a quarantined session (it keeps serving the
        rolled-back last-good state until its next accepted edit)."""
        s = self._session(sid)
        if s.status == "quarantined":
            s.reinstate()

    def evict_idle(self) -> List[str]:
        """Evict every live session idle past ``evict_idle_s`` (called
        by the drain loop each cycle; callable manually too)."""
        if self.evict_idle_s is None or self.ckpt_dir is None:
            return []
        victims = [s for s in self.sessions.values()
                   if s.status == "live" and s.idle_s > self.evict_idle_s]
        evicted = []
        for s in victims:
            s.evict()        # raises before releasing: a failed evict
            evicted.append(s.id)        # leaves the session live
            self.registry.counter("serve.evictions").inc()
            self.registry.event("serve.evict", session=s.id,
                                updates=s.updates)
        return evicted

    def reset_metrics(self,
                      registry: Optional[MetricRegistry] = None) -> None:
        """Open a fresh measurement window: new registry (or the given
        one) and fresh batcher counters.  For steady-state benching —
        e.g. after a warm-up wave has paid each session's one-time
        copy-on-first-scatter — so percentiles and batch rates describe
        only the window.  Plan-cache counters are *not* reset: the
        cache belongs to the compiled graph, not to the window."""
        self.registry = (registry if registry is not None
                         else MetricRegistry())
        ckpt_lib.set_registry(self.registry)
        self.batcher = EditBatcher(max_batch=self.batcher.max_batch)

    def outputs(self, sid: str):
        """A session's current outputs (revives it if evicted;
        quarantined sessions serve their rolled-back state).  Copied,
        like ``submit`` responses: the session's next commit donates the
        touched output leaves in place, which would delete a live view
        under the caller."""
        s = self._session(sid)
        if s.status == "evicted":
            self._revive(s)
        return jax.tree.map(jnp.copy, s.outputs())

    # ------------------------------------------------------------------
    # The service path
    # ------------------------------------------------------------------
    async def submit(self, sid: str, inputs: Optional[Dict[str, Any]] = None,
                     *, deadline_s: Optional[float] = None,
                     **changed) -> Dict[str, Any]:
        """Submit one edit to a session; resolves when propagated with
        ``{"outputs", "stats", "latency", "batch_size"}``.

        Fails fast — before enqueueing anything — with
        :class:`ServerClosed` (not running), :class:`UnknownSession`,
        :class:`SessionQuarantined`, or :class:`ServerOverloaded`
        (queue full; retryable).  ``deadline_s`` (or the server's
        ``default_deadline_s``) bounds total latency: an expired request
        resolves with :class:`DeadlineExceeded` without paying its
        plan/commit."""
        if self._task is None or not self._running:
            raise ServerClosed("submit() on a stopped server")
        s = self._session(sid)
        if s.status == "quarantined":
            raise SessionQuarantined(sid)
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self.registry.counter("serve.rejected").inc()
            raise ServerOverloaded(len(self._queue), self.max_queue)
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        now = time.perf_counter()
        req = EditRequest(
            session=s, inputs={**(inputs or {}), **changed}, t_enqueue=now,
            deadline=(now + deadline_s) if deadline_s is not None else None)
        fut = asyncio.get_running_loop().create_future()
        self._queue.append((req, fut))
        self._wake.set()
        return await fut

    async def _drain_loop(self) -> None:
        # The loop must survive anything: a dead drain task would leave
        # every later submit() parked on a future nobody resolves.
        # _serve_wave resolves its futures per request, so an exception
        # escaping it is a server-side bug (batcher, accounting) — fail
        # the wave's unresolved futures and keep serving.
        while True:
            await self._wake.wait()
            self._wake.clear()
            while self._queue:
                admitted, self._queue = self._queue, []
                try:
                    self._serve_wave(admitted)
                except Exception as e:
                    for _req, fut in admitted:
                        if not fut.done():
                            fut.set_exception(e)
                    self.registry.counter("serve.wave_errors").inc()
                    self.registry.event("serve.error", where="wave",
                                        error=repr(e))
                # Yield between waves so submitters queued during the
                # last wave are admitted together in the next one.
                await asyncio.sleep(0)
            try:
                self.evict_idle()
            except Exception as e:
                self.registry.counter("serve.evict_errors").inc()
                self.registry.event("serve.error", where="evict_idle",
                                    error=repr(e))
            if not self._running:
                return

    def _serve_wave(self, admitted) -> None:
        """One admission wave: revive, plan, batch, execute, resolve.

        Requests to the *same* session are serialized: a round takes at
        most one request per session (arrival order), and each request
        is planned only in its own round — i.e. after the session's
        previous commit has executed.  Planning a second edit against
        pre-commit state would freeze stale mark masks that call
        freshly-recomputed nodes clean, silently dropping part of the
        edit.  Cross-session batching is unaffected: round k still
        groups every session's k-th request by (trace, signature).
        """
        reg = self.registry
        t_admit = time.perf_counter()
        per_session: Dict[int, List[Tuple[EditRequest, asyncio.Future]]] = {}
        session_order: List[int] = []
        for req, fut in admitted:
            req.t_admit = t_admit
            key = id(req.session)
            if key not in per_session:
                per_session[key] = []
                session_order.append(key)
            per_session[key].append((req, fut))
        while any(per_session.values()):
            ready: List[EditRequest] = []
            futures: Dict[int, asyncio.Future] = {}
            for key in session_order:
                if not per_session[key]:
                    continue
                req, fut = per_session[key].pop(0)
                futures[id(req)] = fut
                s = req.session
                if self._expired(req, fut):
                    continue
                if s.status == "quarantined":
                    # Quarantined between submit and this round (an
                    # earlier request of the same wave tripped it).
                    fut.set_exception(SessionQuarantined(s.id))
                    continue
                try:
                    if s.status == "evicted":
                        self._revive(s)
                except Exception as e:
                    # Revival failed: the checkpoint is intact and the
                    # session stays evicted — not a health strike.
                    fut.set_exception(e)
                    continue
                if s.degraded:
                    req.use_oracle = True   # sticky: skip planning
                    ready.append(req)
                    continue
                try:
                    t0 = time.perf_counter()
                    req.pending = self._plan(s, req.inputs)
                    req.plan_ms = (time.perf_counter() - t0) * 1e3
                    ready.append(req)
                except AssertionError as e:
                    fut.set_exception(e)    # client error (bad inputs)
                except Exception as e:
                    # Plan-path failure: degrade this request to the
                    # copy oracle instead of failing it.
                    self._note_plan_failure(s, e)
                    req.pending = None
                    req.use_oracle = True
                    ready.append(req)
            for batch in self.batcher.group(ready):
                if len(batch) > 1:
                    reg.counter("serve.batch_joins").inc(len(batch) - 1)
                    reg.event("serve.batch", size=len(batch),
                              sessions=[r.session.id
                                        for r in batch.requests])
                for req in batch.requests:
                    fut = futures[id(req)]
                    if self._expired(req, fut):
                        continue
                    try:
                        fut.set_result(self._execute(req, len(batch)))
                    except Exception as e:
                        fut.set_exception(e)
                        self._note_request_failure(req.session, e)

    # ------------------------------------------------------------------
    # The failure ladder
    # ------------------------------------------------------------------
    def _backoff(self, attempt: int) -> None:
        # Exponential, synchronous: the loop thread owns all device
        # mutation, so there is nothing useful to overlap the wait with.
        time.sleep(self.retry_backoff_s * (2 ** attempt))

    def _plan(self, s: Session, inputs: Dict[str, Any]):
        attempt = 0
        while True:
            try:
                return s.plan(inputs)     # mark pass, no writes
            except Exception as e:
                if faults.is_transient(e) and attempt < self.max_retries:
                    self.registry.counter("serve.retries").inc()
                    self._backoff(attempt)
                    attempt += 1
                    continue
                raise

    def _revive(self, s: Session) -> None:
        attempt = 0
        t0 = time.perf_counter()
        while True:
            try:
                s.revive()
                break
            except Exception as e:
                if faults.is_transient(e) and attempt < self.max_retries:
                    self.registry.counter("serve.retries").inc()
                    self._backoff(attempt)
                    attempt += 1
                    continue
                raise
        ms = (time.perf_counter() - t0) * 1e3
        self.registry.counter("serve.revivals").inc()
        self.registry.histogram("serve.recovery_ms").observe(ms)

    def _run_edit(self, req: EditRequest) -> Tuple[Dict[str, Any], bool]:
        """Apply one edit through the ladder.  Returns ``(stats,
        degraded)`` — ``degraded=True`` when the copy oracle served it."""
        s = req.session
        if req.use_oracle or s.degraded:
            return self._oracle(s, req.inputs), True
        attempt = 0
        while True:
            try:
                if req.pending is None:
                    return s.propagate(req.inputs), False
                return s.commit(req.pending), False
            except Exception as e:
                if faults.is_transient(e) and attempt < self.max_retries:
                    # Safe: a failed commit is side-effect-free (the
                    # forest stages refcounts), so the same pending
                    # update can re-dispatch as-is.
                    self.registry.counter("serve.retries").inc()
                    self._backoff(attempt)
                    attempt += 1
                    continue
                if (req.pending is not None
                        and not isinstance(e, AssertionError)
                        and not getattr(e, "device_loss", False)):
                    # Planned path exhausted its retries: degrade this
                    # request to the oracle rather than failing it.
                    self._note_plan_failure(s, e)
                    return self._oracle(s, req.inputs), True
                raise

    def _oracle(self, s: Session, inputs: Dict[str, Any]) -> Dict[str, Any]:
        attempt = 0
        while True:
            try:
                return s.propagate_oracle(inputs)
            except Exception as e:
                if faults.is_transient(e) and attempt < self.max_retries:
                    self.registry.counter("serve.retries").inc()
                    self._backoff(attempt)
                    attempt += 1
                    continue
                raise

    def _note_plan_failure(self, s: Session, e: BaseException) -> None:
        s.plan_failures += 1
        if not s.degraded and s.plan_failures >= self.degrade_after:
            s.degraded = True            # sticky: plan no more
            self.registry.event("serve.degrade", session=s.id,
                                error=repr(e))

    def _note_request_failure(self, s: Session, e: BaseException) -> None:
        if isinstance(e, AssertionError):
            return                       # client error, not session health
        s.failures += 1
        self.registry.event("serve.request_error", session=s.id,
                            error=repr(e))
        if s.status == "live" and s.failures >= self.quarantine_after:
            t0 = time.perf_counter()
            s.quarantine()               # rollback to last good snapshot
            ms = (time.perf_counter() - t0) * 1e3
            self.registry.counter("serve.quarantines").inc()
            self.registry.histogram("serve.recovery_ms").observe(ms)
            self.registry.event("serve.quarantine", session=s.id,
                                updates=s.updates, rollback_ms=ms)

    def _expired(self, req: EditRequest, fut: asyncio.Future) -> bool:
        """Resolve an expired request with DeadlineExceeded — *before*
        its plan or commit runs, so no propagation work is paid and the
        session is untouched."""
        if req.deadline is None or time.perf_counter() <= req.deadline:
            return False
        waited = (time.perf_counter() - req.t_enqueue) * 1e3
        if not fut.done():
            fut.set_exception(DeadlineExceeded(req.session.id, waited))
        self.registry.counter("serve.deadline_exceeded").inc()
        return True

    def _execute(self, req: EditRequest, batch_size: int) -> Dict[str, Any]:
        reg = self.registry
        s = req.session
        t_exec = time.perf_counter()
        stats, degraded = self._run_edit(req)
        if degraded:
            reg.counter("serve.degraded").inc()
        t_done = time.perf_counter()
        # Service spans bound the request's *own* work (its mark pass,
        # its commit); everything else — admission wait plus the wave's
        # serialization behind other requests — is queue wait, so
        # total == queue_wait + plan + propagate holds per request.
        total_ms = (t_done - req.t_enqueue) * 1e3
        propagate_ms = (t_done - t_exec) * 1e3
        lat = {
            "queue_wait_ms": total_ms - req.plan_ms - propagate_ms,
            "plan_ms": req.plan_ms,
            "propagate_ms": propagate_ms,
            "total_ms": total_ms,
        }
        reg.counter("serve.requests").inc()
        for k, v in lat.items():
            reg.histogram(f"serve.{k}").observe(v)
        reg.event("serve.request", session=s.id, batch_size=batch_size,
                  degraded=degraded, **lat)
        # Responses own their buffers: a session's next commit donates
        # the output leaf in place, so a live view handed to the caller
        # would be deleted under them.  Output nodes are small (the
        # program's results, not its state) — the copy is the response
        # serialization cost.
        outputs = jax.tree.map(jnp.copy, s.outputs())
        return {"outputs": outputs, "stats": stats,
                "latency": lat, "batch_size": batch_size}

    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        """Service-level numbers: request percentiles, batching
        effectiveness, session census, shared plan-cache counters."""
        reg = self.registry
        total = reg.histograms.get("serve.total_ms")
        prop = reg.histograms.get("serve.propagate_ms")
        queue = reg.histograms.get("serve.queue_wait_ms")
        requests = reg.counters.get("serve.requests")
        n_req = requests.value if requests is not None else 0
        census: Dict[str, int] = {}
        for s in self.sessions.values():
            census[s.status] = census.get(s.status, 0) + 1
        return {
            "requests": n_req,
            "batches": self.batcher.batches_formed,
            "batch_joins": self.batcher.requests_batched,
            "batch_hit_rate": (self.batcher.requests_batched / n_req
                               if n_req else 0.0),
            "p50_ms": total.percentile(50) if total is not None else None,
            "p99_ms": total.percentile(99) if total is not None else None,
            "propagate_p50_ms": (prop.percentile(50)
                                 if prop is not None else None),
            "queue_wait_p50_ms": (queue.percentile(50)
                                  if queue is not None else None),
            "sessions": census,
            "plan_cache": self.cg.plan_cache_snapshot(),
        }
