"""One tenant of the session server: a forest node plus lifecycle.

A ``Session`` owns one ``ForestState`` forked off the server's warm
base.  Its propagation work is exactly the forest's (plan → commit,
COW on first write); what this layer adds is the *lifecycle* the server
manages:

  * ``live``        — forest node resident on device, edits stream in;
  * ``evicted``     — state checkpointed to disk (``forest.save_session``)
    and the device buffers released; a later edit revives it
    (``forest.restore_session``) bitwise, with its warmed plan
    signatures re-inserted into the shared plan cache so the first
    post-revival edit of a familiar shape is still a signature hit;
  * ``quarantined`` — the session's commits failed repeatedly, so it was
    rolled back to its last *good* snapshot (a COW fork refreshed after
    every accepted edit — O(leaves) host metadata, no device copies
    until a commit actually touches a shared leaf).  Reads still serve
    the rolled-back state; ``reinstate()`` re-admits edits.

The good snapshot is what makes quarantine *verifiable*: a failed
commit is side-effect-free (the forest stages refcount changes), so the
snapshot taken after the last accepted edit is bitwise the state a
fault-free replay of the accepted edits would produce — rollback never
serves a half-applied update.

Eviction uses the same committed-checkpoint protocol as training
(``repro.ckpt``), which is what makes sessions durable: a server crash
loses at most the edits since each session's last eviction/checkpoint,
and ``runtime.Supervisor`` can restore one via its pluggable
``restore_fn``.  ``save_session`` runs *before* any buffer is released,
so an injected ``session.evict`` fault leaves the session live and
untouched; ``session.revive`` faults surface to the caller with the
checkpoint intact.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

from repro.runtime import faults

from .forest import ForestState, restore_session, save_session

__all__ = ["Session"]


class Session:
    """One served tenant: id, forest node, lifecycle, edit accounting."""

    def __init__(self, sid: str, fstate: ForestState, out_handles: List[Any],
                 single: bool, ckpt_dir: Optional[str] = None):
        self.id = sid
        self.fstate: Optional[ForestState] = fstate
        self.cg = fstate.cg
        self.out_handles = out_handles
        self._single = single
        self.ckpt_dir = ckpt_dir
        self.status = "live"
        self.updates = 0
        self.revivals = 0
        self.quarantines = 0
        self.failures = 0        # consecutive failed requests (ladder input)
        self.plan_failures = 0   # consecutive planned-path failures
        self.degraded = False    # sticky: plan no more, commit via oracle
        self.last_active = time.monotonic()
        self.last_stats: Dict[str, Any] = {}
        # The rollback anchor: a fork of the state after the last
        # accepted edit (initially the fresh fork off the base).
        self.good: Optional[ForestState] = fstate.fork()

    # ------------------------------------------------------------------
    def touch(self) -> None:
        self.last_active = time.monotonic()

    @property
    def idle_s(self) -> float:
        return time.monotonic() - self.last_active

    # ------------------------------------------------------------------
    # Propagation (delegates to the forest node)
    # ------------------------------------------------------------------
    def plan(self, inputs: Dict[str, Any]):
        assert self.status == "live", self.status
        return self.fstate.plan(inputs)

    def commit(self, pending) -> Dict[str, Any]:
        assert self.status == "live", self.status
        stats = self.fstate.commit(pending)
        self._accepted(stats, planned=True)
        return stats

    def propagate(self, inputs: Dict[str, Any]) -> Dict[str, Any]:
        """Unbatched path (also the ``pending=None`` fallback)."""
        assert self.status == "live", self.status
        stats = self.fstate.propagate(inputs)
        self._accepted(stats, planned=True)
        return stats

    def propagate_oracle(self, inputs: Dict[str, Any]) -> Dict[str, Any]:
        """Degraded path: the non-donating ``plan=False`` copy oracle —
        correct whenever the planned COW path misbehaves."""
        assert self.status == "live", self.status
        stats = self.fstate.propagate_oracle(inputs)
        self._accepted(stats, planned=False)
        return stats

    def _accepted(self, stats: Dict[str, Any], *, planned: bool) -> None:
        """An edit landed: refresh the rollback anchor and reset the
        consecutive-failure ladder (a planned-path success also clears
        the plan-failure streak; an oracle success says nothing about
        the planned path)."""
        self.updates += 1
        self.last_stats = stats
        self.failures = 0
        if planned:
            self.plan_failures = 0
        old, self.good = self.good, self.fstate.fork()
        if old is not None:
            old.release()
        self.touch()

    def outputs(self):
        # Quarantined sessions still serve reads — the rolled-back
        # last-good state, not an error and not a half-applied update.
        assert self.status in ("live", "quarantined"), self.status
        vals = tuple(self.cg.value(self.fstate, h) for h in self.out_handles)
        return vals[0] if self._single else vals

    # ------------------------------------------------------------------
    # Quarantine (rollback to the last good snapshot)
    # ------------------------------------------------------------------
    def quarantine(self) -> None:
        """Roll back to the last accepted state and stop taking edits.
        The good snapshot itself is kept, so a still-failing session can
        be rolled back again after ``reinstate()``."""
        assert self.status == "live", self.status
        assert self.good is not None
        self.fstate.release()
        self.fstate = self.good.fork()
        self.status = "quarantined"
        self.quarantines += 1
        self.failures = 0

    def reinstate(self) -> None:
        """Re-admit edits on a quarantined session."""
        assert self.status == "quarantined", self.status
        self.status = "live"
        self.touch()

    # ------------------------------------------------------------------
    # Eviction / revival
    # ------------------------------------------------------------------
    def evict(self) -> str:
        """Checkpoint this session's state and release its buffers."""
        assert self.status == "live", self.status
        assert self.ckpt_dir is not None, (
            "session eviction needs a ckpt_dir")
        faults.inject("session.evict", sid=self.id)
        # Save first: a failure anywhere above this line leaves the
        # session live with every buffer intact.
        save_session(self.ckpt_dir, self.fstate, step=self.updates,
                     meta={"session": self.id})
        self.fstate.release()
        self.fstate = None
        if self.good is not None:
            self.good.release()
            self.good = None
        self.status = "evicted"
        return self.ckpt_dir

    def revive(self) -> None:
        """Restore an evicted session bitwise from its checkpoint."""
        assert self.status == "evicted", self.status
        faults.inject("session.revive", sid=self.id)
        self.fstate, _meta = restore_session(self.cg, self.ckpt_dir)
        self.good = self.fstate.fork()
        self.status = "live"
        self.revivals += 1
        self.touch()

    def close(self) -> None:
        if self.fstate is not None:
            self.fstate.release()
            self.fstate = None
        if self.good is not None:
            self.good.release()
            self.good = None
        self.status = "closed"
