"""One tenant of the session server: a forest node plus lifecycle.

A ``Session`` owns one ``ForestState`` forked off the server's warm
base.  Its propagation work is exactly the forest's (plan → commit,
COW on first write); what this layer adds is the *lifecycle* the server
manages:

  * ``live``     — forest node resident on device, edits stream in;
  * ``evicted``  — state checkpointed to disk (``forest.save_session``)
    and the device buffers released; a later edit revives it
    (``forest.restore_session``) bitwise, with its warmed plan
    signatures re-inserted into the shared plan cache so the first
    post-revival edit of a familiar shape is still a signature hit.

Eviction uses the same committed-checkpoint protocol as training
(``repro.ckpt``), which is what makes sessions durable: a server crash
loses at most the edits since each session's last eviction/checkpoint,
and ``runtime.Supervisor`` can restore one via its pluggable
``restore_fn``.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

from .forest import ForestState, restore_session, save_session

__all__ = ["Session"]


class Session:
    """One served tenant: id, forest node, lifecycle, edit accounting."""

    def __init__(self, sid: str, fstate: ForestState, out_handles: List[Any],
                 single: bool, ckpt_dir: Optional[str] = None):
        self.id = sid
        self.fstate: Optional[ForestState] = fstate
        self.cg = fstate.cg
        self.out_handles = out_handles
        self._single = single
        self.ckpt_dir = ckpt_dir
        self.status = "live"
        self.updates = 0
        self.revivals = 0
        self.last_active = time.monotonic()
        self.last_stats: Dict[str, Any] = {}

    # ------------------------------------------------------------------
    def touch(self) -> None:
        self.last_active = time.monotonic()

    @property
    def idle_s(self) -> float:
        return time.monotonic() - self.last_active

    # ------------------------------------------------------------------
    # Propagation (delegates to the forest node)
    # ------------------------------------------------------------------
    def plan(self, inputs: Dict[str, Any]):
        assert self.status == "live", self.status
        return self.fstate.plan(inputs)

    def commit(self, pending) -> Dict[str, Any]:
        assert self.status == "live", self.status
        stats = self.fstate.commit(pending)
        self.updates += 1
        self.last_stats = stats
        self.touch()
        return stats

    def propagate(self, inputs: Dict[str, Any]) -> Dict[str, Any]:
        """Unbatched path (also the ``pending=None`` fallback)."""
        assert self.status == "live", self.status
        stats = self.fstate.propagate(inputs)
        self.updates += 1
        self.last_stats = stats
        self.touch()
        return stats

    def outputs(self):
        assert self.status == "live", self.status
        vals = tuple(self.cg.value(self.fstate, h) for h in self.out_handles)
        return vals[0] if self._single else vals

    # ------------------------------------------------------------------
    # Eviction / revival
    # ------------------------------------------------------------------
    def evict(self) -> str:
        """Checkpoint this session's state and release its buffers."""
        assert self.status == "live", self.status
        assert self.ckpt_dir is not None, (
            "session eviction needs a ckpt_dir")
        save_session(self.ckpt_dir, self.fstate, step=self.updates,
                     meta={"session": self.id})
        self.fstate.release()
        self.fstate = None
        self.status = "evicted"
        return self.ckpt_dir

    def revive(self) -> None:
        """Restore an evicted session bitwise from its checkpoint."""
        assert self.status == "evicted", self.status
        self.fstate, _meta = restore_session(self.cg, self.ckpt_dir)
        self.status = "live"
        self.revivals += 1
        self.touch()

    def close(self) -> None:
        if self.fstate is not None:
            self.fstate.release()
            self.fstate = None
        self.status = "closed"
