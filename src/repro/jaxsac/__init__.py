"""jaxsac: TPU-native parallel self-adjusting computation.

The host engine in ``repro.core`` is the paper-faithful implementation:
dynamic RSP trees, per-read closures, reader sets.  None of that jits —
XLA requires static structure.  This package is the *hardware adaptation*
of the paper's idea (see DESIGN.md §Hardware-adaptation):

  * Computations are restricted to **static-structure** RSP dags — the
    paper itself singles this class out ("the RSP tree will always look
    the same", Section 2, the sum example).  The control structure (S/P
    composition) is compiled once; only values change.
  * Dependencies are tracked at **block** granularity (tiles of tensors),
    the tensor-program analogue of the paper's granularity knob
    (Table 9).  A modifiable is a block; its "reader set" is the static
    set of downstream blocks, encoded as an index map instead of a hash
    table.
  * Change propagation = dirty-mask propagation through the static dag +
    masked recompute of exactly the dirty blocks, with the paper's
    value-equality write cutoff (Algorithm 2: a write that does not
    change the value marks no readers) implemented as a per-block
    bitwise-equality check that stops propagation early.

Modules:
  * ``graph``   — the general subsystem: a tracing API (``GraphBuilder``)
    that records a static SP-dag of block-granular ops (map / zip_map /
    reduce_tree / stencil / scan, composed with seq/par mirroring the
    host engine's S/P nodes), where each edge carries a reader index map.
  * ``graph_compile`` — level-schedules the dag and emits ``init`` plus a
    fully jitted ``propagate`` (dirty-mask pushing + masked recompute,
    sparse-gather vs dense-masked per level, Pallas dirty-tile routing).
  * ``graph_ops`` — per-kind forward / dirty-transfer / recompute math.
  * ``reduce``  — incremental balanced reductions (the paper's Algorithm 1
    divide-and-conquer sum, O(k log(n/k)) dirty nodes per k-block update);
    now a thin wrapper over the graph runtime.
  * ``prefill`` — incremental KV-cache prefill for the serving path: edit
    k tokens of an S-token prompt and re-establish the exact cache while
    recomputing only the affected positions per layer (dirty intervals).
  * ``apps``    — host-engine applications ported as graph programs
    (Rabin-Karp string hash).
"""
from .core import BlockTensor, dirty_from_diff
from .graph import GraphBuilder
from .graph_compile import CompiledGraph
from .reduce import IncrementalReduce
from .prefill import incremental_prefill, prefill_distance

__all__ = [
    "BlockTensor",
    "dirty_from_diff",
    "GraphBuilder",
    "CompiledGraph",
    "IncrementalReduce",
    "incremental_prefill",
    "prefill_distance",
]
