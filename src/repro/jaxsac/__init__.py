"""jaxsac: TPU-native parallel self-adjusting computation.

The host engine in ``repro.core`` is the paper-faithful implementation:
dynamic RSP trees, per-read closures, reader sets.  None of that jits —
XLA requires static structure.  This package is the *hardware adaptation*
of the paper's idea (see DESIGN.md §Hardware-adaptation):

  * Computations are restricted to **static-structure** RSP dags — the
    paper itself singles this class out ("the RSP tree will always look
    the same", Section 2, the sum example).  The control structure (S/P
    composition) is compiled once; only values change.
  * Dependencies are tracked at **block** granularity (tiles of tensors),
    the tensor-program analogue of the paper's granularity knob
    (Table 9).  A modifiable is a block; its "reader set" is the static
    set of downstream blocks, encoded as an index map instead of a hash
    table.
  * Change propagation = dirty-set propagation through the static dag +
    masked recompute of exactly the dirty blocks, with the paper's
    value-equality write cutoff (Algorithm 2: a write that does not
    change the value marks no readers) implemented as a per-block
    bitwise-equality check that stops propagation early.

The public way to *author* programs is the ``repro.sac`` tracing
frontend (re-exported here as ``sac``): decorate an ordinary function
with ``@sac.incremental`` and compile it onto this runtime
(``backend="graph"``) or onto the host engine (``backend="host"``).

Modules:
  * ``graph``   — the static SP-dag IR the frontend records into
    (``GraphBuilder`` — deprecated as a user-facing API, see below).
  * ``graph_compile`` — level-schedules the dag and emits ``init`` plus a
    fully jitted ``propagate`` (dirty-set pushing + masked recompute,
    sparse-gather vs dense-masked per level with an auto-tuned
    crossover, Pallas dirty-tile routing).
  * ``graph_ops`` — per-kind forward / dirty-transfer / recompute math.
  * ``dirtyset`` — pluggable dirty representations: exact per-block
    ``MaskDirty`` and O(1) suffix/interval ``IntervalDirty`` (the
    representation causal attention and the serving path propagate).
  * ``autotune`` — timed calibration of the sparse/dense crossover.
  * ``reduce``  — incremental balanced reductions (the paper's Algorithm 1
    divide-and-conquer sum, O(k log(n/k)) dirty nodes per k-block update);
    a thin wrapper over the traced frontend.
  * ``prefill`` — incremental KV-cache prefill for the serving path: edit
    k tokens of an S-token prompt and re-establish the exact cache while
    recomputing only the affected positions; its mark phase runs on the
    runtime's interval DirtySet.
  * ``apps``    — host-engine applications ported as traced programs
    (Rabin-Karp string hash).
"""
import warnings as _warnings

from repro import sac
from .core import BlockTensor, dirty_from_diff
from .dirtyset import IntervalDirty, MaskDirty
from .graph_compile import CompiledGraph
from .reduce import IncrementalReduce
from .prefill import incremental_prefill, prefill_distance

__all__ = [
    "sac",
    "BlockTensor",
    "dirty_from_diff",
    "MaskDirty",
    "IntervalDirty",
    "GraphBuilder",
    "CompiledGraph",
    "IncrementalReduce",
    "incremental_prefill",
    "prefill_distance",
]


def __getattr__(name: str):
    if name == "GraphBuilder":
        # The imperative builder is now the IR behind the repro.sac
        # tracer; reaching it through the package namespace is the
        # legacy spelling.
        _warnings.warn(
            "repro.jaxsac.GraphBuilder is deprecated: write programs "
            "with @repro.sac.incremental (the tracing frontend) instead. "
            "GraphBuilder remains available as the IR at "
            "repro.jaxsac.graph.GraphBuilder.",
            DeprecationWarning, stacklevel=2)
        from .graph import GraphBuilder

        return GraphBuilder
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
