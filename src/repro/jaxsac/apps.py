"""Host-engine applications ported to the SP-dag graph runtime.

The host apps (``repro.apps``) run on the paper-faithful dynamic engine:
Python closures, per-read reader sets.  The ports here re-express the
same dataflow as *traced* static SP-dags so the jit-compiled propagate
of ``graph_compile`` does the change propagation on TPU.

``stringhash_graph`` ports the Rabin-Karp chunk pipeline of
``repro.apps.stringhash``: the string lives in n/g blocks of g character
codes; a leaf map computes each block's (hash, base^len) pair via the
homomorphism h(a ++ b) = h(a) * B^len(b) + h(b) (mod p); a balanced
reduce tree combines pairs, so a k-block edit recomputes O(k log(n/g))
dag blocks.  The modulus is 65521 (largest prime < 2^16) so every
intermediate product stays below 2^32 and the whole pipeline runs in
uint32 without requiring 64-bit mode.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .graph import GraphBuilder, Handle

__all__ = ["MOD", "BASE", "stringhash_graph", "stringhash_oracle",
           "GraphStringHash"]

MOD = 65521            # largest prime < 2^16: keeps products in uint32
BASE = 257


def _block_pair(grain: int):
    """Per-block (hash, base^grain) pair, Horner fold over the block."""
    p_const = pow(BASE, grain, MOD)

    def pair(block: jax.Array) -> jax.Array:
        def step(h, c):
            return (h * jnp.uint32(BASE) + c) % jnp.uint32(MOD), None

        h, _ = jax.lax.scan(step, jnp.uint32(0), block.astype(jnp.uint32))
        return jnp.stack([h, jnp.uint32(p_const)])

    return pair


def _combine(l: jax.Array, r: jax.Array) -> jax.Array:
    """(h, p) homomorphism combine on [..., 2]-stacked pairs."""
    l = l.astype(jnp.uint32)
    r = r.astype(jnp.uint32)
    h = (l[..., 0] * r[..., 1] + r[..., 0]) % jnp.uint32(MOD)
    p = (l[..., 1] * r[..., 1]) % jnp.uint32(MOD)
    return jnp.stack([h, p], axis=-1)


def stringhash_graph(n: int, grain: int = 64, *, max_sparse: int = 64,
                     use_pallas="auto"):
    """Trace + compile the Rabin-Karp pipeline.

    Returns (compiled_graph, output_handle); feed it the character codes
    as the ``"text"`` input (int32 [n]).
    """
    assert n % grain == 0
    g = GraphBuilder()
    x = g.input("text", n=n, block=grain)
    pairs = g.map(_block_pair(grain), x, out_block=1, name="rk.leaf")
    out = g.reduce_tree(_combine, pairs, identity=0, name="rk")
    g.output(out)
    cg = g.compile(max_sparse=max_sparse, use_pallas=use_pallas)
    return cg, out


def stringhash_oracle(codes: Sequence[int]) -> int:
    """From-scratch Rabin-Karp hash in exact Python integers."""
    h = 0
    for c in codes:
        h = (h * BASE + int(c)) % MOD
    return h


class GraphStringHash:
    """Drop-in style app facade mirroring repro.apps.stringhash usage."""

    name = "stringhash_graph"

    def __init__(self, n: int = 65536, grain: int = 64, seed: int = 0):
        import numpy as np

        self.n, self.grain = n, grain
        self.rng = np.random.default_rng(seed)
        self.codes = self.rng.integers(97, 123, n).astype("int32")
        self.cg, self.out = stringhash_graph(n, grain)
        self.state = None

    def run(self):
        # jnp.array (not asarray): self.codes is mutated in place between
        # updates, so hand jax a copy, never a zero-copy view.
        self.state = self.cg.init(text=jnp.array(self.codes))
        return self.state

    def apply_update(self, k: int) -> dict:
        """Edit k random characters; propagate; return stats."""
        idx = self.rng.choice(self.n, size=k, replace=False)
        self.codes[idx] = self.rng.integers(97, 123, k).astype("int32")
        self.state, stats = self.cg.propagate(
            self.state, {"text": jnp.array(self.codes)})
        return stats

    def output(self) -> int:
        return int(self.cg.result(self.state)[0, 0])

    def expected(self) -> int:
        return stringhash_oracle(self.codes)
