"""Host-engine applications ported to the ``repro.sac`` frontend.

The host apps (``repro.apps``) run on the paper-faithful dynamic engine:
Python closures, per-read reader sets.  The ports here re-express the
same dataflow as ordinary ``@sac.incremental`` programs, so one trace
runs on the jit-compiled graph runtime (``backend="graph"``) or back on
the host engine (``backend="host"``) for work/span accounting.

``stringhash_graph`` ports the Rabin-Karp chunk pipeline of
``repro.apps.stringhash``: the string lives in n/g blocks of g character
codes; a leaf map computes each block's (hash, base^len) pair via the
homomorphism h(a ++ b) = h(a) * B^len(b) + h(b) (mod p); a balanced
reduce tree combines pairs, so a k-block edit recomputes O(k log(n/g))
dag blocks.  The modulus is 65521 (largest prime < 2^16) so every
intermediate product stays below 2^32 and the whole pipeline runs in
uint32 without requiring 64-bit mode.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro import sac

__all__ = ["MOD", "BASE", "stringhash_graph", "stringhash_oracle",
           "GraphStringHash"]

MOD = 65521            # largest prime < 2^16: keeps products in uint32
BASE = 257


def _block_pair(grain: int):
    """Per-block (hash, base^grain) pair, Horner fold over the block."""
    p_const = pow(BASE, grain, MOD)

    def pair(block: jax.Array) -> jax.Array:
        def step(h, c):
            return (h * jnp.uint32(BASE) + c) % jnp.uint32(MOD), None

        h, _ = jax.lax.scan(step, jnp.uint32(0), block.astype(jnp.uint32))
        return jnp.stack([h, jnp.uint32(p_const)])

    return pair


def _combine(l: jax.Array, r: jax.Array) -> jax.Array:
    """(h, p) homomorphism combine on [..., 2]-stacked pairs."""
    l = l.astype(jnp.uint32)
    r = r.astype(jnp.uint32)
    h = (l[..., 0] * r[..., 1] + r[..., 0]) % jnp.uint32(MOD)
    p = (l[..., 1] * r[..., 1]) % jnp.uint32(MOD)
    return jnp.stack([h, p], axis=-1)


def stringhash_program(grain: int):
    """The Rabin-Karp pipeline as an ordinary traced program."""

    @sac.incremental(block=grain)
    def rk(text):
        pairs = sac.map_blocks(_block_pair(grain), text, out_block=1,
                               name="rk.leaf")
        # The combine's neutral element is the PAIR (h=0, p=1): it is
        # what identity-padded odd reduce levels splice in, so a scalar
        # 0 here would annihilate the hash on non-power-of-two counts.
        return sac.reduce(_combine, pairs,
                          identity=jnp.array([0, 1], jnp.uint32), name="rk")

    return rk


def stringhash_graph(n: int, grain: int = 64, *, max_sparse="auto",
                     use_pallas="auto", backend: str = "graph"):
    """Trace + compile the Rabin-Karp pipeline via ``@sac.incremental``.

    Returns the compiled handle (``.run`` / ``.update`` / ``.stats``);
    feed it the character codes as the ``"text"`` input (int32 [n]).
    """
    assert n % grain == 0
    if backend == "host":
        return stringhash_program(grain).compile("host", text=n)
    return stringhash_program(grain).compile(
        text=n, max_sparse=max_sparse, use_pallas=use_pallas)


def stringhash_oracle(codes: Sequence[int]) -> int:
    """From-scratch Rabin-Karp hash in exact Python integers."""
    h = 0
    for c in codes:
        h = (h * BASE + int(c)) % MOD
    return h


class GraphStringHash:
    """Drop-in style app facade mirroring repro.apps.stringhash usage."""

    name = "stringhash_graph"

    def __init__(self, n: int = 65536, grain: int = 64, seed: int = 0,
                 backend: str = "graph"):
        import numpy as np

        self.n, self.grain = n, grain
        self.rng = np.random.default_rng(seed)
        self.codes = self.rng.integers(97, 123, n).astype("int32")
        self.handle = stringhash_graph(n, grain, backend=backend)

    def run(self):
        # jnp.array (not asarray): self.codes is mutated in place between
        # updates, so hand jax a copy, never a zero-copy view.
        return self.handle.run(text=jnp.array(self.codes))

    def apply_update(self, k: int) -> dict:
        """Edit k random characters; propagate; return stats."""
        idx = self.rng.choice(self.n, size=k, replace=False)
        self.codes[idx] = self.rng.integers(97, 123, k).astype("int32")
        self.handle.update(text=jnp.array(self.codes))
        return self.handle.stats

    def output(self) -> int:
        return int(self.handle.outputs()[0, 0])

    def expected(self) -> int:
        return stringhash_oracle(self.codes)
