"""Sparse/dense crossover calibration for the compiled graph runtime.

Per node, per update, the runtime picks between the sparse regime
(gather <= k dirty blocks, recompute, scatter) and the dense regime (one
masked pass over all blocks).  The crossover ``k`` used to be a constant
(``max_sparse=64``); this module calibrates it per level from one timed
warmup pass, run when the compiled program is first initialized (that is
when every node's feature width — hence its real per-block payload — is
known).

The crossover is dominated by the *regime mechanics* — gather/scatter
overhead vs full-pass bandwidth — not by the user's combining function
(both regimes apply it to the same lanes), so calibration times a
synthetic elementwise update of the level's [num_blocks, width] shape:

  * ``t_dense``      — one masked pass over all blocks;
  * ``t_sparse(k)``  — gather k lanes, recompute, scatter; measured at
    two k values and modelled linearly, t_sparse(k) ~= a + b*k.

The calibrated crossover is the k where the lines meet, clamped to
[8, num_blocks].  Results are memoized process-wide on (num_blocks,
width) so repeated compiles of same-shaped levels (the common case in
tests and serving) pay for the timing once.

``max_sparse=<int>`` on compile() bypasses all of this (the old
constant behaviour); degenerate timings fall back to the old default.
"""
from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["calibrated_max_sparse", "DEFAULT_MAX_SPARSE", "clear_cache"]

DEFAULT_MAX_SPARSE = 64          # fallback when timing is degenerate

_CACHE: Dict[Tuple[int, int], int] = {}


def clear_cache() -> None:
    _CACHE.clear()


def _best_ms(fn, *args, reps: int = 3) -> float:
    out = fn(*args)                      # warmup (compile)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def calibrated_max_sparse(num_blocks: int, width: int) -> int:
    """Crossover k for a level of ``num_blocks`` blocks of ``width``
    elements, from one timed warmup (memoized)."""
    if num_blocks <= 16:
        return num_blocks                # sparse can never lose: one pass
    key = (num_blocks, width)
    if key in _CACHE:
        return _CACHE[key]
    k = _measure(num_blocks, max(width, 1))
    _CACHE[key] = k
    return k


def _measure(nb: int, w: int) -> int:
    try:
        x = jnp.ones((nb, w), jnp.float32)
        mask = jnp.ones((nb,), bool)

        @jax.jit
        def dense(x):
            new = x * 1.0001 + 1.0
            return jnp.where(mask[:, None], new, x)

        def make_sparse(k):
            idx = jnp.arange(k, dtype=jnp.int32)

            @jax.jit
            def sparse(x):
                g = x.at[idx].get(mode="fill", fill_value=0)
                return x.at[idx].set(g * 1.0001 + 1.0, mode="drop")

            return sparse

        k_lo, k_hi = 1, min(nb, 256)
        t_dense = _best_ms(dense, x)
        t_lo = _best_ms(make_sparse(k_lo), x)
        t_hi = _best_ms(make_sparse(k_hi), x)
        slope = (t_hi - t_lo) / max(k_hi - k_lo, 1)
        if slope <= 0 or t_dense <= t_lo:
            # Gather overhead already beats (or timing can't resolve) a
            # dense pass at this size: the constant served fine, keep it.
            return min(DEFAULT_MAX_SPARSE, nb)
        k_star = int((t_dense - t_lo) / slope) + k_lo
        return max(8, min(k_star, nb))
    except Exception:                    # pragma: no cover - timing guard
        return min(DEFAULT_MAX_SPARSE, nb)
