"""Per-kind semantics of SP-dag nodes: forward, dirty transfer, recompute.

For every node kind this module supplies the four pieces the compiled
runtime (graph_compile.py) assembles:

  * ``forward(node, parents)``       — from-scratch value of the node.
  * ``edge_dirty(node, changed)``    — push per-block *changed* masks of
    the parents through the edge's reader index map: out block i is dirty
    iff some block it reads changed (the mark phase of Algorithm 2,
    vectorized).
  * ``dense_update``                 — recompute every block under a mask
    (one fused pass; clean blocks keep their old value bitwise).
  * ``sparse_update``                — gather the <= k dirty blocks,
    recompute just those lanes, scatter back (O(k) work).

Both recompute regimes produce identical values; the runtime picks per
node per update by dirty count, generalizing the regime switch of
``reduce.py``.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

from .core import broadcast_mask as _bc
from .dirtyset import DirtySet
from .graph import GNode

__all__ = ["forward", "edge_dirty", "dense_update", "sparse_update"]


def _as_blocks(x: jax.Array, num_blocks: int, block: int) -> jax.Array:
    return x.reshape((num_blocks, block) + x.shape[1:])


def _from_blocks(xb: jax.Array) -> jax.Array:
    return xb.reshape((xb.shape[0] * xb.shape[1],) + xb.shape[2:])


def _pack(node: GNode, raw: jax.Array) -> jax.Array:
    """vmap output [nb, ...] -> node value layout [nb*block, *feat]."""
    if node.block == 1:
        return raw
    assert raw.shape[1] == node.block, (
        f"node {node.name}: per-block fn returned leading {raw.shape[1:]}, "
        f"expected out_block={node.block}")
    return raw.reshape((node.num_blocks * node.block,) + raw.shape[2:])


def _parent(node: GNode, nodes) -> GNode:
    return nodes[node.deps[0]]


def _identity_row(node: GNode, like: jax.Array) -> jax.Array:
    """The op identity broadcast to one row of ``like`` ([*feat]).

    Identities may be non-scalar (e.g. the Rabin-Karp combine's neutral
    pair (h=0, p=1)); broadcasting keeps both forms working everywhere
    padding is needed."""
    return jnp.broadcast_to(jnp.asarray(node.identity, like.dtype),
                            like.shape[1:])


# ---------------------------------------------------------------------------
# Window construction (stencil)
# ---------------------------------------------------------------------------
def _windows(node: GNode, p: GNode, x: jax.Array,
             idx: Optional[jax.Array] = None) -> jax.Array:
    """[len(idx), (2r+1)*block, *feat] neighbourhood view of the parent
    at output blocks ``idx`` (all blocks when None — the dense pass)."""
    xb = _as_blocks(x, p.num_blocks, p.block)
    if idx is None:
        idx = jnp.arange(p.num_blocks)
    parts = []
    for off in range(-node.radius, node.radius + 1):
        j = idx + off
        jc = jnp.clip(j, 0, p.num_blocks - 1)
        part = xb[jc]
        if node.fill is not None:
            oob = (j < 0) | (j >= p.num_blocks)
            part = jnp.where(_bc(oob, part),
                             jnp.asarray(node.fill, x.dtype), part)
        parts.append(part)
    return jnp.concatenate(parts, axis=1)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def forward(node: GNode, nodes, parents: List[jax.Array]) -> jax.Array:
    if node.kind == "map":
        p = _parent(node, nodes)
        xb = _as_blocks(parents[0], p.num_blocks, p.block)
        return _pack(node, jax.vmap(node.fn)(xb))
    if node.kind == "zip_map":
        px, py = nodes[node.deps[0]], nodes[node.deps[1]]
        xb = _as_blocks(parents[0], px.num_blocks, px.block)
        yb = _as_blocks(parents[1], py.num_blocks, py.block)
        return _pack(node, jax.vmap(node.fn)(xb, yb))
    if node.kind == "reduce_level":
        x = parents[0]
        if x.shape[0] % 2:       # odd level: pad with one identity block
            pad = _identity_row(node, x)[None]
            x = jnp.concatenate([x, pad], axis=0)
        return node.op(x[0::2], x[1::2])
    if node.kind == "stencil":
        p = _parent(node, nodes)
        win = _windows(node, p, parents[0])
        return _pack(node, jax.vmap(node.fn)(win))
    if node.kind == "causal":
        idx = jnp.arange(node.num_blocks)
        raw = jax.vmap(node.fn, in_axes=(None, 0))(parents[0], idx)
        return _pack(node, raw)
    if node.kind == "escan":
        x = parents[0]
        inclusive = jax.lax.associative_scan(node.op, x, axis=0)
        seed = _identity_row(node, x)[None]
        return jnp.concatenate([seed, inclusive[:-1]], axis=0)
    raise ValueError(f"forward of non-op node {node.kind}")


# ---------------------------------------------------------------------------
# dirty transfer (reader index maps, reversed)
# ---------------------------------------------------------------------------
def edge_dirty(node: GNode, changed: List[DirtySet]) -> DirtySet:
    """Push the parents' changed DirtySets through the edge's reader
    index map.  Representation-agnostic: both the exact per-block mask
    and the interval hull implement the same transfer methods
    (see dirtyset.py)."""
    if node.kind == "map":
        return changed[0]
    if node.kind == "zip_map":
        return changed[0].union(changed[1])
    if node.kind == "reduce_level":
        return changed[0].pair_or(node.num_blocks)
    if node.kind == "stencil":
        return changed[0].dilate(node.radius)
    if node.kind == "escan":
        # out block j reads blocks < j: exclusive prefix-OR.
        return changed[0].prefix_shift()
    if node.kind == "causal":
        # out block j reads blocks <= j: suffix (the interval edge).
        return changed[0].suffix()
    raise ValueError(node.kind)


# ---------------------------------------------------------------------------
# dense recompute (masked pass)
# ---------------------------------------------------------------------------
def dense_update(node: GNode, nodes, parents: List[jax.Array],
                 old: jax.Array, dirty: jax.Array) -> jax.Array:
    new = forward(node, nodes, parents)
    nb = node.num_blocks
    new_b = _as_blocks(new, nb, node.block)
    old_b = _as_blocks(old, nb, node.block)
    return _from_blocks(jnp.where(_bc(dirty, new_b), new_b, old_b))


# ---------------------------------------------------------------------------
# sparse recompute (gather dirty lanes, scatter back)
# ---------------------------------------------------------------------------
def sparse_update(node: GNode, nodes, parents: List[jax.Array],
                  old: jax.Array, dirty: jax.Array, k: int) -> jax.Array:
    nb = node.num_blocks
    if node.kind == "escan":
        # Carries are nb scalars-per-feature; the dense masked pass IS the
        # cheap path (and a gather-based one would serialize the prefix).
        return dense_update(node, nodes, parents, old, dirty)
    (idx,) = jnp.nonzero(dirty, size=k, fill_value=nb)

    if node.kind == "reduce_level":
        # OOB gathers (the odd level's missing right child, and sentinel
        # lanes) must read the op identity; ``fill_value`` only takes
        # scalars, so gather with a dummy fill and patch identity rows in
        # (supports non-scalar identities like the Rabin-Karp pair).
        kids = parents[0]
        ident = _identity_row(node, kids)

        def kid(i):
            g = kids.at[i].get(mode="fill", fill_value=0)
            return jnp.where(_bc(i >= kids.shape[0], g), ident, g)

        vals = node.op(kid(2 * idx), kid(2 * idx + 1))
        return old.at[idx].set(vals, mode="drop")

    if node.kind == "map":
        p = _parent(node, nodes)
        xb = _as_blocks(parents[0], p.num_blocks, p.block)
        xg = xb.at[idx].get(mode="fill", fill_value=0)
        raw = jax.vmap(node.fn)(xg)
    elif node.kind == "zip_map":
        px, py = nodes[node.deps[0]], nodes[node.deps[1]]
        xg = _as_blocks(parents[0], px.num_blocks, px.block).at[idx].get(
            mode="fill", fill_value=0)
        yg = _as_blocks(parents[1], py.num_blocks, py.block).at[idx].get(
            mode="fill", fill_value=0)
        raw = jax.vmap(node.fn)(xg, yg)
    elif node.kind == "stencil":
        # Gather only the k dirty windows; sentinel lanes (idx == nb)
        # gather clamped edge rows and are dropped by the scatter below.
        p = _parent(node, nodes)
        wg = _windows(node, p, parents[0], idx)
        raw = jax.vmap(node.fn)(wg)
    elif node.kind == "causal":
        # fn sees the full parent; sentinel lanes (idx == nb) compute a
        # full-prefix value and are dropped by the scatter below.
        raw = jax.vmap(node.fn, in_axes=(None, 0))(parents[0], idx)
    else:
        raise ValueError(node.kind)

    old_b = _as_blocks(old, nb, node.block)
    if node.block == 1:  # fn returned [*feat] per block; add the block axis
        vals_b = raw.reshape((k, 1) + raw.shape[1:])
    else:
        vals_b = raw
    return _from_blocks(old_b.at[idx].set(vals_b, mode="drop"))
