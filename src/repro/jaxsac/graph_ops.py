"""Per-kind semantics of SP-dag nodes: forward, dirty transfer, recompute.

For every node kind this module supplies the four pieces the compiled
runtime (graph_compile.py) assembles:

  * ``forward(node, parents)``       — from-scratch value of the node.
  * ``edge_dirty(node, changed)``    — push per-block *changed* masks of
    the parents through the edge's reader index map: out block i is dirty
    iff some block it reads changed (the mark phase of Algorithm 2,
    vectorized).
  * ``dense_update``                 — recompute every block under a mask
    (one fused pass; clean blocks keep their old value bitwise).
  * ``sparse_update``                — gather the <= k dirty blocks,
    recompute just those lanes, scatter back (O(k) work).  Also returns
    the *lane-local* Algorithm-2 cutoff — which of the gathered lanes
    actually changed value — so the runtime never has to run an O(n)
    full-array compare after an O(k) recompute.

Both recompute regimes produce identical values; the runtime picks per
node per update by dirty count, generalizing the regime switch of
``reduce.py``.

Carry-causal nodes (``causal`` with a declared carry monoid — see
``GraphBuilder.causal``) additionally get ``causal_carry_states`` /
``causal_carry_update``: the per-block inclusive carry states are cached
in the propagation state, so a dirty suffix recombines the cached prefix
state in O(suffix) instead of rescanning its full prefix per block (the
flash-style block-skip; the Pallas tile-skipping variant lives in
``repro.kernels.dirty_causal``).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from .core import broadcast_mask as _bc
from .dirtyset import DirtySet
from .graph import GNode

__all__ = ["forward", "edge_dirty", "gather_indices", "mask_indices",
           "dense_update", "sparse_update", "sparse_update_group",
           "causal_carry_states", "causal_carry_refold",
           "causal_finalize_sparse", "causal_finalize_dense",
           "escan_block_skip", "exact_dtype"]


def mask_indices(mask: jax.Array, k: int) -> jax.Array:
    """Indices of the first <= k set bits of ``mask``, ascending, padded
    with the sentinel ``num_blocks`` — the device-side twin of the
    host's ``np.flatnonzero`` + pad.

    The j-th set bit is the first position whose running count reaches
    j+1 (``searchsorted`` on the running sum; a query past the total
    lands at the sentinel).  No scatter and no sort: in-jit
    ``jnp.nonzero(size=k)`` lowers to a full sort on CPU and a
    scatter-based extraction serializes one update per block — either
    by itself can cost more than the sparse recompute it feeds.  Large
    masks take a two-level form — per-row counts, a tiny row cumsum,
    then the ``searchsorted`` recursion within the <= k touched rows —
    because one flat O(num_blocks) cumsum alone costs more than a
    small sparse recompute at serving block counts.  Keeping the
    extraction on device is what lets the plan cache skip the host
    plan-freeze round-trip entirely on a signature hit.
    """
    nb = mask.shape[0]
    queries = jnp.arange(1, k + 1, dtype=jnp.int32)
    if nb <= 2048:
        csum = jnp.cumsum(mask.astype(jnp.int32))
        idx = jnp.searchsorted(csum, queries, side="left")
        return jnp.minimum(idx, nb).astype(jnp.int32)
    C = 128                              # row width of the two-level form
    pad = (-nb) % C
    m2 = (jnp.concatenate([mask, jnp.zeros((pad,), bool)]) if pad
          else mask).reshape(-1, C)
    rows_csum = jnp.cumsum(jnp.sum(m2.astype(jnp.int32), axis=1))
    row = jnp.searchsorted(rows_csum, queries, side="left")
    rowc = jnp.clip(row, 0, m2.shape[0] - 1)
    before = jnp.where(rowc > 0, rows_csum[rowc - 1], 0)
    within = jnp.cumsum(m2[rowc].astype(jnp.int32), axis=1)   # [k, C]
    col = jax.vmap(
        lambda c, q: jnp.searchsorted(c, q, side="left"))(
            within, queries - before)
    return jnp.minimum(rowc * C + col, nb).astype(jnp.int32)


def exact_dtype(dtype) -> bool:
    """True when the dtype's arithmetic is exactly associative, so any
    re-bracketing of a fold (the block-skip recombination) is bitwise
    equal to the from-scratch ``associative_scan``.  Floats re-associate
    at ulp level, which would break the bitwise value cutoff — they stay
    on the dense oracle path unless the user forces ``block_skip``."""
    return jnp.issubdtype(dtype, jnp.integer) or jnp.issubdtype(dtype, jnp.bool_)


def _as_blocks(x: jax.Array, num_blocks: int, block: int) -> jax.Array:
    return x.reshape((num_blocks, block) + x.shape[1:])


def _from_blocks(xb: jax.Array) -> jax.Array:
    return xb.reshape((xb.shape[0] * xb.shape[1],) + xb.shape[2:])


def _pack(node: GNode, raw: jax.Array) -> jax.Array:
    """vmap output [nb, ...] -> node value layout [nb*block, *feat]."""
    if node.block == 1:
        return raw
    assert raw.shape[1] == node.block, (
        f"node {node.name}: per-block fn returned leading {raw.shape[1:]}, "
        f"expected out_block={node.block}")
    return raw.reshape((node.num_blocks * node.block,) + raw.shape[2:])


def _parent(node: GNode, nodes) -> GNode:
    return nodes[node.deps[0]]


def _identity_row(node: GNode, like: jax.Array) -> jax.Array:
    """The op identity broadcast to one row of ``like`` ([*feat]).

    Identities may be non-scalar (e.g. the Rabin-Karp combine's neutral
    pair (h=0, p=1)); broadcasting keeps both forms working everywhere
    padding is needed."""
    return jnp.broadcast_to(jnp.asarray(node.identity, like.dtype),
                            like.shape[1:])


# ---------------------------------------------------------------------------
# Window construction (stencil)
# ---------------------------------------------------------------------------
def _windows(node: GNode, p: GNode, x: jax.Array,
             idx: Optional[jax.Array] = None) -> jax.Array:
    """[len(idx), (2r+1)*block, *feat] neighbourhood view of the parent
    at output blocks ``idx`` (all blocks when None — the dense pass)."""
    xb = _as_blocks(x, p.num_blocks, p.block)
    if idx is None:
        idx = jnp.arange(p.num_blocks)
    parts = []
    for off in range(-node.radius, node.radius + 1):
        j = idx + off
        jc = jnp.clip(j, 0, p.num_blocks - 1)
        part = xb[jc]
        if node.fill is not None:
            oob = (j < 0) | (j >= p.num_blocks)
            part = jnp.where(_bc(oob, part),
                             jnp.asarray(node.fill, x.dtype), part)
        parts.append(part)
    return jnp.concatenate(parts, axis=1)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def forward(node: GNode, nodes, parents: List[jax.Array]) -> jax.Array:
    if node.kind == "map":
        p = _parent(node, nodes)
        xb = _as_blocks(parents[0], p.num_blocks, p.block)
        return _pack(node, jax.vmap(node.fn)(xb))
    if node.kind == "zip_map":
        px, py = nodes[node.deps[0]], nodes[node.deps[1]]
        xb = _as_blocks(parents[0], px.num_blocks, px.block)
        yb = _as_blocks(parents[1], py.num_blocks, py.block)
        return _pack(node, jax.vmap(node.fn)(xb, yb))
    if node.kind == "reduce_level":
        x = parents[0]
        if x.shape[0] % 2:       # odd level: pad with one identity block
            pad = _identity_row(node, x)[None]
            x = jnp.concatenate([x, pad], axis=0)
        return node.op(x[0::2], x[1::2])
    if node.kind == "stencil":
        p = _parent(node, nodes)
        win = _windows(node, p, parents[0])
        return _pack(node, jax.vmap(node.fn)(win))
    if node.kind == "causal":
        if node.op is not None:          # carry-causal: scan + finalize
            p = _parent(node, nodes)
            xb = _as_blocks(parents[0], p.num_blocks, p.block)
            states = causal_carry_states(node, nodes, parents[0])
            return _pack(node, jax.vmap(node.finalize)(states, xb))
        idx = jnp.arange(node.num_blocks)
        raw = jax.vmap(node.fn, in_axes=(None, 0))(parents[0], idx)
        return _pack(node, raw)
    if node.kind == "gather":
        if node.packed_fn is not None:
            # Packed form: the per-lane function receives the lane's own
            # block plus exactly its ``arity`` neighbour blocks — no
            # full-parent view to assemble (see GraphBuilder.gather).
            p = _parent(node, nodes)
            xb = _as_blocks(parents[0], p.num_blocks, p.block)
            nbrs = xb[gather_indices(node, parents[0])]
            return _pack(node, jax.vmap(node.packed_fn)(xb, nbrs))
        idx = jnp.arange(node.num_blocks)
        raw = jax.vmap(node.fn, in_axes=(None, 0))(parents[0], idx)
        return _pack(node, raw)
    if node.kind == "escan":
        x = parents[0]
        inclusive = jax.lax.associative_scan(node.op, x, axis=0)
        seed = _identity_row(node, x)[None]
        return jnp.concatenate([seed, inclusive[:-1]], axis=0)
    raise ValueError(f"forward of non-op node {node.kind}")


# ---------------------------------------------------------------------------
# dirty transfer (reader index maps, reversed)
# ---------------------------------------------------------------------------
def edge_dirty(node: GNode, changed: List[DirtySet],
               parents: Optional[List[jax.Array]] = None) -> DirtySet:
    """Push the parents' changed DirtySets through the edge's reader
    index map.  Representation-agnostic: both the exact per-block mask
    and the interval hull implement the same transfer methods
    (see dirtyset.py).

    ``parents`` supplies the parent *values* for the one edge kind whose
    reader map is data-dependent (``gather``): the neighbour indices are
    recomputed from the cached parent, which is sound whether the values
    are pre- or post-edit — a lane whose indices changed is dirty
    through the identity component either way (see
    ``GraphBuilder.gather``)."""
    if node.kind == "map":
        return changed[0]
    if node.kind == "zip_map":
        return changed[0].union(changed[1])
    if node.kind == "reduce_level":
        return changed[0].pair_or(node.num_blocks)
    if node.kind == "stencil":
        return changed[0].dilate(node.radius)
    if node.kind == "escan":
        # out block j reads blocks < j: exclusive prefix-OR.
        return changed[0].prefix_shift()
    if node.kind == "causal":
        # out block j reads blocks <= j: suffix (the interval edge).
        return changed[0].suffix()
    if node.kind == "gather":
        assert parents is not None, "gather dirty transfer needs values"
        return changed[0].gather(gather_indices(node, parents[0]))
    raise ValueError(node.kind)


def gather_indices(node: GNode, parent: jax.Array) -> jax.Array:
    """[nb, arity] int32 neighbour block indices of a gather node,
    evaluated on the given parent value and clamped in-range.  A gather
    node has as many output blocks as its parent, so the parent's block
    size falls out of the value shape."""
    xb = _as_blocks(parent, node.num_blocks, parent.shape[0]
                    // node.num_blocks)
    idx = jnp.asarray(node.idx_fn(xb), jnp.int32)
    assert idx.shape == (node.num_blocks, node.arity), (
        f"gather {node.name}: idx_fn returned {idx.shape}, expected "
        f"{(node.num_blocks, node.arity)}")
    return jnp.clip(idx, 0, node.num_blocks - 1)


# ---------------------------------------------------------------------------
# dense recompute (masked pass)
# ---------------------------------------------------------------------------
def dense_update(node: GNode, nodes, parents: List[jax.Array],
                 old: jax.Array, dirty: jax.Array) -> jax.Array:
    new = forward(node, nodes, parents)
    nb = node.num_blocks
    new_b = _as_blocks(new, nb, node.block)
    old_b = _as_blocks(old, nb, node.block)
    return _from_blocks(jnp.where(_bc(dirty, new_b), new_b, old_b))


# ---------------------------------------------------------------------------
# sparse recompute (gather dirty lanes, scatter back, lane-local cutoff)
# ---------------------------------------------------------------------------
def _lane_changed(old_lanes: jax.Array, vals_b: jax.Array) -> jax.Array:
    """[k] bool: did the recomputed lane's value change (bitwise)?"""
    diff = old_lanes != vals_b
    return jnp.any(diff, axis=tuple(range(1, diff.ndim)))


def sparse_update(node: GNode, nodes, parents: List[jax.Array],
                  old: jax.Array, dirty: jax.Array, k: int,
                  idx: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Gather the <= k dirty blocks, recompute, scatter back.

    Returns ``(new, idx, lane_changed)``: the updated value, the gathered
    lane indices (sentinel ``num_blocks`` for unused lanes), and which of
    those lanes' values actually changed — the Algorithm-2 cutoff applied
    to O(k) lanes instead of the whole array.

    ``idx`` supplies the dirty lane indices directly (the planned
    propagate extracts them on the host from the mark phase's masks —
    ``jnp.nonzero`` inside a jit lowers to a full sort on CPU and costs
    more than the recompute it feeds); when None they are computed
    in-graph from ``dirty``.
    """
    nb = node.num_blocks
    if idx is None:
        (idx,) = jnp.nonzero(dirty, size=k, fill_value=nb)
    else:
        k = idx.shape[0]

    if node.kind == "reduce_level":
        # OOB gathers (the odd level's missing right child, and sentinel
        # lanes) must read the op identity; ``fill_value`` only takes
        # scalars, so gather with a dummy fill and patch identity rows in
        # (supports non-scalar identities like the Rabin-Karp pair).
        kids = parents[0]
        ident = _identity_row(node, kids)

        def kid(i):
            g = kids.at[i].get(mode="fill", fill_value=0)
            return jnp.where(_bc(i >= kids.shape[0], g), ident, g)

        vals = node.op(kid(2 * idx), kid(2 * idx + 1))
        old_lanes = old.at[idx].get(mode="fill", fill_value=0)
        changed = _lane_changed(old_lanes, vals)
        return old.at[idx].set(vals, mode="drop"), idx, changed

    if node.kind == "map":
        p = _parent(node, nodes)
        xb = _as_blocks(parents[0], p.num_blocks, p.block)
        xg = xb.at[idx].get(mode="fill", fill_value=0)
        raw = jax.vmap(node.fn)(xg)
    elif node.kind == "zip_map":
        px, py = nodes[node.deps[0]], nodes[node.deps[1]]
        xg = _as_blocks(parents[0], px.num_blocks, px.block).at[idx].get(
            mode="fill", fill_value=0)
        yg = _as_blocks(parents[1], py.num_blocks, py.block).at[idx].get(
            mode="fill", fill_value=0)
        raw = jax.vmap(node.fn)(xg, yg)
    elif node.kind == "stencil":
        # Gather only the k dirty windows; sentinel lanes (idx == nb)
        # gather clamped edge rows and are dropped by the scatter below.
        p = _parent(node, nodes)
        wg = _windows(node, p, parents[0], idx)
        raw = jax.vmap(node.fn)(wg)
    elif node.kind == "gather" and node.packed_fn is not None:
        # Packed sparse recompute: gather ONLY the k dirty lanes' own
        # blocks plus the arity neighbour blocks their cached indices
        # name — O(k * (1 + arity)) block reads instead of threading the
        # full parent into every lane.  ``idx_fn`` is row-wise by the
        # packed contract, so evaluating it on the gathered subset gives
        # each dirty lane its own neighbour row.
        p = _parent(node, nodes)
        xb = _as_blocks(parents[0], p.num_blocks, p.block)
        own = xb.at[idx].get(mode="fill", fill_value=0)
        nidx = jnp.clip(jnp.asarray(node.idx_fn(own), jnp.int32),
                        0, node.num_blocks - 1)
        assert nidx.shape == (k, node.arity), (nidx.shape, k, node.arity)
        raw = jax.vmap(node.packed_fn)(own, xb[nidx])
    elif node.kind in ("causal", "gather"):
        # fn sees the full parent; sentinel lanes (idx == nb) compute a
        # clamped-index value and are dropped by the scatter below.
        raw = jax.vmap(node.fn, in_axes=(None, 0))(parents[0], idx)
    else:
        raise ValueError(node.kind)

    old_b = _as_blocks(old, nb, node.block)
    if node.block == 1:  # fn returned [*feat] per block; add the block axis
        vals_b = raw.reshape((k, 1) + raw.shape[1:])
    else:
        vals_b = raw
    old_lanes = old_b.at[idx].get(mode="fill", fill_value=0)
    changed = _lane_changed(old_lanes, vals_b)
    return _from_blocks(old_b.at[idx].set(vals_b, mode="drop")), idx, changed


# ---------------------------------------------------------------------------
# Level packing: batched sparse recompute for m same-fn nodes
# ---------------------------------------------------------------------------
def sparse_update_group(gnodes: List[GNode], nodes,
                        parents_per: List[List[jax.Array]],
                        olds: List[jax.Array], masks: List[jax.Array],
                        k: int, gidx: Optional[jax.Array] = None):
    """One batched gather -> fn -> scatter for ``m`` same-kind nodes of a
    level that share the same per-block function and block geometry
    (parallel reduce trees, replicated map pipelines under ``par``).

    One ``nonzero`` over the concatenated masks and ONE vmapped ``fn``
    application cover all m nodes (one kernel launch per level instead of
    per node); gathers/scatters stay per member so each node's buffer
    still updates in place under donation.  Returns
    ``(new_values, per_node_idx, per_node_lane_changed)``.
    """
    m = len(gnodes)
    nd = gnodes[0]
    nb = nd.num_blocks
    if gidx is None:
        mask_st = jnp.concatenate(masks)                # [m*nb]
        (gidx,) = jnp.nonzero(mask_st, size=k, fill_value=m * nb)
    else:
        k = gidx.shape[0]
    g = gidx // nb                                      # member (m = sentinel)
    i = jnp.where(g < m, gidx - g * nb, nb)             # block (nb = sentinel)

    def member_select(per_member):
        """[k, ...] lanes: member g's gather at lane positions, 0 else."""
        out = None
        for j, got in enumerate(per_member):
            sel = _bc((g == j), got)
            out = jnp.where(sel, got, 0) if out is None else (
                jnp.where(sel, got, out))
        return out

    if nd.kind == "reduce_level":
        ident = _identity_row(nd, parents_per[0][0])

        def kid(member_kids, ci):
            gg = member_kids.at[ci].get(mode="fill", fill_value=0)
            return jnp.where(_bc(ci >= member_kids.shape[0], gg), ident, gg)

        left = member_select([kid(p[0], 2 * i) for p in parents_per])
        right = member_select([kid(p[0], 2 * i + 1) for p in parents_per])
        vals = nd.op(left, right)
        vals_b = vals          # reduce_level values are [nb, *feat] rows
        olds_rows = olds
    else:
        gathered = []
        for dep_pos, d in enumerate(nd.deps):
            p = nodes[d]
            per_member = [
                _as_blocks(parents_per[j][dep_pos], p.num_blocks,
                           p.block).at[i].get(mode="fill", fill_value=0)
                for j in range(m)]
            gathered.append(member_select(per_member))
        raw = jax.vmap(nd.fn)(*gathered)
        if nd.block == 1:
            vals_b = raw.reshape((k, 1) + raw.shape[1:])
        else:
            vals_b = raw
        olds_rows = [_as_blocks(o, nb, nd.block) for o in olds]

    old_lanes = member_select(
        [o.at[i].get(mode="fill", fill_value=0) for o in olds_rows])
    lane_changed = _lane_changed(old_lanes, vals_b)

    news, idxs, lcs = [], [], []
    for j in range(m):
        idx_j = jnp.where(g == j, i, nb)                # drop other members
        scat = olds_rows[j].at[idx_j].set(vals_b, mode="drop")
        news.append(scat if nd.kind == "reduce_level" else
                    _from_blocks(scat))
        idxs.append(idx_j)
        lcs.append(lane_changed & (g == j))
    return news, idxs, lcs
def causal_carry_states(node: GNode, nodes, parent: jax.Array) -> jax.Array:
    """[nb, *state_feat] inclusive carry states of a carry-causal node:
    ``states[i] = fold(lift(block_0) .. lift(block_i))`` under ``op``."""
    p = _parent(node, nodes)
    xb = _as_blocks(parent, p.num_blocks, p.block)
    contrib = jax.vmap(node.lift)(xb)
    return jax.lax.associative_scan(node.op, contrib, axis=0)


def _seed_row(node: GNode, old_states: jax.Array,
              start: jax.Array) -> jax.Array:
    """``states[start-1]`` (the cached clean prefix state just before the
    dirty suffix), or the op identity when ``start == 0``."""
    prev = jnp.take(old_states, jnp.maximum(start - 1, 0), axis=0,
                    mode="clip")
    ident = jnp.broadcast_to(
        jnp.asarray(node.identity, old_states.dtype), prev.shape)
    return jnp.where(start > 0, prev, ident)


def _masked_refold(node: GNode, contrib: jax.Array, seed: jax.Array,
                   old_states: jax.Array, start: jax.Array) -> jax.Array:
    """Recombine: keep states < start, recompute the suffix from the
    cached ``seed = states[start-1]`` instead of rescanning the prefix.

    Clean-prefix contributions are replaced by the op identity, so the
    masked scan's row i (i >= start) is ``fold(contrib[start..i])`` and
    ``op(seed, ·)`` completes the state.  ``op(identity, x) == x`` and
    exact associativity are assumed (the caller gates on ``exact_dtype``
    or an explicit ``block_skip`` force); under those, the result is
    bitwise equal to the from-scratch scan.
    """
    nb = contrib.shape[0]
    in_suffix = jnp.arange(nb) >= start
    ident = _identity_row(node, contrib)
    masked = jnp.where(_bc(in_suffix, contrib), contrib, ident)
    suffix_fold = jax.lax.associative_scan(node.op, masked, axis=0)
    recombined = jax.vmap(node.op, in_axes=(None, 0))(seed, suffix_fold)
    return jnp.where(_bc(in_suffix, old_states), recombined, old_states)


def causal_carry_refold(node: GNode, nodes, parent: jax.Array,
                        old_states: jax.Array, start: jax.Array,
                        block_skip: bool) -> jax.Array:
    """Updated carry states of a carry-causal node.

    ``block_skip=True`` recombines the cached prefix state (bitwise-safe
    for exact dtypes only); otherwise the states are rescanned from
    scratch, which is bitwise identical to ``forward`` for any dtype.
    """
    if not block_skip:
        return causal_carry_states(node, nodes, parent)
    p = _parent(node, nodes)
    xb = _as_blocks(parent, p.num_blocks, p.block)
    contrib = jax.vmap(node.lift)(xb)
    seed = _seed_row(node, old_states, start)
    return _masked_refold(node, contrib, seed, old_states, start)


def causal_finalize_dense(node: GNode, nodes, parent: jax.Array,
                          states: jax.Array, old: jax.Array,
                          dirty: jax.Array) -> jax.Array:
    """Masked dense finalize pass of a carry-causal node."""
    p = _parent(node, nodes)
    xb = _as_blocks(parent, p.num_blocks, p.block)
    new = _pack(node, jax.vmap(node.finalize)(states, xb))
    nb = node.num_blocks
    new_b = _as_blocks(new, nb, node.block)
    old_b = _as_blocks(old, nb, node.block)
    return _from_blocks(jnp.where(_bc(dirty, new_b), new_b, old_b))


def causal_finalize_sparse(node: GNode, nodes, parent: jax.Array,
                           states: jax.Array, old: jax.Array,
                           dirty: jax.Array, k: int,
                           idx: Optional[jax.Array] = None,
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Gather the <= k dirty blocks' states + input blocks, finalize just
    those lanes, scatter; returns ``(new, idx, lane_changed)``.
    ``idx`` as in ``sparse_update``."""
    nb = node.num_blocks
    if idx is None:
        (idx,) = jnp.nonzero(dirty, size=k, fill_value=nb)
    else:
        k = idx.shape[0]
    p = _parent(node, nodes)
    xb = _as_blocks(parent, p.num_blocks, p.block)
    sg = states.at[idx].get(mode="fill", fill_value=0)
    xg = xb.at[idx].get(mode="fill", fill_value=0)
    raw = jax.vmap(node.finalize)(sg, xg)
    old_b = _as_blocks(old, nb, node.block)
    if node.block == 1:
        vals_b = raw.reshape((k, 1) + raw.shape[1:])
    else:
        vals_b = raw
    old_lanes = old_b.at[idx].get(mode="fill", fill_value=0)
    changed = _lane_changed(old_lanes, vals_b)
    return _from_blocks(old_b.at[idx].set(vals_b, mode="drop")), idx, changed


def escan_block_skip(node: GNode, agg: jax.Array, old_c: jax.Array,
                     start: jax.Array) -> jax.Array:
    """Block-skip recompute of an exclusive carry scan: keep carries
    before the dirty suffix, reseed the suffix from the cached carry
    ``old_c[start-1]`` (pure-jnp reference of the ``dirty_causal`` Pallas
    kernel; bitwise equal to the dense path for exact dtypes).
    """
    ident = _identity_row(node, agg)[None]
    shifted = jnp.concatenate([ident, agg[:-1]], axis=0)
    seed = _seed_row(node, old_c, start)
    return _masked_refold(node, shifted, seed, old_c, start)
