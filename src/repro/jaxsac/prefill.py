"""Incremental prefill: change propagation through the serving path.

The serving-side integration of the paper's technique.  A prompt of S
tokens was prefilled once (initial run, KV cache = the memoized trace).
The prompt is then *edited* — k tokens change (typically late in the
prompt: a revised instruction, an updated retrieval chunk).  Instead of
re-running prefill from scratch, ``incremental_prefill`` re-establishes
the exact cache by re-executing only the *affected* positions.

Affected-position analysis per layer type (DESIGN.md §Adaptation):

  * token-local ops (embed, norms, q/k/v projections, MLP, MoE routing —
    MoE routing is per-token!): position p affected iff token p changed;
  * causal global attention: position p reads all kv <= p, so the dirty
    set is the suffix [p0, S), p0 = first changed position.  Suffixes are
    a fixed point of every rule, so the whole network propagates the
    single interval [p0, S) — the RSP-tree mark phase collapses to one
    interval comparison;
  * the value-equality write cutoff (paper Algorithm 2) applies at cache
    granularity: unchanged prefix cache blocks are never touched.

The mark phase is no longer hand-rolled: it runs on the graph runtime's
dirty representations (``jaxsac.dirtyset``) — the edit diff as a
``MaskDirty``, folded through the per-layer edge chain (token-local =
identity, causal attention = the interval-carrying edge's suffix
transfer) as an ``IntervalDirty``.  The serving path and the compiled
graph runtime therefore share one dirty-set vocabulary.

Work: O((S - p0) / S) of a full prefill per layer — for the common
"edit near the end" case this is the same order of savings the paper
reports for its dynamic-sequence benchmarks.  The continuation for the
suffix queries attends over [0, S) using the cached prefix K/V, with the
flash block-skip honoring the causal offset.

``p0`` is static per compilation (bucketed to the attention block size),
the standard shape-bucketing of production serving systems; the jit cache
holds one executable per bucket.

Supported families: dense, vlm (text edits), moe (GQA and MLA paths,
dense-residual and dense-prefix layers included).  Not supported (see
DESIGN.md §Arch-applicability): ssm/hybrid (recurrent state would need
checkpointed per-interval states — the RSP-tree analogue for scans) and
encdec (bidirectional encoder attention has unbounded propagation:
every encoder position reads every other, so the computation distance of
any edit is Θ(n) and from-scratch is optimal — the paper's own framework
predicts this).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import mla as mla_mod
from ..models import moe as moe_mod
from ..models.attention import _blocked_attention, _naive_attention
from ..models.layers import apply_norm, apply_rope, embed_tokens, lm_logits, mlp_fwd, rope
from ..models.lm import _res
from .dirtyset import IntervalDirty, MaskDirty

__all__ = ["incremental_prefill", "continue_prefill", "prefill_distance"]

SUPPORTED = ("dense", "vlm", "moe")

# The per-layer dirty-transfer chain of one transformer block, in the
# runtime's edge vocabulary (graph_ops.edge_dirty): token-local ops
# (norms, q/k/v, MLP, MoE routing) are identity edges; causal attention
# is the interval-carrying "causal" edge whose transfer is the suffix
# hull.  Residual adds are zip_map edges (union) of two suffixes — also
# a suffix.  Suffix intervals are a fixed point of every rule, which is
# why the mark phase of the whole network folds into one IntervalDirty.
_LAYER_EDGES = ("map", "causal", "map")       # ln/qkv -> attend -> mlp


# ---------------------------------------------------------------------------
# Change analysis (host side — the mark phase)
# ---------------------------------------------------------------------------
def prefill_distance(old_tokens, new_tokens, *, block: int = 512,
                     prefix_offset: int = 0) -> Dict[str, Any]:
    """Computation distance of a prompt edit (Definition 4.2 analogue).

    The mark phase runs on the runtime's DirtySet representations
    (dirtyset.py): the token-level edit diff becomes a ``MaskDirty``,
    its hull an ``IntervalDirty``, and the per-layer transfer chain
    (``_LAYER_EDGES``) folds it to the dirty suffix that causal
    attention forces — two integers instead of a position mask, for any
    depth.  Returns the first changed position p0 (bucketed down to
    ``block``), the number of recomputed positions, and the work-savings
    ratio (positions saved / total) that the interval rule realizes.
    """
    import numpy as np

    old = np.asarray(old_tokens)
    new = np.asarray(new_tokens)
    assert old.shape == new.shape
    S = old.shape[-1] + prefix_offset
    flat_old = old if old.ndim == 2 else old[None]
    flat_new = new if new.ndim == 2 else new[None]
    changed = MaskDirty(jnp.asarray((flat_old != flat_new).any(axis=0)))
    changed_tokens = int(changed.count())
    if changed_tokens == 0:
        return dict(p0=S, p0_bucket=S, recompute=0, total=S,
                    savings=float("inf"), changed_tokens=0)
    iv = IntervalDirty.from_mask(changed.mask)
    for kind in _LAYER_EDGES:
        iv = iv if kind == "map" else iv.suffix()
    p0 = int(iv.lo) + prefix_offset
    p0_bucket = (p0 // block) * block
    rec = S - p0_bucket
    return dict(p0=p0, p0_bucket=p0_bucket, recompute=rec, total=S,
                savings=S / rec, changed_tokens=changed_tokens)


def _prefill_distance_legacy(old_tokens, new_tokens, *, block: int = 512,
                             prefix_offset: int = 0) -> Dict[str, Any]:
    """Pre-redesign hand-rolled mark phase (numpy index scanning); kept
    verbatim as the equivalence oracle for tests."""
    import numpy as np

    old = np.asarray(old_tokens)
    new = np.asarray(new_tokens)
    assert old.shape == new.shape
    S = old.shape[-1] + prefix_offset
    diff = (old != new).any(axis=0) if old.ndim == 2 else (old != new)
    idx = np.nonzero(diff)[0]
    if len(idx) == 0:
        return dict(p0=S, p0_bucket=S, recompute=0, total=S, savings=float("inf"),
                    changed_tokens=0)
    p0 = int(idx[0]) + prefix_offset
    p0_bucket = (p0 // block) * block
    rec = S - p0_bucket
    return dict(p0=p0, p0_bucket=p0_bucket, recompute=rec, total=S,
                savings=S / rec, changed_tokens=int(diff.sum()))


# ---------------------------------------------------------------------------
# Continuation layers (the re-executed readers)
# ---------------------------------------------------------------------------
def _flash_continue(q, k, v, p0: int):
    """Suffix-query attention through the Pallas flash kernel: query row i
    sits at absolute position p0+i (``offset``), and the kernel's causal
    block-skip never touches kv tiles beyond each query tile's frontier —
    the same cached-prefix block skip the graph runtime's ``dirty_causal``
    kernel applies to carry monoids, here on the running-softmax state.

    q: [B, Sq, H, hd]; k/v: [B, S, KV, hd] -> [B, Sq, H, hv].
    """
    import math

    from repro.kernels.ops import flash_attention

    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    S = k.shape[1]
    qg = q.reshape(B, Sq, KV, H // KV, hd)
    o = flash_attention(qg, k, v, causal=True, offset=p0,
                        q_block=math.gcd(Sq, 128),
                        kv_block=math.gcd(S, 128))
    return o.reshape(B, Sq, H, o.shape[-1])


def _attn_continue(cfg, p, x, positions, cache_k, cache_v, p0: int,
                   *, impl: str):
    """GQA attention for suffix queries against (prefix cache + new kv).

    x: [B, S-p0, D]; cache_k/v: [B, S, KV, hd] (prefix rows valid).
    Returns (out, (k_full, v_full)) with suffix rows refreshed.
    """
    hd = p["wq"].shape[-1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k_suf = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_suf = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    sin, cos = rope(positions, hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k_suf = apply_rope(k_suf, sin, cos)
    k_full = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_suf.astype(cache_k.dtype), p0, axis=1)
    v_full = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_suf.astype(cache_v.dtype), p0, axis=1)
    # End-aligned attention: query i sits at absolute position p0 + i.
    Sq = q.shape[1]
    if impl == "flash":
        o = _flash_continue(q, k_full.astype(q.dtype),
                            v_full.astype(q.dtype), p0)
    elif impl == "blocked" and Sq >= 1024:
        o = _blocked_attention(q, k_full.astype(q.dtype), v_full.astype(q.dtype),
                               causal=True, window=0, q_block=512, kv_block=512)
    else:
        o = _naive_attention(q, k_full.astype(q.dtype), v_full.astype(q.dtype),
                             causal=True, window=0)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (k_full, v_full)


def _mla_continue(cfg, p, x, positions, cache_ckv, cache_krope, p0: int,
                  *, impl: str):
    """MLA (expanded form) for suffix queries against the latent cache."""
    B = x.shape[0]
    H = cfg.num_heads
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q_nope, q_rope = mla_mod._project_q(cfg, p, x, positions)
    c_suf, kr_suf = mla_mod._project_kv_latent(cfg, p, x, positions)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_suf.astype(cache_ckv.dtype), p0, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache_krope, kr_suf.astype(cache_krope.dtype), p0, axis=1)
    S = ckv.shape[1]
    # Expand keys/values for the full context from the latent cache (the
    # same expansion full prefill performs; the *savings* are every other
    # op on the prefix — norms, q path, MLP/MoE, and all later layers).
    k_nope = jnp.einsum("bsc,chk->bshk", ckv.astype(x.dtype), p["wk_b"])
    v = jnp.einsum("bsc,chk->bshk", ckv.astype(x.dtype), p["wv_b"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope.astype(x.dtype)[:, :, None, :],
                                  (B, S, H, dr))], axis=-1)
    Sq = q.shape[1]
    if impl == "flash":
        # Expanded MLA heads attend ungrouped: KV = H, G = 1.
        o = _flash_continue(q, k, v, p0)
    elif impl == "blocked" and Sq >= 1024:
        o = _blocked_attention(q, k, v, causal=True, window=0,
                               q_block=512, kv_block=512)
    else:
        o = _naive_attention(q, k, v, causal=True, window=0)
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
    return out, (ckv, krope)


def _block_continue(cfg, p, x, positions, cache_pair, p0, *, moe: bool,
                    impl: str):
    """One transformer block on the dirty suffix (mirrors lm._attn_*_block)."""
    h = apply_norm(cfg, p["ln1"], x)
    if cfg.attention == "mla":
        a, upd = _mla_continue(cfg, p["attn"], h, positions,
                               cache_pair[0], cache_pair[1], p0, impl=impl)
    else:
        a, upd = _attn_continue(cfg, p["attn"], h, positions,
                                cache_pair[0], cache_pair[1], p0, impl=impl)
    x = _res(cfg, x, a)
    h = apply_norm(cfg, p["ln2"], x)
    if moe:
        mo, _aux = moe_mod.moe_fwd(cfg, p["moe"], h)
        if cfg.moe_dense_residual:
            mo = mo + mlp_fwd(cfg, p["mlp"], h)
        x = _res(cfg, x, mo)
    else:
        x = _res(cfg, x, mlp_fwd(cfg, p["mlp"], h))
    return x, upd


# ---------------------------------------------------------------------------
# Continuation backbone
# ---------------------------------------------------------------------------
def continue_prefill(cfg, params, batch, cache, p0: int, *,
                     impl: str = "blocked"):
    """Re-execute prefill for positions [p0, S) against an existing cache.

    ``batch['tokens']`` is the FULL (edited) token array; the suffix is
    sliced internally so the caller's shapes never depend on p0.  Returns
    (last-token logits, refreshed cache) — bit-identical to
    ``lm_prefill`` on the edited prompt when cache_dtype == activations.
    """
    fam = cfg.family
    if fam not in SUPPORTED:
        raise NotImplementedError(
            f"incremental prefill not supported for family '{fam}' "
            "(see DESIGN.md §Arch-applicability)")
    from ..models.attention import inference_mode
    from ..models.moe import dropless_moe

    with inference_mode(), dropless_moe():
        return _continue_prefill(cfg, params, batch, cache, p0, impl=impl)


def _continue_prefill(cfg, params, batch, cache, p0: int, *, impl: str):
    fam = cfg.family
    tokens = batch["tokens"]
    B = tokens.shape[0]
    prefix = 0
    if fam == "vlm":
        prefix = cfg.num_patches
        assert p0 >= prefix, "edits inside the patch prefix need full prefill"
    S = tokens.shape[1] + prefix
    assert 0 <= p0 < S, (p0, S)

    tok_suf = tokens[:, p0 - prefix:]
    x = embed_tokens(cfg, params["tok"], tok_suf)
    positions = jnp.broadcast_to(jnp.arange(p0, S)[None, :], (B, S - p0))

    new_cache = dict(cache)
    if fam == "moe" and cfg.moe_dense_layers:
        cpair = ((cache["d_ckv"], cache["d_krope"]) if cfg.attention == "mla"
                 else (cache["d_k"], cache["d_v"]))

        def dblk(x, inp):
            pl, ck, cv = inp
            x, upd = _block_continue(cfg, pl, x, positions, (ck, cv), p0,
                                     moe=False, impl=impl)
            return x, upd

        x, upd = jax.lax.scan(dblk, x, (params["dense_blocks"],) + cpair)
        if cfg.attention == "mla":
            new_cache["d_ckv"], new_cache["d_krope"] = upd
        else:
            new_cache["d_k"], new_cache["d_v"] = upd

    cpair = ((cache["ckv"], cache["krope"]) if cfg.attention == "mla"
             else (cache["k"], cache["v"]))

    def blk(x, inp):
        pl, ck, cv = inp
        x, upd = _block_continue(cfg, pl, x, positions, (ck, cv), p0,
                                 moe=(fam == "moe"), impl=impl)
        return x, upd

    x, upd = jax.lax.scan(blk, x, (params["blocks"],) + cpair)
    if cfg.attention == "mla":
        new_cache["ckv"], new_cache["krope"] = upd
    else:
        new_cache["k"], new_cache["v"] = upd

    logits = lm_logits(cfg, params["tok"], x[:, -1:, :])
    return logits, new_cache


def incremental_prefill(model, params, old_tokens, new_tokens, cache,
                        *, batch_extra: Optional[Dict] = None,
                        block: int = 512, impl: str = "blocked"):
    """Edit-and-propagate: diff the prompts, re-run only the dirty suffix.

    Returns (logits, new_cache, distance_info).  Compiles one executable
    per p0 bucket (standard serving shape-bucketing).
    """
    cfg = model.cfg
    prefix = cfg.num_patches if cfg.family == "vlm" else 0
    info = prefill_distance(old_tokens, new_tokens, block=block,
                            prefix_offset=prefix)
    if info["changed_tokens"] == 0:
        return None, cache, info
    p0 = info["p0_bucket"]
    batch = dict(batch_extra or {})
    batch["tokens"] = new_tokens
    logits, new_cache = _jitted_continue(cfg, p0, impl)(params, batch, cache)
    return logits, new_cache, info


@functools.lru_cache(maxsize=64)
def _jitted_continue(cfg, p0: int, impl: str):
    def fn(params, batch, cache):
        return continue_prefill(cfg, params, batch, cache, p0, impl=impl)

    return jax.jit(fn)
