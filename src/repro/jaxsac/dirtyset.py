"""Pluggable dirty-set representations for the graph runtime.

Change propagation needs, per node, a representation of "which output
blocks must be recomputed".  The runtime historically hard-coded one: a
boolean per-block mask.  This module makes the representation pluggable
behind a small protocol so the compiled propagate can pick the cheapest
sound one per program:

  * ``MaskDirty``     — the exact per-block boolean mask (the default).
  * ``IntervalDirty`` — a single half-open block interval ``[lo, hi)``,
    the hull of the dirty blocks.  An over-approximation in general (it
    cannot represent holes), but *exact* for the suffix-shaped sets that
    causal attention and prefix scans produce — and O(1) space, which is
    what lets the serving path (``prefill.py``) mark an S-token prompt
    with two integers instead of an S/block mask.

Every edge kind of the SP-dag pushes dirtiness through its reader index
map via one of the transfer methods below; both representations implement
the same method set, so ``graph_ops.edge_dirty`` and the compiled
propagate are representation-agnostic:

  ============  ==============================  =========================
  edge kind     transfer method                 interval behaviour
  ============  ==============================  =========================
  map           identity                        exact
  zip_map       ``union``                       hull of the two intervals
  reduce_level  ``pair_or``                     exact (hull of halves)
  stencil(r)    ``dilate(r)``                   exact
  escan         ``prefix_shift``                exact (suffix)
  causal        ``suffix``                      exact (suffix) — the
                                                interval-carrying edge
  gather        ``gather(idx)``                 re-hull of the exact
                                                mask transfer
  ============  ==============================  =========================

Soundness: a transfer may over-approximate (recompute extra blocks — by
determinism they recompute to bitwise-equal values) but must never
under-approximate.  ``meet_diff`` re-applies the paper's Algorithm-2
value-equality cutoff after a recompute: the changed set is the dirty set
intersected with the blocks whose value actually changed.

Everything is jit-compatible: members are (traced) jax arrays; the
representation choice itself is static per compilation.  Both
representations are registered as jax pytrees so DirtySets can flow
through ``lax.cond`` branches (the compiled propagate's whole-level skip
returns the level's changed sets from both arms of a cond).
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from .core import dirty_from_diff

__all__ = ["DirtySet", "MaskDirty", "IntervalDirty", "DIRTY_REPS"]


@runtime_checkable
class DirtySet(Protocol):
    """What the compiled propagate needs from a dirty representation."""

    def to_mask(self) -> jax.Array: ...
    def count(self) -> jax.Array: ...
    def any(self) -> jax.Array: ...
    # edge transfers (reader index maps, reversed)
    def union(self, other: "DirtySet") -> "DirtySet": ...
    def pair_or(self, out_blocks: int) -> "DirtySet": ...
    def dilate(self, radius: int) -> "DirtySet": ...
    def prefix_shift(self) -> "DirtySet": ...
    def suffix(self) -> "DirtySet": ...
    def gather(self, idx: jax.Array) -> "DirtySet": ...
    # first dirty block index (num_blocks when empty) — the seed point of
    # the block-skip causal/escan recompute
    def start(self) -> jax.Array: ...
    # Algorithm-2 value cutoff after a recompute
    def meet_diff(self, old: jax.Array, new: jax.Array,
                  block: int) -> "DirtySet": ...


# ---------------------------------------------------------------------------
# Exact per-block mask (the historical representation)
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MaskDirty:
    """Exact dirty set: one bool per block."""

    mask: jax.Array                     # [num_blocks] bool

    @classmethod
    def none(cls, num_blocks: int) -> "MaskDirty":
        return cls(jnp.zeros((num_blocks,), bool))

    @classmethod
    def from_mask(cls, mask: jax.Array) -> "MaskDirty":
        return cls(mask)

    @classmethod
    def from_diff(cls, old: jax.Array, new: jax.Array,
                  block: int) -> "MaskDirty":
        return cls(dirty_from_diff(old, new, block))

    @classmethod
    def from_changed_lanes(cls, idx: jax.Array, lane_changed: jax.Array,
                           num_blocks: int) -> "MaskDirty":
        """Changed set from the sparse regime's lane-local cutoff: the
        gathered dirty lanes ``idx`` (sentinels == num_blocks) whose
        recomputed value differed.  O(num_blocks) scatter instead of an
        O(n) full-array compare."""
        zero = jnp.zeros((num_blocks,), bool)
        return cls(zero.at[idx].set(lane_changed, mode="drop"))

    @property
    def num_blocks(self) -> int:
        return self.mask.shape[0]

    def to_mask(self) -> jax.Array:
        return self.mask

    def count(self) -> jax.Array:
        return jnp.sum(self.mask.astype(jnp.int32))

    def any(self) -> jax.Array:
        return jnp.any(self.mask)

    # ---- transfers ---------------------------------------------------
    def union(self, other: "MaskDirty") -> "MaskDirty":
        return MaskDirty(self.mask | other.mask)

    def pair_or(self, out_blocks: int) -> "MaskDirty":
        c = self.mask
        if c.shape[0] % 2:                   # odd level: identity-padded
            c = jnp.concatenate([c, jnp.zeros((1,), bool)])
        out = c[0::2] | c[1::2]
        assert out.shape[0] == out_blocks, (out.shape, out_blocks)
        return MaskDirty(out)

    def dilate(self, radius: int) -> "MaskDirty":
        d = self.mask
        out = d
        for off in range(1, radius + 1):
            out = out | jnp.roll(d, off).at[:off].set(False)
            out = out | jnp.roll(d, -off).at[-off:].set(False)
        return MaskDirty(out)

    def prefix_shift(self) -> "MaskDirty":
        # out block j reads blocks < j: exclusive prefix-OR.
        pref = jnp.cumsum(self.mask.astype(jnp.int32)) > 0
        return MaskDirty(jnp.concatenate([jnp.zeros((1,), bool), pref[:-1]]))

    def suffix(self) -> "MaskDirty":
        # out block j reads blocks <= j: inclusive prefix-OR.
        return MaskDirty(jnp.cumsum(self.mask.astype(jnp.int32)) > 0)

    def gather(self, idx: jax.Array) -> "MaskDirty":
        # gather edge: out i reads {i} | idx[i, :] — identity OR the
        # reverse neighbour map (a gather of the mask at idx).
        jc = jnp.clip(idx, 0, self.num_blocks - 1)
        return MaskDirty(self.mask | jnp.any(self.mask[jc], axis=1))

    def start(self) -> jax.Array:
        nb = self.num_blocks
        idx = jnp.arange(nb)
        return jnp.min(jnp.where(self.mask, idx, nb)).astype(jnp.int32)

    # ---- value cutoff ------------------------------------------------
    def meet_diff(self, old: jax.Array, new: jax.Array,
                  block: int) -> "MaskDirty":
        return MaskDirty(self.mask & dirty_from_diff(old, new, block))


# ---------------------------------------------------------------------------
# Suffix/interval hull (O(1) space; exact for causal programs)
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class IntervalDirty:
    """Dirty set as the half-open block interval hull ``[lo, hi)``.

    Empty is canonically ``lo == hi == 0``.  ``num_blocks`` is static.
    """

    lo: jax.Array                       # int32 scalar
    hi: jax.Array                       # int32 scalar
    num_blocks: int = dataclasses.field(metadata=dict(static=True))

    @classmethod
    def none(cls, num_blocks: int) -> "IntervalDirty":
        z = jnp.int32(0)
        return cls(z, z, num_blocks)

    @classmethod
    def from_mask(cls, mask: jax.Array) -> "IntervalDirty":
        nb = mask.shape[0]
        idx = jnp.arange(nb)
        nonempty = jnp.any(mask)
        lo = jnp.min(jnp.where(mask, idx, nb))
        hi = jnp.max(jnp.where(mask, idx + 1, 0))
        return cls(jnp.where(nonempty, lo, 0).astype(jnp.int32),
                   jnp.where(nonempty, hi, 0).astype(jnp.int32), nb)

    @classmethod
    def from_diff(cls, old: jax.Array, new: jax.Array,
                  block: int) -> "IntervalDirty":
        return cls.from_mask(dirty_from_diff(old, new, block))

    @classmethod
    def from_changed_lanes(cls, idx: jax.Array, lane_changed: jax.Array,
                           num_blocks: int) -> "IntervalDirty":
        """Hull of the changed lanes (sentinels == num_blocks dropped)."""
        valid = lane_changed & (idx < num_blocks)
        nonempty = jnp.any(valid)
        lo = jnp.min(jnp.where(valid, idx, num_blocks))
        hi = jnp.max(jnp.where(valid, idx + 1, 0))
        return cls(jnp.where(nonempty, lo, 0).astype(jnp.int32),
                   jnp.where(nonempty, hi, 0).astype(jnp.int32), num_blocks)

    def _make(self, lo, hi, nb=None) -> "IntervalDirty":
        nb = self.num_blocks if nb is None else nb
        empty = hi <= lo
        return IntervalDirty(jnp.where(empty, 0, lo).astype(jnp.int32),
                             jnp.where(empty, 0, hi).astype(jnp.int32), nb)

    def to_mask(self) -> jax.Array:
        idx = jnp.arange(self.num_blocks)
        return (idx >= self.lo) & (idx < self.hi)

    def count(self) -> jax.Array:
        return (self.hi - self.lo).astype(jnp.int32)

    def any(self) -> jax.Array:
        return self.hi > self.lo

    # ---- transfers ---------------------------------------------------
    def union(self, other: "IntervalDirty") -> "IntervalDirty":
        # Hull of the union: empty operands must not drag lo to 0.
        big = jnp.int32(max(self.num_blocks, other.num_blocks))
        lo_a = jnp.where(self.any(), self.lo, big)
        lo_b = jnp.where(other.any(), other.lo, big)
        return self._make(jnp.minimum(lo_a, lo_b),
                          jnp.maximum(self.hi, other.hi))

    def pair_or(self, out_blocks: int) -> "IntervalDirty":
        return self._make(self.lo // 2, (self.hi + 1) // 2, out_blocks)

    def dilate(self, radius: int) -> "IntervalDirty":
        lo = jnp.maximum(self.lo - radius, 0)
        hi = jnp.minimum(self.hi + radius, self.num_blocks)
        return self._make(jnp.where(self.any(), lo, 0),
                          jnp.where(self.any(), hi, 0))

    def prefix_shift(self) -> "IntervalDirty":
        # escan: out block j reads blocks < j -> suffix from lo+1.
        return self._make(jnp.where(self.any(), self.lo + 1, 0),
                          jnp.where(self.any(), self.num_blocks, 0))

    def suffix(self) -> "IntervalDirty":
        # causal: out block j reads blocks <= j -> suffix from lo.  This
        # is the transfer rule the serving path folds per layer: suffixes
        # are a fixed point, so a whole causal network propagates one
        # (lo, hi) pair (prefill.py).
        return self._make(self.lo,
                          jnp.where(self.any(), self.num_blocks, 0))

    def gather(self, idx: jax.Array) -> "IntervalDirty":
        # Route through the exact mask transfer and re-hull: data-
        # dependent neighbour maps have no useful closed interval form,
        # and nb is small where gather nodes appear (per-lane apps).
        jc = jnp.clip(idx, 0, self.num_blocks - 1)
        m = self.to_mask()
        return IntervalDirty.from_mask(m | jnp.any(m[jc], axis=1))

    def start(self) -> jax.Array:
        return jnp.where(self.any(), self.lo,
                         self.num_blocks).astype(jnp.int32)

    # ---- value cutoff ------------------------------------------------
    def meet_diff(self, old: jax.Array, new: jax.Array,
                  block: int) -> "IntervalDirty":
        changed = self.to_mask() & dirty_from_diff(old, new, block)
        return IntervalDirty.from_mask(changed)


DIRTY_REPS = {"mask": MaskDirty, "interval": IntervalDirty}
