"""Block-granular modifiables for static-structure self-adjusting programs.

A ``BlockTensor`` is the jaxsac analogue of an array of modifiables: a
tensor whose leading axis is split into blocks of ``block`` elements, with
a boolean dirty mask per block.  Writes compare against the previous value
block-wise (the paper's Algorithm-2 cutoff: a write of an equal value
marks no readers), so propagation distance is measured in *changed*
blocks, not touched blocks.

Everything here is shape-static and jit-compatible; masks are data.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["BlockTensor", "dirty_from_diff", "blocks_of", "broadcast_mask"]


def blocks_of(n: int, block: int) -> int:
    assert n % block == 0, f"size {n} not divisible by block {block}"
    return n // block


def broadcast_mask(mask: jax.Array, like: jax.Array) -> jax.Array:
    """Broadcast a leading-axis mask over the trailing dims of ``like``."""
    return mask.reshape(mask.shape + (1,) * (like.ndim - 1))


def dirty_from_diff(old: jax.Array, new: jax.Array, block: int) -> jax.Array:
    """Per-block "value changed" mask along the leading axis.

    Equality is bitwise; deterministic programs produce bitwise-equal
    outputs for equal inputs, so a False here soundly stops propagation
    (paper, Definition 4.1: unaffected cognate reads).
    """
    assert old.shape == new.shape, (old.shape, new.shape)
    nb = blocks_of(old.shape[0], block)
    diff = (old != new).reshape((nb, block) + old.shape[1:])
    return jnp.any(diff, axis=tuple(range(1, diff.ndim)))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BlockTensor:
    """A block-modifiable: values plus a per-block dirty mask."""

    data: jax.Array          # [n, ...]
    dirty: jax.Array         # [n // block] bool
    block: int = dataclasses.field(metadata=dict(static=True), default=1)

    @classmethod
    def clean(cls, data: jax.Array, block: int = 1) -> "BlockTensor":
        nb = blocks_of(data.shape[0], block)
        return cls(data, jnp.zeros((nb,), bool), block)

    @property
    def num_blocks(self) -> int:
        return self.data.shape[0] // self.block

    def write(self, new_data: jax.Array) -> "BlockTensor":
        """Replace the contents; dirty = blocks whose value changed
        (accumulates into the existing mask)."""
        d = dirty_from_diff(self.data, new_data, self.block)
        return BlockTensor(new_data, self.dirty | d, self.block)

    def write_at(self, start: jax.Array, update: jax.Array) -> "BlockTensor":
        """Write a contiguous slice (dynamic start, static length)."""
        new_data = jax.lax.dynamic_update_slice_in_dim(
            self.data, update.astype(self.data.dtype), start, axis=0)
        d = dirty_from_diff(self.data, new_data, self.block)
        return BlockTensor(new_data, self.dirty | d, self.block)

    def clear(self) -> "BlockTensor":
        return BlockTensor(self.data, jnp.zeros_like(self.dirty), self.block)

    def dirty_count(self) -> jax.Array:
        return jnp.sum(self.dirty.astype(jnp.int32))

    def dirty_interval(self) -> tuple[jax.Array, jax.Array]:
        """(lo, hi) block interval covering all dirty blocks; lo == hi == 0
        when clean.  Interval form is what the serving path propagates —
        every layer rule (causal attention, windowed attention, recurrence)
        maps intervals to intervals (see prefill.py)."""
        nb = self.num_blocks
        idx = jnp.arange(nb)
        any_dirty = jnp.any(self.dirty)
        lo = jnp.min(jnp.where(self.dirty, idx, nb))
        hi = jnp.max(jnp.where(self.dirty, idx + 1, 0))
        return (jnp.where(any_dirty, lo, 0).astype(jnp.int32),
                jnp.where(any_dirty, hi, 0).astype(jnp.int32))
